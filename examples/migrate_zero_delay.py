"""Zero-delay migration demo: move a staged LM job between two partitions
(sub-meshes) at a stage boundary by resharding its inter-stage activation.

Runs with 8 forced host devices (set before jax import) split into two
4-device partitions — the TPU-pod mechanism at laptop scale (DESIGN.md §2).

    PYTHONPATH=src python examples/migrate_zero_delay.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_reduced
from repro.models import build_model
from repro.serving.staging import make_lm_stage_fns, migrate


def main():
    devs = np.array(jax.devices())
    part_a = Mesh(devs[:4].reshape(4), ("data",))
    part_b = Mesh(devs[4:].reshape(4), ("data",))
    print(f"partition A: {[d.id for d in devs[:4]]}")
    print(f"partition B: {[d.id for d in devs[4:]]}")

    cfg = get_reduced("smollm-135m").replace(n_layers=8)
    model = build_model(cfg)
    params = model.init_params(0)
    stages = make_lm_stage_fns(model, n_stages=4)
    pos = jnp.arange(32, dtype=jnp.int32)
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (8, 32)))

    # replicate weights on both partitions up front (candidate partitions
    # pre-stage weights so migration only moves the activation)
    rep_a = NamedSharding(part_a, P())
    rep_b = NamedSharding(part_b, P())
    params_a = jax.device_put(params, rep_a)
    params_b = jax.device_put(params, rep_b)

    # run stages 0-1 on partition A
    x = jax.device_put(tokens, NamedSharding(part_a, P("data", None)))
    for i in (0, 1):
        x, _ = jax.jit(stages[i])(params_a, x, None, pos)
    jax.block_until_ready(x)

    # zero-delay migration at the stage boundary: reshard the activation
    t0 = time.perf_counter()
    x = migrate(x, NamedSharding(part_b, P("data", None, None)))
    jax.block_until_ready(x)
    mig_ms = (time.perf_counter() - t0) * 1000

    for i in (2, 3):
        x, _ = jax.jit(stages[i])(params_b, x, None, pos)
    jax.block_until_ready(x)

    # reference: whole model on partition A
    ref = jax.device_put(tokens, NamedSharding(part_a, P("data", None)))
    for i in range(4):
        ref, _ = jax.jit(stages[i])(params_a, ref, None, pos)

    err = float(jnp.max(jnp.abs(x - jax.device_put(ref, rep_b))))
    stage_ms = 50.0  # representative stage time at this scale
    print(f"\nmigration (activation reshard A->B): {mig_ms:.2f} ms")
    print(f"logits max |A-then-B minus all-A| = {err:.2e}  (bit-exact path)")
    print("no running program was interrupted: migration happened between "
          "stage programs — the paper's 'zero-delay' property (§I).")


if __name__ == "__main__":
    main()
