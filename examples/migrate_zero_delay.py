"""Zero-delay migration demo, both layers of the stack:

Act 1 — the *mechanism*: move a staged LM job between two partitions
(sub-meshes) at a stage boundary by resharding its inter-stage activation.
Runs with 8 forced host devices (set before jax import) split into two
4-device partitions — the TPU-pod mechanism at laptop scale (DESIGN.md §2).

Act 2 — the *policy*: the same property driven end-to-end through the
``repro.api`` facade — a context dies mid-run, DARIS re-runs Algorithm 1,
in-flight stages replay on surviving partitions, and a scale-out event
restores capacity, all without interrupting a running stage program.

Act 3 — *live elastic repartitioning*: the whole Eq. 9 geometry is
reshaped mid-run (``reconfigure_at``); queued work re-homes, in-flight
stages finish where they run and migrate at the next stage boundary, and
HP deadlines survive untouched.

    PYTHONPATH=src python examples/migrate_zero_delay.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_reduced
from repro.models import build_model
from repro.serving.staging import make_lm_stage_fns, migrate


def main():
    devs = np.array(jax.devices())
    part_a = Mesh(devs[:4].reshape(4), ("data",))
    part_b = Mesh(devs[4:].reshape(4), ("data",))
    print(f"partition A: {[d.id for d in devs[:4]]}")
    print(f"partition B: {[d.id for d in devs[4:]]}")

    cfg = get_reduced("smollm-135m").replace(n_layers=8)
    model = build_model(cfg)
    params = model.init_params(0)
    stages = make_lm_stage_fns(model, n_stages=4)
    pos = jnp.arange(32, dtype=jnp.int32)
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (8, 32)))

    # replicate weights on both partitions up front (candidate partitions
    # pre-stage weights so migration only moves the activation)
    rep_a = NamedSharding(part_a, P())
    rep_b = NamedSharding(part_b, P())
    params_a = jax.device_put(params, rep_a)
    params_b = jax.device_put(params, rep_b)

    # run stages 0-1 on partition A
    x = jax.device_put(tokens, NamedSharding(part_a, P("data", None)))
    for i in (0, 1):
        x, _ = jax.jit(stages[i])(params_a, x, None, pos)
    jax.block_until_ready(x)

    # zero-delay migration at the stage boundary: reshard the activation
    t0 = time.perf_counter()
    x = migrate(x, NamedSharding(part_b, P("data", None, None)))
    jax.block_until_ready(x)
    mig_ms = (time.perf_counter() - t0) * 1000

    for i in (2, 3):
        x, _ = jax.jit(stages[i])(params_b, x, None, pos)
    jax.block_until_ready(x)

    # reference: whole model on partition A
    ref = jax.device_put(tokens, NamedSharding(part_a, P("data", None)))
    for i in range(4):
        ref, _ = jax.jit(stages[i])(params_a, ref, None, pos)

    err = float(jnp.max(jnp.abs(x - jax.device_put(ref, rep_b))))
    stage_ms = 50.0  # representative stage time at this scale
    print(f"\nmigration (activation reshard A->B): {mig_ms:.2f} ms")
    print(f"logits max |A-then-B minus all-A| = {err:.2e}  (bit-exact path)")
    print("no running program was interrupted: migration happened between "
          "stage programs — the paper's 'zero-delay' property (§I).")


def scheduled_migration_demo():
    """Act 2: the same zero-delay property at the scheduler level, driven
    through the facade — fault at 2s, elastic scale-out at 3.5s."""
    from repro.api import HP, LP, FaultPlan, ServerConfig
    from repro.serving.profiles import device
    from repro.serving.requests import table2_taskset

    server = (ServerConfig.sim()
              .tasks(table2_taskset("resnet18"))
              .contexts(4).streams(1).oversubscribe(4.0)
              .device(device())
              .horizon_ms(5000.0).seed(0)
              .fail_context_at(0, 2000.0)
              .scale_out_at(3500.0)
              .build())
    m = server.run()
    s = m.summary()
    snap = server.snapshot()
    alive = [c["index"] for c in snap["contexts"] if c["alive"]]
    print(f"\nfault drill via repro.api: ctx0 died @2s, scale-out @3.5s")
    print(f"surviving contexts: {alive} | faults {s['faults']} "
          f"| migrations {s['migrations']}")
    print(f"HP DMR {s['dmr_hp']:.1%} (orphaned stages replayed at stage "
          f"granularity; HP stayed protected)")
    print(f"throughput {s['jps']:.0f} JPS across the fault window")


def elastic_reconfigure_demo():
    """Act 3: online repartitioning — 4x1 OS=4 reshaped to 6x1 OS=6 at
    2s and back down to 3 contexts at 3.5s, without draining."""
    from repro.api import ServerConfig
    from repro.serving.profiles import device
    from repro.serving.requests import table2_taskset

    server = (ServerConfig.sim()
              .tasks(table2_taskset("resnet18"))
              .contexts(4).streams(1).oversubscribe(4.0)
              .device(device())
              .horizon_ms(5000.0).seed(0)
              .reconfigure_at(2000.0, n_contexts=6, oversubscription=6.0)
              .reconfigure_at(3500.0, n_contexts=3)
              .build())
    m = server.run()
    s = m.summary()
    live = [c.index for c in server.scheduler.contexts if c.alive]
    print(f"\nelastic repartition via repro.api: 4 ctx -> 6 ctx @2s "
          f"-> 3 ctx @3.5s ({s['reconfigures']} reconfigures)")
    print(f"live contexts: {live} | migrations {s['migrations']} "
          f"| HP DMR {s['dmr_hp']:.1%} (zero-delay: in-flight stages "
          f"finished on retired lanes, moved at stage boundaries)")
    assert s["dmr_hp"] == 0.0, "HP deadlines must survive a reshape"


if __name__ == "__main__":
    main()
    scheduled_migration_demo()
    elastic_reconfigure_demo()
