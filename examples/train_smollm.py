"""Train a SmolLM-family model with the full training substrate:
synthetic pipeline, AdamW, remat, grad accumulation, checkpointing.

Default is a reduced ~6M-param config that loss-drops visibly on CPU in a
couple of minutes; --full uses the real 135M config (slow on CPU).

    PYTHONPATH=src python examples/train_smollm.py --steps 200
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import save_pytree
from repro.configs import get_config, get_reduced
from repro.data.pipeline import TokenPipeline
from repro.models import build_model
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="artifacts/ckpt/smollm")
    args = ap.parse_args()

    cfg = get_config("smollm-135m") if args.full else \
        get_reduced("smollm-135m").replace(n_layers=6, d_model=128,
                                           d_ff=384, vocab_size=4096)
    model = build_model(cfg)
    params = model.init_params(0)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} ({n/1e6:.1f}M params)")

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    opt = adamw_init(params, opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg, accum=args.accum))
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, seed=0)

    t0 = time.time()
    for i in range(args.steps):
        batch = {"tokens": jnp.asarray(pipe.next_batch()["tokens"])}
        params, opt, metrics = step_fn(params, opt, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    path = save_pytree(params, args.ckpt, step=args.steps)
    print(f"saved checkpoint -> {path}")


if __name__ == "__main__":
    main()
