"""Serving front-end smoke: daemon round-trip, SIGTERM crash-restart
durability, and deterministic journal replay — out of process.

Phase 1 starts the ops daemon (``python -m repro.serve daemon``) with
virtual time nearly frozen, acknowledges a burst of submissions, cancels
one, then kills the daemon with SIGTERM mid-traffic: the checkpoint is
written but nothing has finished. Phase 2 restarts the daemon on the same
journal + checkpoint; every acknowledged seq must reach a terminal state
under its ORIGINAL identity (the zero-lost contract), after which the
journal audit and an offline replay both pass.

    PYTHONPATH=src python examples/serve_daemon.py [--dir WORKDIR]

Exits non-zero on any violated contract (CI runs this as the daemon
smoke; the journal is uploaded as an artifact from WORKDIR).
"""
import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, "src")

from repro.serve import DarisClient, audit_zero_lost, read_journal

CONFIG = {
    "tasks": [
        {"dnn": "resnet18", "priority": "HP", "jps": 30.0},
        {"dnn": "unet", "priority": "LP", "jps": 10.0},
    ],
    "contexts": 2, "streams": 1, "oversubscribe": 2.0,
    "seed": 0, "noise": 0.0,
}


def spawn_daemon(cfg_path, sock, journal, ckpt, time_scale):
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serve", "daemon",
         "--config", cfg_path, "--socket", sock, "--journal", journal,
         "--checkpoint", ckpt, "--time-scale", str(time_scale)],
        env=env)
    c = DarisClient(sock)
    c.wait_up(timeout_s=30.0)
    return proc, c


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=None,
                    help="workdir (journal lands here); default: tmpdir")
    args = ap.parse_args()
    work = args.dir or tempfile.mkdtemp(prefix="daris-serve-")
    os.makedirs(work, exist_ok=True)
    cfg_path = os.path.join(work, "serve.json")
    sock = os.path.join(work, "daris.sock")
    journal = os.path.join(work, "journal.jsonl")
    ckpt = os.path.join(work, "ckpt.msgpack")
    with open(cfg_path, "w", encoding="utf-8") as f:
        json.dump(CONFIG, f)

    # ---- phase 1: acknowledge traffic, then die by SIGTERM ----------
    print("phase 1: daemon up (virtual time ~frozen), submitting...")
    proc, c = spawn_daemon(cfg_path, sock, journal, ckpt, time_scale=1e-7)
    seqs = []
    for i in range(6):
        r = c.submit("resnet18" if i % 2 else "unet",
                     tenant="teamA" if i % 3 else "teamB")
        print(f"  acked seq={r['seq']} status={r['status']}")
        seqs.append(r["seq"])
    cancelled_seq = seqs.pop()
    print(f"  cancel seq={cancelled_seq} ->",
          c.cancel(cancelled_seq)["status"])
    print(f"  SIGTERM pid={proc.pid} with {len(seqs)} jobs unfinished")
    proc.send_signal(signal.SIGTERM)
    assert proc.wait(timeout=30) == 0, "daemon did not exit cleanly"

    recs = read_journal(journal)
    owed = audit_zero_lost(recs)
    assert owed == sorted(seqs), \
        f"owed-after-crash mismatch: {owed} != {sorted(seqs)}"
    assert any(r["rec"] == "checkpoint" for r in recs), "no checkpoint"
    print(f"  journal owes {owed} across the restart — as it must\n")

    # ---- phase 2: restart, finish everything, drain -----------------
    print("phase 2: restart on same journal+checkpoint, fast clock...")
    proc, c = spawn_daemon(cfg_path, sock, journal, ckpt, time_scale=500.0)
    for seq in seqs:
        r = c.result(seq, timeout_s=60.0)
        print(f"  seq={seq} -> {r['status']} "
              f"(resp={r['response_ms']:.2f}ms virtual)")
        assert r["status"] in ("completed", "missed"), r
    summary = c.drain()["summary"]
    assert proc.wait(timeout=30) == 0
    print(f"  drained: jps_hp={summary['jps_hp']:.1f} "
          f"dmr_hp={summary['dmr_hp']:.4f}\n")

    # ---- audits: zero lost, deterministic replay --------------------
    for verb in (["audit", "--journal", journal],
                 ["replay", "--config", cfg_path, "--journal", journal]):
        rc = subprocess.call(
            [sys.executable, "-m", "repro.serve", *verb],
            env=dict(os.environ, PYTHONPATH="src"))
        assert rc == 0, f"{verb[0]} failed"
    print(f"zero acknowledged-but-lost jobs; journal: {journal}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
