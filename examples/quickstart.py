"""Quickstart: DARIS scheduling the paper's ResNet18 task set (Table II)
through the ``repro.api`` facade on the calibrated simulator. Runs in a
few seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.api import ServerConfig
from repro.serving.profiles import TABLE1, device
from repro.serving.requests import table2_taskset


def main():
    print("DARIS quickstart: ResNet18 task set (17 HP + 34 LP @ 30 JPS)")
    print(f"pure-batching upper baseline: {TABLE1['resnet18'][1]:.0f} JPS\n")
    for nc, ns, os_ in [(1, 6, 1.0), (6, 1, 1.0), (6, 1, 6.0), (4, 1, 4.0)]:
        server = (ServerConfig.sim()
                  .tasks(table2_taskset("resnet18"))
                  .contexts(nc).streams(ns).oversubscribe(os_)
                  .device(device())
                  .horizon_ms(6000.0).seed(0)
                  .build())
        s = server.run().summary()
        policy = "STR" if nc == 1 else "MPS"
        print(f"{policy} {nc}x{ns}_OS{os_:g}: {s['jps']:7.1f} JPS | "
              f"HP DMR {s['dmr_hp']:.1%} LP DMR {s['dmr_lp']:.1%} | "
              f"resp HP {s['resp_hp']['mean']:.1f}ms / LP "
              f"{s['resp_lp']['mean']:.1f}ms | migrations {s['migrations']}")
    print("\nOversubscription (OS=Nc) recovers capacity isolation strands;")
    print("HP deadline misses stay at zero (paper §VI-A).")


if __name__ == "__main__":
    main()
