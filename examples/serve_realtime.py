"""End-to-end driver: REAL JAX execution of staged CNNs under DARIS,
served through the ``repro.api`` facade.

Three DNN families (the paper's benchmarks, reduced size for CPU), staged
into 4 sub-tasks each, scheduled by the full DARIS stack — MRET estimation
from *measured* wall times, admission, priorities, migration — on wall-
clock time with one worker thread per lane. Identical scheduler, identical
drive loop as the simulator: only the backend differs.

    PYTHONPATH=src python examples/serve_realtime.py [--seconds 4]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.api import HP, LP, DeviceModel, ServerConfig
from repro.models.cnn import build_inception, build_resnet, build_unet
from repro.serving.engine import staged_cnn_taskspec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=float, default=4.0)
    ap.add_argument("--hw", type=int, default=32)
    args = ap.parse_args()

    print("building + calibrating staged CNNs (AFET measurement)...")
    rn = build_resnet(18, width=8)
    un = build_unet(width=8)
    iv = build_inception(width=8)
    specs = [
        staged_cnn_taskspec(rn, priority=HP, jps=12.0, input_hw=args.hw,
                            tag="-hp0"),
        staged_cnn_taskspec(rn, priority=LP, jps=12.0, input_hw=args.hw,
                            tag="-lp0"),
        staged_cnn_taskspec(un, priority=LP, jps=8.0, input_hw=args.hw,
                            tag="-lp0"),
        staged_cnn_taskspec(iv, priority=HP, jps=8.0, input_hw=args.hw,
                            tag="-hp0"),
    ]
    for s in specs:
        mret = sum(st.t_alone_ms for st in s.stages)
        print(f"  {s.name:18s} prio={'HP' if s.priority == HP else 'LP'} "
              f"measured t_alone={mret:6.1f}ms period={s.period_ms:.0f}ms")

    server = (ServerConfig.realtime()
              .tasks(specs)
              .contexts(2).streams(1).oversubscribe(2.0)
              .device(DeviceModel(n_units=2.0))
              .horizon_ms(args.seconds * 1000.0)
              .phase_offsets(False)
              .realtime_io(input_hw=args.hw)
              .build())
    print(f"\nserving for {args.seconds:.0f}s of wall clock...")
    m = server.run()
    s = m.summary()
    print(f"\ncompleted: HP {m.completed[HP]}  LP {m.completed[LP]} "
          f"({s['jps']:.1f} JPS)")
    print(f"deadline miss rate: HP {s['dmr_hp']:.1%}  LP {s['dmr_lp']:.1%}")
    print(f"response ms: HP mean {s['resp_hp']['mean']:.1f} "
          f"p95 {s['resp_hp']['p95']:.1f} | LP mean "
          f"{s['resp_lp']['mean']:.1f} p95 {s['resp_lp']['p95']:.1f}")
    print(f"rejected (admission): LP {s['rejected_lp']}  HP {s['rejected_hp']}")
    print(f"skipped releases (stall protection): {s['skipped_releases']}")
    print("\nMRET adapted from measured stage times (ws=5); HP responses "
          "should sit well below LP.")


if __name__ == "__main__":
    main()
