import os
import sys

# tests see the single real CPU device; only the dry-run subprocess test
# forces a bigger host-device count (in its own process)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
