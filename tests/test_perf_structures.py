"""Tests for the vectorized/incremental engine hot-path structures.

Covers the exactness contracts the perf work leans on:
  * incremental re-prediction (skip heap re-push while the recomputed
    finish time is unchanged) == full recompute-and-repush, bit for bit;
  * the scalar and NumPy rate kernels produce identical bits;
  * LaneMap's free/busy indexes stay coherent under plain assignment;
  * MRET memoization is invalidated by observation.
"""
import numpy as np
import pytest

from repro.core.scheduler import DarisScheduler, LaneMap, SchedulerConfig
from repro.core.task import HP, LP, Job, StageInstance, StageProfile, Task, TaskSpec
from repro.runtime.arrivals import PeriodicArrival
from repro.runtime.backend import SimBackend
from repro.runtime.contention import ContentionModel, DeviceModel
from repro.runtime.engine_core import EngineCore


def _random_taskset(rng, n_tasks=6):
    specs = []
    for i in range(n_tasks):
        stages = [StageProfile(f"t{i}/s{j}",
                               float(rng.uniform(0.3, 3.0)),
                               float(rng.uniform(10, 68)),
                               float(rng.uniform(0.1, 0.8)),
                               batch_gain=float(rng.uniform(1.0, 3.0)))
                  for j in range(int(rng.integers(1, 5)))]
        specs.append(TaskSpec(name=f"t{i}",
                              period_ms=float(rng.uniform(15, 80)),
                              priority=HP if rng.random() < 0.4 else LP,
                              stages=stages))
    return specs


def _run(specs, cfg, backend, horizon=1500.0, seed=7):
    sched = DarisScheduler(specs, cfg, DeviceModel())
    core = EngineCore(
        sched, backend, horizon_ms=horizon, seed=seed,
        arrivals={t.index: PeriodicArrival(phase_ms="random")
                  for t in sched.tasks})
    return core.run()


def _fingerprint(m):
    return (m.completed, m.missed, m.rejected, m.unfinished,
            m.migrations, m.stragglers, m.batch_hist,
            tuple(m.response_ms[HP]), tuple(m.response_ms[LP]))


@pytest.mark.parametrize("seed", range(5))
def test_incremental_repredict_matches_full_recompute(seed):
    """The incremental engine (epoch-dirty rates + skip-unchanged-eta)
    must be indistinguishable — bitwise — from recomputing and re-pushing
    every lane's prediction on every running-set change."""
    rng = np.random.default_rng(seed)
    specs = _random_taskset(rng)
    nc = int(rng.integers(1, 5))
    cfg = SchedulerConfig(n_contexts=nc, n_streams=int(rng.integers(1, 4)),
                          oversubscription=float(rng.uniform(1.0, nc)))
    fresh = lambda: [TaskSpec(s.name, s.period_ms, s.priority,
                              list(s.stages)) for s in specs]
    m_inc = _run(fresh(), cfg, SimBackend())
    m_full = _run(fresh(), cfg, SimBackend(full_repredict=True))
    assert _fingerprint(m_inc) == _fingerprint(m_full)


def test_incremental_with_batching_matches_full():
    from repro.core.batching import BatchPolicy
    rng = np.random.default_rng(11)
    specs = _random_taskset(rng, n_tasks=4)
    cfg = SchedulerConfig(n_contexts=2, n_streams=1, oversubscription=2.0,
                          batch_policy=BatchPolicy(max_batch=4))
    fresh = lambda: [TaskSpec(s.name, s.period_ms, s.priority,
                              list(s.stages)) for s in specs]
    m_inc = _run(fresh(), cfg, SimBackend())
    m_full = _run(fresh(), cfg, SimBackend(full_repredict=True))
    assert _fingerprint(m_inc) == _fingerprint(m_full)


def test_predict_eps_relaxes_but_still_completes():
    """predict_eps > 0 trades prediction freshness for fewer heap pushes;
    it must still complete comparable work (sanity, not bit-equality)."""
    rng = np.random.default_rng(3)
    specs = _random_taskset(rng)
    cfg = SchedulerConfig(n_contexts=2, n_streams=2, oversubscription=2.0)
    fresh = lambda: [TaskSpec(s.name, s.period_ms, s.priority,
                              list(s.stages)) for s in specs]
    m0 = _run(fresh(), cfg, SimBackend())
    m1 = _run(fresh(), cfg, SimBackend(predict_eps=1e-6))
    total0 = sum(m0.completed.values())
    total1 = sum(m1.completed.values())
    assert total1 > 0
    assert abs(total1 - total0) <= max(3, 0.05 * total0)


@pytest.mark.parametrize("seed", range(8))
def test_rates_scalar_matches_vector_kernel(seed):
    """Scalar fast path and NumPy kernel are the same float program."""
    rng = np.random.default_rng(seed)
    cm = ContentionModel(DeviceModel())
    m = int(rng.integers(1, 40))
    u = [float(rng.uniform(1.0, 40.0)) for _ in range(m)]
    ns = [float(rng.uniform(6.0, 68.0)) for _ in range(m)]
    mf = [float(rng.uniform(0.05, 0.9)) for _ in range(m)]
    scalar = cm._rates_scalar(list(u), list(ns), list(mf))
    vector = cm.rates_arrays(np.array(u), np.array(ns),
                             np.array(mf)).tolist()
    assert scalar == vector          # bitwise: no tolerance


def test_rates_seq_dispatch_consistency():
    """rates_seq must agree with both paths regardless of which side of
    VECTOR_MIN the input lands on."""
    rng = np.random.default_rng(42)
    cm = ContentionModel(DeviceModel())
    for m in (1, 2, cm.VECTOR_MIN - 1, cm.VECTOR_MIN, 3 * cm.VECTOR_MIN):
        u = [float(rng.uniform(1.0, 40.0)) for _ in range(m)]
        ns = [float(rng.uniform(6.0, 68.0)) for _ in range(m)]
        mf = [float(rng.uniform(0.05, 0.9)) for _ in range(m)]
        assert cm.rates_seq(list(u), list(ns), list(mf)) == \
            cm._rates_scalar(list(u), list(ns), list(mf))


def test_lane_map_indexes_stay_coherent():
    lm = LaneMap()
    for c in range(2):
        for s in range(2):
            lm[(c, s)] = None
    assert lm.free_lanes() == [(0, 0), (0, 1), (1, 0), (1, 1)]

    spec = TaskSpec("t", 30.0, HP,
                    [StageProfile("t/s0", 1.0, 30.0, 0.3)])
    task = Task(spec=spec, index=0)
    inst = StageInstance(job=Job(task=task, release_ms=0.0),
                         enqueue_ms=0.0, virtual_deadline_ms=10.0)
    lm[(0, 1)] = inst
    assert lm.free_lanes() == [(0, 0), (1, 0), (1, 1)]
    assert lm.busy_in_ctx(0) == [((0, 1), inst)]
    lm[(0, 1)] = None
    assert lm.busy_in_ctx(0) == []
    assert (0, 1) in set(lm.free_lanes())

    lm[(1, 0)] = inst
    lm.retire_ctx(1)
    assert lm.free_lanes() == [(0, 0), (0, 1)]
    lm[(1, 0)] = None                  # harvest after death
    assert lm.free_lanes() == [(0, 0), (0, 1)]   # stays retired


def test_mret_memoization_invalidates_on_observe():
    from repro.core.mret import TaskMret
    tm = TaskMret([2.0, 3.0], ws=2)
    assert tm.task_mret() == 5.0
    tm.observe(0, 7.0)
    assert tm.stage_mret(0) == 7.0
    assert tm.task_mret() == 10.0
    tm.observe(0, 1.0)
    tm.observe(0, 0.5)                 # window of 2 -> max(1.0, 0.5)
    assert tm.stage_mret(0) == 1.0
    tm.invalidate()
    assert tm.task_mret() == 4.0
