"""repro.api facade: builder validation, sim-vs-real backend parity,
Poisson arrival determinism, submit()-path admission, release clamping."""
import pytest

from repro.api import (HP, LP, DeviceModel, FaultPlan, PeriodicArrival,
                       PoissonArrival, ServerConfig, StageProfile,
                       SubmitHandle, TaskSpec, TraceArrival)


def make_spec(name, prio, stage_times, period_ms, n_sat=1.0):
    return TaskSpec(
        name=name, period_ms=period_ms, priority=prio,
        stages=[StageProfile(f"{name}/s{j}", t, n_sat=n_sat, mem_frac=0.0,
                             overhead_ms=0.0)
                for j, t in enumerate(stage_times)])


def ideal_device():
    """Device on which one stage per lane runs at exactly t_alone speed."""
    return DeviceModel(n_units=4.0, bubble=0.0, l2_pressure=0.0)


# ---------------------------------------------------------------- builder
def test_builder_validates_horizon_and_geometry():
    spec = make_spec("t", HP, [1.0], 10.0)
    with pytest.raises(ValueError, match="horizon"):
        ServerConfig.sim().task(spec).horizon_ms(0.0).build()
    with pytest.raises(ValueError, match="context"):
        ServerConfig.sim().task(spec).contexts(0).build()
    with pytest.raises(ValueError, match="oversubscription"):
        ServerConfig.sim().task(spec).oversubscribe(0.5).build()


def test_builder_rejects_noise_on_realtime_backend():
    spec = make_spec("t", HP, [1.0], 10.0)
    with pytest.raises(ValueError, match="sim backend"):
        ServerConfig.realtime().task(spec).noise(0.1).build()


def test_builder_rejects_arrival_for_unknown_task():
    spec = make_spec("t", HP, [1.0], 10.0)
    with pytest.raises(ValueError, match="unknown task"):
        (ServerConfig.sim().task(spec)
         .arrival("nope", PeriodicArrival()).build())


def test_server_runs_once():
    srv = (ServerConfig.sim().task(make_spec("t", HP, [1.0], 10.0))
           .contexts(1).streams(1).oversubscribe(1.0)
           .horizon_ms(50.0).build())
    srv.run()
    with pytest.raises(RuntimeError, match="already"):
        srv.run()


# ----------------------------------------------------- sim vs real parity
def _parity_config(kind):
    # stage times chosen so every completion is >= 10ms away from any other
    # event: wall-clock jitter cannot reorder the decision sequence
    specs = [make_spec("hp-a", HP, [40.0, 25.0], 250.0),
             make_spec("lp-b", LP, [55.0, 35.0], 300.0)]
    cfg = ServerConfig.sim() if kind == "sim" else ServerConfig.realtime()
    cfg = (cfg.tasks(specs)
           .contexts(2).streams(1).oversubscribe(1.0)
           .device(ideal_device())
           .horizon_ms(580.0).phase_offsets(False).seed(0)
           .record_decisions())
    if kind == "sim":
        cfg = cfg.noise(0.0)
    return cfg.build()


def test_sim_and_realtime_backends_make_identical_decisions():
    """The acceptance contract of the facade redesign: on a fixed-time task
    set both backends must produce the same admit/dispatch/finish sequence
    (payload-less stages run as sleeps on the real backend)."""
    sim = _parity_config("sim")
    m_sim = sim.run()
    real = _parity_config("realtime")
    m_real = real.run()
    assert sim.decisions == real.decisions
    assert len(sim.decisions) > 20          # releases actually happened
    assert m_sim.completed == m_real.completed
    assert m_sim.rejected == m_real.rejected


# ------------------------------------------------------- poisson arrivals
def _poisson_run(seed):
    srv = (ServerConfig.sim()
           .task(make_spec("p0", HP, [5.0], 50.0))
           .task(make_spec("p1", LP, [5.0], 50.0))
           .contexts(2).streams(1).oversubscribe(1.0)
           .device(ideal_device())
           .open_loop(rate_jps=40.0, seed=seed)
           .horizon_ms(1000.0).seed(3).record_decisions()
           .build())
    m = srv.run()
    return tuple(srv.decisions), m.completed[HP], m.completed[LP]


def test_poisson_arrivals_deterministic_under_fixed_seed():
    a = _poisson_run(seed=7)
    b = _poisson_run(seed=7)
    assert a == b
    assert a[1] + a[2] > 0
    c = _poisson_run(seed=8)
    assert c != a                      # the seed actually drives the trace


# ----------------------------------------------------------- submit path
def test_submit_admission_and_rejection():
    """Eq. 12 through the facade: U_r = 1 - 0.7; a 0.5-utilization LP job
    must be rejected, a 0.1-utilization one admitted and completed."""
    srv = (ServerConfig.sim()
           .task(make_spec("hog", HP, [70.0], 100.0))
           .contexts(1).streams(1).oversubscribe(1.0)
           .device(DeviceModel(n_units=1.0, bubble=0.0, l2_pressure=0.0))
           .horizon_ms(500.0).phase_offsets(False).noise(0.0)
           .build())
    big = srv.submit(make_spec("big-lp", LP, [50.0], 100.0), at_ms=10.0)
    small = srv.submit(make_spec("small-lp", LP, [10.0], 100.0), at_ms=20.0)
    m = srv.run()
    assert big.status == SubmitHandle.REJECTED
    assert small.status == SubmitHandle.COMPLETED
    assert small.response_ms > 0
    assert m.rejected[LP] == 1


def test_drain_completes_trace_workload():
    """drain() runs until submitted work finishes instead of spinning to
    the horizon."""
    srv = (ServerConfig.sim()
           .contexts(1).streams(1).oversubscribe(1.0)
           .device(ideal_device())
           .horizon_ms(10_000.0).noise(0.0)
           .build())
    handles = [srv.submit(make_spec(f"j{i}", LP, [5.0], 100.0), at_ms=i * 2.0)
               for i in range(5)]
    srv.drain()
    assert all(h.status == SubmitHandle.COMPLETED for h in handles)
    assert srv.core.now_ms() < 10_000.0      # stopped at idle, not horizon


def test_snapshot_shape():
    srv = (ServerConfig.sim().task(make_spec("t", HP, [1.0], 10.0))
           .contexts(2).streams(1).oversubscribe(1.0)
           .horizon_ms(100.0).build())
    srv.run()
    snap = srv.snapshot()
    assert {"now_ms", "contexts", "queue_depth", "lanes_busy",
            "active_jobs", "completed", "migrations"} <= set(snap)
    assert len(snap["contexts"]) == 2


# ------------------------------------------------------- release clamping
def test_periodic_arrival_clamps_release_storms():
    """After a stall past whole periods the next release is clamped to now
    and the fully-passed periods are reported as skipped (the
    release-storm fix)."""
    proc = PeriodicArrival(period_ms=10.0)
    proc.start(make_spec("t", HP, [1.0], 10.0), None)
    # no stall: strict periodicity, nothing skipped
    assert proc.next_after(20.0, 20.0) == (30.0, 0)
    # loop stalled from t=20 to t=55: releases at 30, 40, 50 would have
    # burst; instead we fire at 55 and report 2 fully-passed periods
    nxt, skipped = proc.next_after(20.0, 55.0)
    assert nxt == 55.0
    assert skipped == 2


def test_trace_arrival_replays_recorded_times():
    srv = (ServerConfig.sim()
           .task(make_spec("t", HP, [2.0], 100.0),
                 arrival=TraceArrival([5.0, 17.0, 42.0]))
           .contexts(1).streams(1).oversubscribe(1.0)
           .device(ideal_device())
           .horizon_ms(200.0).noise(0.0)
           .build())
    m = srv.run()
    assert m.completed[HP] == 3
    assert m.response_ms[HP] == pytest.approx([2.0, 2.0, 2.0])
