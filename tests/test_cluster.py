"""Cluster layer (repro/cluster): global admission, cross-GPU zero-delay
migration, heterogeneous devices, whole-GPU elasticity.

The anchor test is single-GPU equivalence: a 1-GPU cluster must
reproduce the plain single-device server BIT-identically (same RNG draw
order, same placement, same admission floats) — the cluster layer is a
pure generalization, not a new scheduler.
"""
from __future__ import annotations

import pytest

from repro.api import ServerConfig, TraceArrival
from repro.cluster import DEVICE_PRESETS, ClusterScheduler, resolve_device
from repro.core.batching import BatchPolicy
from repro.core.scheduler import SchedulerConfig
from repro.core.task import HP, LP, StageProfile, TaskSpec
from repro.runtime.contention import DeviceModel
from repro.serving.profiles import device
from repro.serving.requests import table2_taskset


def _spec(name, period=40.0, priority=LP, t_alone=2.0):
    return TaskSpec(name=name, period_ms=period, priority=priority,
                    stages=[StageProfile(name=f"{name}/s0",
                                         t_alone_ms=t_alone,
                                         n_sat=20.0, mem_frac=0.3),
                            StageProfile(name=f"{name}/s1",
                                         t_alone_ms=t_alone,
                                         n_sat=20.0, mem_frac=0.3)])


def _cluster_cfg(n_gpus, specs, horizon=800.0, nc=4, os_=4.0, **kw):
    return (ServerConfig.cluster(n_gpus, **kw)
            .tasks(specs)
            .contexts(nc).streams(1).oversubscribe(os_)
            .device(device())
            .horizon_ms(horizon).seed(0))


class TestSingleGpuEquivalence:
    def test_one_gpu_cluster_is_bit_identical_to_single(self):
        specs = table2_taskset("resnet18")
        single = (ServerConfig.sim().tasks(specs)
                  .contexts(6).streams(1).oversubscribe(6.0)
                  .device(device()).horizon_ms(600.0).seed(0).build())
        clustered = _cluster_cfg(1, specs, horizon=600.0,
                                 nc=6, os_=6.0).build()
        m1, mc = single.run(), clustered.run()
        assert m1.completed == mc.completed
        assert m1.missed == mc.missed
        assert m1.rejected == mc.rejected
        assert m1.migrations == mc.migrations
        # bit-exact: every response time, in completion order
        assert m1.response_ms == mc.response_ms

    def test_one_gpu_cluster_placement_matches_single(self):
        specs = table2_taskset("unet")
        cfg = SchedulerConfig(n_contexts=4, n_streams=1,
                              oversubscription=4.0)
        from repro.core.scheduler import DarisScheduler
        single = DarisScheduler(list(specs), cfg)
        cluster = ClusterScheduler(list(specs),
                                   SchedulerConfig(n_contexts=4, n_streams=1,
                                                   oversubscription=4.0),
                                   n_gpus=1)
        for ts, tc in zip(single.tasks, cluster.tasks):
            assert ts.name == tc.name
            assert tc.ctx == (0, ts.ctx)          # namespaced, same slot
            assert ts.fixed_ctx == tc.fixed_ctx


class TestConstruction:
    def test_workers_share_one_namespace(self):
        sched = ClusterScheduler([_spec("a"), _spec("b")],
                                 SchedulerConfig(n_contexts=2), n_gpus=3)
        for w in sched.workers.values():
            assert w.lanes is sched.lanes
            assert w.queues is sched.queues
            assert w.active_jobs is sched.active_jobs
        # 3 devices x 2 contexts x 1 stream
        assert len(sched.lanes) == 6
        assert {k[0][0] for k in sched.lanes} == {0, 1, 2}

    def test_hp_first_placement_spreads_devices(self):
        specs = table2_taskset("resnet18")
        sched = ClusterScheduler(list(specs),
                                 SchedulerConfig(n_contexts=4,
                                                 oversubscription=4.0),
                                 n_gpus=4)
        hp_per_dev = {d: sum(1 for t in w.tasks if t.priority == HP)
                      for d, w in sched.workers.items()}
        # 17 HP tasks over 4 devices: no device gets more than ceil+1
        assert max(hp_per_dev.values()) - min(hp_per_dev.values()) <= 1
        for t in sched.tasks:
            if t.priority == HP:
                assert t.fixed_ctx

    def test_heterogeneous_placement_prefers_fast_devices(self):
        specs = table2_taskset("resnet18")
        sched = ClusterScheduler(
            list(specs), SchedulerConfig(n_contexts=4, oversubscription=4.0),
            n_gpus=4, device_models=["a100", "v100", "rtx2080ti", "l4"])
        n = {d: len(w.tasks) for d, w in sched.workers.items()}
        # task counts must be ordered by speed factor (2.1 > 1.3 > 1.0 > 0.8)
        assert n[0] > n[1] > n[2] >= n[3]

    def test_device_presets_resolve(self):
        assert resolve_device("a100").speed == pytest.approx(2.1)
        # the speed=1.0 preset IS the calibration device: same issue-gap
        # waste as every other figure's reference
        assert resolve_device("rtx2080ti").bubble == device().bubble
        dm = DeviceModel(n_units=10.0, name="custom", speed=3.0)
        assert resolve_device(dm) is dm
        with pytest.raises(ValueError, match="unknown device preset"):
            resolve_device("h100000")

    def test_validation(self):
        with pytest.raises(ValueError, match="n_gpus"):
            ServerConfig.cluster(0).task(_spec("a")).build()
        with pytest.raises(ValueError, match="transfer_ms"):
            ServerConfig.cluster(2, transfer_ms=-1.0).task(_spec("a")).build()
        with pytest.raises(ValueError, match="fail_device_at"):
            (ServerConfig.sim().task(_spec("a"))
             .fail_device_at(0, 10.0).build())
        with pytest.raises(ValueError, match="n_gpus"):
            (ServerConfig.sim().task(_spec("a"))
             .reconfigure_at(10.0, n_gpus=2).build())
        # cluster context keys are (device, k) tuples: a bare int key
        # must be rejected at build time, not explode mid-run
        with pytest.raises(ValueError, match=r"\(device, context\) tuple"):
            (ServerConfig.cluster(2).task(_spec("a"))
             .fail_context_at(0, 10.0).build())
        with pytest.raises(ValueError, match="out of range"):
            (ServerConfig.cluster(2).task(_spec("a"))
             .fail_context_at((5, 0), 10.0).build())
        # context index past the build-time shape with no reshape planned
        with pytest.raises(ValueError, match="context 9 out of range"):
            (ServerConfig.cluster(2).task(_spec("a"))
             .fail_context_at((0, 9), 10.0).build())
        # losing a 1-GPU cluster's only device is certain death — reject
        # at build unless the fleet can grow first
        with pytest.raises(ValueError, match="1-GPU cluster"):
            (ServerConfig.cluster(1).task(_spec("a"))
             .fail_device_at(0, 10.0).build())
        (ServerConfig.cluster(1).task(_spec("a"))
         .reconfigure_at(5.0, n_gpus=2).fail_device_at(0, 10.0).build())
        # same certain death via last-context escalation
        with pytest.raises(ValueError, match="1-context cluster"):
            (ServerConfig.cluster(1).task(_spec("a")).contexts(1)
             .fail_context_at((0, 0), 10.0).build())
        (ServerConfig.cluster(1).task(_spec("a")).contexts(1)
         .reconfigure_at(5.0, n_gpus=2).fail_context_at((0, 0), 10.0)
         .build())
        # grow-then-kill-a-grown-GPU is a legitimate chaos plan: ids
        # past the build-time size are valid once the fleet can grow
        (ServerConfig.cluster(4).task(_spec("a"))
         .reconfigure_at(100.0, n_gpus=6).fail_device_at(5, 200.0)
         .build())
        # ...but a lone SHRINK can't mint new ids: keep the range check
        with pytest.raises(ValueError, match="out of range"):
            (ServerConfig.cluster(4).task(_spec("a"))
             .reconfigure_at(100.0, n_gpus=2).fail_device_at(9, 200.0)
             .build())
        # shrink-then-regrow mints fresh ids again
        (ServerConfig.cluster(4).task(_spec("a"))
         .reconfigure_at(100.0, n_gpus=2).reconfigure_at(200.0, n_gpus=4)
         .fail_device_at(5, 300.0).build())
        # a monotone shrink plan never mints ids: range check stays on
        with pytest.raises(ValueError, match="out of range"):
            (ServerConfig.cluster(4).task(_spec("a"))
             .reconfigure_at(300.0, n_gpus=3).reconfigure_at(600.0, n_gpus=2)
             .fail_device_at(9, 800.0).build())
        # scale_out_at (ADD_CTX) also mints context indices past the
        # build-time shape
        (ServerConfig.cluster(2).task(_spec("a")).contexts(2)
         .scale_out_at(100.0).fail_context_at((0, 2), 500.0).build())

    def test_fail_context_tuple_key_works_on_cluster(self):
        specs = table2_taskset("resnet18", load_scale=0.4)
        srv = (_cluster_cfg(2, specs, horizon=600.0, nc=2, os_=2.0)
               .fail_context_at((0, 0), 200.0).build())
        m = srv.run()
        assert m.faults == 1
        assert m.missed[HP] == 0
        assert not srv.scheduler.contexts[(0, 0)].alive


class TestFailureAndMigration:
    def test_fail_device_replaces_all_tasks_hp_first(self):
        specs = table2_taskset("resnet18", load_scale=0.5)
        srv = (_cluster_cfg(4, specs, horizon=1200.0)
               .fail_device_at(1, 400.0).build())
        moved = len(srv.scheduler.workers[1].tasks)  # before run: placed
        assert moved > 0
        m = srv.run()
        assert m.faults == 1
        assert m.missed[HP] == 0
        # every task homed on device 1 migrated to a survivor
        assert len(srv.scheduler.workers[1].tasks) == 0
        assert m.migrations > 0
        assert 1 not in srv.scheduler.live_devices()
        for t in srv.scheduler.tasks:
            assert t.ctx[0] != 1

    def test_fail_device_completions_continue_on_survivors(self):
        specs = table2_taskset("resnet18", load_scale=0.5)
        srv = (_cluster_cfg(4, specs, horizon=1200.0)
               .fail_device_at(0, 300.0).build())
        m = srv.run()
        dead = m.per_device[0]["completed"]
        live = {d: s["completed"] for d, s in m.per_device.items() if d != 0}
        # the dead device stopped early; survivors absorbed its share
        assert all(sum(c.values()) > sum(dead.values())
                   for c in live.values())

    def test_all_devices_failed_raises(self):
        sched = ClusterScheduler([_spec("a")], SchedulerConfig(n_contexts=1),
                                 n_gpus=1)
        # rejected BEFORE any mutation: the fleet is left untouched
        with pytest.raises(RuntimeError, match="last live device"):
            sched.fail_device(0, 0.0)
        assert sched.live_devices() == [0]

    def test_fail_context_escalates_on_last_context(self):
        sched = ClusterScheduler([_spec("a")], SchedulerConfig(n_contexts=1),
                                 n_gpus=2)
        sched.fail_context((0, 0), now=0.0)   # device 0's only context
        assert 0 not in sched.live_devices()
        assert all(t.ctx[0] == 1 for t in sched.tasks)

    def test_cross_device_admission_fallback(self):
        # one tiny device drowning in LP load + one idle device: releases
        # the home device cannot admit must migrate across, not reject
        specs = [_spec(f"lp{i}", period=6.0, t_alone=2.5) for i in range(8)]
        srv = _cluster_cfg(2, specs, horizon=400.0, nc=1,
                           os_=1.0).build()
        m = srv.run()
        sched = srv.scheduler
        assert m.migrations > 0
        devs = {t.ctx[0] for t in sched.tasks}
        assert devs == {0, 1}

    def test_transfer_cost_charged_on_cross_device_dispatch(self):
        sched = ClusterScheduler([_spec("a")], SchedulerConfig(n_contexts=1),
                                 n_gpus=2, transfer_ms=3.0)
        task = sched.tasks[0]
        job = sched.on_release(task, 0.0)
        assert job is not None
        home = task.ctx
        inst = sched.next_for_lane(home, 0.0)
        assert inst is not None and inst.transfer_ms == 0.0   # stage 0: local
        # state location commits at COMPLETION, not dispatch
        assert job.job_id not in sched._state_dev
        inst.lane = (home, 0)
        done = sched.on_stage_finish(inst, 1.0, 1.0)   # 2-stage spec
        assert done is None
        assert sched._state_dev[job.job_id] == home[0]
        # re-home the queued stage-1 instance to the other device
        other = next(c.index for c in sched.live_contexts()
                     if c.index[0] != home[0])
        inst2 = sched.queues[home].pop()
        job.ctx = other
        sched.queues[other].push(inst2)
        inst3 = sched.next_for_lane(other, 2.0)
        assert inst3 is inst2
        assert inst3.transfer_ms == 3.0
        assert sched.transfers == 1
        # a killed/cancelled transfer stage never moved the state: its
        # replay pays the charge again
        sched.queues[other].push(inst3)
        inst4 = sched.next_for_lane(other, 3.0)
        assert inst4 is inst3 and inst4.transfer_ms == 3.0
        assert sched.transfers == 2
        assert sched._state_dev[job.job_id] == home[0]   # still not moved

    def test_migration_eta_charges_transfer_only_with_remote_state(self):
        # the surcharge must mirror next_for_lane's rule exactly: pay
        # when the job holds inter-stage state on another device, never
        # for a fresh release (stage 0 materializes where it first runs)
        sched = ClusterScheduler([_spec("a")], SchedulerConfig(n_contexts=2),
                                 n_gpus=2, transfer_ms=5.0)
        src = (0, 0)
        base = sched.workers[1].predicted_finish((1, 0), 0.0)
        assert sched.migration_eta((1, 0), 0.0, src) == pytest.approx(base)
        task = sched.tasks[0]
        job = sched.on_release(task, 0.0)
        assert sched.migration_eta((1, 0), 0.0, src,
                                   job) == pytest.approx(base)
        sched._state_dev[job.job_id] = 0       # a stage completed on dev 0
        assert sched.migration_eta((1, 0), 0.0, src,
                                   job) == pytest.approx(base + 5.0)
        # the device already holding the state charges nothing
        home = sched.workers[0].predicted_finish((0, 1), 0.0)
        assert sched.migration_eta((0, 1), 0.0, src,
                                   job) == pytest.approx(home)

    def test_predicted_finish_uses_device_units_for_busy_lanes(self):
        # work_done accrues in device-local wall ms (SimBackend.launch
        # divides work by speed), so the remaining-work estimate must
        # put MRET in device units BEFORE subtracting — dividing the
        # difference afterwards makes fast devices look backed up
        fast = DeviceModel(speed=2.0, name="fast")
        sched = ClusterScheduler([_spec("a", period=40.0)],
                                 SchedulerConfig(n_contexts=1),
                                 n_gpus=1, device_models=[fast])
        task = sched.tasks[0]
        assert sched.on_release(task, 0.0) is not None
        k = task.ctx
        inst = sched.next_for_lane(k, 0.0)
        lane = (k, 0)
        inst.lane = lane
        sched.lanes[lane] = inst
        w = sched.workers[0]
        mret_dev = inst.smret.value() * inst.cost_b / fast.speed
        inst.work_done = 0.8 * mret_dev          # 80% done, device units
        ns = max(w.contexts[k].n_streams, 1)
        assert w.predicted_finish(k, 0.0) == pytest.approx(
            0.2 * mret_dev / ns)

    def test_retired_key_fault_does_not_escalate(self):
        # a fault aimed at an already-retired (draining) context must
        # not take the device's healthy survivor down with it
        sched = ClusterScheduler([_spec("a")], SchedulerConfig(n_contexts=2),
                                 n_gpus=2)
        sched.reconfigure(0.0, n_contexts=1)   # retires (d,0),(d,1) -> (d,2)
        assert sched.fault_cancel_keys((0, 0)) == [(0, 0)]
        sched.fail_context((0, 0), now=1.0)    # retired key
        assert 0 in sched.live_devices()
        assert sched.workers[0].contexts[(0, 2)].alive
        # the actual last LIVE context still escalates
        assert set(sched.fault_cancel_keys((0, 2))) == {(0, 0), (0, 1),
                                                        (0, 2)}
        sched.fail_context((0, 2), now=2.0)
        assert 0 not in sched.live_devices()

    def test_planned_fault_on_unminted_context_is_skipped(self):
        # scale_out picks the least-loaded device, so a planned context
        # fault can name a key that never materialized — skip, don't
        # abort (direct scheduler calls still get the ValueError)
        specs = table2_taskset("resnet18", load_scale=0.4)
        srv = (_cluster_cfg(2, specs, horizon=500.0, nc=2, os_=2.0)
               .scale_out_at(100.0).fail_context_at((0, 5), 300.0)
               .build())
        m = srv.run()
        assert m.faults == 0
        assert sum(m.completed.values()) > 0

    def test_ctx_fault_on_dead_device_not_counted(self):
        # a planned context fault landing after its device was shrunk
        # away is a no-op and must not count into metrics.faults
        specs = table2_taskset("resnet18", load_scale=0.4)
        srv = (_cluster_cfg(2, specs, horizon=500.0, nc=2, os_=2.0)
               .reconfigure_at(150.0, n_gpus=1)    # retires device 1
               .fail_context_at((1, 0), 300.0)
               .build())
        m = srv.run()
        assert m.faults == 0
        assert sum(m.completed.values()) > 0

    def test_escalating_ctx_fault_on_last_survivor_is_skipped(self):
        # a context fault whose escalation would kill the fleet's sole
        # survivor must skip like FAIL_DEV does, not abort the run
        specs = table2_taskset("resnet18", load_scale=0.4)
        srv = (_cluster_cfg(1, specs, horizon=500.0, nc=1, os_=1.0)
               .fail_context_at((0, 0), 200.0)
               .reconfigure_at(400.0, n_contexts=2)   # makes build legal
               .build())
        m = srv.run()
        assert m.faults == 0
        assert srv.scheduler.live_devices() == [0]
        assert sum(m.completed.values()) > 0

    def test_planned_fault_on_last_survivor_is_skipped(self):
        # a whole-GPU shrink can leave the planned victim as the sole
        # survivor; the fault must skip, not abort the run
        specs = table2_taskset("resnet18", load_scale=0.4)
        srv = (_cluster_cfg(2, specs, horizon=500.0, nc=2, os_=2.0)
               .reconfigure_at(150.0, n_gpus=1)    # retires device 1
               .fail_device_at(0, 300.0)
               .build())
        m = srv.run()
        assert m.faults == 0                       # skipped, not fired
        assert srv.scheduler.live_devices() == [0]
        assert sum(m.completed.values()) > 0

    def test_fail_unknown_context_key_raises_cleanly(self):
        # reconfigure mints fresh context indices, so bad keys can only
        # be caught mid-run — but with a diagnosable error
        sched = ClusterScheduler([_spec("a")], SchedulerConfig(n_contexts=2),
                                 n_gpus=2)
        with pytest.raises(ValueError, match="unknown context key"):
            sched.fail_context((0, 99), now=0.0)

    def test_fault_cancel_keys_escalation_covers_whole_device(self):
        sched = ClusterScheduler([_spec("a")], SchedulerConfig(n_contexts=2),
                                 n_gpus=2)
        assert sched.fault_cancel_keys((0, 0)) == [(0, 0)]
        sched.fail_context((0, 0), now=0.0)
        # last live context: the escalated whole-device failure requeues
        # in-flight stages from EVERY context of the device, so the
        # engine must cancel all of their backend lanes
        assert set(sched.fault_cancel_keys((0, 1))) == {(0, 0), (0, 1)}
        sched.fail_context((0, 1), now=0.0)
        assert sched.fault_cancel_keys((0, 1)) == [(0, 1)]   # dead: no-op

    def test_escalated_fault_after_shape_shrink_no_ghost_completions(self):
        # shape reconfigure leaves stages draining on retired contexts;
        # a later fault on the device's last live context escalates to
        # fail_device, which requeues those draining stages — their
        # backend entries must die too, or a ghost completion
        # double-executes the replayed stage
        specs = [_spec(f"lp{i}", period=120.0, t_alone=25.0)
                 for i in range(4)]
        srv = (_cluster_cfg(2, specs, horizon=600.0, nc=2, os_=2.0)
               .reconfigure_at(150.0, n_contexts=1)
               .fail_context_at((0, 2), 152.0)   # retired lanes still busy
               .build())
        m = srv.run()
        assert 0 not in srv.scheduler.live_devices()
        assert sum(m.completed.values()) > 0
        # each completed job contributed exactly one response sample
        assert sum(m.completed.values()) == sum(
            len(v) for v in m.response_ms.values())


class TestElasticity:
    def test_reconfigure_grows_by_whole_gpus(self):
        specs = table2_taskset("resnet18", load_scale=0.5)
        srv = (_cluster_cfg(2, specs, horizon=1000.0)
               .reconfigure_at(300.0, n_gpus=4).build())
        m = srv.run()
        assert m.reconfigures == 1
        assert len(srv.scheduler.live_devices()) == 4
        assert m.missed[HP] == 0
        # the new devices actually absorbed load
        late = {d for d, s in m.per_device.items() if d >= 2}
        assert late and all(
            sum(m.per_device[d]["completed"].values()) > 0 for d in late)

    def test_reconfigure_shrinks_gracefully(self):
        specs = table2_taskset("resnet18", load_scale=0.4)
        srv = (_cluster_cfg(4, specs, horizon=1000.0)
               .reconfigure_at(300.0, n_gpus=2).build())
        m = srv.run()
        assert len(srv.scheduler.live_devices()) == 2
        assert m.missed[HP] == 0
        for t in srv.scheduler.tasks:
            assert t.ctx[0] in (0, 1)

    def test_autoscale_scales_whole_gpus(self):
        specs = table2_taskset("resnet18")     # full overload on one GPU
        srv = (_cluster_cfg(1, specs, horizon=1500.0)
               .autoscale(0.2, 0.6, check_every_ms=200.0, min_contexts=1,
                          max_contexts=4, cooldown_ms=300.0).build())
        m = srv.run()
        assert m.reconfigures > 0
        # the fleet grew by whole GPUs at some point (workers registry
        # keeps every device ever created; the autoscaler may well have
        # shrunk back down by the end of the run)
        assert len(srv.scheduler.workers) > 1

    def test_shape_reconfigure_survives_cross_device_active_job(self):
        # a sticky cross-GPU migration can leave a job registered on its
        # OLD device while the task points at the new one; a per-device
        # reshape must re-home it without a foreign-key KeyError
        sched = ClusterScheduler([_spec("a"), _spec("b")],
                                 SchedulerConfig(n_contexts=2), n_gpus=2)
        task = sched.tasks[0]
        job = sched.on_release(task, 0.0)
        other = next(c.index for c in sched.live_contexts()
                     if c.index[0] != task.ctx[0])
        sched._move_task(task, other)       # job stays at the old home
        info = sched.reconfigure(100.0, n_contexts=3)   # must not raise
        assert job.ctx == task.ctx
        assert job in sched.active_jobs[job.ctx]
        assert info["rehomed"] >= 0

    def test_per_device_shape_reconfigure_applies_to_each_worker(self):
        specs = table2_taskset("resnet18", load_scale=0.4)
        srv = (_cluster_cfg(2, specs, horizon=800.0)
               .reconfigure_at(300.0, n_contexts=6,
                               oversubscription=6.0).build())
        m = srv.run()
        assert m.missed[HP] == 0
        for d in srv.scheduler.live_devices():
            w = srv.scheduler.workers[d]
            assert len(w.live_contexts()) == 6


class TestIntrospection:
    def test_snapshot_has_devices_and_percentiles(self):
        specs = table2_taskset("resnet18", load_scale=0.5)
        srv = _cluster_cfg(2, specs, horizon=500.0).build()
        # the cluster block is complete even before the first completion
        pre = srv.snapshot()
        assert "devices" in pre and "transfers" in pre
        assert pre["device_completed"] == {}
        srv.run()
        snap = srv.snapshot()
        assert set(snap["devices"]) == {0, 1}
        for d, s in snap["devices"].items():
            assert s["alive"] and s["live_contexts"] == 4
        for key in ("resp_hp", "resp_lp"):
            assert {"p50", "p95", "p99"} <= set(snap[key])
        assert snap["resp_hp"]["p99"] >= snap["resp_hp"]["p50"] > 0.0
        assert "device_completed" in snap

    def test_summary_has_per_device_and_flat_percentiles(self):
        specs = table2_taskset("resnet18", load_scale=0.5)
        m = _cluster_cfg(2, specs, horizon=500.0).build().run()
        s = m.summary()
        assert set(s["per_device"]) == {"0", "1"}
        assert s["resp_hp_p99"] == s["resp_hp"]["p99"]
        assert s["resp_lp_p95"] == s["resp_lp"]["p95"]

    def test_submit_lands_on_least_loaded_device(self):
        srv = _cluster_cfg(2, [_spec("seed", period=100.0)],
                           horizon=300.0).build()
        handles = [srv.submit(_spec(f"one{i}", period=100.0), at_ms=10.0)
                   for i in range(4)]
        srv.drain()
        assert all(h.status == h.COMPLETED for h in handles)
        devs = {h.task.ctx[0] for h in handles}
        assert devs == {0, 1}      # submissions alternated across devices

    def test_summary_carries_per_device_when_nothing_completes(self):
        # zero completions must not drop the cluster summary keys:
        # consumers read summary()["transfers"] unconditionally
        srv = (ServerConfig.cluster(2)
               .task(_spec("idle"), arrival=TraceArrival([]))
               .contexts(2).streams(1).oversubscribe(2.0)
               .device(device()).horizon_ms(50.0).seed(0).build())
        s = srv.run().summary()
        assert set(s["per_device"]) == {"0", "1"}
        assert s["transfers"] == 0

    def test_cluster_checkpoint_unsupported(self):
        srv = _cluster_cfg(2, [_spec("a")], horizon=100.0).build()
        with pytest.raises(NotImplementedError, match="cluster"):
            srv.save_state("/tmp/should-not-exist.ckpt")
        with pytest.raises(NotImplementedError, match="cluster"):
            srv.load_state("/tmp/does-not-matter.ckpt")


class TestClusterBatching:
    def test_release_joins_home_batch_before_cross_gpu_fallback(self):
        # a release that joins an open batch head charges only the
        # incremental Eq. 12 utilization, so it can coalesce at home
        # even when full-task admission fails there AND another device
        # would admit — the head must win over a cross-GPU migration
        pol = BatchPolicy(max_batch=8, scope="task")
        cfg = SchedulerConfig(n_contexts=1, n_streams=1,
                              oversubscription=1.0, batch_policy=pol)
        spec = TaskSpec(
            name="lp", period_ms=9.6, priority=LP,
            stages=[StageProfile(name="lp/s0", t_alone_ms=2.4, n_sat=20.0,
                                 mem_frac=0.3, batch_gain=3.0),
                    StageProfile(name="lp/s1", t_alone_ms=2.4, n_sat=20.0,
                                 mem_frac=0.3, batch_gain=3.0)])
        sched = ClusterScheduler([spec], cfg, n_gpus=2)
        task = sched.tasks[0]
        home = task.ctx
        j1 = sched.on_release(task, 0.0)
        assert j1 is not None
        # sanity: the second full job fails home admission but the idle
        # device would take it — exactly the migrate-vs-coalesce race
        assert not sched.workers[home[0]].admits(home, task, 0.5)
        other = next(d for d in sched.live_devices() if d != home[0])
        assert any(sched.workers[other].admits(c.index, task, 0.5)
                   for c in sched.workers[other].live_contexts())
        j2 = sched.on_release(task, 0.5)
        assert j2 is j1 and j1.n_inputs == 2
        assert task.ctx == home
        assert sched.migrations == 0


class TestStragglerTransferCredit:
    def test_transfer_charge_credited_at_contention_rate(self):
        # the transfer charge sits inside the entry's remaining work, so
        # the straggler projection burns it at the contention rate; the
        # kill threshold must credit it the same way or a contended
        # transfer-charged stage dies purely from transfer serialization
        from repro.runtime.backend import (SimBackend, _COST, _FLOOR,
                                           _RATE, _REM, _SMRET)
        from repro.runtime.engine_core import EngineCore
        xfer = 50.0
        specs = [_spec("mover", period=400.0, t_alone=10.0),
                 _spec("bystander", period=4000.0, t_alone=100.0)]
        cfg = SchedulerConfig(n_contexts=2, n_streams=1,
                              oversubscription=1.0, straggler_kappa=3.0)
        narrow = DeviceModel(n_units=4.0, bubble=0.0, l2_pressure=0.0)
        sched = ClusterScheduler(specs, cfg, narrow, n_gpus=1)
        backend = SimBackend(noise_sigma=0.0)
        core = EngineCore(sched, backend, horizon_ms=10_000.0)
        backend.bind(core)
        backend.start()
        lanes = {}
        for task in sched.tasks:
            job = sched.on_release(task, 0.0)
            inst = sched.next_for_lane(job.ctx, 0.0)
            if task.spec.name == "mover":
                inst.transfer_ms = xfer     # as the dispatcher would stamp
            lane = (job.ctx, 0)
            inst.start_ms = 0.0
            inst.lane = lane
            sched.lanes[lane] = inst
            backend.launch(lane, inst)
            lanes[task.spec.name] = lane
        backend.running_set_changed()       # set rates + predictions
        entry = backend.running[lanes["mover"]]
        rate, rem = entry[_RATE], entry[_REM]
        assert rate < 1.0                   # two lanes contend
        base = max(3.0 * entry[_SMRET].value() * entry[_COST],
                   entry[_FLOOR])
        # projected completion between the raw-xfer and rate-scaled
        # thresholds: legitimate transfer serialization, must survive
        backend.now = base + (xfer + xfer / rate) / 2 - rem / rate
        backend._check_stragglers()
        assert core.metrics.stragglers == 0
        assert lanes["mover"] in backend.running
        # truly late — past the rate-scaled threshold — still dies (the
        # kill re-enqueues and _dispatch may relaunch it immediately, so
        # the counter is the signal, not lane membership)
        backend.now = base + xfer / rate - rem / rate + 1.0
        backend._check_stragglers()
        assert core.metrics.stragglers == 1


class TestCrossDeviceMretHygiene:
    def test_stale_head_from_other_device_is_sealed(self):
        # a cluster re-place can move a batch head's job to another
        # device; the old home's coalescer must seal it on the next
        # probe (its context table has no such key), not KeyError
        pol = BatchPolicy(max_batch=8, scope="task")
        cfg = SchedulerConfig(n_contexts=2, batch_policy=pol)
        sched = ClusterScheduler([_spec("lp", period=40.0)], cfg, n_gpus=2)
        task = sched.tasks[0]
        j1 = sched.on_release(task, 0.0)
        assert j1 is not None
        foreign = next(c.index for c in sched.workers[1].live_contexts()
                       if c.index[0] != task.ctx[0])
        j1.ctx = foreign                 # as _global_replace would set
        w = sched.workers[task.ctx[0]]
        assert w._try_coalesce(task, 0.5) is None
        assert w._coalescer.head(task) is None     # sealed

    def test_transfer_wall_share_removed_from_mret(self):
        # the backend burns the folded-in transfer charge at the
        # contention rate, so its wall share is its fraction of the
        # executed work — subtracting the raw charge leaks the residual
        # into the MRET window after every cross-GPU move
        sched = ClusterScheduler([_spec("a", period=400.0, t_alone=10.0)],
                                 SchedulerConfig(n_contexts=1),
                                 n_gpus=1, transfer_ms=5.0)
        task = sched.tasks[0]
        job = sched.on_release(task, 0.0)
        inst = sched.next_for_lane(job.ctx, 0.0)
        inst.transfer_ms = 5.0
        inst.work_done = 20.0        # total device-local work incl. charge
        inst.lane = (job.ctx, 0)
        sched.on_stage_finish(inst, 40.0, 40.0)   # wall = 2x work: rate 0.5
        # charge's wall share = 40 * 5/20 = 10 -> observe 30, not 35
        assert task.mret.stage_mret(0) == pytest.approx(30.0)

    def test_coalesce_slack_uses_device_wall_clock(self):
        # the slack bound predicts stage-0 completion in wall clock, so
        # reference-speed MRET must be divided by the device speed: a
        # 2x device can still take a join that reference units reject
        from repro.runtime.contention import batch_cost
        pol = BatchPolicy(max_batch=8, scope="task")
        cfg = SchedulerConfig(n_contexts=1, batch_policy=pol)
        fast = DeviceModel(speed=2.0, name="fast2x")
        sched = ClusterScheduler([_spec("lp", period=9.6, t_alone=2.4)],
                                 cfg, n_gpus=1, device_models=[fast])
        task = sched.tasks[0]
        j1 = sched.on_release(task, 0.0)
        w = sched.workers[0]
        inst = w._coalescer.head(task)
        mret0 = task.mret.stage_mret(0)
        cj = batch_cost(task.spec.stages[0], 2)   # batch_gain 1 -> 2.0
        vdl = inst.virtual_deadline_ms
        now = vdl - 0.75 * mret0 * cj
        # reference units reject the join (and are not late_anyway);
        # this device finishes in half the time, so it fits
        assert now + mret0 * cj > vdl
        assert now + mret0 * batch_cost(task.spec.stages[0], 1) <= vdl
        assert now + (mret0 / fast.speed) * cj <= vdl
        j2 = sched.on_release(task, now)
        assert j2 is j1 and j1.n_inputs == 2

    def test_shape_and_ngpus_reconfigure_must_be_separate(self):
        sched = ClusterScheduler([_spec("a")], SchedulerConfig(n_contexts=2),
                                 n_gpus=2)
        with pytest.raises(ValueError, match="separate reconfigure"):
            sched.reconfigure(0.0, n_gpus=3, n_contexts=4)
        with pytest.raises(ValueError, match="separate events"):
            (ServerConfig.cluster(2).task(_spec("a"))
             .reconfigure_at(10.0, n_gpus=3, n_contexts=4).build())
