"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp ref."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 2e-4, jnp.bfloat16: 3e-2}


@pytest.mark.parametrize("m,d", [(64, 128), (100, 96), (256, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(m, d, dtype):
    rng = np.random.default_rng(0)
    x = _rand(rng, (m, d), dtype)
    w = _rand(rng, (d,), jnp.float32)
    a = ops.rmsnorm(x, w, mode="kernel")
    b = ops.rmsnorm(x, w, mode="ref")
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=TOL[dtype], atol=TOL[dtype])


def test_rmsnorm_residual_fused():
    rng = np.random.default_rng(1)
    x = _rand(rng, (96, 256), jnp.float32)
    r = _rand(rng, (96, 256), jnp.float32)
    w = _rand(rng, (256,), jnp.float32)
    (ya, ra) = ops.rmsnorm_residual(x, r, w, mode="kernel")
    (yb, rb) = ops.rmsnorm_residual(x, r, w, mode="ref")
    np.testing.assert_allclose(ya, yb, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(ra, rb, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("h,kv,s,dh", [(8, 8, 256, 64), (8, 2, 256, 64),
                                       (4, 1, 128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(h, kv, s, dh, dtype):
    rng = np.random.default_rng(2)
    q = _rand(rng, (2, h, s, dh), dtype)
    k = _rand(rng, (2, kv, s, dh), dtype)
    v = _rand(rng, (2, kv, s, dh), dtype)
    a = ops.flash_attention(q, k, v, mode="kernel", block_q=64, block_k=64)
    b = ops.flash_attention(q, k, v, mode="ref")
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=TOL[dtype], atol=TOL[dtype])


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (64, 0.0), (0, 30.0),
                                            (32, 50.0)])
def test_flash_attention_masks(window, softcap):
    rng = np.random.default_rng(3)
    q = _rand(rng, (1, 4, 128, 64), jnp.float32)
    k = _rand(rng, (1, 2, 128, 64), jnp.float32)
    v = _rand(rng, (1, 2, 128, 64), jnp.float32)
    a = ops.flash_attention(q, k, v, mode="kernel", window=window,
                            softcap=softcap, block_q=64, block_k=64)
    b = ops.flash_attention(q, k, v, mode="ref", window=window,
                            softcap=softcap)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("h,kv,s", [(8, 8, 512), (8, 2, 512), (4, 4, 256)])
@pytest.mark.parametrize("fill", [1.0, 0.6])
def test_decode_attention_sweep(h, kv, s, fill):
    rng = np.random.default_rng(4)
    q = _rand(rng, (2, h, 64), jnp.float32)
    k = _rand(rng, (2, kv, s, 64), jnp.float32)
    v = _rand(rng, (2, kv, s, 64), jnp.float32)
    n_valid = int(s * fill)
    kv_pos = jnp.where(jnp.arange(s) < n_valid, jnp.arange(s), -1)
    q_pos = jnp.asarray([n_valid - 1, n_valid // 2], jnp.int32)
    a = ops.decode_attention(q, k, v, kv_pos, q_pos, mode="kernel",
                             block_k=128)
    b = ops.decode_attention(q, k, v, kv_pos, q_pos, mode="ref")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("l,h,p,n,chunk", [(256, 4, 32, 16, 64),
                                           (512, 2, 64, 32, 128),
                                           (128, 8, 16, 16, 32)])
def test_ssd_sweep(l, h, p, n, chunk):
    rng = np.random.default_rng(5)
    x = _rand(rng, (2, l, h, p), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (2, l, h)), jnp.float32)
    al = jnp.asarray(rng.uniform(-0.5, 1.5, (h,)), jnp.float32)
    b = _rand(rng, (2, l, 1, n), jnp.float32)
    c = _rand(rng, (2, l, 1, n), jnp.float32)
    ya, sa = ops.ssd(x, dt, al, b, c, chunk=chunk, mode="kernel")
    yb, sb = ops.ssd(x, dt, al, b, c, chunk=chunk, mode="ref")
    np.testing.assert_allclose(ya, yb, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(sa, sb, rtol=5e-4, atol=5e-4)


def test_ssd_matches_sequential_recurrence():
    """Chunked SSD == token-by-token recurrence (independent oracle)."""
    from repro.models.mamba2 import ssd_decode_step
    rng = np.random.default_rng(6)
    l, h, p, n = 64, 2, 8, 8
    x = _rand(rng, (1, l, h, p), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (1, l, h)), jnp.float32)
    al = jnp.asarray(rng.uniform(-0.5, 1.0, (h,)), jnp.float32)
    b = _rand(rng, (1, l, 1, n), jnp.float32)
    c = _rand(rng, (1, l, 1, n), jnp.float32)
    y_chunk, s_chunk = ops.ssd(x, dt, al, b, c, chunk=16, mode="kernel")
    state = jnp.zeros((1, h, p, n), jnp.float32)
    ys = []
    for t in range(l):
        y_t, state = ssd_decode_step(state, x[:, t], dt[:, t], al,
                                     b[:, t], c[:, t])
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_seq, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(s_chunk, state, rtol=2e-3, atol=2e-3)
