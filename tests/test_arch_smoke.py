"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
shape + no-NaN assertions; prefill+decode consistency; MoE capacity path
vs dense oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_reduced
from repro.models import build_model


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_frames, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_image_tokens, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_decode(arch):
    cfg = get_reduced(arch)
    m = build_model(cfg)
    params = m.init_params(0)
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    loss = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss))

    cache = m.init_cache(b, s + 4)
    pre = dict(batch, cache=cache)
    logits, cache = jax.jit(m.prefill)(params, pre)
    assert logits.shape[:2] in {(b, s), (b, s + cfg.n_image_tokens)}
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    rng = np.random.default_rng(1)
    dec = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 1))),
           "cache": cache}
    if cfg.family == "encdec":
        dec["enc_out"] = batch["frames"]
    logits2, _ = jax.jit(m.decode_step)(params, dec)
    assert logits2.shape[1] == 1
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-2.7b"])
def test_train_step_decreases_loss(arch):
    from repro.training.optimizer import AdamWConfig, adamw_init
    from repro.training.train_step import make_train_step
    cfg = get_reduced(arch)
    m = build_model(cfg)
    params = m.init_params(0)
    opt_cfg = AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=50)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(m, opt_cfg))
    batch = _batch(cfg, 4, 32)
    losses = []
    for i in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]        # memorizes a fixed batch


def test_train_step_grad_accum_matches():
    from repro.training.optimizer import AdamWConfig, adamw_init
    from repro.training.train_step import make_train_step
    cfg = get_reduced("smollm-135m")
    m = build_model(cfg)
    params = m.init_params(0)
    opt_cfg = AdamWConfig(lr=1e-3, grad_clip=0.0, weight_decay=0.0)
    batch = _batch(cfg, 4, 16)
    p1, _, m1 = jax.jit(make_train_step(m, opt_cfg, accum=1))(
        params, adamw_init(params, opt_cfg), batch)
    p2, _, m2 = jax.jit(make_train_step(m, opt_cfg, accum=2))(
        params, adamw_init(params, opt_cfg), batch)
    # same data split in microbatches -> same mean grad -> same update
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_moe_capacity_matches_oracle_when_uncapped():
    from repro.models.moe import init_moe, moe_capacity, moe_dense_oracle
    from repro.models.layers import InitCtx
    rng = jax.random.PRNGKey(0)
    ctx = InitCtx(rng, jnp.float32)
    p = init_moe(ctx, d=32, n_experts=8, moe_d_ff=16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
    out_o, _ = moe_dense_oracle(p, x, topk=2)
    # capacity large enough that nothing drops -> must match oracle
    out_c, _ = moe_capacity(p, x, topk=2, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(out_o), np.asarray(out_c),
                               rtol=2e-5, atol=2e-5)


def test_ring_kv_cache_decode_matches_full():
    """Sliding-window decode through a ring cache == full cache + window."""
    from repro.models.attention import (attention_block, init_attention,
                                        make_kv_cache)
    from repro.models.layers import InitCtx
    ctx = InitCtx(jax.random.PRNGKey(0), jnp.float32)
    p = init_attention(ctx, 32, 4, 2, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 32))
    window = 8
    full = make_kv_cache(1, 64, 2, 8, "float32")
    ring = make_kv_cache(1, window, 2, 8, "float32")
    pos = jnp.arange(24)
    _, full = attention_block(p, x, positions=pos, window=window, cache=full)
    _, ring = attention_block(p, x, positions=pos, window=window, cache=ring)
    for t in range(24, 30):
        xt = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(2), t),
                               (1, 1, 32))
        pt = jnp.asarray([t])
        yf, full = attention_block(p, xt, positions=pt, window=window,
                                   cache=full)
        yr, ring = attention_block(p, xt, positions=pt, window=window,
                                   cache=ring)
        np.testing.assert_allclose(np.asarray(yf), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)
