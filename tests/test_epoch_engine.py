"""Twin-path bit-identity tests for the array-programmed epoch engine.

The epoch engine (``repro.runtime.epoch.EpochSimBackend``) keeps per-lane
hot state in preallocated NumPy arrays and advances the simulation in
vectorized epochs; the heap engine (``SimBackend``) is the bit-exact
reference. These tests pin the twin-path contract:

* every golden fixture in ``tests/golden/engine_golden.json`` (including
  the cluster and chaos fixtures) reproduces BIT-IDENTICALLY through the
  epoch engine — counts exactly, response times by SHA-256 over IEEE-754
  hex forms;
* the contract survives the scheduler sanitizer and a fleet-shaped
  trace-replay cluster run (the epoch engine's target workload);
* the jitted JAX contention+ETA kernel returns the same bits as
  ``ContentionModel.rates_seq`` at every lane count, so sweeping the
  ``DARIS_EPOCH_KERNEL_MIN`` dispatch threshold cannot change results;
* the prediction-heap compaction hook fires on the serving pump's idle
  pause (churny cancel traffic must not accrete stale predictions);
* the dispatch hot-queue index tracks queue occupancy exactly.
"""
from __future__ import annotations

import json
import math

import pytest

from test_engine_golden import GOLDEN, _capture, _scenarios


def _kernel():
    from repro.kernels import contention_eta
    return contention_eta


def _kernel_available() -> bool:
    try:
        return _kernel().available()
    except Exception:
        return False


# ------------------------------------------------------------------ goldens
@pytest.mark.parametrize("name", sorted(_scenarios()))
def test_epoch_matches_golden(name):
    golden = json.loads(GOLDEN.read_text())
    assert name in golden, f"{name} missing from fixture; --regen?"
    got = _capture(_scenarios()[name], engine="epoch")
    assert got == golden[name]


def test_epoch_matches_heap_under_sanitizer():
    """DSAN invariant checks must pass identically on both engines (the
    sanitizer reads scheduler state the backends feed differently)."""
    build = _scenarios()["chaos_rn18_4x1_os4"]
    heap = _capture(lambda: build().sanitize(2), engine="heap")
    epoch = _capture(lambda: build().sanitize(2), engine="epoch")
    assert epoch == heap


def test_epoch_matches_heap_fleet_trace():
    """Fleet-shaped run: multi-device cluster replaying an arrival trace
    — the workload the epoch engine exists for."""
    import numpy as np
    from benchmarks.perf_engine import _diurnal_trace
    from repro.api import ServerConfig, TraceArrival
    from repro.core.task import LP, StageProfile, TaskSpec
    from repro.serving.profiles import device

    def build():
        n_dev, per_dev, h = 8, 2, 400.0
        specs = [TaskSpec(name=f"svc{i:02d}", period_ms=24.0, priority=LP,
                          stages=[StageProfile(name=f"svc{i:02d}/s0",
                                               t_alone_ms=2.0,
                                               n_sat=20.0, mem_frac=0.3),
                                  StageProfile(name=f"svc{i:02d}/s1",
                                               t_alone_ms=2.0,
                                               n_sat=20.0, mem_frac=0.3)])
                 for i in range(n_dev * per_dev)]
        cfg = (ServerConfig.cluster(n_dev).tasks(specs)
               .contexts(2).streams(1).oversubscribe(2.0)
               .device(device()).horizon_ms(h).seed(0))
        for i, s in enumerate(specs):
            rng = np.random.default_rng(9000 + i)
            cfg.arrival(s.name,
                        TraceArrival(_diurnal_trace(rng, 1.0 / 24.0, h)))
        return cfg

    heap = _capture(build, engine="heap")
    epoch = _capture(build, engine="epoch")
    assert epoch == heap
    assert sum(int(v) for v in heap["completed"].values()) > 0


# ------------------------------------------------------------------- kernel
pytestmark_kernel = pytest.mark.skipif(
    not _kernel_available(), reason="JAX contention kernel unavailable")


@pytestmark_kernel
@pytest.mark.parametrize("m", [1, 2, 3, 7, 17, 64, 255, 1000])
def test_kernel_rates_bit_exact(m):
    """The jitted kernel must return the same 64 bits per lane as the
    sequential reference at every lane count (panel padding included)."""
    import numpy as np
    from repro.runtime.contention import ContentionModel
    from repro.serving.profiles import device

    rng = np.random.default_rng(1234 + m)
    cm = ContentionModel(device())
    u = (rng.uniform(0.2, 4.0, m)).tolist()
    ns = (rng.uniform(5.0, 40.0, m)).tolist()
    mf = (rng.uniform(0.05, 0.9, m)).tolist()
    ref = cm.rates_seq(list(u), list(ns), list(mf))
    got = _kernel().rates(cm.device, u, ns, mf)
    assert len(got) == m
    for g, r in zip(got, ref):
        assert g == r, (g.hex(), r.hex())


@pytestmark_kernel
def test_kernel_rates_bit_exact_hetero_device():
    """Device parameters are traced (not jit-time constants): a second
    device model must not recompile into different float sequences."""
    import dataclasses

    import numpy as np
    from repro.runtime.contention import ContentionModel
    from repro.serving.profiles import device

    rng = np.random.default_rng(77)
    dev = dataclasses.replace(device(), n_units=40,
                              bubble=0.17, l2_pressure=0.013)
    cm = ContentionModel(dev)
    m = 33
    u = rng.uniform(0.2, 4.0, m).tolist()
    ns = rng.uniform(5.0, 40.0, m).tolist()
    mf = rng.uniform(0.05, 0.9, m).tolist()
    assert _kernel().rates(dev, u, ns, mf) == cm.rates_seq(
        list(u), list(ns), list(mf))


@pytestmark_kernel
def test_kernel_fused_eta_bit_exact():
    """fused() = rates + the ETA arithmetic the epoch engine would do."""
    import numpy as np
    from repro.runtime.contention import ContentionModel
    from repro.serving.profiles import device

    rng = np.random.default_rng(5)
    cm = ContentionModel(device())
    m, now = 129, 123.456
    u = rng.uniform(0.2, 4.0, m).tolist()
    ns = rng.uniform(5.0, 40.0, m).tolist()
    mf = rng.uniform(0.05, 0.9, m).tolist()
    rem = rng.uniform(0.1, 8.0, m).tolist()
    rates, etas = _kernel().fused(cm.device, now, u, ns, mf, rem)
    ref = cm.rates_seq(list(u), list(ns), list(mf))
    assert list(rates) == ref
    for e, rm, rt in zip(etas, rem, ref):
        assert e == now + rm / rt


@pytestmark_kernel
@pytest.mark.parametrize("threshold", [1, 3, 17])
def test_kernel_threshold_sweep_bit_identical(threshold, monkeypatch):
    """Property: results are invariant to WHERE the NumPy/kernel dispatch
    threshold sits. Forcing tiny thresholds routes every rate-group
    through the jitted kernel; the run must still match the golden
    fixture bit for bit."""
    monkeypatch.setenv("DARIS_EPOCH_KERNEL_MIN", str(threshold))
    golden = json.loads(GOLDEN.read_text())
    name = "mpsstr_rn18_3x3_os3_plain"
    got = _capture(_scenarios()[name], engine="epoch")
    assert got == golden[name]


# ------------------------------------------- serving pump heap compaction
def test_serving_pump_compacts_prediction_heap():
    """Churny cancel traffic on an idling serving pump must not accrete
    stale finish predictions: the pause path calls maybe_compact (the
    batch-run compaction site, running_set_changed, never fires while
    the daemon idles)."""
    from repro.api import ManualArrival, ServerConfig
    from repro.serving.profiles import device
    from repro.serving.requests import table2_taskset

    spec = table2_taskset("resnet18")[0]
    server = (ServerConfig().tasks([spec]).arrival(spec.name,
                                                   ManualArrival())
              .contexts(2).streams(1).oversubscribe(2.0)
              .device(device()).horizon_ms(1e9).seed(0).build())
    server.begin_serving()
    t = 0.0
    for i in range(300):
        h = server.request(spec.name, at_ms=t)
        if i % 2:
            server.cancel(h)
        t += 2.0
        server.pump(frontier_ms=t)
    server.pump(frontier_ms=t + 1e6)      # drain, then idle pause
    b = server.core.backend
    assert server.serving_idle()
    assert len(b._heap) <= max(b._COMPACT_MIN, 2 * len(b.running)), (
        f"stale predictions accreted: heap={len(b._heap)} "
        f"running={len(b.running)}")
    server.end_serving(until_idle=False)


# ------------------------------------------------- dispatch hot-queue index
def test_stage_queue_hot_index_tracks_occupancy():
    """register_hot keeps the key in the shared set exactly while the
    queue holds work — push/pop/remove/drain all maintain it."""
    from repro.core.stage_queue import QueueConfig, StageQueue
    from repro.core.task import (HP, Job, StageInstance, StageProfile,
                                 Task, TaskSpec)

    spec = TaskSpec(name="t", period_ms=30.0, priority=HP,
                    stages=[StageProfile("t/s0", 1.0, 40.0, 0.4)])
    task = Task(spec=spec, index=0)

    def inst(vdl):
        job = Job(task=task, release_ms=0.0)
        return StageInstance(job=job, enqueue_ms=0.0,
                             virtual_deadline_ms=vdl)

    hot: set = set()
    q = StageQueue(QueueConfig())
    a, b = inst(1.0), inst(2.0)
    q.push(a)
    q.register_hot("k", hot)              # late registration syncs state
    assert hot == {"k"}
    assert q.pop() is a and hot == set()
    q.push(a)
    q.push(b)
    assert hot == {"k"}
    assert q.remove(a) and hot == {"k"}   # b still queued
    assert q.remove(b) and hot == set()
    q.push(a)
    q.drain()
    assert hot == set()
    empty = StageQueue(QueueConfig())
    empty.register_hot("e", hot)
    assert hot == set()


def test_scheduler_hot_queues_after_run():
    """End-to-end: after a full run every queue's hot membership matches
    its occupancy (the engine dispatch loop trusts this)."""
    build = _scenarios()["mps_rn18_6x1_os6_plain"]
    server = build().engine("epoch").build()
    server.run()
    sched = server.scheduler
    for k, q in sched.queues.items():
        assert (k in sched.hot_queues) == (len(q) > 0)


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q"] + sys.argv[1:]))
