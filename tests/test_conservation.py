"""Metrics conservation laws across the nasty paths, audited live.

Every scenario runs with the DSAN sanitizer at level 2 (audit every
engine step), so the per-step conservation identities are asserted
continuously by the auditor; the tests then assert the end-of-run laws
explicitly: per priority and per tenant,

    submitted == completed + missed + cancelled + rejected + aborted
                 + pending

where ``completed`` counts every finished job (missed ones included —
soft real-time: a missed job still completed, so ``missed`` is a subset
of ``completed``, not a disjoint term), and per device the completed/
missed sums must reproduce the global counters.
"""
from __future__ import annotations

from repro.api import (HP, LP, ManualArrival, ServerConfig, SubmitHandle)
from repro.analysis import Sanitizer

from tests.test_serve import (daemon_cfg, ideal_device, make_spec,
                              start_daemon)


def assert_conservation(m, handles):
    """The full conservation lattice over finalized metrics + handles."""
    for p in (HP, LP):
        sub = [h for h in handles if h.task.priority == p]
        by = {s: sum(1 for h in sub if h.status == s)
              for s in ("completed", "missed", "cancelled", "rejected",
                        "aborted", "pending", "queued", "running")}
        finished = by["completed"] + by["missed"]
        pending = by["pending"] + by["queued"] + by["running"]
        assert len(sub) == (finished + by["cancelled"] + by["rejected"]
                            + by["aborted"] + pending)
    pt = m.per_tenant or {}
    for tenant, d in pt.items():
        assert d["submitted"] == (d["completed"] + d["cancelled"]
                                  + d["rejected"] + d["aborted"]
                                  + d["pending"]), tenant
        assert d["missed"] <= d["completed"]
    if m.per_device:
        for p in (HP, LP):
            assert sum(s["completed"][p]
                       for s in m.per_device.values()) == m.completed[p]
            assert sum(s["missed"][p]
                       for s in m.per_device.values()) == m.missed[p]


def _audited(m, srv):
    s = srv.core._sanitizer
    assert isinstance(s, Sanitizer) and s.violations == 0 and s.audits > 0
    return m


# ------------------------------------------------------- cancel-mid-batch
def test_conservation_cancel_mid_batch():
    """Batched head with members cancelled in every phase: one detached
    while queued, one dropped after the batch sealed, the primary of a
    second batch cancelled outright."""
    sc = ServerConfig.sim().sanitize(level=2)
    sc.task(make_spec("hog", HP, [30.0], 1000.0), arrival=ManualArrival())
    sc.task(make_spec("lp", LP, [10.0], 500.0), arrival=ManualArrival())
    sc.contexts(1).streams(1).oversubscribe(1.0).device(ideal_device())
    sc.horizon_ms(1e6).phase_offsets(False).noise(0.0).seed(0)
    sc.batching(max_batch=8, scope="task")
    srv = sc.build()
    srv.begin_serving()

    srv.request("hog", at_ms=0.0, tenant="ops")
    batch = [srv.request("lp", at_ms=t, tenant="batchers")
             for t in (5.0, 6.0, 7.0)]
    srv.pump(7.0)
    # member detaches while the head is queued behind the hog
    srv.cancel(batch[1], at_ms=8.0)
    srv.pump(8.0)
    assert batch[1].status == SubmitHandle.CANCELLED
    # batch seals at 30 (hog done); drop a member mid-flight
    srv.pump(31.0)
    srv.cancel(batch[2], at_ms=32.0)
    srv.pump(32.0)
    # a second batch whose PRIMARY is cancelled before dispatch
    second = [srv.request("lp", at_ms=t, tenant="batchers")
              for t in (33.0, 34.0)]
    srv.pump(34.0)
    srv.cancel(second[0], at_ms=35.0)
    srv.pump(35.0)

    m = _audited(srv.end_serving(), srv)
    handles = srv.core._all_handles
    assert_conservation(m, handles)
    assert batch[0].status in (SubmitHandle.COMPLETED, SubmitHandle.MISSED)
    assert m.cancelled[LP] == 3
    assert m.per_tenant["batchers"]["cancelled"] == 3


# --------------------------------------------------- fault-then-reconfigure
def test_conservation_fault_then_reconfigure():
    """A context dies with work queued on it, then an online repartition
    reshapes the surviving geometry — orphans must re-home twice without
    double-counting or leaking."""
    sc = ServerConfig.sim().sanitize(level=2)
    sc.task(make_spec("hp", HP, [5.0], 40.0))
    sc.task(make_spec("lp0", LP, [8.0, 8.0], 120.0))
    sc.task(make_spec("lp1", LP, [6.0, 6.0], 100.0))
    sc.contexts(2).streams(2).oversubscribe(2.0).device(ideal_device())
    sc.horizon_ms(800.0).phase_offsets(False).noise(0.0).seed(0)
    sc.fail_context_at(1, 200.0)
    sc.reconfigure_at(400.0, n_contexts=3, n_streams=1)
    srv = sc.build()
    # tenanted one-shots ride alongside the periodic load
    extra = [srv.submit(make_spec(f"x{i}", LP, [7.0], 150.0),
                        at_ms=150.0 + 10.0 * i, tenant="burst")
             for i in range(4)]
    m = _audited(srv.run(), srv)
    assert m.faults == 1 and m.reconfigures == 1
    assert_conservation(m, srv.core._all_handles)
    assert all(h.done or h.status in (SubmitHandle.QUEUED,
                                      SubmitHandle.RUNNING)
               for h in extra)


# ------------------------------- cluster fail_device, in-flight transfers
def test_conservation_cluster_fail_device_with_transfers():
    """Kill a device while multi-stage jobs hold inter-stage state on it:
    survivors re-place, replayed stages pay the transfer charge, and
    every counter still adds up globally and per device."""
    sc = (ServerConfig.cluster(2, transfer_ms=1.5).sanitize(level=2)
          .contexts(2).streams(1).oversubscribe(2.0)
          .device(ideal_device()).horizon_ms(600.0)
          .phase_offsets(False).noise(0.0).seed(0))
    sc.task(make_spec("hp", HP, [4.0], 50.0))
    sc.task(make_spec("lpa", LP, [10.0, 10.0], 90.0))
    sc.task(make_spec("lpb", LP, [8.0, 8.0], 80.0))
    sc.fail_device_at(1, 100.0)
    srv = sc.build()
    subs = [srv.submit(make_spec(f"s{i}", LP, [9.0, 9.0], 140.0),
                       at_ms=90.0 + 2.0 * i, tenant="inflight")
            for i in range(3)]
    m = _audited(srv.run(), srv)
    assert m.per_device and set(m.per_device) == {0, 1}
    assert_conservation(m, srv.core._all_handles)
    # the fault really stranded inter-stage state: at least one survivor
    # paid the cross-device transfer charge (deterministic under seed 0)
    assert m.faults == 1 and m.transfers >= 1
    assert sum(m.completed.values()) > 0
    assert all(h.done or h.status in (SubmitHandle.QUEUED,
                                      SubmitHandle.RUNNING)
               for h in subs)


# --------------------------------------------------- chaos retry / abort
def test_conservation_chaos_faults_with_tenants():
    """Transient stage faults with deadline-aware retry: some jobs
    recover, some abort — the lattice (now with the ``aborted`` term)
    must still close, per priority, per tenant, live on every step."""
    from repro.api import ChaosPlan, RetryPolicy
    sc = ServerConfig.sim().sanitize(level=2)
    sc.task(make_spec("hp", HP, [4.0], 60.0))
    sc.task(make_spec("lp", LP, [6.0], 50.0))
    sc.task(make_spec("one", LP, [5.0], 45.0), arrival=ManualArrival())
    sc.contexts(2).streams(1).oversubscribe(2.0).device(ideal_device())
    sc.horizon_ms(1500.0).phase_offsets(False).noise(0.0).seed(0)
    sc.chaos(ChaosPlan(seed=0, stage_fault_rate=0.5,
                       retry=RetryPolicy(max_attempts=3, backoff_ms=2.0)))
    srv = sc.build()
    subs = [srv.submit(make_spec(f"x{i}", LP, [5.0], 45.0),
                       at_ms=40.0 * i, tenant="chaosers")
            for i in range(6)]
    m = _audited(srv.run(), srv)
    assert m.chaos_faults > 0 and m.retries > 0
    assert sum(m.aborted.values()) > 0          # 50% faults: some give up
    assert sum(m.completed.values()) > 0        # ...and some recover
    assert_conservation(m, srv.core._all_handles)
    d = m.per_tenant["chaosers"]
    assert d["submitted"] == 6
    assert all(h.done or h.status in (SubmitHandle.QUEUED,
                                      SubmitHandle.RUNNING)
               for h in subs)


# ------------------------------------------- SIGTERM-restart resubmission
def test_conservation_sigterm_restart_resubmission(tmp_path):
    """Daemon dies by SIGTERM with acked-but-unfinished work; the restart
    resubmits under original identities and the final run's books must
    balance — the restart engine is sanitized end to end."""
    cfg = daemon_cfg(sanitize=2)
    d1, th1, c1 = start_daemon(tmp_path, name="d1", cfg=cfg,
                               time_scale=1e-7)
    seqs = [c1.submit("resnet18", tenant="teamA")["seq"]
            for _ in range(3)]
    seqs.append(c1.submit("unet", tenant="teamB")["seq"])
    d1._on_signal(None, None)
    th1.join(timeout=10.0)
    assert not th1.is_alive()
    assert d1.server.core._sanitizer.violations == 0

    d2, th2, c2 = start_daemon(tmp_path, name="d2", cfg=cfg,
                               time_scale=500.0)
    for seq in seqs:
        r = c2.result(seq, timeout_s=30.0)
        assert r["status"] in ("completed", "missed")
    fin = c2.drain()
    th2.join(timeout=10.0)
    assert fin["lost"] == []
    m = _audited(d2.final_metrics, d2.server)
    assert_conservation(m, d2.server.core._all_handles)
    pt = m.per_tenant
    assert pt["teamA"]["submitted"] == 3 and pt["teamB"]["submitted"] == 1
    assert pt["teamA"]["completed"] == 3 and pt["teamB"]["completed"] == 1
