"""Chaos layer (repro.chaos): seeded fault injection, deadline-aware
retry/abort, stage watchdogs, brownout degradation, journal fsck, and
client connect retry.

Every engine scenario runs with the DSAN sanitizer at level 2, so the
conservation law with the new ``aborted`` term —

    admitted == completed + retired + aborted + live

— is audited on every engine step, not just at the end.
"""
from __future__ import annotations

import json
import socket

import pytest

from repro.api import (HP, LP, Brownout, ChaosPlan, DegradationPolicy,
                       ManualArrival, RetryPolicy, ServerConfig,
                       SubmitHandle)
from repro.analysis import Sanitizer
from repro.chaos import ChaosState, NORMAL, plan_from_dict

from tests.test_serve import daemon_cfg, ideal_device, make_spec


def chaos_server(plan=None, *, specs, horizon=600.0, contexts=2,
                 streams=1, os_=2.0, sanitize=2, manual=(), **sched_kw):
    sc = ServerConfig.sim()
    if sanitize:
        sc.sanitize(level=sanitize)
    for s in specs:
        sc.task(s)
    for s in manual:
        sc.task(s, arrival=ManualArrival())
    sc.contexts(contexts).streams(streams).oversubscribe(os_)
    sc.device(ideal_device()).horizon_ms(horizon)
    sc.phase_offsets(False).noise(0.0).seed(0)
    if sched_kw:
        sc.scheduler_options(**sched_kw)
    if plan is not None:
        sc.chaos(plan)
    return sc.build()


def _audited(srv):
    s = srv.core._sanitizer
    assert isinstance(s, Sanitizer) and s.violations == 0 and s.audits > 0


SPECS = lambda: [make_spec("hp", HP, [4.0], 40.0),          # noqa: E731
                 make_spec("lp0", LP, [6.0], 60.0),
                 make_spec("lp1", LP, [5.0], 50.0)]


# ------------------------------------------------------------ determinism
def test_chaos_determinism_same_seed_same_run():
    """Same seed + plan + workload -> bit-identical summaries."""
    plan = ChaosPlan(seed=7, stage_fault_rate=0.2, stall_rate=0.2,
                     stall_ms=8.0, watchdog_kappa=6.0,
                     degradation=DegradationPolicy(
                         check_every_ms=50.0, brownout_enter=0.5,
                         brownout_exit=0.3, emergency_enter=0.8,
                         emergency_exit=0.4))
    runs = []
    for _ in range(2):
        srv = chaos_server(plan, specs=SPECS())
        m = srv.run()
        _audited(srv)
        runs.append(m.summary())
    assert runs[0] == runs[1]
    assert runs[0]["chaos_faults"] > 0


def test_chaos_off_bit_identical():
    """An installed all-defaults plan is a no-op: the run matches a bare
    engine exactly (twin-path discipline)."""
    bare = chaos_server(None, specs=SPECS()).run().summary()
    noop = chaos_server(ChaosPlan(seed=3), specs=SPECS()).run().summary()
    assert bare == noop
    assert "chaos_faults" not in bare


def test_different_seed_different_faults():
    a = chaos_server(ChaosPlan(seed=1, stage_fault_rate=0.3),
                     specs=SPECS()).run().summary()
    b = chaos_server(ChaosPlan(seed=2, stage_fault_rate=0.3),
                     specs=SPECS()).run().summary()
    assert a["chaos_faults"] > 0 and b["chaos_faults"] > 0
    assert a != b


# ---------------------------------------------------------- retry / abort
def test_retry_recovers_transient_faults():
    """Moderate fault rate + generous deadlines: retries succeed, work
    still completes, books balance under level-2 audit."""
    plan = ChaosPlan(seed=0, stage_fault_rate=0.25,
                     retry=RetryPolicy(max_attempts=5, backoff_ms=0.5))
    srv = chaos_server(plan, specs=[make_spec("hp", HP, [4.0], 80.0),
                                    make_spec("lp", LP, [6.0], 120.0)])
    m = srv.run()
    _audited(srv)
    assert m.chaos_faults > 0 and m.retries > 0
    assert sum(m.completed.values()) > 0


def test_abort_after_attempts_exhausted():
    """Every stage faults, retries capped, deadline-awareness off: every
    admitted job must end ABORTED — none completed, none leaked."""
    plan = ChaosPlan(seed=0, stage_fault_rate=1.0,
                     retry=RetryPolicy(max_attempts=2, backoff_ms=0.5,
                                       deadline_aware=False))
    srv = chaos_server(plan, specs=[],
                       manual=[make_spec("job", LP, [5.0], 200.0)],
                       horizon=2000.0, contexts=1, os_=1.0)
    srv.begin_serving()
    hs = [srv.request("job", at_ms=10.0 * i, tenant="t")
          for i in range(5)]
    m = srv.end_serving(until_idle=True)
    _audited(srv)
    assert all(h.status == SubmitHandle.ABORTED for h in hs)
    assert m.aborted[LP] == 5 and sum(m.completed.values()) == 0
    # each job: first try + one retry, both fault
    assert m.chaos_faults == 10 and m.retries == 5
    assert m.per_tenant["t"]["aborted"] == 5


def test_deadline_aware_gives_up_early():
    """Tight deadline + always-failing stage: the deadline-aware bailout
    aborts without burning the full attempt budget."""
    plan = ChaosPlan(seed=0, stage_fault_rate=1.0,
                     retry=RetryPolicy(max_attempts=50, backoff_ms=4.0,
                                       backoff_mult=1.0,
                                       deadline_aware=True))
    srv = chaos_server(plan, specs=[],
                       manual=[make_spec("job", LP, [5.0], 20.0)],
                       horizon=2000.0, contexts=1, os_=1.0)
    srv.begin_serving()
    h = srv.request("job", at_ms=0.0)
    m = srv.end_serving(until_idle=True)
    _audited(srv)
    assert h.status == SubmitHandle.ABORTED
    assert m.aborted[LP] == 1
    assert m.retries < 10    # far under the 50-attempt budget


def test_cancel_while_retry_waiting():
    """Cancelling a job parked in backoff resolves cleanly (the RETRY
    event is the job's only token; cancel must not leak it)."""
    plan = ChaosPlan(seed=0, stage_fault_rate=1.0,
                     retry=RetryPolicy(max_attempts=10, backoff_ms=50.0,
                                       backoff_cap_ms=50.0,
                                       deadline_aware=False))
    srv = chaos_server(plan, specs=[],
                       manual=[make_spec("job", LP, [5.0], 1000.0)],
                       horizon=5000.0, contexts=1, os_=1.0)
    srv.begin_serving()
    h = srv.request("job", at_ms=0.0)
    srv.pump(10.0)           # first attempt faulted; now in backoff
    srv.cancel(h, at_ms=12.0)
    m = srv.end_serving(until_idle=True)
    _audited(srv)
    assert h.status == SubmitHandle.CANCELLED
    assert sum(m.completed.values()) == 0


# --------------------------------------------------------------- watchdog
def test_watchdog_kills_and_redispatches():
    """Stalled stages blow the k x MRET watchdog, get killed at the lane
    and re-dispatched at the stage boundary; clean launches complete."""
    plan = ChaosPlan(seed=0, stall_rate=0.5, stall_ms=60.0,
                     watchdog_kappa=3.0)
    srv = chaos_server(plan, specs=SPECS(), horizon=1200.0,
                       straggler_kappa=0.0)    # watchdog, not stragglers
    m = srv.run()
    _audited(srv)
    assert m.watchdog_kills > 0
    assert m.stragglers == 0
    assert sum(m.completed.values()) > 0


# ------------------------------------------------------------- brownouts
def test_brownout_slows_device_and_stays_deterministic():
    plan = ChaosPlan(seed=0, brownouts=(
        Brownout(t0_ms=100.0, t1_ms=400.0, device=0, slow_factor=3.0),))
    clean = chaos_server(None, specs=SPECS()).run().summary()
    srv = chaos_server(plan, specs=SPECS())
    browned = srv.run().summary()
    _audited(srv)
    # a 3x slowdown for half the run must show up in LP response times
    assert browned["resp_lp"]["mean"] > clean["resp_lp"]["mean"]
    again = chaos_server(plan, specs=SPECS()).run().summary()
    assert browned == again


# ------------------------------------------------------------ degradation
def test_degradation_sheds_lp_keeps_hp():
    """Overload trips BROWNOUT/EMERGENCY: LP admissions are shed, HP
    keeps its zero-miss record, transitions are recorded."""
    specs = [make_spec("hp", HP, [4.0], 40.0)] + [
        make_spec(f"lp{i}", LP, [9.0], 30.0) for i in range(4)]
    plan = ChaosPlan(seed=0, degradation=DegradationPolicy(
        check_every_ms=20.0, brownout_enter=0.5, brownout_exit=0.3,
        emergency_enter=0.75, emergency_exit=0.4))
    srv = chaos_server(plan, specs=specs, contexts=1, os_=4.0)
    m = srv.run()
    _audited(srv)
    assert m.degrade_transitions > 0
    assert m.shed[LP] > 0 and m.shed[HP] == 0
    assert m.dmr(HP) == 0.0
    ch = srv.core._chaos
    assert ch.transitions and ch.transitions[0][1] == NORMAL


# -------------------------------------------------------------- I/O chaos
def test_journal_append_io_chaos_retries_then_survives(tmp_path):
    from repro.serve.journal import Journal, read_journal
    ch = ChaosState(ChaosPlan(seed=0, io_error_rate=0.2, io_max_retries=4))
    j = Journal(str(tmp_path / "j.jsonl"), chaos=ch)
    for i in range(20):
        j.append({"rec": "submit", "seq": i})
    j.close()
    assert ch.io_injected > 0
    assert len(read_journal(j.path)) == 21      # meta + 20, none lost


def test_journal_append_io_chaos_exhausts(tmp_path):
    from repro.serve.journal import Journal
    ch = ChaosState(ChaosPlan(seed=0, io_error_rate=1.0, io_max_retries=2))
    with pytest.raises(OSError, match="chaos"):
        Journal(str(tmp_path / "j.jsonl"), chaos=ch)   # meta append fails


def test_checkpoint_io_chaos(tmp_path):
    from repro.checkpoint.ckpt import (load_scheduler_state,
                                       save_scheduler_state)
    srv = chaos_server(None, specs=SPECS(), sanitize=0, horizon=100.0)
    srv.run()
    path = str(tmp_path / "s.msgpack")
    ch = ChaosState(ChaosPlan(seed=0, io_error_rate=1.0, io_max_retries=2))
    with pytest.raises(OSError, match="chaos"):
        save_scheduler_state(srv.scheduler, path, chaos=ch)
    ch2 = ChaosState(ChaosPlan(seed=0, io_error_rate=0.4, io_max_retries=4))
    save_scheduler_state(srv.scheduler, path, chaos=ch2)
    load_scheduler_state(srv.scheduler, path)   # round-trips after retry


# ------------------------------------------------------------ journal fsck
def _write_journal(path, lines):
    path.write_text("\n".join(lines) + "\n")


def test_fsck_clean_and_torn_tail(tmp_path):
    from repro.serve.journal import fsck_journal
    p = tmp_path / "j.jsonl"
    good = [json.dumps({"rec": "submit", "seq": i}) for i in range(3)]
    _write_journal(p, good)
    r = fsck_journal(str(p))
    assert r["kind"] == "clean" and r["ok"] and len(r["records"]) == 3
    # torn tail: partial trailing line, no newline
    p.write_text("\n".join(good) + "\n" + '{"rec": "sub')
    r = fsck_journal(str(p))
    assert r["kind"] == "torn-tail" and r["ok"]
    assert r["bad_line"] == 4 and len(r["records"]) == 3


def test_fsck_midfile_detect_and_repair(tmp_path):
    from repro.serve.journal import fsck_journal, read_journal, repair_journal
    p = tmp_path / "j.jsonl"
    good = [json.dumps({"rec": "submit", "seq": i}) for i in range(4)]
    lines = good[:2] + ["@@corrupt@@"] + good[2:]
    _write_journal(p, lines)
    r = fsck_journal(str(p))
    assert r["kind"] == "mid-file" and not r["ok"]
    assert r["bad_line"] == 3 and len(r["records"]) == 2
    # a tolerant reader would silently drop the 2 records after the rot
    assert len(read_journal(str(p))) == 2
    repair_journal(str(p))
    r2 = fsck_journal(str(p))
    assert r2["kind"] == "clean" and len(r2["records"]) == 2
    assert read_journal(str(p)) == r2["records"]


def test_daemon_refuses_midfile_corrupt_journal(tmp_path):
    from repro.serve.daemon import ServeDaemon
    p = tmp_path / "journal.jsonl"
    rec = {"rec": "submit", "seq": 0, "task": "resnet18", "tenant": None,
           "prio": 0, "at_ms": 1.0}
    _write_journal(p, [json.dumps({"rec": "meta", "version": 1}),
                       "@@rot@@", json.dumps(rec)])
    with pytest.raises(RuntimeError, match="repro.serve fsck"):
        ServeDaemon(daemon_cfg(), socket_path=str(tmp_path / "d.sock"),
                    journal_path=str(p))


def test_fsck_cli_verb(tmp_path, capsys):
    from repro.serve.__main__ import main
    p = tmp_path / "j.jsonl"
    good = [json.dumps({"rec": "submit", "seq": i}) for i in range(3)]
    _write_journal(p, good[:2] + ["@@rot@@"] + good[2:])
    assert main(["fsck", "--journal", str(p)]) == 1     # refuse w/o --yes
    assert "CORRUPT" in capsys.readouterr().out
    assert main(["fsck", "--journal", str(p), "--yes"]) == 0
    assert main(["fsck", "--journal", str(p)]) == 0     # clean now


# -------------------------------------------------------- client retries
def test_client_connect_retry_backoff(tmp_path, monkeypatch):
    """Connect refusals retry with doubling capped backoff, then raise."""
    from repro.serve.client import DarisClient
    sock_path = str(tmp_path / "dead.sock")
    s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    s.bind(sock_path)
    s.close()            # socket file exists, nobody listening -> refused
    sleeps = []
    monkeypatch.setattr("repro.serve.client.time.sleep", sleeps.append)
    c = DarisClient(sock_path, connect_retries=3, retry_backoff_s=0.05,
                    retry_backoff_cap_s=0.08)
    with pytest.raises(ConnectionRefusedError):
        c.ping()
    assert sleeps == [0.05, 0.08, 0.08]


# ----------------------------------------------------- realtime backend
@pytest.mark.slow
def test_realtime_backend_chaos_faults_and_retry():
    """Chaos on the wall-clock backend: faults drawn deterministically on
    the engine thread at launch, failed completions never commit worker
    output, retries recover — real JAX execution underneath."""
    from repro.api import DeviceModel
    from repro.models.cnn import build_resnet
    from repro.serving.engine import staged_cnn_taskspec
    model = build_resnet(18, width=8)
    specs = [staged_cnn_taskspec(model, priority=HP, jps=20.0,
                                 input_hw=32, tag="-hp"),
             staged_cnn_taskspec(model, priority=LP, jps=20.0,
                                 input_hw=32, tag="-lp")]
    srv = (ServerConfig.realtime()
           .tasks(specs).contexts(2).oversubscribe(2.0)
           .device(DeviceModel(n_units=2.0)).horizon_ms(1500.0)
           .sanitize(level=1)
           .chaos(ChaosPlan(seed=0, stage_fault_rate=0.3,
                            retry=RetryPolicy(max_attempts=4,
                                              backoff_ms=1.0)))
           .build())
    m = srv.run()
    _audited(srv)
    assert m.chaos_faults > 0 and m.retries > 0
    assert sum(m.completed.values()) > 0


# ------------------------------------------------------- config plumbing
def test_plan_from_dict_serving_config():
    plan = plan_from_dict({
        "seed": 5, "stage_fault_rate": 0.01,
        "retry": {"max_attempts": 4, "backoff_ms": 2.0},
        "degradation": {"check_every_ms": 50.0},
        "brownouts": [{"t0_ms": 10.0, "t1_ms": 20.0, "slow_factor": 2.5}],
        "watchdog_kappa": 4.0})
    assert plan.retry.max_attempts == 4
    assert plan.degradation.check_every_ms == 50.0
    assert plan.brownouts[0].slow_factor == 2.5


def test_serve_config_chaos_key():
    from repro.serve.config import build_server
    cfg = daemon_cfg(chaos={"seed": 1, "stage_fault_rate": 0.02,
                            "watchdog_kappa": 4.0})
    srv = build_server(cfg)
    assert srv.core._chaos is not None
    assert srv.core._chaos.plan.stage_fault_rate == 0.02
