"""Property-based tests (seeded randomized — hypothesis is unavailable
offline; each case is an explicit invariant over many random task sets)."""
import numpy as np
import pytest

from repro.core.scheduler import DarisScheduler, SchedulerConfig
from repro.core.task import HP, LP, StageProfile, TaskSpec
from repro.runtime.sim import SimEngine
from repro.runtime.contention import ContentionModel, DeviceModel


def random_taskset(rng, n_tasks=None):
    n_tasks = n_tasks or rng.integers(3, 12)
    specs = []
    for i in range(int(n_tasks)):
        n_stages = int(rng.integers(1, 5))
        stages = [StageProfile(f"t{i}/s{j}",
                               float(rng.uniform(0.3, 3.0)),
                               float(rng.uniform(10, 68)),
                               float(rng.uniform(0.1, 0.8)))
                  for j in range(n_stages)]
        specs.append(TaskSpec(name=f"t{i}",
                              period_ms=float(rng.uniform(15, 80)),
                              priority=HP if rng.random() < 0.4 else LP,
                              stages=stages))
    return specs


def random_cfg(rng):
    nc = int(rng.integers(1, 7))
    return SchedulerConfig(
        n_contexts=nc, n_streams=int(rng.integers(1, 4)),
        oversubscription=float(rng.uniform(1.0, nc)))


@pytest.mark.parametrize("seed", range(8))
def test_conservation_and_hp_guarantees(seed):
    """Invariants: (1) completed + rejected <= released; (2) HP jobs are
    never rejected without HPA; (3) response times positive; (4) DMR in
    [0, 1]."""
    rng = np.random.default_rng(seed)
    specs = random_taskset(rng)
    cfg = random_cfg(rng)
    sched = DarisScheduler(specs, cfg, DeviceModel())
    m = SimEngine(sched, horizon_ms=2500.0, seed=seed).run()
    released_max = sum(int(2500.0 / s.period_ms) + 1 for s in specs)
    total = (m.completed[HP] + m.completed[LP]
             + m.rejected[HP] + m.rejected[LP])
    assert total <= released_max
    assert m.rejected[HP] == 0          # no HPA -> HP always admitted
    for p in (HP, LP):
        assert all(r > 0 for r in m.response_ms[p])
        assert 0.0 <= m.dmr(p) <= 1.0


@pytest.mark.parametrize("seed", range(4))
def test_sim_determinism(seed):
    rng = np.random.default_rng(seed)
    specs = random_taskset(rng, n_tasks=6)
    cfg = random_cfg(rng)
    runs = []
    for _ in range(2):
        sched = DarisScheduler(
            [TaskSpec(s.name, s.period_ms, s.priority, list(s.stages))
             for s in specs], cfg, DeviceModel())
        m = SimEngine(sched, horizon_ms=2000.0, seed=123).run()
        runs.append((m.completed[HP], m.completed[LP], m.missed[HP],
                     m.missed[LP], tuple(np.round(m.response_ms[HP], 9))))
    assert runs[0] == runs[1]


@pytest.mark.parametrize("seed", range(6))
def test_contention_rates_properties(seed):
    """Rates are in (0, 1]; adding a stage never speeds others up."""
    rng = np.random.default_rng(seed)
    cm = ContentionModel(DeviceModel())
    profs = [StageProfile(f"s{i}", 1.0, float(rng.uniform(10, 68)),
                          float(rng.uniform(0.1, 0.9)))
             for i in range(int(rng.integers(2, 8)))]
    running = [(i, p, 34.0, len(profs)) for i, p in enumerate(profs)]
    rates = cm.rates(running)
    assert all(0 < r <= 1.0 + 1e-9 for r in rates)
    # drop one stage -> remaining rates should not decrease
    running2 = running[:-1]
    running2 = [(k, p, 34.0, len(running2)) for k, p, _, _ in running2]
    rates2 = cm.rates(running2)
    for r_new, r_old in zip(rates2, rates[:-1]):
        assert r_new >= r_old - 1e-9


@pytest.mark.parametrize("seed", range(4))
def test_fault_recovery_invariants(seed):
    """Killing a context mid-run never deadlocks; surviving contexts absorb
    its tasks; throughput stays > 0."""
    from repro.runtime.sim import FaultPlan
    rng = np.random.default_rng(seed)
    specs = random_taskset(rng, n_tasks=8)
    cfg = SchedulerConfig(n_contexts=3, n_streams=1, oversubscription=2.0)
    sched = DarisScheduler(specs, cfg, DeviceModel())
    m = SimEngine(sched, horizon_ms=2500.0, seed=seed,
                  fault_plan=FaultPlan(fail_ctx_at=(0, 800.0))).run()
    assert m.faults == 1
    assert not sched.contexts[0].alive
    assert all(t.ctx != 0 for t in sched.tasks)
    assert m.completed[HP] + m.completed[LP] > 0


def test_elastic_add_context():
    rng = np.random.default_rng(0)
    specs = random_taskset(rng, n_tasks=6)
    cfg = SchedulerConfig(n_contexts=2, n_streams=1, oversubscription=1.0)
    from repro.runtime.sim import FaultPlan
    sched = DarisScheduler(specs, cfg, DeviceModel())
    m = SimEngine(sched, horizon_ms=2000.0, seed=0,
                  fault_plan=FaultPlan(add_ctx_at=500.0)).run()
    assert len(sched.contexts) == 3
    assert m.completed[HP] + m.completed[LP] > 0
