"""Serving front-end: SubmitHandle lifecycle, durable journal semantics,
daemon socket round-trips, crash-restart zero-lost durability, and the
journal -> TraceArrival bit-identical replay contract."""
from __future__ import annotations

import hashlib
import json
import threading
import time

import pytest

from repro.api import (HP, LP, DeviceModel, ManualArrival, ServerConfig,
                       StageProfile, SubmitHandle, TaskSpec)
from repro.serve import (DarisClient, Journal, ServeDaemon, audit_zero_lost,
                         build_server, read_journal, to_trace_arrivals,
                         unfinished_submits)
from repro.serve.journal import replay_plan, submit_records


def make_spec(name, prio, stage_times, period_ms, n_sat=1.0):
    return TaskSpec(
        name=name, period_ms=period_ms, priority=prio,
        stages=[StageProfile(f"{name}/s{j}", t, n_sat=n_sat, mem_frac=0.0,
                             overhead_ms=0.0)
                for j, t in enumerate(stage_times)])


def ideal_device():
    return DeviceModel(n_units=4.0, bubble=0.0, l2_pressure=0.0)


def serving_server(specs, *, contexts=1):
    cfg = ServerConfig.sim()
    for s in specs:
        cfg.task(s, arrival=ManualArrival())
    srv = (cfg.contexts(contexts).streams(1)
           .oversubscribe(float(contexts)).device(ideal_device())
           .horizon_ms(1e6).phase_offsets(False).noise(0.0).seed(0)
           .build())
    srv.begin_serving()
    return srv


# --------------------------------------------------- SubmitHandle surface
def test_handle_lifecycle_queued_running_completed():
    srv = serving_server([make_spec("hog", HP, [30.0], 1000.0),
                          make_spec("lp", LP, [10.0], 1000.0)])
    srv.request("hog", at_ms=0.0)
    h = srv.request("lp", at_ms=5.0)
    assert h.status == SubmitHandle.PENDING      # release not pumped yet
    assert not h.done
    srv.pump(5.0)
    assert h.status == SubmitHandle.QUEUED       # lane pinned by the hog
    assert h.status == SubmitHandle.ADMITTED     # back-compat alias
    srv.pump(30.0)
    assert h.status == SubmitHandle.RUNNING
    srv.pump(45.0)
    assert h.status == SubmitHandle.COMPLETED and h.done
    assert h.response_ms == pytest.approx(35.0)  # 5 -> 40
    r = h.result()
    assert r["status"] == "completed"
    assert r["task"] == "lp" and r["release_ms"] == 5.0
    assert srv.serving_idle()
    srv.end_serving()


def test_handle_rejected_on_admission_failure():
    srv = serving_server([make_spec("lp", LP, [900.0], 1000.0)])
    h1 = srv.request("lp", at_ms=0.0)
    h2 = srv.request("lp", at_ms=1.0)
    srv.pump(1.0)
    assert h1.status in (SubmitHandle.QUEUED, SubmitHandle.RUNNING)
    assert h2.status == SubmitHandle.REJECTED and h2.done
    m = srv.end_serving()
    assert m.rejected[LP] == 1


def test_handle_missed_when_deadline_blown():
    srv = serving_server([make_spec("hp", HP, [30.0], 20.0)])
    h = srv.request("hp", at_ms=0.0)
    srv.pump(0.0)
    m = srv.end_serving()
    assert h.status == SubmitHandle.MISSED and h.done
    assert h.response_ms == pytest.approx(30.0)
    assert m.missed[HP] == 1 and m.completed[HP] == 1


def test_per_tenant_accounting():
    srv = serving_server([make_spec("lp", LP, [10.0], 1000.0)])
    srv.request("lp", at_ms=0.0, tenant="teamA")
    srv.request("lp", at_ms=40.0, tenant="teamA")
    srv.request("lp", at_ms=80.0, tenant="teamB")
    m = srv.end_serving()
    assert set(m.per_tenant) == {"teamA", "teamB"}
    assert m.per_tenant["teamA"]["submitted"] == 2
    assert m.per_tenant["teamA"]["completed"] == 2
    assert m.per_tenant["teamB"]["submitted"] == 1
    assert m.per_tenant["teamB"]["resp"]["mean"] == pytest.approx(10.0)
    assert "per_tenant" in m.summary()


def test_serving_metrics_horizon_is_elapsed_time():
    srv = serving_server([make_spec("lp", LP, [10.0], 1000.0)])
    srv.request("lp", at_ms=5.0)
    m = srv.end_serving()
    assert m.horizon_ms == pytest.approx(15.0)   # not the 1e6 guard


# -------------------------------------------------------- journal basics
def test_journal_append_and_read(tmp_path):
    p = tmp_path / "j.jsonl"
    j = Journal(p)
    j.append({"rec": "submit", "seq": 0, "task": "t", "at_ms": 1.0})
    j.append({"rec": "done", "seq": 0, "status": "completed",
              "response_ms": 9.5})
    j.close()
    recs = read_journal(p)
    assert recs[0]["rec"] == "meta" and recs[0]["version"] == 1
    assert [r["rec"] for r in recs[1:]] == ["submit", "done"]
    # reopening an existing journal must NOT write a second meta record
    Journal(p).close()
    assert [r["rec"] for r in read_journal(p)].count("meta") == 1


def test_journal_drops_torn_tail(tmp_path):
    p = tmp_path / "j.jsonl"
    j = Journal(p)
    j.append({"rec": "submit", "seq": 0, "task": "t", "at_ms": 1.0})
    j.close()
    with open(p, "a", encoding="utf-8") as f:
        f.write('{"rec": "submit", "seq": 1, "ta')    # crash mid-write
    recs = read_journal(p)
    assert [r.get("seq") for r in submit_records(recs)] == [0]


def test_unfinished_and_audit():
    recs = [
        {"rec": "meta", "version": 1},
        {"rec": "submit", "seq": 0, "task": "a", "at_ms": 1.0},
        {"rec": "submit", "seq": 1, "task": "a", "at_ms": 2.0},
        {"rec": "submit", "seq": 2, "task": "b", "at_ms": 3.0},
        {"rec": "done", "seq": 1, "status": "completed",
         "response_ms": 5.0},
        {"rec": "resubmitted", "seq": 0, "at_ms": 9.0},
    ]
    # resubmitted does not finish a seq; 0 and 2 are still owed
    assert [r["seq"] for r in unfinished_submits(recs)] == [0, 2]
    assert audit_zero_lost(recs) == [0, 2]
    recs.append({"rec": "done", "seq": 0, "status": "cancelled",
                 "response_ms": None})
    recs.append({"rec": "done", "seq": 2, "status": "missed",
                 "response_ms": 30.0})
    assert audit_zero_lost(recs) == []


def test_to_trace_arrivals_and_replay_plan():
    recs = [
        {"rec": "submit", "seq": 0, "task": "a", "at_ms": 1.0},
        {"rec": "submit", "seq": 1, "task": "b", "at_ms": 2.0},
        {"rec": "submit", "seq": 2, "task": "a", "at_ms": 7.0},
        {"rec": "cancel", "seq": 1, "at_ms": 3.0},
    ]
    arr = to_trace_arrivals(recs)
    assert set(arr) == {"a", "b"}
    assert list(arr["a"].times) == [1.0, 7.0]
    arr2 = to_trace_arrivals(recs, until_ms=2.0)
    assert list(arr2["a"].times) == [1.0]
    subs, cancels = replay_plan(recs)
    assert len(subs) == 3 and cancels == [(1, 3.0)]


# ------------------------------------------------------- daemon fixtures
def daemon_cfg(**over):
    cfg = {
        "tasks": [
            {"dnn": "resnet18", "priority": "HP", "jps": 30.0},
            {"dnn": "unet", "priority": "LP", "jps": 10.0},
        ],
        "contexts": 2, "streams": 1, "oversubscribe": 2.0,
        "seed": 0, "noise": 0.0,
        "batching": {"max_batch": 4, "scope": "model"},
    }
    cfg.update(over)
    return cfg


def start_daemon(tmp_path, name="d", cfg=None, **kw):
    d = ServeDaemon(cfg or daemon_cfg(),
                    socket_path=str(tmp_path / f"{name}.sock"),
                    journal_path=str(tmp_path / "journal.jsonl"),
                    checkpoint_path=str(tmp_path / "ckpt.msgpack"), **kw)
    th = threading.Thread(target=d.run, daemon=True)
    th.start()
    c = DarisClient(d.socket_path)
    c.wait_up()
    return d, th, c


def test_daemon_round_trip(tmp_path):
    d, th, c = start_daemon(tmp_path, time_scale=200.0, tick_ms=1.0)
    assert c.ping()["ok"]
    s0 = c.submit("resnet18", tenant="teamA")
    assert s0["status"] in ("queued", "running", "completed")
    s1 = c.submit("unet", tenant="teamB")
    r0 = c.result(s0["seq"], timeout_s=30.0)
    assert r0["status"] in ("completed", "missed")
    assert r0["tenant"] == "teamA" and r0["response_ms"] is not None
    st = c.status(s1["seq"])
    assert st["ok"] and st["task"] == "unet"
    stats = c.stats()
    assert stats["submitted"] == 2
    assert "completed" in stats["snapshot"]
    assert "cancelled" in stats["snapshot"]
    # unknown task / unknown seq are clean errors, not daemon deaths
    from repro.serve.client import DaemonError
    with pytest.raises(DaemonError, match="KeyError"):
        c.submit("nonexistent-model")
    with pytest.raises(DaemonError, match="unknown seq"):
        c.cancel(999)
    out = c.drain()
    th.join(timeout=10.0)
    assert not th.is_alive()
    assert out["lost"] == []
    assert out["summary"]["jps_hp"] > 0.0       # the HP job completed
    assert audit_zero_lost(read_journal(tmp_path / "journal.jsonl")) == []


def test_daemon_cancel_round_trip(tmp_path):
    # virtual time frozen at ticks: submissions stay queued long enough
    # to be cancelled deterministically
    d, th, c = start_daemon(tmp_path, time_scale=0.0, tick_ms=1.0)
    s = c.submit("unet", tenant="teamA")
    assert s["status"] == "running"      # empty engine: dispatches at once
    out = c.cancel(s["seq"])
    assert out["status"] == "cancelled"
    r = c.result(s["seq"], timeout_s=5.0)
    assert r["status"] == "cancelled"
    fin = c.drain()
    th.join(timeout=10.0)
    assert fin["summary"]["cancelled_lp"] == 1
    assert fin["lost"] == []
    recs = read_journal(tmp_path / "journal.jsonl")
    assert [r["rec"] for r in recs if r.get("seq") == s["seq"]] \
        == ["submit", "cancel", "done"]


def test_daemon_sigterm_restart_zero_lost(tmp_path):
    """The durability contract end-to-end: acknowledge work, die by
    SIGTERM with it unfinished, restart on the same journal+checkpoint,
    finish every acknowledged seq under its original identity."""
    # time barely moves: nothing can finish before the TERM
    d1, th1, c1 = start_daemon(tmp_path, name="d1", time_scale=1e-7)
    seqs = [c1.submit("resnet18", tenant="teamA")["seq"] for _ in range(3)]
    seqs.append(c1.submit("unet", tenant="teamB")["seq"])
    d1._on_signal(None, None)            # what SIGTERM delivers
    th1.join(timeout=10.0)
    assert not th1.is_alive()

    recs = read_journal(tmp_path / "journal.jsonl")
    assert audit_zero_lost(recs) == seqs                # owed, not lost
    assert any(r["rec"] == "checkpoint" for r in recs)

    d2, th2, c2 = start_daemon(tmp_path, name="d2", time_scale=500.0)
    for seq in seqs:
        r = c2.result(seq, timeout_s=30.0)
        assert r["status"] in ("completed", "missed")
    fin = c2.drain()
    th2.join(timeout=10.0)
    assert fin["lost"] == []
    recs = read_journal(tmp_path / "journal.jsonl")
    assert audit_zero_lost(recs) == []
    assert sum(r["rec"] == "resubmitted" for r in recs) == len(seqs)


# ---------------------------------------------- bit-identical replay
def _digest(m):
    payload = repr((m.completed, m.missed, m.completed_inputs,
                    sorted(m.batch_hist.items()),
                    {p: [x.hex() for x in xs]
                     for p, xs in m.response_ms.items()}))
    return hashlib.sha256(payload.encode()).hexdigest()


def test_journal_replay_is_bit_identical(tmp_path):
    """Golden serving contract (sibling of test_engine_golden): traffic
    recorded by a live daemon, replayed from the journal as TraceArrival
    into a freshly built engine, reproduces the run bit-exactly —
    same counts and SHA-256 over IEEE-754 response times.

    Batching is off: the lazy-dispatch hold keys off future-arrival
    knowledge a live daemon cannot have (see ``to_trace_arrivals``), so
    the bit-exact contract covers hold-free traffic."""
    cfg = daemon_cfg()
    del cfg["batching"]
    # time_scale=0: stamps come purely from the deterministic tick
    d, th, c = start_daemon(tmp_path, cfg=cfg, time_scale=0.0, tick_ms=5.0)
    for i in range(12):
        c.submit("resnet18" if i % 3 else "unet",
                 tenant="teamA" if i % 2 else "teamB")
    c.drain()
    th.join(timeout=10.0)
    live = d.final_metrics
    assert sum(live.completed.values()) > 0

    recs = read_journal(tmp_path / "journal.jsonl")
    arrivals = to_trace_arrivals(recs)
    replay = build_server(cfg, arrivals=arrivals)
    m = replay.drain()
    assert _digest(m) == _digest(live)


def test_replay_cli_and_audit_cli(tmp_path):
    from repro.serve.__main__ import main
    cfg_path = tmp_path / "serve.json"
    cfg_path.write_text(json.dumps(daemon_cfg()))
    d, th, c = start_daemon(tmp_path, time_scale=0.0, tick_ms=5.0)
    c.submit("unet")
    c.drain()
    th.join(timeout=10.0)
    jrn = str(tmp_path / "journal.jsonl")
    assert main(["audit", "--journal", jrn]) == 0
    assert main(["replay", "--config", str(cfg_path),
                 "--journal", jrn]) == 0
    # an owed seq flips the audit to failing
    Journal(jrn).append({"rec": "submit", "seq": 99, "task": "unet",
                         "at_ms": 1e6})
    assert main(["audit", "--journal", jrn]) == 1


def test_build_server_requires_tasks():
    with pytest.raises(ValueError, match="at least one task"):
        build_server({"tasks": []})
