"""End-to-end behaviour tests: the paper's headline claims hold in-sim,
checkpoint round-trips, data pipeline resume, real-execution engine."""
import numpy as np
import pytest

from repro.core.scheduler import DarisScheduler, SchedulerConfig
from repro.core.task import HP, LP
from repro.runtime.contention import DeviceModel
from repro.runtime.sim import SimEngine
from repro.serving.profiles import TABLE1, device
from repro.serving.requests import table2_taskset


def _run(nc, ns, os_, dnn="resnet18", horizon=4000.0, **kw):
    sched = DarisScheduler(
        table2_taskset(dnn),
        SchedulerConfig(n_contexts=nc, n_streams=ns, oversubscription=os_,
                        **kw), device())
    return SimEngine(sched, horizon_ms=horizon, seed=0).run(), sched


def test_no_hp_misses_and_low_lp_dmr():
    m, _ = _run(6, 1, 6.0)
    assert m.dmr(HP) == 0.0                  # paper: no HP misses observed
    assert m.dmr(LP) < 0.10                  # paper: <7% worst (MPS)


def test_hp_responses_faster_than_lp():
    m, _ = _run(6, 1, 6.0)
    hp = m.resp_stats(HP)["mean"]
    lp = m.resp_stats(LP)["mean"]
    assert hp < lp                            # paper: ~2.5x faster
    assert lp / hp > 1.5


def test_oversubscription_beats_batching_baseline():
    """DARIS (no batching) exceeds the pure-batching upper baseline
    (paper: +13% for RN18); without oversubscription it falls below."""
    best = 0.0
    for nc in (4, 6, 8):
        m, _ = _run(nc, 1, float(nc))
        best = max(best, m.jps)
    assert best > TABLE1["resnet18"][1]       # beats 1025 JPS
    m_iso, _ = _run(8, 1, 1.0)
    assert m_iso.jps <= best


def test_overload_hpa_protects_hp():
    from repro.serving.requests import ratio_taskset
    upper = TABLE1["resnet18"][1]
    specs = ratio_taskset("resnet18", 0.85, 30, upper * 2.0 / 30)
    sched = DarisScheduler(specs, SchedulerConfig(
        n_contexts=6, n_streams=1, oversubscription=6.0, overload_hpa=True),
        device())
    m = SimEngine(sched, horizon_ms=3000.0, seed=0).run()
    assert m.dmr(HP) < 0.02                   # HPA: near-zero HP misses
    assert m.rejected[HP] > 0                 # at the cost of HP rejections


def test_migration_happens_under_pressure():
    m, sched = _run(6, 1, 2.0)
    assert sched.migrations > 0


def test_scheduler_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_scheduler_state, save_scheduler_state
    m, sched = _run(4, 1, 2.0, horizon=1500.0)
    path = str(tmp_path / "sched.msgpack")
    save_scheduler_state(sched, path)
    sched2 = DarisScheduler(
        table2_taskset("resnet18"),
        SchedulerConfig(n_contexts=4, n_streams=1, oversubscription=2.0),
        device())
    load_scheduler_state(sched2, path)
    for a, b in zip(sched.tasks, sched2.tasks):
        assert a.ctx == b.ctx
        assert a.mret.task_mret() == pytest.approx(b.mret.task_mret())


def test_params_checkpoint_roundtrip(tmp_path):
    import jax.numpy as jnp
    from repro.checkpoint import load_pytree, save_pytree
    from repro.configs import get_reduced
    from repro.models import build_model
    m = build_model(get_reduced("smollm-135m"))
    params = m.init_params(0)
    save_pytree(params, str(tmp_path / "p"), step=7)
    zeros = __import__("jax").tree.map(lambda a: jnp.zeros_like(a), params)
    restored = load_pytree(zeros, str(tmp_path / "p"))
    flat_a = __import__("jax").tree.leaves(params)
    flat_b = __import__("jax").tree.leaves(restored)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic_resume():
    from repro.data.pipeline import TokenPipeline
    p1 = TokenPipeline(1000, 4, 32, seed=3)
    b0 = p1.next_batch()
    b1 = p1.next_batch()
    state = p1.state_dict()
    b2 = p1.next_batch()
    p2 = TokenPipeline(1000, 4, 32, seed=3)
    p2.load_state_dict(state)
    b2r = p2.next_batch()
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])
    assert b0["tokens"].max() < 1000
    assert not np.array_equal(b0["tokens"], b1["tokens"])


@pytest.mark.slow
def test_realtime_engine_with_staged_lm_decode():
    """Real staged LM decode under DARIS: one decode step per job, split
    into stage programs; inter-stage state carries hidden + KV-cache
    slices (serving.staging.slice_cache) so migrations move real state."""
    from repro.api import ServerConfig
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.serving.engine import staged_lm_taskspec
    model = build_model(get_reduced("smollm-135m").replace(n_layers=8))
    spec = staged_lm_taskspec(model, priority=HP, jps=10.0, n_stages=4,
                              prompt_len=8, batch=1, tag="-hp")
    srv = (ServerConfig.realtime()
           .tasks([spec])
           .contexts(2).oversubscribe(2.0)
           .device(DeviceModel(n_units=2.0))
           .horizon_ms(1200.0)
           .build())
    m = srv.run()
    assert m.completed[HP] > 0
    assert m.resp_stats(HP)["mean"] > 0


@pytest.mark.slow
def test_realtime_engine_with_cnn_stages():
    """Real JAX execution: tiny staged CNNs under DARIS on wall clock."""
    from repro.core.scheduler import DarisScheduler, SchedulerConfig
    from repro.models.cnn import build_resnet
    from repro.serving.engine import RealtimeEngine, staged_cnn_taskspec
    model = build_resnet(18, width=8)
    specs = [
        staged_cnn_taskspec(model, priority=HP, jps=20.0, input_hw=32,
                            tag="-hp"),
        staged_cnn_taskspec(model, priority=LP, jps=20.0, input_hw=32,
                            tag="-lp0"),
    ]
    sched = DarisScheduler(specs, SchedulerConfig(
        n_contexts=2, n_streams=1, oversubscription=2.0),
        DeviceModel(n_units=2.0))
    eng = RealtimeEngine(sched, horizon_ms=1500.0, input_hw=32)
    m = eng.run()
    assert m.completed[HP] > 0
    assert m.resp_stats(HP)["mean"] > 0
