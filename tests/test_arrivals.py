"""Trace-replay edge cases (runtime/arrivals.py) + deprecated-shim
warnings (runtime/sim.py, serving/engine.py)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.api import ServerConfig, TraceArrival
from repro.core.scheduler import DarisScheduler, SchedulerConfig
from repro.core.task import HP, LP, StageProfile, TaskSpec


def _spec(name="trace-task", period=50.0, priority=LP):
    return TaskSpec(name=name, period_ms=period, priority=priority,
                    stages=[StageProfile(name=f"{name}/s0", t_alone_ms=2.0,
                                         n_sat=20.0, mem_frac=0.3)])


def _server(spec, times, horizon=200.0):
    return (ServerConfig.sim()
            .task(_spec() if spec is None else spec,
                  arrival=TraceArrival(times))
            .contexts(1).streams(1).oversubscribe(1.0)
            .horizon_ms(horizon).seed(0).noise(0.0).build())


class TestTraceReplay:
    def test_empty_trace_never_releases(self):
        server = _server(None, [])
        m = server.drain()
        assert sum(m.completed.values()) == 0
        assert sum(m.rejected.values()) == 0
        assert sum(m.unfinished.values()) == 0

    def test_empty_trace_start_returns_none(self):
        proc = TraceArrival([])
        assert proc.start(_spec(), np.random.default_rng(0)) is None

    def test_out_of_order_times_sort_deterministically(self):
        # the contract: out-of-order traces are sorted, not an error,
        # and two replays of the same shuffled trace behave identically
        proc = TraceArrival([50.0, 10.0, 30.0])
        assert proc.times == [10.0, 30.0, 50.0]
        shuffled = [90.0, 10.0, 50.0, 30.0, 70.0]
        runs = []
        for _ in range(2):
            m = _server(None, list(shuffled)).drain()
            runs.append((dict(m.completed), sorted(m.response_ms[LP])))
        assert runs[0] == runs[1]
        assert runs[0][0][LP] == len(shuffled)

    def test_release_order_is_sorted_order(self):
        server = _server(None, [90.0, 10.0, 50.0])
        server._cfg  # built fine
        core = server.core
        m = server.drain()
        assert m.completed[LP] == 3
        # releases fired at the sorted times: every response started at
        # its own (sorted) release, so none can pre-date the first time
        assert min(core.metrics.response_ms[LP]) >= 0.0

    def test_trace_past_horizon_is_truncated(self):
        times = [10.0, 50.0, 150.0, 500.0, 900.0]
        server = _server(None, times, horizon=200.0)
        m = server.drain()
        # only releases at t <= horizon fire; the rest never existed
        assert m.completed[LP] == 3
        assert m.unfinished[LP] == 0

    def test_trace_exactly_at_horizon_admits_but_cannot_finish(self):
        # a release stamped exactly at the horizon is admitted (it is
        # inside the run) but time ends before its stage can execute:
        # the horizon sweep counts it as unfinished, not completed
        server = _server(None, [10.0, 200.0], horizon=200.0)
        m = server.drain()
        assert m.completed[LP] == 1
        assert m.unfinished[LP] == 1

    def test_duplicate_times_release_each(self):
        server = _server(None, [20.0, 20.0, 20.0])
        m = server.drain()
        assert m.completed[LP] + m.rejected[LP] == 3


class TestDeprecatedShims:
    def _sched(self):
        return DarisScheduler([_spec(priority=HP)],
                              SchedulerConfig(n_contexts=1, n_streams=1,
                                              oversubscription=1.0))

    def test_sim_engine_warns_on_construction(self):
        from repro.runtime.sim import SimEngine
        with pytest.warns(DeprecationWarning, match="SimEngine is deprecated"):
            SimEngine(self._sched(), horizon_ms=100.0)

    def test_realtime_engine_warns_on_construction(self):
        from repro.serving.engine import RealtimeEngine
        with pytest.warns(DeprecationWarning,
                          match="RealtimeEngine is deprecated"):
            RealtimeEngine(self._sched(), horizon_ms=100.0)
