"""Integrity of the shipped dry-run/roofline artifacts: every assigned
(arch x shape x mesh) cell is present — compiled OK or explicitly skipped
by the long_500k full-attention rule — and roofline rows are well-formed."""
import json
import pathlib

import pytest

from repro.configs import ARCH_IDS, SHAPES, cells

ART = pathlib.Path(__file__).parent.parent / "artifacts" / "dryrun"

pytestmark = pytest.mark.skipif(not ART.exists(),
                                reason="dry-run artifacts not generated")


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_all_cells_present_and_ok(mesh):
    n_ok = n_skip = 0
    for arch in ARCH_IDS:
        for cell, runnable, reason in cells(arch):
            p = ART / f"{arch}__{cell.name}__{mesh}.json"
            assert p.exists(), f"missing artifact {p.name}"
            art = json.loads(p.read_text())
            if runnable:
                assert art["status"] == "ok", (p.name, art.get("error"))
                assert art["cost_per_device"]["flops"] > 0
                assert art["hlo_cost_per_device"]["flops"] > 0
                assert art["peak_bytes_per_device"] > 0
                n_ok += 1
            else:
                assert art["status"] == "skipped"
                assert "full-attention" in art["reason"]
                n_skip += 1
    assert n_ok == 33 and n_skip == 7        # 40 assigned cells


def test_multi_pod_mesh_shape():
    art = json.loads((ART / "smollm-135m__train_4k__multi.json").read_text())
    assert art["mesh_shape"] == {"pod": 2, "data": 16, "model": 16}
    assert art["n_chips"] == 512
    single = json.loads((ART / "smollm-135m__train_4k__single.json").read_text())
    assert single["n_chips"] == 256


def test_roofline_rows_cover_runnable_cells():
    rl = pathlib.Path(__file__).parent.parent / "artifacts" / "roofline.json"
    if not rl.exists():
        pytest.skip("roofline.json not generated")
    rows = json.loads(rl.read_text())
    assert len(rows) == 33
    for r in rows:
        assert r["dominant"] in ("compute", "memory", "collective")
        assert 0.0 <= r["roofline_fraction"] <= 1.0 + 1e-9
        assert r["t_compute_s"] >= 0 and r["t_collective_s"] >= 0
