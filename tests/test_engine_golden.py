"""Golden-snapshot determinism tests for the sim event engine.

The ten ``mps_/str_/mpsstr_`` entries of the fixture
``tests/golden/engine_golden.json`` were captured from the
pre-vectorization engine (PR 2 head); the ``cluster_``/``chaos_``
entries were captured from the heap engine at the point the epoch
engine landed. These tests assert that the current engine reproduces
those runs BIT-IDENTICALLY — counts exactly, response times by SHA-256
over their IEEE-754 hex forms — across all three policies (MPS, STR,
MPS+STR), with dynamic batching on and off, plus a heterogeneous
cluster and a chaos (faults + brownout + watchdog) run.

``tests/test_epoch_engine.py`` replays every fixture through the
array-programmed epoch engine and asserts the same digests — the
twin-path bit-identity contract.

Regenerate (only when a *deliberate* semantic change is made, never to
paper over a perf refactor):

    PYTHONPATH=src python -m tests.test_engine_golden --regen
"""
from __future__ import annotations

import hashlib
import json
import pathlib

import pytest

GOLDEN = pathlib.Path(__file__).resolve().parent / "golden" / "engine_golden.json"


def _scenarios():
    """name -> builder returning an UNBUILT ServerConfig, so callers can
    select the sim engine (``.engine("heap"|"epoch")``) before build."""
    from repro.api import Brownout, ServerConfig
    from repro.core.scheduler import SchedulerConfig
    from repro.core.batching import BatchPolicy
    from repro.serving.profiles import device
    from repro.serving.requests import table2_taskset
    from benchmarks.common import make_server

    def cfg(nc, ns, os_, batched):
        pol = BatchPolicy(max_batch=4) if batched else None
        return SchedulerConfig(n_contexts=nc, n_streams=ns,
                               oversubscription=os_, batch_policy=pol)

    def mk(specs, c, horizon):
        return make_server(specs, c, horizon_ms=horizon, seed=0)

    out = {}
    for batched in (False, True):
        tag = "batch" if batched else "plain"
        out[f"mps_unet_4x1_os4_{tag}"] = (
            lambda b=batched: mk(table2_taskset("unet"), cfg(4, 1, 4.0, b), 1200.0))
        out[f"str_unet_1x4_{tag}"] = (
            lambda b=batched: mk(table2_taskset("unet"), cfg(1, 4, 1.0, b), 1200.0))
        out[f"mpsstr_unet_2x2_os2_{tag}"] = (
            lambda b=batched: mk(table2_taskset("unet"), cfg(2, 2, 2.0, b), 1200.0))
        out[f"mps_rn18_6x1_os6_{tag}"] = (
            lambda b=batched: mk(table2_taskset("resnet18"), cfg(6, 1, 6.0, b), 700.0))
        out[f"mpsstr_rn18_3x3_os3_{tag}"] = (
            lambda b=batched: mk(table2_taskset("resnet18"), cfg(3, 3, 3.0, b), 500.0))
    # heterogeneous cluster (fig13-shaped): global admission + placement
    out["cluster_rn18_2gpu"] = lambda: (
        ServerConfig.cluster(2, device_models=["a100", "v100"])
        .tasks(table2_taskset("resnet18"))
        .contexts(3).streams(1).oversubscribe(3.0)
        .device(device()).horizon_ms(600.0).seed(0))
    # chaos (fig14-shaped): faults + stalls + mid-run brownout with the
    # stage watchdog armed — pins the kill/retry/rate-shift hot paths
    out["chaos_rn18_4x1_os4"] = lambda: (
        mk(table2_taskset("resnet18"), cfg(4, 1, 4.0, False), 600.0)
        .chaos(seed=3, stage_fault_rate=0.02, stall_rate=0.05,
               stall_ms=3.0, watchdog_kappa=6.0,
               brownouts=(Brownout(150.0, 330.0, device=0,
                                   slow_factor=2.0),)))
    return out


def _capture(build, engine: str = "heap") -> dict:
    """Run one scenario and reduce its RunMetrics to a bit-exact digest."""
    from repro.core.task import HP, LP

    server = build().engine(engine).build()
    m = server.run()

    def float_digest(xs):
        h = hashlib.sha256()
        for x in xs:
            h.update(float(x).hex().encode())
        return h.hexdigest()

    return {
        "completed": {str(p): m.completed[p] for p in (HP, LP)},
        "missed": {str(p): m.missed[p] for p in (HP, LP)},
        "rejected": {str(p): m.rejected[p] for p in (HP, LP)},
        "unfinished": {str(p): m.unfinished[p] for p in (HP, LP)},
        "completed_inputs": {str(p): m.completed_inputs[p] for p in (HP, LP)},
        "batch_hist": {str(k): v for k, v in sorted(m.batch_hist.items())},
        "migrations": m.migrations,
        "stragglers": m.stragglers,
        "skipped_releases": m.skipped_releases,
        "n_resp": {str(p): len(m.response_ms[p]) for p in (HP, LP)},
        "resp_sha256": {str(p): float_digest(m.response_ms[p])
                        for p in (HP, LP)},
    }


@pytest.mark.parametrize("name", sorted(_scenarios()))
def test_engine_matches_golden(name):
    golden = json.loads(GOLDEN.read_text())
    assert name in golden, f"{name} missing from fixture; --regen?"
    got = _capture(_scenarios()[name])
    assert got == golden[name]


def _regen() -> None:
    GOLDEN.parent.mkdir(parents=True, exist_ok=True)
    out = {name: _capture(build)
           for name, build in sorted(_scenarios().items())}
    GOLDEN.write_text(json.dumps(out, indent=1))
    print(f"wrote {GOLDEN} ({len(out)} scenarios)")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
