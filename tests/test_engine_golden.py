"""Golden-snapshot determinism tests for the sim event engine.

The fixture ``tests/golden/engine_golden.json`` was captured from the
pre-vectorization engine (PR 2 head). These tests assert that the current
engine reproduces those runs BIT-IDENTICALLY — counts exactly, response
times by SHA-256 over their IEEE-754 hex forms — across all three
policies (MPS, STR, MPS+STR), with dynamic batching on and off.

Regenerate (only when a *deliberate* semantic change is made, never to
paper over a perf refactor):

    PYTHONPATH=src python -m tests.test_engine_golden --regen
"""
from __future__ import annotations

import hashlib
import json
import pathlib

import pytest

GOLDEN = pathlib.Path(__file__).resolve().parent / "golden" / "engine_golden.json"


def _scenarios():
    from repro.core.scheduler import SchedulerConfig
    from repro.core.batching import BatchPolicy
    from repro.serving.requests import table2_taskset

    def cfg(nc, ns, os_, batched):
        pol = BatchPolicy(max_batch=4) if batched else None
        return SchedulerConfig(n_contexts=nc, n_streams=ns,
                               oversubscription=os_, batch_policy=pol)

    out = {}
    for batched in (False, True):
        tag = "batch" if batched else "plain"
        out[f"mps_unet_4x1_os4_{tag}"] = (
            lambda b=batched: (table2_taskset("unet"), cfg(4, 1, 4.0, b), 1200.0))
        out[f"str_unet_1x4_{tag}"] = (
            lambda b=batched: (table2_taskset("unet"), cfg(1, 4, 1.0, b), 1200.0))
        out[f"mpsstr_unet_2x2_os2_{tag}"] = (
            lambda b=batched: (table2_taskset("unet"), cfg(2, 2, 2.0, b), 1200.0))
        out[f"mps_rn18_6x1_os6_{tag}"] = (
            lambda b=batched: (table2_taskset("resnet18"), cfg(6, 1, 6.0, b), 700.0))
        out[f"mpsstr_rn18_3x3_os3_{tag}"] = (
            lambda b=batched: (table2_taskset("resnet18"), cfg(3, 3, 3.0, b), 500.0))
    return out


def _capture(build) -> dict:
    """Run one scenario and reduce its RunMetrics to a bit-exact digest."""
    from repro.core.task import HP, LP
    from benchmarks.common import make_server

    specs, cfg, horizon = build()
    server = make_server(specs, cfg, horizon_ms=horizon, seed=0).build()
    m = server.run()

    def float_digest(xs):
        h = hashlib.sha256()
        for x in xs:
            h.update(float(x).hex().encode())
        return h.hexdigest()

    return {
        "completed": {str(p): m.completed[p] for p in (HP, LP)},
        "missed": {str(p): m.missed[p] for p in (HP, LP)},
        "rejected": {str(p): m.rejected[p] for p in (HP, LP)},
        "unfinished": {str(p): m.unfinished[p] for p in (HP, LP)},
        "completed_inputs": {str(p): m.completed_inputs[p] for p in (HP, LP)},
        "batch_hist": {str(k): v for k, v in sorted(m.batch_hist.items())},
        "migrations": m.migrations,
        "stragglers": m.stragglers,
        "skipped_releases": m.skipped_releases,
        "n_resp": {str(p): len(m.response_ms[p]) for p in (HP, LP)},
        "resp_sha256": {str(p): float_digest(m.response_ms[p])
                        for p in (HP, LP)},
    }


@pytest.mark.parametrize("name", sorted(_scenarios()))
def test_engine_matches_golden(name):
    golden = json.loads(GOLDEN.read_text())
    assert name in golden, f"{name} missing from fixture; --regen?"
    got = _capture(_scenarios()[name])
    assert got == golden[name]


def _regen() -> None:
    GOLDEN.parent.mkdir(parents=True, exist_ok=True)
    out = {name: _capture(build)
           for name, build in sorted(_scenarios().items())}
    GOLDEN.write_text(json.dumps(out, indent=1))
    print(f"wrote {GOLDEN} ({len(out)} scenarios)")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
