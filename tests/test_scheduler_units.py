"""Unit tests: MRET (Eq 1-2), virtual deadlines (Eq 8), partitions (Eq 9),
the 8-level stage queue, Algorithm 1 balance, admission (Eq 11-12)."""
import math

import pytest

from repro.core.mret import StageMret, TaskMret
from repro.core.partition import ceil_even, make_contexts
from repro.core.scheduler import DarisScheduler, SchedulerConfig
from repro.core.stage_queue import QueueConfig, StageQueue, stage_level
from repro.core.task import (HP, LP, Job, StageInstance, StageProfile, Task,
                             TaskSpec)
from repro.runtime.contention import DeviceModel


def _spec(name="t", period=30.0, prio=HP, n_stages=3):
    stages = [StageProfile(f"{name}/s{i}", 1.0, 40.0, 0.4)
              for i in range(n_stages)]
    return TaskSpec(name=name, period_ms=period, priority=prio, stages=stages)


def test_mret_is_window_max():
    m = StageMret(afet_ms=9.0, ws=3)
    assert m.value() == 9.0                    # AFET before history
    for v in (1.0, 5.0, 2.0):
        m.observe(v)
    assert m.value() == 5.0
    m.observe(0.5)                             # evicts 1.0
    assert m.value() == 5.0
    m.observe(0.1)
    m.observe(0.1)                             # evicts 5.0 and 2.0
    assert m.value() == 0.5


def test_task_mret_sum_and_vdl_split():
    t = TaskMret([2.0, 6.0], ws=5)
    assert t.task_mret() == 8.0
    vdls = t.virtual_deadlines(40.0)
    assert vdls == pytest.approx([10.0, 30.0])  # Eq. 8 proportional split
    assert sum(vdls) == pytest.approx(40.0)


def test_ceil_even():
    assert ceil_even(11.2) == 12
    assert ceil_even(12.0) == 12
    assert ceil_even(12.1) == 14


def test_partition_eq9_oversubscription():
    # OS=1: disjoint; OS=Nc: full sharing
    iso = make_contexts(4, 1, 1.0, 64)
    assert all(len(c.units) == 16 for c in iso)
    union = set().union(*[c.units for c in iso])
    assert len(union) == 64
    for a in range(4):
        for b in range(a + 1, 4):
            assert not (iso[a].units & iso[b].units)
    full = make_contexts(4, 1, 4.0, 64)
    assert all(len(c.units) == 64 for c in full)
    mid = make_contexts(4, 1, 2.0, 64)
    assert all(len(c.units) == 32 for c in mid)   # overlapping neighbours
    assert mid[0].units & mid[1].units


def test_stage_queue_eight_levels_and_edf():
    q = StageQueue(QueueConfig())
    hp_task = Task(spec=_spec("hp", prio=HP), index=0)
    lp_task = Task(spec=_spec("lp", prio=LP), index=1)

    def inst(task, stage_idx, vdl, missed=False):
        job = Job(task=task, release_ms=0.0)
        job.stage_idx = stage_idx
        job.vdl_missed_prev = missed
        return StageInstance(job=job, enqueue_ms=0.0, virtual_deadline_ms=vdl)

    lp_last = inst(lp_task, 2, 1.0)            # LP last stage, urgent vdl
    hp_mid = inst(hp_task, 1, 100.0)           # HP middle stage, late vdl
    hp_boost = inst(hp_task, 1, 200.0, missed=True)
    hp_last = inst(hp_task, 2, 300.0)
    for i in (lp_last, hp_mid, hp_boost, hp_last):
        q.push(i)
    # HP always precedes LP; last > boost > plain within HP
    assert q.pop() is hp_last
    assert q.pop() is hp_boost
    assert q.pop() is hp_mid
    assert q.pop() is lp_last

    # EDF within the same level
    q2 = StageQueue(QueueConfig())
    a = inst(hp_task, 1, 50.0)
    b = inst(hp_task, 1, 10.0)
    q2.push(a)
    q2.push(b)
    assert q2.pop() is b


def test_stage_level_ablations():
    task = Task(spec=_spec("lp", prio=LP), index=0)
    job = Job(task=task, release_ms=0.0)
    job.stage_idx = task.spec.n_stages - 1
    inst = StageInstance(job=job, enqueue_ms=0.0, virtual_deadline_ms=1.0)
    assert stage_level(inst, QueueConfig()) == 4 + 0 + 1
    assert stage_level(inst, QueueConfig(no_last=True)) == 4 + 2 + 1
    assert stage_level(inst, QueueConfig(no_fixed=True)) < 4


def test_algorithm1_balances_and_pins_hp():
    specs = ([_spec(f"hp{i}", prio=HP) for i in range(4)]
             + [_spec(f"lp{i}", prio=LP) for i in range(8)])
    sched = DarisScheduler(specs, SchedulerConfig(n_contexts=4, n_streams=1,
                                                  oversubscription=2.0),
                           DeviceModel())
    per_ctx = [0.0] * 4
    for t in sched.tasks:
        per_ctx[t.ctx] += t.utilization(0.0)
        if t.priority == HP:
            assert t.fixed_ctx
    assert max(per_ctx) - min(per_ctx) < max(per_ctx) * 0.5 + 1e-9


def test_admission_eq12_and_migration():
    specs = [_spec("hp0", prio=HP, period=10.0)]
    sched = DarisScheduler(specs, SchedulerConfig(n_contexts=2, n_streams=1,
                                                  oversubscription=1.0),
                           DeviceModel())
    # a LP task too big for remaining utilization gets rejected
    fat = Task(spec=_spec("fat", prio=LP, period=1.0), index=99)
    fat.mret = sched.tasks[0].mret.__class__([50.0], ws=5)
    fat.ctx = 0
    assert sched.on_release(fat, 0.0) is None
    assert sched.rejections and sched.rejections[0].priority == LP
    # HP bypasses admission by default
    hp = sched.tasks[0]
    assert sched.on_release(hp, 0.0) is not None
