"""SchedCheck: static analyzer verdict classes, timeline epoch
splitting, autoscale what-if epochs, Eq. 8 slice accounting, soundness
of the worst-case rate bound against the live contention model, the
bound-vs-sim differential oracle, the ServerConfig/daemon-config wiring
(satellite: duplicate reconfigure_at rejection), and the CLI."""
from __future__ import annotations

import json
import math

import pytest

from repro.analysis.schedcheck import (CONDITIONAL, GUARANTEED,
                                       UNSCHEDULABLE, UnschedulableError,
                                       analyze_config, differential_check,
                                       worst_verdict)
from repro.analysis.schedcheck.analyzer import _worst_speed
from repro.api import HP, LP, Brownout, ChaosPlan, ServerConfig
from repro.runtime.contention import ContentionModel, DeviceModel
from repro.serve.config import check_schedulability

from tests.test_serve import ideal_device, make_spec


def light_cfg(horizon=1000.0):
    """2 tasks, 2 contexts, os=2 on the ideal device: comfortably
    schedulable, finite bounds everywhere."""
    sc = ServerConfig.sim().horizon_ms(horizon)
    sc.task(make_spec("hp", HP, [5.0], 50.0))
    sc.task(make_spec("lp", LP, [8.0], 100.0))
    sc.device(ideal_device()).contexts(2).streams(1).oversubscribe(2.0)
    sc.phase_offsets(False).noise(0.0).seed(0)
    return sc


# ------------------------------------------------------- verdict classes
def test_light_config_hp_guaranteed():
    rep = analyze_config(light_cfg(), label="light")
    assert rep.hp_verdict == GUARANTEED
    assert len(rep.epochs) == 1 and rep.epochs[0].cause == "build"
    tv = rep.task_verdicts("hp")[0]
    assert tv.binding == "wcrt-within-deadline"
    assert tv.wcrt_ms <= tv.deadline_ms and tv.slack_ms > 0
    bound = rep.hp_bound_ms()
    assert math.isfinite(bound) and bound >= tv.solo_ms


def test_wcet_exceeds_deadline_unschedulable():
    sc = ServerConfig.sim().horizon_ms(500.0)
    sc.task(make_spec("hp", HP, [60.0], 50.0))     # solo 60ms > D 50ms
    sc.device(ideal_device()).contexts(1).streams(1).oversubscribe(1.0)
    sc.phase_offsets(False).noise(0.0).seed(0)
    rep = analyze_config(sc)
    tv = rep.task_verdicts("hp")[0]
    assert (tv.verdict, tv.binding) == (UNSCHEDULABLE,
                                        "wcet-exceeds-deadline")
    assert rep.verdict == UNSCHEDULABLE
    assert rep.hp_bound_ms() > tv.deadline_ms


def test_eq11_overload_unschedulable():
    sc = ServerConfig.sim().horizon_ms(500.0)
    sc.task(make_spec("hp-a", HP, [40.0], 50.0))   # 0.8 lanes solo
    sc.task(make_spec("hp-b", HP, [40.0], 50.0))   # + 0.8 > 1 stream
    sc.device(ideal_device()).contexts(1).streams(1).oversubscribe(1.0)
    sc.phase_offsets(False).noise(0.0).seed(0)
    rep = analyze_config(sc)
    assert {tv.binding for tv in rep.epochs[0].tasks} == {"eq11-overload"}
    assert rep.hp_verdict == UNSCHEDULABLE


def test_open_loop_arrivals_are_conditional():
    sc = light_cfg().open_loop(100.0, seed=1)
    rep = analyze_config(sc)
    tv = rep.task_verdicts("hp")[0]
    assert (tv.verdict, tv.binding) == (CONDITIONAL, "arrival-process")
    assert tv.wcrt_ms == math.inf
    assert rep.hp_verdict == CONDITIONAL
    assert any("open-loop" in a for a in rep.assumptions)


def test_chaos_fault_rate_caps_verdict():
    sc = light_cfg().chaos(ChaosPlan(seed=0, stage_fault_rate=0.01))
    rep = analyze_config(sc)
    tv = rep.task_verdicts("hp")[0]
    assert (tv.verdict, tv.binding) == (CONDITIONAL, "chaos-fault-rate")
    # the WCRT number itself is still finite — only the guarantee is off
    assert math.isfinite(tv.wcrt_ms)


def test_verdict_ordering():
    assert worst_verdict([GUARANTEED, CONDITIONAL]) == CONDITIONAL
    assert worst_verdict([CONDITIONAL, UNSCHEDULABLE]) == UNSCHEDULABLE
    assert worst_verdict([]) == GUARANTEED


# ------------------------------------------------------- timeline epochs
def test_reconfigure_splits_epochs():
    sc = light_cfg(horizon=1000.0)
    sc.reconfigure_at(400.0, n_contexts=1, oversubscription=1.0)
    rep = analyze_config(sc)
    assert [e.cause for e in rep.epochs] == ["build", "reconfigure"]
    assert (rep.epochs[0].t0_ms, rep.epochs[0].t1_ms) == (0.0, 400.0)
    assert (rep.epochs[1].t0_ms, rep.epochs[1].t1_ms) == (400.0, 1000.0)
    # retired-lane carry is surfaced as an explicit assumption
    assert any("draining lanes" in a for a in rep.assumptions)


def test_fail_context_and_scale_out_epochs():
    sc = light_cfg(horizon=1000.0)
    sc.fail_context_at(1, 300.0).scale_out_at(600.0)
    rep = analyze_config(sc)
    assert [e.cause for e in rep.epochs] == ["build", "fail-context",
                                            "scale-out"]
    n_ctx = [len(e.contexts) for e in rep.epochs]
    assert n_ctx == [2, 1, 2]


def test_last_context_fault_is_total_failure():
    sc = ServerConfig.sim().horizon_ms(1000.0)
    sc.task(make_spec("hp", HP, [5.0], 50.0))
    sc.device(ideal_device()).contexts(1).streams(1).oversubscribe(1.0)
    sc.phase_offsets(False).noise(0.0).seed(0)
    sc.fail_context_at(0, 300.0)
    rep = analyze_config(sc)
    dead = rep.epochs[-1]
    assert dead.cause == "total-failure"
    assert dead.t1_ms == 1000.0
    assert all(tv.binding == "total-failure" for tv in dead.tasks)
    assert rep.verdict == UNSCHEDULABLE


def test_brownout_epochs_inflate_the_bound():
    plan = ChaosPlan(seed=0, brownouts=(
        Brownout(t0_ms=200.0, t1_ms=400.0, device=0, slow_factor=4.0),))
    rep = analyze_config(light_cfg(horizon=600.0).chaos(plan))
    assert [e.cause for e in rep.epochs] == ["build", "brownout-start",
                                             "brownout-end"]
    wc = [e.tasks[0].wcrt_ms for e in rep.epochs]
    assert wc[1] > wc[0]                   # 4x slowdown inflates the bound
    assert wc[2] == pytest.approx(wc[0], rel=1e-6)   # and it clears


def test_cluster_fail_device_epoch():
    sc = ServerConfig.cluster(2, transfer_ms=0.0)
    sc.task(make_spec("g0-hp", HP, [5.0], 50.0))
    sc.task(make_spec("g1-hp", HP, [5.0], 50.0))
    sc.device(ideal_device()).contexts(1).streams(1).oversubscribe(1.0)
    sc.horizon_ms(1000.0).phase_offsets(False).noise(0.0).seed(0)
    sc.fail_device_at(1, 300.0)
    rep = analyze_config(sc)
    assert [e.cause for e in rep.epochs] == ["build", "fail-device"]
    devices = [{tv.device for tv in e.tasks} for e in rep.epochs]
    assert devices[0] == {0, 1} and devices[1] == {0}


def test_autoscale_floor_is_a_hypothetical_epoch():
    sc = light_cfg().autoscale(0.3, 0.85, min_contexts=1, max_contexts=4)
    rep = analyze_config(sc)
    assert [e.cause for e in rep.epochs] == ["build"]
    assert [e.cause for e in rep.hypothetical] == ["autoscale-floor"]
    # the what-if shape counts toward the verdict but not the HP bound
    floor_wcrt = max(tv.wcrt_ms for tv in rep.hypothetical[0].tasks
                     if tv.priority == "HP")
    assert rep.hp_bound_ms() <= floor_wcrt
    assert rep.verdict == worst_verdict(
        [e.verdict for e in rep.epochs + rep.hypothetical])


# ---------------------------------------------------- Eq. 8 slice checks
def test_virtual_deadline_slices_sum_to_deadline():
    sc = ServerConfig.sim().horizon_ms(500.0)
    sc.task(make_spec("hp", HP, [4.0, 2.0, 6.0], 60.0))
    sc.device(ideal_device()).contexts(1).streams(1).oversubscribe(1.0)
    sc.phase_offsets(False).noise(0.0).seed(0)
    rep = analyze_config(sc)
    tv = rep.task_verdicts("hp")[0]
    assert sum(s.vdl_ms for s in tv.stages) \
        == pytest.approx(tv.deadline_ms, rel=1e-9)
    # Eq. 8: slices proportional to the MRET split
    assert tv.stages[2].vdl_ms > tv.stages[0].vdl_ms > tv.stages[1].vdl_ms


# ------------------------------------------- worst-case speed soundness
def test_worst_speed_lower_bounds_contention_model():
    """Property: for sampled co-resident lane sets, the analyzer's
    independently-worst-cased speed never exceeds what the live
    contention model actually grants any lane (the soundness argument
    behind every per-stage wc_ms)."""
    import numpy as np
    rng = np.random.default_rng(42)
    dev = DeviceModel(n_units=6.0, bubble=0.3, l2_pressure=0.15)
    cm = ContentionModel(dev)
    for _ in range(300):
        m = int(rng.integers(1, 7))
        nsat = rng.uniform(0.5, 5.0, size=m)
        mf = rng.uniform(0.0, 0.9, size=m)
        share = rng.uniform(0.25, 4.0, size=m)
        actual = cm.rates_seq(list(share), list(nsat), list(mf))
        total_cap = float(share.sum())
        co_nsat, co_mf = float(nsat.max()), float(mf.max())
        for i in range(m):
            lb = _worst_speed(dev, float(nsat[i]), float(mf[i]),
                              float(share[i]), total_cap, m,
                              co_nsat, co_mf)
            assert lb <= actual[i] + 1e-12, (
                f"worst-case speed {lb} above model speed {actual[i]} "
                f"for lane {i} of {m}")


# --------------------------------------------------- differential oracle
def test_oracle_bound_dominates_simulation():
    res = differential_check(light_cfg(horizon=2000.0).noise(0.06),
                             label="light")
    assert res.ok and not res.vacuous
    assert res.observed_max_ms <= res.bound_ms
    assert res.violations == []
    assert "light" in res.render()


def test_guaranteed_implies_zero_hp_misses():
    res = differential_check(light_cfg(horizon=2000.0).noise(0.06))
    assert res.hp_verdict == GUARANTEED
    assert res.dmr_hp == 0.0


def test_oracle_on_figure_scenarios():
    figure_specs = pytest.importorskip(
        "benchmarks.figure_specs",
        reason="benchmarks package needs the repo root on sys.path")
    for name in ("fig4_6_light", "fig13_light"):
        res = differential_check(figure_specs.scenario(name), label=name)
        assert res.ok, res.violations
        assert res.hp_verdict == GUARANTEED and res.dmr_hp == 0.0
        assert not res.vacuous


# ------------------------------------------------------- config wiring
def test_duplicate_reconfigure_events_rejected():
    sc = light_cfg()
    sc.reconfigure_at(400.0, n_contexts=1)
    sc.reconfigure_at(400.0, oversubscription=3.0)
    with pytest.raises(ValueError, match="duplicate reconfigure_at"):
        analyze_config(sc)
    # distinct timestamps stay legal
    sc2 = light_cfg()
    sc2.reconfigure_at(400.0, n_contexts=1)
    sc2.reconfigure_at(500.0, oversubscription=3.0)
    assert len(analyze_config(sc2).epochs) == 3


def test_server_config_verify_gate():
    ok = light_cfg().verify()
    assert ok.schedcheck_report.hp_verdict == GUARANTEED

    bad = ServerConfig.sim().horizon_ms(500.0)
    bad.task(make_spec("hp", HP, [60.0], 50.0))
    bad.device(ideal_device()).contexts(1).streams(1).oversubscribe(1.0)
    bad.phase_offsets(False).noise(0.0).seed(0)
    with pytest.raises(UnschedulableError) as ei:
        bad.verify()
    assert ei.value.report.hp_verdict == UNSCHEDULABLE
    # warn-only mode keeps the report without raising
    bad.verify(enforce=False)
    assert bad.schedcheck_report.hp_verdict == UNSCHEDULABLE


def test_check_schedulability_modes():
    cfg = {"tasks": [{"dnn": "resnet18", "priority": "HP", "jps": 30.0}],
           "contexts": 2, "streams": 1, "oversubscribe": 2.0, "seed": 0}
    assert check_schedulability(cfg) is None            # default: off
    rep = check_schedulability({**cfg, "schedcheck": "warn"})
    assert rep is not None and rep.hp_verdict in (GUARANTEED, CONDITIONAL)
    rep = check_schedulability({**cfg, "schedcheck": "enforce"})
    assert rep.hp_verdict != UNSCHEDULABLE
    with pytest.raises(ValueError, match="schedcheck mode"):
        check_schedulability({**cfg, "schedcheck": "always"})


def test_enforce_mode_blocks_unschedulable_daemon_config():
    cfg = {"tasks": [{"dnn": "unet", "priority": "HP", "jps": 2000.0}],
           "contexts": 1, "streams": 1, "oversubscribe": 1.0, "seed": 0,
           "schedcheck": "enforce"}
    with pytest.raises(UnschedulableError):
        check_schedulability(cfg)
    # the same config in warn mode reports instead of raising
    rep = check_schedulability({**cfg, "schedcheck": "warn"})
    assert rep.hp_verdict == UNSCHEDULABLE


# ------------------------------------------------------------ JSON + CLI
def test_report_json_roundtrip():
    rep = analyze_config(light_cfg(), label="rt")
    d = json.loads(rep.to_json())
    assert d["label"] == "rt" and d["hp_verdict"] == GUARANTEED
    assert len(d["epochs"]) == 1
    task_names = {t["task"] for t in d["epochs"][0]["tasks"]}
    assert task_names == {"hp", "lp"}
    # infinities must serialize as nulls, not break json
    bad = light_cfg().open_loop(100.0)
    d2 = json.loads(analyze_config(bad).to_json())
    hp = [t for t in d2["epochs"][0]["tasks"] if t["task"] == "hp"][0]
    assert hp["wcrt_ms"] is None


def test_cli_on_config_files(tmp_path, capsys):
    from repro.analysis.schedcheck.__main__ import main
    cfg = {"tasks": [{"dnn": "resnet18", "priority": "HP", "jps": 30.0},
                     {"dnn": "unet", "priority": "LP", "jps": 10.0}],
           "contexts": 2, "streams": 1, "oversubscribe": 2.0, "seed": 0}
    path = tmp_path / "serve.json"
    path.write_text(json.dumps(cfg))
    out = tmp_path / "verdicts.json"
    rc = main([str(path), "--require-hp-guaranteed",
               "--json", str(out)])
    assert rc == 0
    payload = json.loads(out.read_text())   # single config -> bare report
    assert payload["hp_verdict"] == GUARANTEED
    assert math.isfinite(payload["hp_bound_ms"])
    assert "GUARANTEED" in capsys.readouterr().out


def test_cli_fails_unschedulable_config(tmp_path, capsys):
    from repro.analysis.schedcheck.__main__ import main
    cfg = {"tasks": [{"dnn": "unet", "priority": "HP", "jps": 2000.0}],
           "contexts": 1, "streams": 1, "oversubscribe": 1.0, "seed": 0}
    path = tmp_path / "hot.json"
    path.write_text(json.dumps(cfg))
    assert main([str(path)]) == 1
    capsys.readouterr()


def test_cli_usage_error_is_2(capsys):
    from repro.analysis.schedcheck.__main__ import main
    assert main([]) == 2
    capsys.readouterr()


def test_shipped_example_configs_are_guaranteed(capsys):
    from repro.analysis.schedcheck.__main__ import main
    assert main(["examples/configs/serve_basic.json",
                 "examples/configs/serve_tiered.json",
                 "--require-hp-guaranteed"]) == 0
    capsys.readouterr()
