"""Staged LM == monolithic forward; sharding rule sanity; dry-run subprocess."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import build_model


def test_lm_stages_compose_to_full_forward():
    from repro.serving.staging import make_lm_stage_fns
    cfg = get_reduced("smollm-135m").replace(n_layers=4)
    m = build_model(cfg)
    params = m.init_params(0)
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, 256, (2, 12)))
    full_logits, _, _ = m._lm_forward(params, {"tokens": tokens})
    stages = make_lm_stage_fns(m, n_stages=2)
    pos = jnp.arange(12, dtype=jnp.int32)
    x = tokens
    for st in stages:
        x, _ = st(params, x, None, pos)
    np.testing.assert_allclose(np.asarray(full_logits), np.asarray(x),
                               rtol=2e-4, atol=2e-4)


def test_stage_boundaries():
    from repro.serving.staging import stage_boundaries
    assert stage_boundaries(10, 4) == [(0, 3), (3, 6), (6, 8), (8, 10)]
    assert stage_boundaries(4, 4) == [(0, 1), (1, 2), (2, 3), (3, 4)]


def test_sharding_sanitize_indivisible():
    from repro.parallel.sharding import ShardingRules
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_reduced("mamba2-2.7b")
    rules = ShardingRules(cfg, mesh)
    # 50280 % 1 == 0 trivially here; test _sanitize directly with fake mesh
    spec = rules._sanitize(P("model", "data"), (7, 8))
    assert spec == P("model", "data")   # sizes 1 divide everything


def test_act_constraint_noop_without_mesh():
    from repro.parallel.sharding import ActConstraint
    c = ActConstraint(None)
    x = jnp.ones((2, 4, 8))
    assert c.hidden(x) is x


DRYRUN_CELLS = [
    ("smollm-135m", "train_4k", "tiny"),
    ("qwen2-moe-a2.7b", "decode_32k", "tiny"),
    ("mamba2-2.7b", "prefill_32k", "tiny-multi"),
]


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape,mesh", DRYRUN_CELLS)
def test_dryrun_tiny_mesh_subprocess(arch, shape, mesh, tmp_path):
    """The multi-pod dry-run machinery end-to-end on an 8-device tiny mesh
    (subprocess so the forced device count never leaks into this process)."""
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=420)
    assert "OK" in out.stdout, out.stdout + out.stderr
    arts = list(tmp_path.glob("*.json"))
    assert arts
    art = json.loads(arts[0].read_text())
    assert art["status"] == "ok"
    assert art["cost_per_device"].get("flops", 0) > 0
    assert art["hlo_cost_per_device"]["flops"] > 0


def test_hlo_cost_counts_while_loops():
    """The while-aware walker multiplies loop bodies by trip count."""
    from repro.launch.hlo_cost import analyze

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jnp.ones((32, 32))
    w = jnp.ones((32, 32))
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    c = analyze(hlo)
    one_dot = 2 * 32 * 32 * 32
    assert c["flops"] >= 9 * one_dot     # ~10 iterations counted
