"""Deep correctness equivalences across independent implementation paths."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import build_model


def test_mla_absorbed_decode_matches_naive_block():
    """DeepSeek MLA: the absorbed decode path == the naive (expanded K/V)
    path, bit-tight at the block level (the full-model comparison is below
    with a loose tolerance — MoE routing amplifies f32 noise at ties)."""
    from repro.models.layers import InitCtx
    from repro.models.mla import init_mla, make_mla_cache, mla_block
    cfg = get_reduced("deepseek-v2-236b")
    ctx = InitCtx(jax.random.PRNGKey(0), jnp.float32)
    p = init_mla(ctx, cfg)
    b, s = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s + 1, cfg.d_model),
                          jnp.float32)
    y_full, _ = mla_block(p, x, cfg=cfg, positions=jnp.arange(s + 1))
    cache = make_mla_cache(b, s + 1, cfg, "float32")
    y_pre, cache = mla_block(p, x[:, :s], cfg=cfg,
                             positions=jnp.arange(s), cache=cache)
    y_dec, _ = mla_block(p, x[:, s:s + 1], cfg=cfg,
                         positions=jnp.asarray([s]), cache=cache)
    np.testing.assert_allclose(np.asarray(y_full[:, :s]), np.asarray(y_pre),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_full[:, s]),
                               np.asarray(y_dec[:, 0]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-2.7b",
                                  "deepseek-v2-236b"])
def test_full_model_decode_consistency(arch):
    """prefill(s) + decode(1) tracks the full forward at position s
    (loose tolerance: einsum-order noise, MoE routing near ties)."""
    cfg = get_reduced(arch)
    m = build_model(cfg)
    params = m.init_params(0)
    rng = np.random.default_rng(0)
    b, s = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)))
    logits_full, _, _ = m._lm_forward(params, {"tokens": tokens})
    cache = m.init_cache(b, s + 1)
    _, cache = m.prefill(params, {"tokens": tokens[:, :s], "cache": cache})
    logits_dec, _ = m.decode_step(params, {"tokens": tokens[:, s:s + 1],
                                           "cache": cache})
    a = np.asarray(logits_full[:, -1], np.float32)
    d = np.asarray(logits_dec[:, 0], np.float32)
    assert np.max(np.abs(a - d)) < 5e-2
    assert (np.argmax(a, -1) == np.argmax(d, -1)).all()


def test_chunked_decode_attention_matches_unchunked():
    """attend_cache_chunked (flash-decode) == full-cache einsum path."""
    from repro.models.attention import (attend_cache_chunked,
                                        attention_block, init_attention,
                                        make_kv_cache, mha, read_kv_cache)
    from repro.models.layers import InitCtx
    ctx = InitCtx(jax.random.PRNGKey(0), jnp.float32)
    p = init_attention(ctx, 32, 4, 2, 16)
    s_max = 64
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 40, 32))
    cache = make_kv_cache(2, s_max, 2, 16, "float32")
    pos = jnp.arange(40)
    _, cache = attention_block(p, x, positions=pos, cache=cache)
    xt = jax.random.normal(jax.random.PRNGKey(2), (2, 1, 32))
    pt = jnp.asarray([40])
    # build q/k/v by hand to compare the two cores on identical inputs
    from repro.models.attention import update_kv_cache
    q = jnp.einsum("bsd,dhk->bshk", xt, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xt, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xt, p["wv"])
    from repro.models.layers import apply_rope
    q = apply_rope(q, pt, 10000.0)
    k = apply_rope(k, pt, 10000.0)
    nc = update_kv_cache(cache, k, v, cache["length"])
    out_chunked = attend_cache_chunked(q, nc, pt, scale=16 ** -0.5,
                                       kv_chunk=16)
    kc, vc, kv_pos = read_kv_cache(nc, jnp.float32)
    out_full = mha(q, kc, vc, q_positions=pt, kv_positions=kv_pos,
                   causal=True, scale=16 ** -0.5)
    np.testing.assert_allclose(np.asarray(out_chunked), np.asarray(out_full),
                               rtol=1e-4, atol=1e-4)


def test_int8_kv_cache_quantization_error_bounded():
    from repro.models.attention import make_kv_cache, read_kv_cache, update_kv_cache
    k = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 2, 8))
    cache = make_kv_cache(2, 16, 2, 8, "int8")
    cache = update_kv_cache(cache, k, v, jnp.zeros((), jnp.int32))
    kd, vd, _ = read_kv_cache(cache, jnp.float32)
    # per-(token,head) scales -> relative error ~ 1/127
    assert float(jnp.max(jnp.abs(kd - k))) < np.abs(np.asarray(k)).max() * 0.02
    assert float(jnp.max(jnp.abs(vd - v))) < np.abs(np.asarray(v)).max() * 0.02


def test_gemma2_local_global_cache_structure():
    cfg = get_reduced("gemma2-27b")
    m = build_model(cfg)
    cache = m.init_cache(2, 64)
    assert set(cache) == {"local", "global"}
    # local ring capped at the sliding window
    assert cache["local"]["k"].shape[2] == cfg.sliding_window
    assert cache["global"]["k"].shape[2] == 64


def test_straggler_mitigation_triggers_and_conserves():
    from repro.core.scheduler import DarisScheduler, SchedulerConfig
    from repro.runtime.contention import DeviceModel
    from repro.runtime.sim import SimEngine
    from repro.serving.requests import table2_taskset
    sched = DarisScheduler(
        table2_taskset("resnet18"),
        SchedulerConfig(n_contexts=4, n_streams=1, oversubscription=1.0,
                        straggler_kappa=1.05),   # aggressive -> will trigger
        DeviceModel())
    m = SimEngine(sched, horizon_ms=2000.0, seed=0, noise_sigma=0.4).run()
    assert m.stragglers > 0
    assert m.completed[0] + m.completed[1] > 0
    assert m.dmr(0) <= 1.0


def test_hlo_param_traffic_slice_aware():
    from repro.launch.hlo_cost import HloCost

    def f(arena, idx):
        return jax.lax.dynamic_index_in_dim(arena, idx, 0, keepdims=False).sum()

    arena = jnp.ones((64, 256, 256))
    hlo = jax.jit(f).lower(arena, jnp.int32(3)).compile().as_text()
    c = HloCost(hlo).entry_cost()
    # traffic should be ~one slice (256*256*4 = 256KB), not the 16MB arena
    assert c["bytes"] < 64 * 256 * 256 * 4 / 4
