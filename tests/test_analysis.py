"""DSAN correctness tooling: custom lint rules against known-bad
snippets, sanitizer engagement/zero-overhead/corruption-detection, the
event-order legality model, violation report artifacts, and the daemon
race detector (injected cross-thread mutation + clean normal lane)."""
from __future__ import annotations

import json
import textwrap
import threading

import pytest

from repro.analysis import Sanitizer, SanitizerViolation
from repro.analysis.lint import check_source
from repro.analysis.races import RaceViolation, ThreadAffinityGuard
from repro.api import HP, LP, ServerConfig

from tests.test_serve import (daemon_cfg, ideal_device, make_spec,
                              serving_server, start_daemon)


def _rules(src):
    return [f.rule for f in check_source(textwrap.dedent(src))]


# ------------------------------------------------------- custom lint rules
def test_lint_memo_mutation_without_invalidate_flagged():
    bad = """
    def restore(self, values):
        self.window.clear()
        self.window.extend(values)
    """
    assert _rules(bad) == ["DSAN001", "DSAN001"]


def test_lint_memo_mutation_with_invalidate_clean():
    good = """
    def restore(self, values):
        self.window.clear()
        self.window.extend(values)
        self.invalidate()
    """
    ok2 = """
    def observe(self, et_ms):
        self.window.append(et_ms)
        self._value = None
    """
    assert _rules(good) == [] and _rules(ok2) == []


def test_lint_identity_dataclass_as_value_key_flagged():
    assert _rules("table[Job(task, 0.0)] = 1\n") == ["DSAN002"]
    assert _rules("x = Task(spec, 0) in sched.tasks\n") == ["DSAN002"]
    # looking up by an existing identity is fine
    assert _rules("table[job] = 1\nx = job in sched.tasks\n") == []


def test_lint_float_eq_on_time_quantity_flagged():
    assert _rules("if a.release_ms == b.release_ms:\n    pass\n") \
        == ["DSAN003"]
    assert _rules("if util == 0.5:\n    pass\n") == ["DSAN003"]
    # None/str state checks are not float comparisons
    assert _rules("if job.finish_ms == None:\n    pass\n") == []
    assert _rules("ok = status == 'missed'\n") == []


def test_lint_wall_clock_in_deterministic_path_flagged():
    src = "import time\nnow = time.time()\n"
    bad = check_source(src, path="src/repro/core/scheduler.py")
    assert [f.rule for f in bad] == ["DSAN004"]
    # the serve daemon is wall-clock by design: out of scope
    assert check_source(src, path="src/repro/serve/daemon.py") == []


def test_lint_bare_remove_on_identity_collection_flagged():
    assert _rules("self.tasks.remove(task)\n") == ["DSAN005"]
    assert _rules("w.jobs.remove(job)\n") == ["DSAN005"]
    assert _rules("free.remove(lane)\n") == []


def test_lint_suppression_same_line_and_line_above():
    assert _rules(
        "self.tasks.remove(task)  # dsan: ignore[DSAN005]\n") == []
    assert _rules(
        "# identity scan on purpose  # dsan: ignore[DSAN005]\n"
        "self.tasks.remove(task)\n") == []
    assert _rules("self.tasks.remove(task)  # dsan: ignore\n") == []
    # suppressing a DIFFERENT rule does not silence this one
    assert _rules(
        "self.tasks.remove(task)  # dsan: ignore[DSAN003]\n") \
        == ["DSAN005"]


def test_lint_unguarded_hook_call_flagged():
    bad = """
    def step(self):
        self._sanitizer.on_step(1.0)
    """
    assert _rules(bad) == ["DSAN006"]
    # reassigning the hook inside the guard invalidates it
    sneaky = """
    def step(self):
        if self._chaos is not None:
            self._chaos = other
            self._chaos.tick()
    """
    assert _rules(sneaky) == ["DSAN006"]


def test_lint_guarded_hook_call_clean():
    good = """
    def step(self):
        if self._sanitizer is not None:
            self._sanitizer.on_step(1.0)
        if self._chaos:
            self._chaos.tick()
    """
    early = """
    def step(self):
        if self._chaos is None:
            return
        self._chaos.tick()
    """
    ternary = """
    def step(self):
        f = self._chaos.factor() if self._chaos is not None else 1.0
        return f
    """
    assert _rules(good) == [] and _rules(early) == []
    assert _rules(ternary) == []


def test_lint_chaos_rng_stream_rules():
    foreign = "def roll(self, engine):\n    return engine.rng.uniform()\n"
    glob = "def roll(self):\n    return np.random.random()\n"
    own = "def roll(self):\n    return self.rng.uniform() + " \
          "self.io_rng.normal()\n"
    chaos = "src/repro/chaos/plan.py"
    assert [f.rule for f in check_source(foreign, path=chaos)] \
        == ["DSAN007"]
    assert [f.rule for f in check_source(glob, path=chaos)] == ["DSAN007"]
    assert check_source(own, path=chaos) == []
    # only chaos code is in scope; the engine owns the sim stream
    assert check_source(foreign, path="src/repro/sim/engine.py") == []


def test_lint_src_tree_is_clean():
    """The shipping tree must satisfy its own lint gate (CI runs the
    same command with ruff/mypy chained)."""
    from repro.analysis.lint import main
    assert main(["src", "--no-tools"]) == 0


# --------------------------------------------------- sanitizer activation
def _tiny_server(sanitize_level=None, horizon=400.0):
    sc = ServerConfig.sim().horizon_ms(horizon)
    sc.task(make_spec("hp", HP, [5.0], 50.0))
    sc.task(make_spec("lp", LP, [8.0, 8.0], 100.0))
    sc.device(ideal_device()).contexts(2).streams(1).oversubscribe(2.0)
    sc.phase_offsets(False).noise(0.0).seed(0)
    if sanitize_level is not None:
        sc.sanitize(level=sanitize_level)
    return sc.build()


def test_sanitizer_disabled_is_zero_overhead(monkeypatch):
    """The zero-cost contract: a non-sanitizing engine stores None and
    never dispatches a hook."""
    monkeypatch.delenv("DARIS_SANITIZE", raising=False)
    srv = _tiny_server()
    assert srv.core._sanitizer is None
    srv.run()
    assert srv.core._sanitizer is None


def test_sanitizer_env_activation(monkeypatch):
    monkeypatch.setenv("DARIS_SANITIZE", "2")
    srv = _tiny_server()
    s = srv.core._sanitizer
    assert isinstance(s, Sanitizer)
    assert s.level == 2 and s.cadence == 1
    srv.run()
    assert s.audits > 0 and s.violations == 0
    monkeypatch.setenv("DARIS_SANITIZE", "0")
    assert _tiny_server().core._sanitizer is None


def test_sanitizer_config_activation_and_clean_run():
    srv = _tiny_server(sanitize_level=2)
    m = srv.run()
    s = srv.core._sanitizer
    assert s.audits == s.steps + 1          # every step + finalize
    assert s.violations == 0
    assert sum(m.completed.values()) > 0


def test_sanitized_run_is_bit_identical():
    """Auditing must not perturb the run: identical metrics with the
    sanitizer on and off (the goldens assert the same at suite level)."""
    m0 = _tiny_server().run()
    m1 = _tiny_server(sanitize_level=2).run()
    assert m0.completed == m1.completed
    assert m0.missed == m1.missed
    assert m0.response_ms == m1.response_ms   # exact float lists


def test_sanitizer_catches_stale_mret_memo():
    """A stale memo between audits is caught at the next audit. The
    poison is injected inside after_step (right before the audit) —
    injecting it mid-step would let a same-step ``observe`` legally
    invalidate-and-heal it first."""
    srv = _tiny_server(sanitize_level=2)
    san = srv.core._sanitizer
    t = srv.scheduler.tasks[0]
    orig = san.after_step

    def poisoned(engine):
        if san.steps == 9 and t.mret is not None:
            t.mret.stages[0]._value = 777.0   # memo != window
        orig(engine)

    san.after_step = poisoned
    with pytest.raises(SanitizerViolation) as ei:
        srv.run()
    assert ei.value.check in ("mret-stage-memo", "eq11-hp-utilization",
                              "eq12-lp-utilization")
    assert ei.value.cursor["steps"] >= 10


def test_sanitizer_catches_lanemap_corruption():
    """Dropping an empty live lane from the free index (the classic
    lost-lane leak: the lane never dispatches again) is caught at the
    next audit."""
    srv = _tiny_server(sanitize_level=2)
    san = srv.core._sanitizer
    lanes = srv.scheduler.lanes
    orig = san.after_step

    def poisoned(engine):
        if san.steps >= 9 and lanes._free:
            lanes._free.discard(next(iter(lanes._free)))
        orig(engine)

    san.after_step = poisoned
    with pytest.raises(SanitizerViolation) as ei:
        srv.run()
    assert ei.value.check == "lanemap-free-index"


def test_sanitizer_catches_conservation_drift():
    srv = _tiny_server(sanitize_level=2)
    orig = srv.core._step
    calls = [0]

    def corrupting(*a, **kw):
        calls[0] += 1
        if calls[0] == 10:
            srv.core.metrics.completed[LP] += 1   # phantom completion
        return orig(*a, **kw)

    srv.core._step = corrupting
    with pytest.raises(SanitizerViolation) as ei:
        srv.run()
    assert ei.value.check == "metrics-completed-mirror"


def test_violation_report_written_as_artifact(tmp_path):
    s = Sanitizer(level=2, report_dir=str(tmp_path))
    with pytest.raises(SanitizerViolation):
        # note_pop with t far beyond now: event fired before its time
        s.note_pop(1000.0, 0, 0, now=0.0)
    reports = list(tmp_path.glob("dsan-*.json"))
    assert len(reports) == 1
    payload = json.loads(reports[0].read_text())
    assert payload["check"] == "event-never-early"
    assert payload["cursor"]["pops"] == 1


# ------------------------------------------------- event-order legality
def test_event_order_backdated_open_loop_push_is_legal():
    """PoissonArrival pushes past-due successors (open loop): a pop of a
    SMALLER key is legal when the entry was pushed after the larger key
    was already popped."""
    s = Sanitizer(level=1)
    s.note_push(10.0, 0, 1)
    s.note_pop(10.0, 0, 1, now=10.0)       # pop t=10
    s.note_push(3.0, 0, 2)                 # back-dated successor
    s.note_pop(3.0, 0, 2, now=10.0)        # legal: pushed after the pop
    assert s.violations == 0


def test_event_order_heap_violation_caught():
    """Two entries queued together must pop in key order — same-instant
    kind priority (RELEASE before CANCEL before FAULT) included."""
    s = Sanitizer(level=1)
    s.note_push(5.0, 2, 1)                 # FAULT@5
    s.note_push(5.0, 0, 2)                 # RELEASE@5 — must pop first
    s.note_pop(5.0, 2, 1, now=5.0)         # FAULT popped first: illegal
    with pytest.raises(SanitizerViolation) as ei:
        s.note_pop(5.0, 0, 2, now=5.0)
    assert ei.value.check == "event-order"


# ----------------------------------------------------- daemon race guard
def test_race_guard_catches_cross_thread_mutation():
    """Acceptance: a deliberately-injected cross-thread scheduler
    mutation raises a tsan-style report."""
    srv = serving_server([make_spec("lp", LP, [10.0], 1000.0)])
    guard = ThreadAffinityGuard(srv).install()    # owner: this thread
    srv.request("lp", at_ms=0.0)                  # owner calls pass
    srv.pump(0.0)

    caught = []

    def off_thread():
        try:
            srv.pump(50.0)                        # scheduler mutation
        except RaceViolation as e:
            caught.append(e)

    th = threading.Thread(target=off_thread)
    th.start()
    th.join()
    assert len(caught) == 1
    report = caught[0].report
    assert "data race on scheduler/engine state" in report
    assert "pump" in report and "single-owner" in report
    assert guard.violations == [report]

    guard.uninstall()                             # pristine instance again
    t2 = threading.Thread(target=lambda: srv.pump(60.0))
    t2.start()
    t2.join()
    srv.end_serving()


def test_race_guard_daemon_normal_lane_clean(tmp_path):
    """The guard rides a real daemon (config-enabled) without tripping:
    handler threads funnel through the command queue, so every guarded
    call lands on the pump thread."""
    d, th, c = start_daemon(
        tmp_path, cfg=daemon_cfg(sanitize={"level": 1, "cadence": 64}),
        time_scale=200.0, tick_ms=1.0)
    assert c.ping()["ok"]
    s0 = c.submit("resnet18", tenant="a")
    c.result(s0["seq"], timeout_s=30.0)
    assert c.stats()["ok"]
    # guard is installed and bound to the pump thread, not ours
    assert d.race_guard is not None
    assert d.race_guard.owner is th
    # injected violation from the client thread is caught...
    with pytest.raises(RaceViolation):
        d.server.pump(1.0)
    # ...and the daemon itself never tripped it
    assert d.race_guard.violations == [d.race_guard.violations[0]]
    out = c.drain()
    th.join(timeout=10.0)
    assert out["lost"] == []
    assert len(d.race_guard.violations) == 1      # only our injection


def test_daemon_sanitizer_via_config_runs_clean(tmp_path):
    """ServeDaemon with {"sanitize": ...} builds a sanitizing engine;
    a full submit/cancel/drain session audits clean."""
    d, th, c = start_daemon(tmp_path, cfg=daemon_cfg(sanitize=2),
                            time_scale=0.0, tick_ms=1.0)
    san = d.server.core._sanitizer
    assert isinstance(san, Sanitizer) and san.level == 2
    s0 = c.submit("unet", tenant="a")
    c.cancel(s0["seq"])
    s1 = c.submit("resnet18", tenant="b")
    c.drain()
    th.join(timeout=10.0)
    assert san.violations == 0 and san.audits > 0
