"""Job cancellation: queued retire + Eq. 12 charge release, in-flight
stage-boundary retirement, completed no-op, batch member detach/promote,
sealed-batch accounting drops, cluster-device cancel, and the StageQueue
surgery primitives that make queued removal possible."""
import math

import pytest

from repro.api import (HP, LP, DeviceModel, ManualArrival, ServerConfig,
                       StageProfile, SubmitHandle, TaskSpec)
from repro.core.scheduler import DarisScheduler, SchedulerConfig
from repro.core.stage_queue import StageQueue
from repro.core.task import Job, Task


def make_spec(name, prio, stage_times, period_ms, n_sat=1.0):
    return TaskSpec(
        name=name, period_ms=period_ms, priority=prio,
        stages=[StageProfile(f"{name}/s{j}", t, n_sat=n_sat, mem_frac=0.0,
                             overhead_ms=0.0)
                for j, t in enumerate(stage_times)])


def ideal_device():
    return DeviceModel(n_units=4.0, bubble=0.0, l2_pressure=0.0)


def serving_server(specs, *, contexts=1, batching=None, horizon=1e6):
    cfg = ServerConfig.sim()
    for s in specs:
        cfg.task(s, arrival=ManualArrival())
    cfg = (cfg.contexts(contexts).streams(1).oversubscribe(float(contexts))
           .device(ideal_device()).horizon_ms(horizon)
           .phase_offsets(False).noise(0.0).seed(0))
    if batching:
        cfg.batching(**batching)
    srv = cfg.build()
    srv.begin_serving()
    return srv


def lanes_all_free(sched):
    return all(inst is None for inst in sched.lanes.values())


# ------------------------------------------------- queued-job cancellation
def test_cancel_queued_job_releases_lane_and_admission_charge():
    """A queued LP job cancelled before dispatch must vanish from the
    active set and stop charging Eq. 12 (util_lp_active back to zero)."""
    srv = serving_server([make_spec("hog", HP, [50.0], 1000.0),
                          make_spec("lp", LP, [10.0, 10.0], 1000.0)])
    sched = srv.scheduler
    srv.request("hog", at_ms=0.0)
    srv.pump(0.0)
    h = srv.request("lp", at_ms=5.0)
    srv.pump(5.0)
    assert h.status == SubmitHandle.QUEUED
    assert sched.util_lp_active(0, 6.0) > 0.0

    srv.cancel(h, at_ms=6.0)
    srv.pump(6.0)
    assert h.status == SubmitHandle.CANCELLED
    assert h.done and h._cancelled
    # charge unwound, job gone, only the HP hog remains active
    assert sched.util_lp_active(0, 7.0) == 0.0
    assert [j.task.spec.name for j in sched.active_jobs[0]] == ["hog"]
    assert srv.metrics.cancelled[LP] == 1

    m = srv.end_serving()
    assert m.completed[HP] == 1 and m.completed[LP] == 0
    assert lanes_all_free(sched)


def test_cancel_pending_release_never_admits():
    """Cancel stamped before the release event: the release is skipped
    entirely — no admission, no scheduler job, still counted."""
    srv = serving_server([make_spec("lp", LP, [10.0], 1000.0)])
    h = srv.request("lp", at_ms=100.0)
    srv.cancel(h, at_ms=50.0)
    m = srv.end_serving()
    assert h.status == SubmitHandle.CANCELLED
    assert h.job is None
    assert m.completed[LP] == 0 and m.cancelled[LP] == 1
    assert all(not jobs for jobs in srv.scheduler.active_jobs.values())


# ----------------------------------------------- in-flight cancellation
def test_cancel_inflight_retires_at_stage_boundary():
    """Cancelling a running job marks it immediately but the engine only
    reclaims it at the next stage boundary (mid-kernel preemption is not
    a thing); the second stage must never dispatch."""
    srv = serving_server([make_spec("lp", LP, [20.0, 20.0], 1000.0)])
    sched = srv.scheduler
    h = srv.request("lp", at_ms=5.0)
    srv.pump(5.0)
    assert h.status == SubmitHandle.RUNNING
    job = h.job
    assert job is not None and job.stage_idx == 0

    srv.cancel(h, at_ms=10.0)
    srv.pump(10.0)
    # still physically on the lane until stage 0 finishes at t=25
    assert h.status == SubmitHandle.CANCELLED
    assert job.cancelled and job in sched.active_jobs[0]
    assert not lanes_all_free(sched)

    srv.pump(30.0)
    assert job not in sched.active_jobs[0]
    assert lanes_all_free(sched)
    assert job.finish_ms == pytest.approx(25.0)

    m = srv.end_serving()
    assert m.completed[LP] == 0 and m.cancelled[LP] == 1
    assert sched.util_lp_active(0, 100.0) == 0.0


def test_cancel_completed_job_is_noop():
    srv = serving_server([make_spec("lp", LP, [10.0], 1000.0)])
    h = srv.request("lp", at_ms=0.0)
    srv.pump(20.0)
    assert h.status == SubmitHandle.COMPLETED
    srv.cancel(h, at_ms=21.0)
    srv.pump(21.0)
    m = srv.end_serving()
    assert h.status == SubmitHandle.COMPLETED
    assert m.cancelled == {HP: 0, LP: 0}
    assert m.completed[LP] == 1


def test_double_cancel_counts_once():
    srv = serving_server([make_spec("hog", HP, [50.0], 1000.0),
                          make_spec("lp", LP, [10.0], 1000.0)])
    srv.request("hog", at_ms=0.0)
    h = srv.request("lp", at_ms=5.0)
    srv.pump(5.0)
    srv.cancel(h, at_ms=6.0)
    srv.cancel(h, at_ms=7.0)
    m = srv.end_serving()
    assert m.cancelled[LP] == 1


# ------------------------------------------------- batched head members
BATCH_LP = dict(batching=dict(max_batch=8, scope="task"))


def _batched_setup(hog_ms):
    """One lane, an HP hog pinning it, three same-task LP releases that
    coalesce into a single queued stage-0 head of batch size 3."""
    srv = serving_server(
        [make_spec("hog", HP, [hog_ms], 1000.0),
         make_spec("lp", LP, [10.0], 500.0)], **BATCH_LP)
    srv.request("hog", at_ms=0.0)
    handles = [srv.request("lp", at_ms=t) for t in (5.0, 6.0, 7.0)]
    srv.pump(7.0)
    jobs = [j for j in srv.scheduler.active_jobs[0]
            if j.task.spec.name == "lp"]
    assert len(jobs) == 1 and jobs[0].n_inputs == 3
    return srv, handles, jobs[0]


def test_cancel_batched_member_detaches_from_queued_head():
    srv, (h0, h1, h2), job = _batched_setup(50.0)
    sched = srv.scheduler
    charge3 = sched.util_lp_active(0, 8.0)

    srv.cancel(h1, at_ms=8.0)            # middle member
    srv.pump(8.0)
    assert h1.status == SubmitHandle.CANCELLED
    assert job.n_inputs == 2
    assert job.extra_release_ms == [7.0]
    # the queued instance's batch cost shrank with the membership
    inst = sched.queues[0].find_inst(job)
    assert inst is not None
    assert sched.util_lp_active(0, 8.5) < charge3

    m = srv.end_serving()
    assert h0.status == SubmitHandle.COMPLETED
    assert h2.status == SubmitHandle.COMPLETED
    assert m.completed[LP] == 1 and m.completed_inputs[LP] == 2
    assert m.cancelled[LP] == 1
    assert m.batch_hist.get(2) == 1


def test_cancel_batched_primary_promotes_surviving_member():
    """Cancelling the head's primary promotes the earliest surviving
    member: new release time, re-anchored virtual deadline, smaller
    batch — the batch itself survives."""
    srv, (h0, h1, h2), job = _batched_setup(50.0)
    sched = srv.scheduler

    srv.cancel(h0, at_ms=8.0)            # the primary
    srv.pump(8.0)
    assert h0.status == SubmitHandle.CANCELLED
    assert job.release_ms == 6.0         # earliest member took over
    assert job.extra_release_ms == [7.0]
    assert job.n_inputs == 2
    inst = sched.queues[0].find_inst(job)
    vdl0 = job.task.mret.virtual_deadlines(job.task.spec.deadline_ms)[0]
    assert inst.virtual_deadline_ms == pytest.approx(6.0 + vdl0)

    m = srv.end_serving()
    assert h1.status == SubmitHandle.COMPLETED
    assert h2.status == SubmitHandle.COMPLETED
    assert m.completed[LP] == 1 and m.completed_inputs[LP] == 2
    assert m.cancelled[LP] == 1


def test_cancel_member_of_sealed_batch_drops_accounting_only():
    """Once the batch is dispatched the member's work rides physically;
    cancellation only removes it from the books: its handle terminates
    cancelled, completion counts survivors only."""
    srv, (h0, h1, h2), job = _batched_setup(20.0)
    srv.pump(30.0)       # hog done at 20, batch hold expires, in flight
    assert h0.status == SubmitHandle.RUNNING

    srv.cancel(h1, at_ms=30.0)
    srv.pump(30.0)
    assert h1.status == SubmitHandle.CANCELLED
    assert 6.0 in job.dropped_releases
    assert job.n_inputs == 3             # physical membership unchanged

    m = srv.end_serving()
    assert h0.status == SubmitHandle.COMPLETED
    assert h2.status == SubmitHandle.COMPLETED
    assert m.completed[LP] == 1 and m.completed_inputs[LP] == 2
    assert m.cancelled[LP] == 1
    assert m.batch_hist.get(2) == 1      # survivors, not physical size


def test_cancel_all_members_then_primary_retires_whole_job():
    srv, (h0, h1, h2), job = _batched_setup(50.0)
    for h, t in ((h1, 8.0), (h2, 9.0), (h0, 10.0)):
        srv.cancel(h, at_ms=t)
    srv.pump(10.0)
    assert all(h.status == SubmitHandle.CANCELLED for h in (h0, h1, h2))
    assert all(j.task.spec.name != "lp"
               for j in srv.scheduler.active_jobs[0])
    assert srv.scheduler.util_lp_active(0, 11.0) == 0.0
    m = srv.end_serving()
    assert m.completed[LP] == 0 and m.cancelled[LP] == 3


# ---------------------------------------------------------- cluster path
def test_cancel_on_cluster_device():
    spec = make_spec("lp", LP, [20.0, 20.0], 1000.0)
    srv = (ServerConfig.cluster(2)
           .task(spec, arrival=ManualArrival())
           .contexts(2).streams(1).oversubscribe(2.0)
           .device(ideal_device()).horizon_ms(1e6)
           .phase_offsets(False).noise(0.0).seed(0).build())
    srv.begin_serving()
    sched = srv.scheduler
    h = srv.request("lp", at_ms=5.0)
    srv.pump(5.0)
    assert h.status == SubmitHandle.RUNNING
    job = h.job

    srv.cancel(h, at_ms=10.0)
    srv.pump(60.0)
    assert h.status == SubmitHandle.CANCELLED
    assert all(not w.active_jobs[k] for w in sched.workers.values()
               for k in w.active_jobs)
    assert job.job_id not in sched._state_dev

    m = srv.end_serving()
    assert m.completed[LP] == 0 and m.cancelled[LP] == 1


def test_cluster_cancel_absent_job():
    spec = make_spec("lp", LP, [5.0], 1000.0)
    srv = (ServerConfig.cluster(2)
           .task(spec, arrival=ManualArrival())
           .contexts(2).streams(1).oversubscribe(2.0)
           .device(ideal_device()).horizon_ms(1e6)
           .phase_offsets(False).noise(0.0).seed(0).build())
    outcome, job = srv.scheduler.cancel_job(0, 123.0, now=0.0)
    assert outcome == "absent" and job is None


# ------------------------------------------- scheduler/queue primitives
def _bare_sched(spec):
    cfg = SchedulerConfig(n_contexts=1, n_streams=1, oversubscription=1.0)
    return DarisScheduler([spec], cfg, device=ideal_device())


def test_cancel_job_absent_and_find_job():
    spec = make_spec("lp", LP, [10.0], 1000.0)
    sched = _bare_sched(spec)
    assert sched.cancel_job(0, 0.0, now=0.0) == ("absent", None)
    job = sched.on_release(sched.tasks[0], 0.0)
    assert job is not None
    found, member = sched.find_job(sched.tasks[0].index, job.release_ms)
    assert found is job and member is None
    assert sched.find_job(sched.tasks[0].index, 999.0) == (None, None)


def test_stage_queue_remove_preserves_pop_order():
    """Surgical removal of an arbitrary queued instance must keep the
    heap's pop order for everything else."""
    spec = make_spec("lp", LP, [10.0], 1000.0)
    sched = _bare_sched(spec)
    q = sched.queues[0]
    jobs = []
    for i in range(6):
        t = sched.tasks[0]
        job = Job(task=t, release_ms=float(i), ctx=0)
        vdls = t.mret.virtual_deadlines(t.spec.deadline_ms)
        sched._enqueue_stage(job, float(i))
        jobs.append(job)
    victim = q.find_inst(jobs[3])
    assert victim is not None and victim.job is jobs[3]
    q.remove(victim)
    assert q.find_inst(jobs[3]) is None
    popped = []
    while len(q) > 0:
        inst = q.pop()
        if inst is None:
            break
        popped.append(inst.job.release_ms)
    assert popped == [0.0, 1.0, 2.0, 4.0, 5.0]
