"""Dynamic deadline-aware batching (core/batching.py) + the scheduler
correctness fixes that shipped with it: horizon-miss sweep, fixed-ctx
straggler replay, HP-first fault re-placement, late-submit rejection."""
import math

import pytest

from repro.api import (HP, LP, BatchPolicy, DeviceModel, ServerConfig,
                       StageProfile, TaskSpec, TraceArrival)
from repro.core.scheduler import DarisScheduler, SchedulerConfig
from repro.runtime.backend import SimBackend
from repro.runtime.contention import batch_cost, batch_speedup
from repro.runtime.engine_core import EngineCore
from repro.serving.requests import table2_taskset


def make_spec(name, prio, stage_times, period_ms, n_sat=1.0, batch_gain=1.0):
    return TaskSpec(
        name=name, period_ms=period_ms, priority=prio,
        stages=[StageProfile(f"{name}/s{j}", t, n_sat=n_sat, mem_frac=0.0,
                             overhead_ms=0.0, batch_gain=batch_gain)
                for j, t in enumerate(stage_times)])


def ideal_device():
    """Device on which one stage per lane runs at exactly t_alone speed."""
    return DeviceModel(n_units=4.0, bubble=0.0, l2_pressure=0.0)


def serve(specs_with_traces, *, policy=None, horizon=500.0,
          device=None, n_contexts=1):
    cfg = (ServerConfig.sim()
           .contexts(n_contexts).streams(1).oversubscribe(1.0)
           .device(device or ideal_device())
           .horizon_ms(horizon).noise(0.0).phase_offsets(False)
           .record_decisions())
    for spec, times in specs_with_traces:
        cfg.task(spec, arrival=TraceArrival(times))
    if policy is not None:
        cfg.batching(max_batch=policy.max_batch,
                     max_wait_ms=policy.max_wait_ms)
    return cfg.build()


# ------------------------------------------------------------ speedup curve
def test_batch_speedup_curve_anchors():
    prof = StageProfile("s", 1.0, 1.0, 0.0, batch_gain=3.0)
    assert batch_speedup(prof, 1) == 1.0
    assert batch_cost(prof, 1) == 1.0                  # exact: bit-identical
    assert batch_speedup(prof, 2) == pytest.approx(2.0)
    # asymptote: g(b) -> g_inf, cost grows sublinearly
    assert batch_speedup(prof, 1000) == pytest.approx(3.0, rel=1e-2)
    assert batch_cost(prof, 4) < 4.0
    # gain 1.0 means linear scaling (no free lunch for wide DNNs)
    flat = StageProfile("s", 1.0, 1.0, 0.0, batch_gain=1.0)
    assert batch_cost(flat, 8) == pytest.approx(8.0)


def test_batch_policy_validation():
    with pytest.raises(ValueError, match="max_batch"):
        BatchPolicy(max_batch=0)
    with pytest.raises(ValueError, match="max_wait_ms"):
        BatchPolicy(max_wait_ms=-1.0)
    with pytest.raises(ValueError, match="scope"):
        BatchPolicy(scope="dnn")


# --------------------------------------------------------------- coalescing
def test_releases_coalesce_into_batched_job():
    """Releases arriving while a job of the same task is queued at stage 0
    join it; the batch carries per-input release times and input-level
    accounting (jps_inputs > jps)."""
    spec = make_spec("t", HP, [10.0], 200.0)
    srv = serve([(spec, [0.0, 1.0, 2.0, 3.0])],
                policy=BatchPolicy(max_batch=4))
    m = srv.run()
    # t=0 is held for the pending releases (lazy dispatch); 1, 2, 3 join
    # -> one full 4-batch, sealed the moment it hits max_batch
    assert m.completed[HP] == 1
    assert m.completed_inputs[HP] == 4
    assert m.batch_hist == {4: 1}
    assert len(m.response_ms[HP]) == 4      # one response per input
    assert m.jps_inputs == 4 * m.jps
    assert m.mean_batch() == pytest.approx(4.0)
    snap = srv.snapshot()
    assert snap["coalesced"] == 3
    assert any(d.startswith("batch ") for d in srv.decisions)


def test_slack_bound_respected():
    """A release may not join if the enlarged batch would newly push the
    head past its stage-0 virtual deadline."""
    # single stage -> vdl == absolute deadline (release + 25); afet = 10
    spec = make_spec("t", HP, [10.0], 25.0)
    srv = serve([(spec, [0.0, 1.0, 2.0, 3.0])],
                policy=BatchPolicy(max_batch=8))
    m = srv.run()
    # head released at 0 (vdl 25): t=1 joins (1 + 2*10 <= 25); t=3 would
    # need 30ms more while the 2-batch can still make its deadline ->
    # refused, a second job forms instead (which t=3's successor joins)
    assert m.batch_hist == {2: 2}
    assert 3 not in m.batch_hist
    assert m.completed_inputs[HP] == 4


def test_max_wait_bounds_joining():
    spec = make_spec("t", HP, [10.0], 500.0)
    srv = serve([(spec, [0.0, 1.0, 9.0])],
                policy=BatchPolicy(max_batch=8, max_wait_ms=5.0))
    m = srv.run()
    # head at t=0, t=1 joins; despite 500ms of deadline slack the head may
    # not keep accumulating past max_wait -> t=9 starts a fresh job
    assert m.batch_hist == {1: 1, 2: 1}
    assert m.completed[HP] == 2
    assert m.completed_inputs[HP] == 3


def test_admission_charges_batched_utilization():
    """Joining charges the incremental b/g(b) utilization against Eq. 12:
    batching cannot sneak LP load past the admission test."""
    dev = DeviceModel(n_units=1.0, bubble=0.0, l2_pressure=0.0)
    hog = make_spec("hog", HP, [70.0], 100.0)       # U_r = 1 - 0.7 = 0.3
    lp = make_spec("lp", LP, [10.0], 100.0)         # u = 0.1 per input
    srv = serve([(hog, [0.0]), (lp, [5.0, 10.0, 15.0])],
                policy=BatchPolicy(max_batch=8), device=dev)
    m = srv.run()
    # t=5 admitted (0.1 < 0.3); t=10 joins (charge 0.2 < 0.3); t=15 can
    # neither join (0.2 + 0.1 >= 0.3) nor be admitted alone -> rejected
    assert m.batch_hist.get(2) == 1
    assert m.rejected[LP] == 1
    assert m.completed_inputs[LP] == 2


def test_model_scope_batches_across_streams_task_scope_does_not():
    """scope='model' (default) coalesces identical-profile streams — the
    Table II population; scope='task' keeps streams separate."""
    specs = [(make_spec(f"t{i}", HP, [10.0], 200.0), [float(i)])
             for i in range(4)]

    def run_with(scope):
        cfg = (ServerConfig.sim().contexts(1).streams(1).oversubscribe(1.0)
               .device(ideal_device()).horizon_ms(500.0).noise(0.0)
               .phase_offsets(False))
        for spec, times in specs:
            cfg.task(spec, arrival=TraceArrival(times))
        cfg.batching(max_batch=4, scope=scope)
        return cfg.build().run()

    m_model = run_with("model")
    m_task = run_with("task")
    assert max(m_model.batch_hist) > 1        # cross-stream batch formed
    assert max(m_task.batch_hist) == 1        # streams never coalesce
    assert m_model.completed_inputs[HP] == m_task.completed_inputs[HP] == 4


def test_lazy_dispatch_holds_head_for_forming_batch():
    """A growable head is held until its latest start time when the engine
    will wake again before then — so batches form even with free lanes."""
    spec = make_spec("t", HP, [10.0], 100.0)
    srv = serve([(spec, [0.0, 2.0, 4.0])], policy=BatchPolicy(max_batch=4))
    m = srv.run()
    # t=0 job is dispatchable immediately, but the pending release at t=2
    # lets the scheduler hold it; t=2 and t=4 join -> one 3-batch
    assert m.batch_hist == {3: 1}
    assert m.completed_inputs[HP] == 3


def test_unbatched_path_identical_without_policy():
    """BatchPolicy off => decision traces and metrics match a server that
    never heard of batching (the no-drift contract), including under
    straggler-heavy noise."""
    def run_one(with_noop_policy):
        cfg = (ServerConfig.sim()
               .tasks(table2_taskset("resnet18"))
               .contexts(4).oversubscribe(4.0)
               .horizon_ms(600.0).seed(0).record_decisions())
        if with_noop_policy:
            cfg.batching(max_batch=1)     # policy present, coalescing off
        srv = cfg.build()
        m = srv.run()
        return srv.decisions, m.completed, m.missed, m.unfinished

    plain = run_one(False)
    noop = run_one(True)
    assert plain == noop


# ------------------------------------------------------- horizon-miss sweep
def test_horizon_sweep_counts_unfinished_and_late_jobs():
    """Jobs still in flight past their deadline when run() exits count as
    missed (fig11 overload DMR is otherwise understated)."""
    late = make_spec("late", HP, [50.0], 20.0)     # deadline 20 < exec 50
    srv = serve([(late, [0.0])], horizon=30.0)
    m = srv.run()
    assert m.completed[HP] == 0
    assert m.unfinished[HP] == 1
    assert m.missed[HP] == 1
    assert m.dmr(HP) == 1.0


def test_horizon_sweep_spares_jobs_still_within_deadline():
    fresh = make_spec("fresh", HP, [50.0], 100.0)  # deadline 100 > horizon
    srv = serve([(fresh, [0.0])], horizon=30.0)
    m = srv.run()
    assert m.unfinished[HP] == 1
    assert m.missed[HP] == 0
    assert m.dmr(HP) == 0.0


# -------------------------------------------------- straggler replay fixes
def _straggler_rig(first, second):
    """Two tasks on separate contexts, both launched at t=0; returns
    (sched, backend, jobs, insts) with rates computed. ``first`` launches
    first, so the straggler pass kills it first."""
    cfg = SchedulerConfig(n_contexts=2, n_streams=1, oversubscription=1.0,
                          straggler_kappa=3.0)
    sched = DarisScheduler([first, second], cfg, ideal_device())
    backend = SimBackend(noise_sigma=0.0)
    core = EngineCore(sched, backend, horizon_ms=10_000.0)
    backend.bind(core)
    backend.start()
    jobs, insts = {}, {}
    order = sorted(sched.tasks, key=lambda t: t.spec.name != first.name)
    for task in order:
        job = sched.on_release(task, 0.0)
        inst = sched.next_for_lane(job.ctx, 0.0)
        lane = (job.ctx, 0)
        inst.start_ms = 0.0
        inst.lane = lane
        sched.lanes[lane] = inst
        backend.launch(lane, inst)
        jobs[task.spec.name], insts[task.spec.name] = job, inst
    backend.running_set_changed()      # set rates + predictions
    return sched, backend, jobs, insts


def test_straggler_replay_respects_fixed_ctx():
    """An HP straggler replays on its OWN fixed context (Algorithm 1),
    never migrating; no migration is counted for it."""
    hp = make_spec("hp", HP, [1.0], 30.0)
    other = make_spec("lp-long", LP, [1000.0], 3000.0)
    sched, backend, jobs, insts = _straggler_rig(hp, other)
    own_ctx = sched.tasks[0].ctx
    backend.now = 500.0                # projected >> max(kappa*mret, floor)
    backend.running_set_changed()      # straggler pass fires on hp
    assert backend.core.metrics.stragglers == 1
    assert jobs["hp"].ctx == own_ctx                 # replayed in place
    assert sched.migrations == 0
    # the replayed instance went back through hp's own context queue/lane
    relaunched = sched.lanes[(own_ctx, 0)]
    assert relaunched is insts["hp"]


def test_straggler_move_of_lp_counts_as_migration():
    lp = make_spec("lp", LP, [1.0], 30.0)
    other = make_spec("hp", HP, [1.0], 30.0)
    sched, backend, jobs, insts = _straggler_rig(lp, other)
    lp_task = next(t for t in sched.tasks if t.priority == LP)
    old_ctx = lp_task.ctx
    # back up lp's own context so the predicted-finish argmin moves it
    sched.on_release(lp_task, 0.0)
    sched.on_release(lp_task, 0.0)
    backend.now = 500.0
    backend.running_set_changed()
    assert backend.core.metrics.stragglers == 1
    assert jobs["lp"].ctx != old_ctx
    assert sched.migrations == 1


# --------------------------------------------- HP-first fault re-placement
def test_fail_context_replaces_hp_before_lp():
    """Algorithm 1 re-run on fault: HP orphans claim the min-utilization
    survivor before any LP orphan, regardless of registration order."""
    specs = [make_spec("lp-big", LP, [8.0], 10.0),     # LP listed first
             make_spec("hp-mid", HP, [5.0], 10.0),
             make_spec("r-small", LP, [1.0], 10.0),
             make_spec("r-large", LP, [4.0], 10.0)]
    sched = DarisScheduler(
        specs, SchedulerConfig(n_contexts=3, n_streams=1,
                               oversubscription=1.0), ideal_device())
    lp_big, hp_mid, r_small, r_large = sched.tasks
    lp_big.ctx = 0
    hp_mid.ctx = 0
    r_small.ctx = 1
    r_large.ctx = 2
    sched.fail_context(0, 0.0)
    # HP goes first to ctx1 (the least-utilized survivor); the big LP then
    # lands on ctx2. The buggy self.tasks-order placement gave ctx1 to the
    # LP (listed first) and pushed the HP task to ctx2.
    assert hp_mid.ctx == 1
    assert lp_big.ctx == 2
    assert hp_mid.fixed_ctx


def test_coalesced_submit_handles_complete():
    """A submitted release that coalesces into another task's batch head
    still completes its own handle, at its own response time."""
    from repro.api import SubmitHandle
    srv = (ServerConfig.sim().contexts(1).streams(1).oversubscribe(1.0)
           .device(ideal_device()).horizon_ms(500.0).noise(0.0)
           .batching(max_batch=4).build())
    a = srv.submit(make_spec("a", HP, [10.0], 200.0), at_ms=0.0)
    b = srv.submit(make_spec("b", HP, [10.0], 200.0), at_ms=1.0)
    m = srv.run()
    assert m.batch_hist == {2: 1}            # b joined a's job
    assert a.status == SubmitHandle.COMPLETED
    assert b.status == SubmitHandle.COMPLETED
    assert a.response_ms == pytest.approx(b.response_ms + 1.0)


# ------------------------------------------------------ late-submit reject
def test_submit_beyond_horizon_raises():
    srv = (ServerConfig.sim().contexts(1).streams(1).oversubscribe(1.0)
           .horizon_ms(100.0).build())
    with pytest.raises(ValueError, match="horizon"):
        srv.submit(make_spec("t", HP, [1.0], 10.0), at_ms=200.0)
