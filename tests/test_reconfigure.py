"""Live elastic repartitioning: Eq. 9 geometry properties, the online
reconfiguration controller, the autoscaler, and checkpoint-consistent
scheduler state across fault + reconfigure events (seeded randomized —
hypothesis is unavailable offline)."""
import numpy as np
import pytest

from repro.core.partition import (ceil_even, make_contexts, overlap_matrix,
                                  reconfigure)
from repro.core.scheduler import DarisScheduler, SchedulerConfig
from repro.core.task import HP, LP
from repro.serving.profiles import device
from repro.serving.requests import table2_taskset


# ------------------------------------------------------ Eq. 9 geometry
@pytest.mark.parametrize("seed", range(10))
def test_partition_unit_count_matches_eq9(seed):
    """Per-context unit count is min(ceil_even(OS * N / N_c), N) for both
    make_contexts and reconfigure (same geometry, shifted indices)."""
    rng = np.random.default_rng(seed)
    nc = int(rng.integers(1, 9))
    ns = int(rng.integers(1, 4))
    n_units = int(rng.integers(nc, 96))
    os_v = float(rng.uniform(1.0, nc))
    want = min(ceil_even(os_v * n_units / nc), n_units)
    for ctxs in (make_contexts(nc, ns, os_v, n_units),
                 reconfigure(nc, ns, os_v, n_units, base_index=7)):
        assert len(ctxs) == nc
        for c in ctxs:
            assert len(c.units) == want
            assert all(0 <= u < n_units for u in c.units)
            assert c.n_streams == ns
    assert [c.index for c in reconfigure(nc, ns, os_v, n_units,
                                         base_index=7)] \
        == list(range(7, 7 + nc))


@pytest.mark.parametrize("seed", range(10))
def test_overlap_matrix_symmetric(seed):
    rng = np.random.default_rng(seed)
    nc = int(rng.integers(2, 8))
    ctxs = make_contexts(nc, 1, float(rng.uniform(1.0, nc)),
                         int(rng.integers(nc * 2, 128)))
    m = overlap_matrix(ctxs)
    for a in range(nc):
        for b in range(nc):
            assert m[a][b] == m[b][a]
        assert m[a][a] == len(ctxs[a].units)


@pytest.mark.parametrize("seed", range(8))
def test_os1_disjoint_and_osn_identical(seed):
    """OS=1 -> disjoint partitions (shapes where N/N_c is even, so
    ceil_even adds no overlap); OS=N_c -> every context sees the full
    device."""
    rng = np.random.default_rng(seed)
    nc = int(rng.integers(2, 7))
    n_units = nc * 2 * int(rng.integers(1, 9))    # N/N_c even
    iso = make_contexts(nc, 1, 1.0, n_units)
    m = overlap_matrix(iso)
    for a in range(nc):
        for b in range(nc):
            if a != b:
                assert m[a][b] == 0
    assert set().union(*(c.units for c in iso)) == set(range(n_units))
    full = make_contexts(nc, 1, float(nc), n_units)
    for c in full:
        assert c.units == set(range(n_units))


def _sched(nc=4, os_=4.0, ns=1, **kw) -> DarisScheduler:
    return DarisScheduler(
        table2_taskset("resnet18"),
        SchedulerConfig(n_contexts=nc, n_streams=ns, oversubscription=os_,
                        **kw), device())


def test_add_context_deterministic_eq9_geometry():
    """Scale-out appends the last Eq. 9 wrap-around slot of the
    post-scale-out shape — identically on every run (the historic path
    sliced an unordered set)."""
    units = []
    for _ in range(3):
        sched = _sched(nc=6, os_=3.0)
        ctx = sched.add_context(0.0)
        units.append(sorted(ctx.units))
        assert ctx.index == 6
    assert units[0] == units[1] == units[2]
    want = reconfigure(7, 1, 3.0, int(device().n_units))[-1]
    assert set(units[0]) == want.units


# ------------------------------------------------- online reconfigure
def test_reconfigure_rederives_geometry_and_replaces_all_tasks():
    sched = _sched(nc=4, os_=4.0)
    info = sched.reconfigure(0.0, n_contexts=6, oversubscription=3.0)
    assert info["retired"] == [0, 1, 2, 3]
    assert info["created"] == [4, 5, 6, 7, 8, 9]
    live = [c for c in sched.contexts if c.alive]
    n_units = int(device().n_units)
    want = min(ceil_even(3.0 * n_units / 6), n_units)
    assert len(live) == 6 and all(len(c.units) == want for c in live)
    for t in sched.tasks:            # Algorithm 1 re-ran over everyone
        assert sched.contexts[t.ctx].alive
    # HP spread: no live context holds two HP tasks while another has none
    by_ctx = {}
    for t in sched.tasks:
        if t.priority == HP:
            by_ctx[t.ctx] = by_ctx.get(t.ctx, 0) + 1
    assert max(by_ctx.values()) - min(by_ctx.values()) <= 1


def test_reconfigure_streams_change_creates_lanes():
    sched = _sched(nc=4, ns=1)
    sched.reconfigure(0.0, n_contexts=2, n_streams=3)
    live = [c for c in sched.contexts if c.alive]
    assert all(c.n_streams == 3 for c in live)
    free = sched.free_lanes()
    assert sorted(free) == [(4, 0), (4, 1), (4, 2), (5, 0), (5, 1), (5, 2)]


def _elastic_server(horizon=3000.0, **hooks):
    from repro.api import ServerConfig
    cfg = (ServerConfig.sim().tasks(table2_taskset("resnet18"))
           .contexts(6).oversubscribe(6.0).device(device())
           .horizon_ms(horizon).seed(0))
    for name, args in hooks.items():
        getattr(cfg, name)(*args[0], **args[1])
    return cfg.build()


def test_midrun_reconfigure_conserves_work_and_protects_hp():
    """The acceptance scenario: fault + scale-out + reshape in one run,
    zero HP misses, nothing stranded on retired contexts."""
    srv = _elastic_server(
        fail_context_at=((0, 900.0), {}),
        scale_out_at=((1500.0,), {}),
        reconfigure_at=((2100.0,), dict(n_contexts=6, oversubscription=5.0)))
    m = srv.run()
    assert m.dmr(HP) == 0.0
    assert m.reconfigures == 1 and m.faults == 1
    sched = srv.scheduler
    for c in sched.contexts:
        if not c.alive:
            assert len(sched.queues[c.index]) == 0
            assert not sched.active_jobs[c.index]
            assert all(i is None for ln, i in sched.lanes.items()
                       if ln[0] == c.index)
    assert m.completed[HP] + m.completed[LP] > 0


def test_midrun_reconfigure_deterministic():
    runs = []
    for _ in range(2):
        srv = _elastic_server(
            reconfigure_at=((1200.0,), dict(n_contexts=4,
                                            oversubscription=2.0)))
        m = srv.run()
        runs.append((m.completed[HP], m.completed[LP], m.missed[LP],
                     m.migrations,
                     tuple(np.round(m.response_ms[HP], 12))))
    assert runs[0] == runs[1]


def test_autoscaler_grows_under_load_and_shrinks_idle():
    from repro.api import ServerConfig
    grow = (ServerConfig.sim().tasks(table2_taskset("resnet18"))
            .contexts(1).oversubscribe(1.0).device(device())
            .horizon_ms(2500.0).seed(0)
            .autoscale(0.3, 0.8, check_every_ms=200.0, max_contexts=6)
            .build())
    mg = grow.run()
    assert sum(c.alive for c in grow.scheduler.contexts) > 1
    assert mg.reconfigures > 0 and mg.dmr(HP) == 0.0
    shrink = (ServerConfig.sim().tasks(table2_taskset("resnet18")[:2])
              .contexts(6).oversubscribe(6.0).device(device())
              .horizon_ms(2500.0).seed(0)
              .autoscale(0.4, 0.9, check_every_ms=200.0, min_contexts=2)
              .build())
    ms = shrink.run()
    assert sum(c.alive for c in shrink.scheduler.contexts) < 6
    assert ms.reconfigures > 0 and ms.dmr(HP) == 0.0


def test_reconfigure_at_validation():
    from repro.api import ServerConfig
    cfg = (ServerConfig.sim().tasks(table2_taskset("resnet18"))
           .device(device()).horizon_ms(1000.0))
    with pytest.raises(ValueError):
        cfg.reconfigure_at(500.0)                     # no shape change
    with pytest.raises(ValueError):
        cfg.reconfigure_at(2000.0, n_contexts=2).build()   # past horizon
    with pytest.raises(ValueError):
        (ServerConfig.sim().tasks(table2_taskset("resnet18"))
         .device(device()).horizon_ms(1000.0)
         .reconfigure_at(500.0, n_streams=0).build())      # zero lanes
    with pytest.raises(ValueError):
        (ServerConfig.sim().tasks(table2_taskset("resnet18"))
         .device(device()).horizon_ms(1000.0)
         .autoscale(0.9, 0.3).build())                # low >= high
    with pytest.raises(ValueError):
        (ServerConfig.sim().tasks(table2_taskset("resnet18"))
         .device(device()).horizon_ms(1000.0)
         .autoscale(0.3, 0.9, check_every_ms=0.0).build())  # would hang
    with pytest.raises(ValueError):
        _sched().reconfigure(0.0, n_streams=0)


# ------------------------------------------- checkpoint-consistent state
def test_checkpoint_roundtrip_through_fault_and_reconfigure(tmp_path):
    """save -> restore -> identical placement: geometry (incl. retired
    contexts), task assignments, MRET history, and the migrations
    counter all survive."""
    from repro.checkpoint import load_scheduler_state, save_scheduler_state
    srv = _elastic_server(
        horizon=2500.0,
        fail_context_at=((0, 700.0), {}),
        reconfigure_at=((1600.0,), dict(n_contexts=4, oversubscription=3.0)))
    srv.run()
    a = srv.scheduler
    assert a.migrations > 0
    path = str(tmp_path / "sched.msgpack")
    save_scheduler_state(a, path)
    b = _sched(nc=6, os_=6.0)
    load_scheduler_state(b, path)
    assert b.migrations == a.migrations
    assert len(b.contexts) == len(a.contexts)
    for ca, cb in zip(a.contexts, b.contexts):
        assert (ca.index, ca.alive, ca.n_streams) == \
            (cb.index, cb.alive, cb.n_streams)
        assert ca.units == cb.units
    for ta, tb in zip(a.tasks, b.tasks):
        assert (ta.ctx, ta.fixed_ctx) == (tb.ctx, tb.fixed_ctx)
        assert ta.mret.task_mret() == tb.mret.task_mret()
        for sa, sb in zip(ta.mret.stages, tb.mret.stages):
            assert list(sa.window) == list(sb.window)
    # same lane topology (occupancy is runtime state, not checkpointed):
    # every lane key exists in both, and retired contexts stay retired
    assert sorted(b.lanes) == sorted(a.lanes)
    live_lanes = {ln[0] for ln in b.free_lanes()}
    assert live_lanes == {c.index for c in b.contexts if c.alive}
    assert (b.cfg.n_contexts, b.cfg.n_streams, b.cfg.oversubscription) == \
        (a.cfg.n_contexts, a.cfg.n_streams, a.cfg.oversubscription)


def test_server_save_load_state(tmp_path):
    srv = _elastic_server(
        horizon=1500.0,
        reconfigure_at=((800.0,), dict(n_contexts=3)))
    srv.run()
    path = str(tmp_path / "srv.msgpack")
    srv.save_state(path)
    from repro.api import ServerConfig
    srv2 = (ServerConfig.sim().tasks(table2_taskset("resnet18"))
            .contexts(6).oversubscribe(6.0).device(device())
            .horizon_ms(1500.0).seed(0).build())
    srv2.load_state(path)
    for ta, tb in zip(srv.scheduler.tasks, srv2.scheduler.tasks):
        assert ta.ctx == tb.ctx
    assert srv2.scheduler.migrations == srv.scheduler.migrations


def test_load_scheduler_state_raises_on_stage_count_mismatch(tmp_path):
    from repro.checkpoint import load_scheduler_state, save_scheduler_state
    a = _sched()
    path = str(tmp_path / "s.msgpack")
    save_scheduler_state(a, path)
    b = DarisScheduler(table2_taskset("resnet18"),
                       SchedulerConfig(n_contexts=4, no_staging=True),
                       device())   # stages merged -> count mismatch
    with pytest.raises(ValueError, match="shape mismatch"):
        load_scheduler_state(b, path)


def test_load_scheduler_state_raises_on_stream_count_mismatch(tmp_path):
    """A constructor-built context's lane table can't be resized at
    restore; adopting the saved stream count silently would skew
    Eq. 11 against the lanes that exist."""
    from repro.checkpoint import load_scheduler_state, save_scheduler_state
    a = _sched(nc=4, ns=2)
    path = str(tmp_path / "s.msgpack")
    save_scheduler_state(a, path)
    b = _sched(nc=4, ns=1)
    with pytest.raises(ValueError, match="shape mismatch for context"):
        load_scheduler_state(b, path)


# ------------------------------------------- realtime state resharding
def test_realtime_backend_reshards_migrated_state():
    """Inter-stage state produced on one context physically reshards via
    serving.staging.migrate when its next stage runs on another context
    that has a sharding configured."""
    import jax
    from repro.runtime.backend import RealtimeBackend

    dev = jax.devices()[0]
    sh = jax.sharding.SingleDeviceSharding(dev)
    be = RealtimeBackend(ctx_shardings={1: sh})
    x = jax.device_put(np.arange(4.0, dtype=np.float32))
    be._job_state[7] = x
    be._state_ctx[7] = 0
    out = be._migrate_state(x, 7, 1)          # ctx 0 -> ctx 1: reshard
    assert be.resharded == 1
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    assert be._migrate_state(x, 7, 0) is x    # same ctx: untouched
    be2 = RealtimeBackend()                   # no shardings: no-op
    be2._state_ctx[7] = 0
    assert be2._migrate_state(x, 7, 1) is x
    assert be2.resharded == 0


# -------------------------------------------------- atomic pytree saves
def _tiny_tree():
    return {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.zeros(3, np.float32)}


def test_save_pytree_overwrite_leaves_no_debris(tmp_path):
    from repro.checkpoint import load_pytree, save_pytree
    tree = _tiny_tree()
    p = str(tmp_path / "ck")
    save_pytree(tree, p, step=1)
    tree2 = {k: v + 1 for k, v in tree.items()}
    save_pytree(tree2, p, step=2)           # exercises the .old sidestep
    leftovers = [q.name for q in tmp_path.iterdir()
                 if q.name != "ck.ckpt"]
    assert leftovers == []
    out = load_pytree({k: np.zeros_like(v) for k, v in tree.items()}, p)
    np.testing.assert_array_equal(out["w"], tree2["w"])


def test_load_pytree_falls_back_to_old_sidestep(tmp_path):
    """Crash window between sidestep and swap: .ckpt is gone but .old
    holds the previous complete checkpoint — loads must survive."""
    import os
    from repro.checkpoint import load_pytree, save_pytree
    tree = _tiny_tree()
    p = str(tmp_path / "ck")
    final = save_pytree(tree, p, step=1)
    os.rename(final, final + ".old")        # simulate the crash window
    out = load_pytree({k: np.zeros_like(v) for k, v in tree.items()}, p)
    np.testing.assert_array_equal(out["w"], tree["w"])


def test_save_pytree_keeps_old_until_swap_when_final_missing(tmp_path):
    """Double-crash window: if a prior crash left only .old, the next
    save must not delete it before the new .ckpt is swapped in — and it
    must reap staging dirs orphaned by SIGKILL'd saves."""
    import os
    from repro.checkpoint import load_pytree, save_pytree
    tree = _tiny_tree()
    p = str(tmp_path / "ck")
    final = save_pytree(tree, p, step=1)
    os.rename(final, final + ".old")          # crash #1: only .old left
    (tmp_path / "ck.tmpDEAD").mkdir()         # crash #2 debris: staging
    real_rename = os.rename
    seen = []

    def spy(a, b):
        # at the moment staging swaps to final, .old must still exist
        if str(b).endswith(".ckpt"):
            seen.append((tmp_path / "ck.ckpt.old").exists())
        real_rename(a, b)

    os.rename = spy
    try:
        save_pytree({k: v + 5 for k, v in tree.items()}, p, step=2)
    finally:
        os.rename = real_rename
    assert seen == [True]                     # invariant held at swap
    assert not (tmp_path / "ck.tmpDEAD").exists()
    assert [q.name for q in tmp_path.iterdir()] == ["ck.ckpt"]
    out = load_pytree({k: np.zeros_like(v) for k, v in tree.items()}, p)
    np.testing.assert_array_equal(out["w"], tree["w"] + 5)


def test_autoscaler_does_not_block_drain():
    """drain() must idle past pending autoscale check events."""
    from repro.api import ServerConfig
    from repro.serving.requests import make_task
    srv = (ServerConfig.sim()
           .tasks([make_task("resnet18", priority=HP, jps=20.0)])
           .contexts(2).oversubscribe(2.0).device(device())
           .horizon_ms(50_000.0).seed(0)
           .autoscale(0.1, 0.95, check_every_ms=100.0)
           .build())
    srv.core.arrivals = {}        # no periodic releases: submit-only run
    h = srv.submit(make_task("resnet18", priority=LP, jps=20.0,
                             tag="-oneshot"), at_ms=10.0)
    m = srv.drain()
    assert h.status == "completed"
    # idled shortly after the one job, not at the 50s horizon
    assert srv.core.now_ms() < 5_000.0


def test_fig12_cache_is_fidelity_keyed(tmp_path, monkeypatch):
    import json

    import benchmarks.fig12_elastic as fig12

    def fake_load(name):
        p = tmp_path / f"{name}.json"
        return json.loads(p.read_text()) if p.exists() else None

    monkeypatch.setattr(fig12, "load_json", fake_load)
    assert fig12.load_cached(fast=True) is None
    (tmp_path / "fig12.json").write_text(
        '{"_meta": {"fast": false}, "chaos": []}')
    assert fig12.load_cached(fast=True) is None      # wrong fidelity
    assert fig12.load_cached(fast=False) is not None


def test_save_pytree_recovers_from_stale_old_dir(tmp_path):
    """A .old left by an earlier crash must not wedge the next save."""
    from repro.checkpoint import load_pytree, save_pytree
    tree = _tiny_tree()
    p = str(tmp_path / "ck")
    save_pytree(tree, p, step=1)
    stale = tmp_path / "ck.ckpt.old"
    stale.mkdir()
    (stale / "junk").write_text("x")
    tree2 = {k: v * 2 for k, v in tree.items()}
    save_pytree(tree2, p, step=2)
    assert not stale.exists()
    out = load_pytree({k: np.zeros_like(v) for k, v in tree.items()}, p)
    np.testing.assert_array_equal(out["b"], tree2["b"])
