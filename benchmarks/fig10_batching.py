"""Paper Fig 10 / §VI-H: DARIS + dynamic batching (batch 4/2/8 for
RN18/UNet/IncV3).

Jobs arrive at the paper's UNSCALED Table II rates and the scheduler forms
batches itself: queued releases of the same task coalesce into batched
jobs under the earliest member's virtual deadline (core/batching.py),
instead of callers pre-scaling arrival rates — so this benchmark actually
exercises runtime batch formation, which is what §VI-H measures.

Key paper observations to reproduce: InceptionV3 gains >= 55% over
unbatched DARIS; per-DNN gain ordering follows Table I
(InceptionV3 > ResNet18 > UNet, narrow DNNs gain most).
"""
from __future__ import annotations

from repro.api import BatchPolicy
from repro.serving.profiles import TABLE1
from repro.serving.requests import table2_taskset

from .common import HORIZON_MS, cache_json, load_json, mps_cfg, run_sim

BATCH = {"resnet18": 4, "unet": 2, "inceptionv3": 8}


def load_cached(fast: bool = False):
    cached = load_json("fig10")
    # reuse the cache only if it is from this benchmark format AND the
    # same fidelity: pre-rewrite caches lack the dynamic-path fields, and
    # a --fast run's trimmed sweep must never masquerade as the full one
    if (cached and cached.get("_meta", {}).get("fast") == fast
            and all("batching_gain" in b for k, b in cached.items()
                    if k != "_meta")):
        return cached
    return None


def run(fast: bool = False) -> dict:
    cached = load_cached(fast)
    if cached:
        return cached
    horizon = 2500.0 if fast else HORIZON_MS
    ncs = (2, 6) if fast else (1, 2, 4, 6, 8)
    out = {"_meta": {"fast": fast}}
    for dnn, b in BATCH.items():
        rows = []
        for nc in ncs:
            cfg = mps_cfg(max(nc, 1), float(max(nc, 1)))
            base = run_sim(table2_taskset(dnn), cfg, horizon_ms=horizon)
            cfg_b = mps_cfg(max(nc, 1), float(max(nc, 1)),
                            batch_policy=BatchPolicy(max_batch=b))
            bat = run_sim(table2_taskset(dnn), cfg_b, horizon_ms=horizon)
            rows.append(dict(nc=nc, batch=b,
                             unbatched_jps_inputs=base["jps_inputs"],
                             unbatched_dmr_lp=base["dmr_lp"], **bat))
        best = max(rows, key=lambda r: r["jps_inputs"])
        best_unbatched = max(r["unbatched_jps_inputs"] for r in rows)
        out[dnn] = {
            "rows": rows,
            "upper_baseline": TABLE1[dnn][1],
            "best_jps_inputs": best["jps_inputs"],
            "best_unbatched_jps_inputs": best_unbatched,
            "batching_gain": best["jps_inputs"] / max(best_unbatched, 1e-9),
        }
    cache_json("fig10", out)
    return out


def csv_lines(out) -> list:
    lines = []
    for dnn, blob in out.items():
        if dnn == "_meta":
            continue
        best = max(blob["rows"], key=lambda r: r["jps_inputs"])
        lines.append(f"fig10/{dnn}_batched_best,{best['wall_s']*1e6:.0f},"
                     f"{best['jps_inputs']:.0f}")
        lines.append(f"fig10/{dnn}_batching_gain,0,"
                     f"{blob['batching_gain']:.3f}")
        lines.append(f"fig10/{dnn}_batched_dmr_lp,0,{best['dmr_lp']:.4f}")
        lines.append(f"fig10/{dnn}_mean_batch,0,{best['mean_batch']:.2f}")
    return lines
