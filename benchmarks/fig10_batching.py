"""Paper Fig 10 / §VI-H: batching + DARIS (batch 4/2/8 for RN18/UNet/IncV3).

Key paper observations to reproduce: fewer parallel tasks needed to exceed
the upper baseline; InceptionV3 gains >= 55% over unbatched DARIS; UNet DMR
drops under 0.5%.
"""
from __future__ import annotations

from repro.serving.profiles import TABLE1
from repro.serving.requests import table2_taskset

from .common import cache_json, load_json, mps_cfg, run_sim

BATCH = {"resnet18": 4, "unet": 2, "inceptionv3": 8}


def run() -> dict:
    cached = load_json("fig10")
    if cached:
        return cached
    out = {}
    for dnn, b in BATCH.items():
        rows = []
        for nc in (1, 2, 4, 6, 8):
            # batched jobs arrive at rate/b (each carries b inputs)
            specs = table2_taskset(dnn, batch=b, load_scale=1.0 / b)
            s = run_sim(specs, mps_cfg(max(nc, 1), float(max(nc, 1))))
            s["jps_inputs"] = s["jps"] * b
            s["jps_hp_inputs"] = s["jps_hp"] * b
            rows.append(dict(nc=nc, batch=b, **s))
        out[dnn] = {"rows": rows, "upper_baseline": TABLE1[dnn][1]}
    cache_json("fig10", out)
    return out


def csv_lines(out) -> list:
    lines = []
    for dnn, blob in out.items():
        best = max(blob["rows"], key=lambda r: r["jps_inputs"])
        lines.append(f"fig10/{dnn}_batched_best,{best['wall_s']*1e6:.0f},"
                     f"{best['jps_inputs']:.0f}")
        lines.append(f"fig10/{dnn}_batched_dmr_lp,0,{best['dmr_lp']:.4f}")
    return lines
