"""SOTA comparison rows (paper §VI-B) + beyond-paper fault tolerance.

  * Clockwork-like: one DNN at a time (1 ctx, 1 stream, EDF only) — trades
    throughput for predictability, like [14].
  * GSlice-like: spatially-partitioned batched server, no priorities /
    deadline awareness (2 ctx, batch-4, no fixed levels, no staging).
  * DARIS best: from fig4_6.
  * Fault drill: kill a context mid-run, elastic re-add (DESIGN.md §7).
"""
from __future__ import annotations

from repro.api import FaultPlan
from repro.core.scheduler import SchedulerConfig
from repro.serving.requests import table2_taskset

from .common import cache_json, load_json, mps_cfg, run_sim


def load_cached(fast: bool = False):
    return load_json("baselines")


def run() -> dict:
    cached = load_cached()
    if cached:
        return cached
    dnn = "resnet50" if False else "resnet18"   # paper quotes RN50; RN18 set is richer
    out = {}
    # Clockwork-like
    out["clockwork_like"] = run_sim(
        table2_taskset(dnn),
        SchedulerConfig(n_contexts=1, n_streams=1, oversubscription=1.0,
                        no_staging=True, no_last=True, no_prior=True))
    # GSlice-like
    out["gslice_like"] = run_sim(
        table2_taskset(dnn, batch=4, load_scale=0.25),
        SchedulerConfig(n_contexts=2, n_streams=1, oversubscription=2.0,
                        no_fixed=True, no_staging=True))
    out["gslice_like"]["jps_inputs"] = out["gslice_like"]["jps"] * 4
    # DARIS (batched + unbatched best configs)
    out["daris_best"] = run_sim(table2_taskset(dnn), mps_cfg(8, 8.0))
    # fault tolerance drill: ctx 0 dies at 2s, new ctx added at 3.5s
    out["fault_drill"] = run_sim(
        table2_taskset(dnn), mps_cfg(6, 6.0),
        fault_plan=FaultPlan(fail_ctx_at=(0, 2000.0), add_ctx_at=3500.0))
    out["fault_free"] = run_sim(table2_taskset(dnn), mps_cfg(6, 6.0))
    cache_json("baselines", out)
    return out


def csv_lines(out) -> list:
    return [
        f"baselines/clockwork_like_jps,{out['clockwork_like']['wall_s']*1e6:.0f},"
        f"{out['clockwork_like']['jps']:.0f}",
        f"baselines/gslice_like_inputs_jps,{out['gslice_like']['wall_s']*1e6:.0f},"
        f"{out['gslice_like']['jps_inputs']:.0f}",
        f"baselines/daris_best_jps,{out['daris_best']['wall_s']*1e6:.0f},"
        f"{out['daris_best']['jps']:.0f}",
        f"baselines/fault_drill_dmr_hp,0,{out['fault_drill']['dmr_hp']:.4f}",
        f"baselines/fault_drill_jps,0,{out['fault_drill']['jps']:.0f}",
    ]
