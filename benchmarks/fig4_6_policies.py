"""Paper Figs 4-6: throughput + LP DMR per policy/config per DNN task set.

Policies: MPS (Nc x 1, OS in {1, 2, Nc}), STR (1 x Ns), MPS+STR (Nc x Ns).
Baselines per DNN: lower = single-stream JPS, upper = pure batching
(Table I). The headline cells the paper quotes:
  RN18:  MPS 6x1_6 -> 1158 JPS (13% over batching); UNet 6x1_2 -> 281 (+8%);
  IncV3: 8x1_8 -> 87% of upper baseline.
"""
from __future__ import annotations

from repro.serving.profiles import TABLE1
from repro.serving.requests import table2_taskset

from .common import cache_json, load_json, mps_cfg, mps_str_cfg, run_sim, str_cfg


# parallel-unit protocol (benchmarks.run): one unit per DNN task set —
# this figure is by far the widest sweep, so --jobs splits it below the
# figure level
UNITS = ("resnet18", "unet", "inceptionv3")


def load_cached(fast: bool = False):
    return load_json("fig4_6")


def run_unit(dnn: str, fast: bool = False) -> dict:
    """Full policy sweep for one DNN task set (one parallel work unit)."""
    ncs = (2, 4, 6, 8, 10) if fast else (2, 3, 4, 5, 6, 7, 8, 9, 10)
    specs_fn = lambda: table2_taskset(dnn)
    rows = []
    for nc in ncs:
        for os_ in (1.0, 2.0, float(nc)):
            s = run_sim(specs_fn(), mps_cfg(nc, os_))
            rows.append(dict(policy="MPS", nc=nc, ns=1, os=os_, **s))
    for ns in ncs:
        s = run_sim(specs_fn(), str_cfg(ns))
        rows.append(dict(policy="STR", nc=1, ns=ns, os=1.0, **s))
    for nc in (2, 3, 4):
        for ns in (2, 3):
            for os_ in (1.0, float(nc)):
                s = run_sim(specs_fn(), mps_str_cfg(nc, ns, os_))
                rows.append(dict(policy="MPS+STR", nc=nc, ns=ns, os=os_,
                                 **s))
    return {
        "rows": rows,
        "upper_baseline": TABLE1[dnn][1],
        "lower_baseline": TABLE1[dnn][0],
    }


def merge_units(parts: dict, fast: bool = False) -> dict:
    out = {dnn: parts[dnn] for dnn in UNITS}
    cache_json("fig4_6", out)
    return out


def run(fast: bool = False) -> dict:
    cached = load_cached(fast)
    if cached:
        return cached
    return merge_units({dnn: run_unit(dnn, fast) for dnn in UNITS}, fast)


def best_of(rows, policy):
    cand = [r for r in rows if r["policy"] == policy]
    return max(cand, key=lambda r: r["jps"]) if cand else None


def csv_lines(out) -> list:
    lines = []
    for dnn, blob in out.items():
        for pol in ("MPS", "STR", "MPS+STR"):
            b = best_of(blob["rows"], pol)
            if b:
                lines.append(
                    f"fig4_6/{dnn}_{pol}_best,{b['wall_s']*1e6:.0f},"
                    f"{b['jps']:.0f}")
    return lines
