"""Paper Fig 7: mixed task set (all DNN types colocated)."""
from __future__ import annotations

from repro.serving.requests import mixed_taskset

from .common import cache_json, load_json, mps_cfg, run_sim, str_cfg


def load_cached(fast: bool = False):
    return load_json("fig7")


def run() -> dict:
    cached = load_cached()
    if cached:
        return cached
    rows = []
    for nc in (2, 4, 6, 8):
        for os_ in (1.0, 2.0, float(nc)):
            s = run_sim(mixed_taskset(), mps_cfg(nc, os_))
            rows.append(dict(policy="MPS", nc=nc, os=os_, **s))
    for ns in (2, 4, 6, 8):
        s = run_sim(mixed_taskset(), str_cfg(ns))
        rows.append(dict(policy="STR", ns=ns, **s))
    out = {"rows": rows}
    cache_json("fig7", out)
    return out


def csv_lines(out) -> list:
    best_mps = max((r for r in out["rows"] if r["policy"] == "MPS"),
                   key=lambda r: r["jps"])
    best_str = max((r for r in out["rows"] if r["policy"] == "STR"),
                   key=lambda r: r["jps"])
    return [
        f"fig7/mixed_MPS_best,{best_mps['wall_s']*1e6:.0f},{best_mps['jps']:.0f}",
        f"fig7/mixed_STR_best,{best_str['wall_s']*1e6:.0f},{best_str['jps']:.0f}",
    ]
