"""Fig 14 (beyond-paper): seeded chaos — fault rate vs DMR, degradation
on/off.

Sweeps the transient stage-fault rate with the full recovery stack
enabled (bounded deadline-aware retry, per-stage watchdog) and compares
the brownout/emergency degradation controller against a run that takes
the same faults with no load shedding. The acceptance bar at the
reference 1% fault rate with retry + degradation: ZERO HP deadline
misses, LP DMR within budget — transient faults must be an LP problem.

The ``twin`` entry is the chaos-off bit-identity check: an engine built
with ``.chaos(ChaosPlan(stage_fault_rate=0, ...))`` (hooks installed,
nothing ever drawn) must produce the SAME summary as one built with no
chaos at all. That guards the twin-path discipline — installing the
chaos layer cannot perturb a healthy run.
"""
from __future__ import annotations

from repro.api import ChaosPlan, DegradationPolicy, RetryPolicy, ServerConfig
from repro.serving.profiles import device
from repro.serving.requests import table2_taskset

from .common import HORIZON_MS, cache_json, load_json

DNN = "resnet18"
RATES = (0.0, 0.005, 0.01, 0.02, 0.05)
FAST_RATES = (0.0, 0.01)
REFERENCE_RATE = 0.01


def load_cached(fast: bool = False):
    cached = load_json("fig14")
    if cached and cached.get("_meta", {}).get("fast") == fast:
        return cached
    return None


def _base(horizon: float) -> ServerConfig:
    return (ServerConfig.sim()
            .tasks(table2_taskset(DNN))
            .contexts(4).streams(1).oversubscribe(4.0)
            .device(device())
            .horizon_ms(horizon).seed(0))


def _plan(rate: float, degradation: bool) -> ChaosPlan:
    return ChaosPlan(
        seed=0,
        stage_fault_rate=rate,
        retry=RetryPolicy(),
        watchdog_kappa=4.0,
        degradation=DegradationPolicy() if degradation else None)


def _row(name: str, rate: float, degradation: bool, horizon: float) -> dict:
    server = _base(horizon).chaos(_plan(rate, degradation)).build()
    s = server.run().summary()
    return dict(
        name=name, fault_rate=rate, degradation=degradation,
        dmr_hp=s["dmr_hp"], dmr_lp=s["dmr_lp"], jps=s["jps"],
        chaos_faults=s.get("chaos_faults", 0),
        retries=s.get("retries", 0),
        aborted_hp=s.get("aborted_hp", 0),
        aborted_lp=s.get("aborted_lp", 0),
        watchdog_kills=s.get("watchdog_kills", 0),
        shed_lp=s.get("shed_lp", 0),
        degrade_transitions=s.get("degrade_transitions", 0))


def run_twin(horizon: float) -> dict:
    """Chaos-off bit-identity: no plan vs an all-defaults (no-op) plan.

    The no-op plan has every hazard at zero AND the watchdog disabled —
    an armed watchdog is a real feature, not a no-op: its timer events
    legally split ``advance()`` into smaller integration steps, which
    reorders float accumulation at the 1e-14 level."""
    bare = _base(horizon).build().run().summary()
    zero = _base(horizon).chaos(ChaosPlan(seed=0)).build().run().summary()
    return {"identical": bare == zero, "bare": bare, "zero_plan": zero}


def run(fast: bool = False) -> dict:
    cached = load_cached(fast)
    if cached:
        return cached
    horizon = 2000.0 if fast else HORIZON_MS
    rates = FAST_RATES if fast else RATES
    rows = []
    for rate in rates:
        for deg in (False, True):
            tag = "deg" if deg else "nodeg"
            rows.append(_row(f"fault{rate:g}_{tag}", rate, deg, horizon))
    out = {"_meta": {"fast": fast},
           "sweep": rows,
           "twin": run_twin(horizon)}
    cache_json("fig14", out)
    return out


def csv_lines(out) -> list:
    lines = [f"fig14/twin_identical,0,{int(out['twin']['identical'])}"]
    for r in out["sweep"]:
        lines.append(f"fig14/{r['name']}_dmr_hp,0,{r['dmr_hp']:.4f}")
        lines.append(f"fig14/{r['name']}_dmr_lp,0,{r['dmr_lp']:.4f}")
        lines.append(f"fig14/{r['name']}_retries,0,{r['retries']}")
        lines.append(f"fig14/{r['name']}_aborted,0,"
                     f"{r['aborted_hp'] + r['aborted_lp']}")
        lines.append(
            f"fig14/{r['name']}_watchdog_kills,0,{r['watchdog_kills']}")
        lines.append(f"fig14/{r['name']}_shed_lp,0,{r['shed_lp']}")
    return lines
