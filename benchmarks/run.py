"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract; each
module also caches full JSON under artifacts/bench/ (EXPERIMENTS.md reads
those). ``--fast`` trims sweep widths for CI.

``--jobs N`` runs figures process-parallel (default: one worker per CPU,
capped at the number of work items). Figures that declare ``UNITS``
(fig4_6: one unit per DNN task set) are split below the figure level so
the widest sweep doesn't serialize the whole suite; their unit results
are merged and cached in the parent process. ``--jobs 1`` preserves the
historic in-process sequential path. Results and cache files are
identical whichever path runs — workers only compute, the CSV is emitted
in canonical figure order by the parent.
"""
from __future__ import annotations

import argparse
import importlib
import os
import sys
import time
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait

# canonical figure order: (name, module, runner of last resort)
FIGURES = [
    ("table1", "benchmarks.table1_batching"),
    ("fig4_6", "benchmarks.fig4_6_policies"),
    ("fig7", "benchmarks.fig7_mixed"),
    ("fig8", "benchmarks.fig8_ablation"),
    ("fig9", "benchmarks.fig9_mret"),
    ("fig10", "benchmarks.fig10_batching"),
    ("fig11", "benchmarks.fig11_overload"),
    ("fig12", "benchmarks.fig12_elastic"),
    ("fig13", "benchmarks.fig13_cluster"),
    ("fig14", "benchmarks.fig14_chaos"),
    ("baselines", "benchmarks.baselines"),
]


def _run_figure(modname: str, fast: bool):
    """Worker: compute (and cache) a whole figure."""
    import inspect
    mod = importlib.import_module(modname)
    # inspect the signature instead of catching TypeError: a TypeError
    # raised inside run(fast=...) must surface, not silently rerun the
    # figure at full fidelity
    if "fast" in inspect.signature(mod.run).parameters:
        return mod.run(fast=fast)
    return mod.run()


def _run_unit(modname: str, unit: str, fast: bool):
    """Worker: compute one parallel unit of a UNITS-declaring figure."""
    mod = importlib.import_module(modname)
    return mod.run_unit(unit, fast)


def _sequential(selected, fast: bool) -> dict:
    out = {}
    for name, modname in selected:
        t0 = time.time()
        try:
            out[name] = _run_figure(modname, fast)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:  # keep the harness running
            print(f"# {name} FAILED: {e!r}", file=sys.stderr)
            out[name] = None
    return out


def _parallel(selected, fast: bool, jobs: int) -> dict:
    out = {}
    t0 = {}
    pending_units: dict = {}   # name -> {unit: result|None}
    fut_info = {}
    with ProcessPoolExecutor(max_workers=jobs) as pool:
        for name, modname in selected:
            mod = importlib.import_module(modname)
            cached = None
            t0[name] = time.time()
            try:
                if hasattr(mod, "load_cached"):
                    cached = mod.load_cached(fast)
            except Exception as e:   # e.g. a truncated cache file
                print(f"# {name} cache unreadable ({e!r}), recomputing",
                      file=sys.stderr)
                cached = None
            if cached:
                out[name] = cached
                print(f"# {name} cached", file=sys.stderr)
            elif hasattr(mod, "UNITS"):
                pending_units[name] = {u: None for u in mod.UNITS}
                for u in mod.UNITS:
                    fut = pool.submit(_run_unit, modname, u, fast)
                    fut_info[fut] = (name, modname, u)
            else:
                fut = pool.submit(_run_figure, modname, fast)
                fut_info[fut] = (name, modname, None)
        not_done = set(fut_info)
        while not_done:
            done, not_done = wait(not_done, return_when=FIRST_COMPLETED)
            for fut in done:
                name, modname, unit = fut_info[fut]
                err = fut.exception()
                if err is not None:
                    print(f"# {name} FAILED: {err!r}", file=sys.stderr)
                    out.setdefault(name, None)
                    pending_units.pop(name, None)
                    continue
                if unit is None:
                    out[name] = fut.result()
                    print(f"# {name} done in {time.time()-t0[name]:.1f}s",
                          file=sys.stderr)
                    continue
                units = pending_units.get(name)
                if units is None:
                    continue       # a sibling unit already failed
                units[unit] = fut.result()
                if all(v is not None for v in units.values()):
                    mod = importlib.import_module(modname)
                    try:
                        out[name] = mod.merge_units(units, fast)
                        print(f"# {name} done in "
                              f"{time.time()-t0[name]:.1f}s "
                              f"({len(units)} units)", file=sys.stderr)
                    except Exception as e:   # keep the harness running
                        print(f"# {name} FAILED: {e!r}", file=sys.stderr)
                        out[name] = None
                    pending_units.pop(name)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--jobs", type=int, default=0,
                    help="parallel worker processes (0 = one per CPU, "
                         "capped; 1 = historic sequential path)")
    args, _ = ap.parse_known_args()

    selected = [(n, m) for n, m in FIGURES
                if not args.only or n == args.only]
    n_items = sum(len(getattr(importlib.import_module(m), "UNITS", ())) or 1
                  for _, m in selected)
    jobs = args.jobs or min(os.cpu_count() or 1, 8, n_items)
    if jobs > 1 and len(selected) > 1 or jobs > 1 and any(
            hasattr(importlib.import_module(m), "UNITS")
            for _, m in selected):
        results = _parallel(selected, args.fast, jobs)
    else:
        results = _sequential(selected, args.fast)

    lines = []
    for name, modname in selected:
        blob = results.get(name)
        if blob is None:
            lines.append(f"{name}/FAILED,0,0")
            continue
        mod = importlib.import_module(modname)
        try:
            lines.extend(mod.csv_lines(blob))
        except Exception as e:
            print(f"# {name} csv FAILED: {e!r}", file=sys.stderr)
            lines.append(f"{name}/FAILED,0,0")

    # roofline summary rows (from dry-run artifacts, if present)
    try:
        from repro.launch.roofline import build_table
        rows = build_table()
        for r in rows:
            lines.append(
                f"roofline/{r['arch']}__{r['shape']},0,"
                f"{r['roofline_fraction']:.4f}")
    except Exception as e:
        print(f"# roofline rows skipped: {e!r}", file=sys.stderr)

    print("name,us_per_call,derived")
    for ln in lines:
        print(ln)


if __name__ == "__main__":
    main()
