"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract; each
module also caches full JSON under artifacts/bench/ (EXPERIMENTS.md reads
those). ``--fast`` trims sweep widths for CI.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()

    from . import (baselines, fig4_6_policies, fig7_mixed, fig8_ablation,
                   fig9_mret, fig10_batching, fig11_overload, table1_batching)

    lines = []
    jobs = [
        ("table1", lambda: table1_batching.csv_lines(table1_batching.run())),
        ("fig4_6", lambda: fig4_6_policies.csv_lines(
            fig4_6_policies.run(fast=args.fast))),
        ("fig7", lambda: fig7_mixed.csv_lines(fig7_mixed.run())),
        ("fig8", lambda: fig8_ablation.csv_lines(fig8_ablation.run())),
        ("fig9", lambda: fig9_mret.csv_lines(fig9_mret.run())),
        ("fig10", lambda: fig10_batching.csv_lines(
            fig10_batching.run(fast=args.fast))),
        ("fig11", lambda: fig11_overload.csv_lines(fig11_overload.run())),
        ("baselines", lambda: baselines.csv_lines(baselines.run())),
    ]
    for name, fn in jobs:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            lines.extend(fn())
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:  # keep the harness running
            print(f"# {name} FAILED: {e!r}", file=sys.stderr)
            lines.append(f"{name}/FAILED,0,0")

    # roofline summary rows (from dry-run artifacts, if present)
    try:
        from repro.launch.roofline import build_table
        rows = build_table()
        for r in rows:
            lines.append(
                f"roofline/{r['arch']}__{r['shape']},0,"
                f"{r['roofline_fraction']:.4f}")
    except Exception as e:
        print(f"# roofline rows skipped: {e!r}", file=sys.stderr)

    print("name,us_per_call,derived")
    for ln in lines:
        print(ln)


if __name__ == "__main__":
    main()
