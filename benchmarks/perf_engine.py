"""Engine performance harness: simulated-events/sec per scenario.

This is the repo's perf trajectory anchor. Each scenario builds a
representative DARIS workload (policy sweeps, batching, overload), runs it
through the sim engine, and reports

    events          = job releases + stage completions harvested
    wall_s          = wall-clock time of ``server.run()``
    events_per_sec  = events / wall_s

Events are counted by wrapping ``backend.advance`` and the release
handler, not by touching engine internals, so the harness measures any
engine version identically — that is what makes the committed
before/after numbers in ``benchmarks/BENCH_engine.json`` comparable.

Usage:
    python -m benchmarks.perf_engine [--fast]          # measure + write
        artifacts/bench/BENCH_engine.json
    python -m benchmarks.perf_engine --fast --check    # compare against
        the committed benchmarks/BENCH_engine.json; exit 1 if any
        scenario's events/sec regressed more than --tolerance (30%)
    python -m benchmarks.perf_engine --fast --write-baseline
        # refresh the committed baseline (keeps before_* fields)

CI runs the ``--check`` mode on every push. Absolute events/sec moves
with host hardware, so the gate is *shape-normalized*: each scenario is
compared by its events/sec relative to the run's geometric mean, which
is hardware-independent and catches any single hot path regressing
(a wide absolute floor backstops uniform slowdowns). See ``check``.
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys
import time

BASELINE = pathlib.Path(__file__).resolve().parent / "BENCH_engine.json"
OUT = pathlib.Path("artifacts/bench/BENCH_engine.json")


def _scenarios(fast: bool):
    """name -> zero-arg builder returning an unrun DarisServer."""
    from repro.api import BatchPolicy
    from repro.core.scheduler import SchedulerConfig
    from repro.serving.profiles import TABLE1
    from repro.serving.requests import ratio_taskset, table2_taskset

    from .common import make_server, mps_cfg, mps_str_cfg, str_cfg

    h = 1500.0 if fast else 4000.0

    def build(specs, cfg, horizon=None):
        return make_server(specs, cfg, horizon_ms=horizon or h).build()

    rn18_over_jps = TABLE1["resnet18"][1] * 1.5 / 30
    return {
        "mps_rn18_6x1_os6": lambda: build(
            table2_taskset("resnet18"), mps_cfg(6, 6.0)),
        "mps_incv3_8x1_os8": lambda: build(
            table2_taskset("inceptionv3"), mps_cfg(8, 8.0)),
        "str_unet_6": lambda: build(table2_taskset("unet"), str_cfg(6)),
        "mps_str_rn18_3x3_os3": lambda: build(
            table2_taskset("resnet18"), mps_str_cfg(3, 3, 3.0)),
        "batch_incv3_6x1_os6": lambda: build(
            table2_taskset("inceptionv3"),
            mps_cfg(6, 6.0, batch_policy=BatchPolicy(max_batch=8))),
        "overload_rn18_hpa": lambda: build(
            ratio_taskset("resnet18", 0.66, 30, rn18_over_jps),
            mps_cfg(6, 6.0, overload_hpa=True)),
    }


def run_scenario(build, repeat: int = 1) -> dict:
    """Best-of-``repeat`` measurement: scenarios are deterministic, so
    event counts are identical across repeats and the fastest wall time
    is the least-noisy estimate — fast-mode runs are short enough that
    shared-runner noise would otherwise dominate a single shot."""
    best = None
    for _ in range(max(repeat, 1)):
        r = _run_scenario_once(build)
        if best is None or r["wall_s"] < best["wall_s"]:
            best = r
    return best


def _run_scenario_once(build) -> dict:
    server = build()
    core = server.core
    counts = {"releases": 0, "stage_completions": 0}

    orig_advance = core.backend.advance
    orig_release = core._handle_release

    def advance(cap_ms):
        out = orig_advance(cap_ms)
        counts["stage_completions"] += len(out)
        return out

    def handle_release(task, proc, t):
        counts["releases"] += 1
        return orig_release(task, proc, t)

    core.backend.advance = advance
    core._handle_release = handle_release
    t0 = time.perf_counter()
    m = server.run()
    wall = time.perf_counter() - t0
    events = counts["releases"] + counts["stage_completions"]
    return {
        "events": events,
        "releases": counts["releases"],
        "stage_completions": counts["stage_completions"],
        "wall_s": round(wall, 4),
        "events_per_sec": round(events / max(wall, 1e-9), 1),
        "jps": round(m.jps, 2),
    }


def measure(fast: bool, repeat: int = 1) -> dict:
    out = {"meta": {"fast": fast}, "scenarios": {}}
    for name, build in _scenarios(fast).items():
        r = run_scenario(build, repeat)
        out["scenarios"][name] = r
        print(f"# {name}: {r['events']} events in {r['wall_s']:.2f}s "
              f"-> {r['events_per_sec']:.0f} ev/s", file=sys.stderr)
    return out


def _geomean(xs) -> float:
    xs = [max(x, 1e-9) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def check(fresh: dict, baseline: dict, tolerance: float,
          abs_tolerance: float = 0.30) -> int:
    """Exit code 1 on regression.

    Absolute events/sec moves with host hardware (the committed baseline
    was measured on a developer machine; CI runners are often 2-3x
    slower), so a scenario passes if EITHER of two views is healthy:

    * shape-normalized: its events/sec relative to the run's geometric
      mean, vs the same ratio in the baseline — hardware-independent,
      catches one hot path regressing (e.g. the MPS+STR pathology
      returning) on any machine;
    * absolute: its events/sec within ``abs_tolerance`` of the
      committed number — so a large speedup of ONE scenario (which
      shifts the geomean and lowers every other scenario's ratio) does
      not flag the unchanged ones as regressions.

    A true regression fails both: it drops relative to its siblings AND
    below its absolute floor. The residual blind spot is a uniform
    slowdown measured on much slower hardware — refresh the baseline
    with ``--write-baseline`` when hardware or engine generations
    change."""
    if fresh["meta"].get("fast") != baseline.get("meta", {}).get("fast"):
        print("# baseline fidelity (meta.fast) does not match this run; "
              "refresh it with the same mode (--write-baseline)",
              file=sys.stderr)
        return 1
    base = baseline.get("scenarios", {})
    common = [n for n in fresh["scenarios"] if n in base]
    for name in fresh["scenarios"]:
        if name not in base:
            print(f"# {name}: no committed baseline, skipping",
                  file=sys.stderr)
    if not common:
        return 0
    f_gm = _geomean([fresh["scenarios"][n]["events_per_sec"]
                     for n in common])
    b_gm = _geomean([base[n]["events_per_sec"] for n in common])
    failed = 0
    for name in common:
        r, b = fresh["scenarios"][name], base[name]
        rel_fresh = r["events_per_sec"] / f_gm
        rel_base = b["events_per_sec"] / b_gm
        rel_ok = rel_fresh >= rel_base * (1.0 - tolerance)
        abs_ok = (r["events_per_sec"]
                  >= b["events_per_sec"] * (1.0 - abs_tolerance))
        ok = rel_ok or abs_ok
        print(f"# {name}: {r['events_per_sec']:.0f} ev/s "
              f"(norm {rel_fresh:.2f} vs baseline {rel_base:.2f}; "
              f"committed {b['events_per_sec']:.0f}) "
              f"{'OK' if ok else 'REGRESSION'}", file=sys.stderr)
        failed += 0 if ok else 1
    return 1 if failed else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="compare against the committed baseline")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="max normalized events/sec drop per scenario")
    ap.add_argument("--abs-tolerance", type=float, default=0.30,
                    help="absolute events/sec floor; a scenario passes "
                         "on EITHER the normalized or the absolute view")
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh benchmarks/BENCH_engine.json (keeps "
                         "before_* fields)")
    ap.add_argument("--repeat", type=int, default=0,
                    help="best-of-N per scenario (default: 3 with "
                         "--check, else 1)")
    ap.add_argument("--out", default=str(OUT))
    args = ap.parse_args()

    repeat = args.repeat or (3 if args.check else 1)
    fresh = measure(args.fast, repeat)
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(fresh, indent=1))
    print(f"# wrote {out}", file=sys.stderr)

    if args.write_baseline:
        old = (json.loads(BASELINE.read_text()) if BASELINE.exists()
               else {"scenarios": {}, "meta": {}})
        for name, r in fresh["scenarios"].items():
            prev = old["scenarios"].get(name, {})
            merged = dict(r)
            for k in ("before_events_per_sec", "before_wall_s"):
                if k in prev:
                    merged[k] = prev[k]
            old["scenarios"][name] = merged
        # refresh fidelity, keep provenance fields (the note explaining
        # where before_* numbers came from must survive refreshes)
        meta = old.get("meta", {})
        meta["fast"] = fresh["meta"]["fast"]
        old["meta"] = meta
        BASELINE.write_text(json.dumps(old, indent=1))
        print(f"# wrote {BASELINE}", file=sys.stderr)

    if args.check:
        if not BASELINE.exists():
            print("# no committed baseline; nothing to check",
                  file=sys.stderr)
            return
        sys.exit(check(fresh, json.loads(BASELINE.read_text()),
                       args.tolerance, args.abs_tolerance))

    for name, r in fresh["scenarios"].items():
        print(f"perf_engine/{name},{r['wall_s']*1e6:.0f},"
              f"{r['events_per_sec']:.0f}")


if __name__ == "__main__":
    main()
