"""Engine performance harness: simulated-events/sec per scenario.

This is the repo's perf trajectory anchor. Each scenario builds a
representative DARIS workload (policy sweeps, batching, overload), runs it
through the sim engine, and reports

    events          = job releases + stage completions harvested
    wall_s          = wall-clock time of ``server.run()``
    events_per_sec  = events / wall_s

Events are counted by wrapping ``backend.advance`` and the release
handler, not by touching engine internals, so the harness measures any
engine version identically — that is what makes the committed
before/after numbers in ``benchmarks/BENCH_engine.json`` comparable.

Usage:
    python -m benchmarks.perf_engine [--fast]          # measure + write
        artifacts/bench/BENCH_engine.json (heap engine)
    python -m benchmarks.perf_engine --fast --engine epoch
        # same scenarios through the array-programmed epoch engine
    python -m benchmarks.perf_engine --fast --check    # compare against
        the committed benchmarks/BENCH_engine.json; exit 1 if any
        scenario's events/sec regressed more than --tolerance (30%);
        --engine epoch gates against the epoch_* baseline columns
    python -m benchmarks.perf_engine --fast --engine both --write-baseline
        # refresh the committed baseline (keeps before_* fields unless
        # --refresh-before; epoch numbers land in epoch_* columns)

CI runs the ``--check`` mode on every push. Absolute events/sec moves
with host hardware, so the gate is *shape-normalized*: each scenario is
compared by its events/sec relative to the run's geometric mean, which
is hardware-independent and catches any single hot path regressing
(a wide absolute floor backstops uniform slowdowns). See ``check``.
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys
import time

BASELINE = pathlib.Path(__file__).resolve().parent / "BENCH_engine.json"
OUT = pathlib.Path("artifacts/bench/BENCH_engine.json")


def _diurnal_trace(rng, base_per_ms: float, horizon_ms: float):
    """Arrival times (ms) from an inhomogeneous Poisson process whose
    rate swings sinusoidally over one full cycle of the horizon —
    fleet traffic following a compressed diurnal curve. Thinning against
    the peak rate keeps the draw exact and seed-deterministic."""
    peak = base_per_ms * 1.8
    times, t = [], 0.0
    while True:
        t += float(rng.exponential(1.0 / peak))
        if t >= horizon_ms:
            return times
        lam = base_per_ms * (1.0 + 0.8 * math.sin(
            2.0 * math.pi * t / horizon_ms))
        if float(rng.uniform()) * peak < lam:
            times.append(t)


def _scenarios(fast: bool):
    """name -> builder(engine) returning an unrun DarisServer with that
    sim engine ("heap" | "epoch") selected."""
    import numpy as np

    from repro.api import BatchPolicy, Brownout, ServerConfig, TraceArrival
    from repro.core.task import LP, StageProfile, TaskSpec
    from repro.serving.profiles import TABLE1, device
    from repro.serving.requests import ratio_taskset, table2_taskset

    from .common import make_server, mps_cfg, mps_str_cfg, str_cfg

    h = 1500.0 if fast else 4000.0

    def build(specs, cfg, horizon=None, engine="heap"):
        return (make_server(specs, cfg, horizon_ms=horizon or h)
                .engine(engine).build())

    def cluster_build(engine):
        # fig13-shaped: heterogeneous 4-GPU cluster, global admission,
        # speed-aware placement
        return (ServerConfig.cluster(
                    4, device_models=["a100", "a100", "v100", "v100"])
                .tasks(table2_taskset("resnet18"))
                .contexts(4).streams(1).oversubscribe(4.0)
                .device(device()).horizon_ms(h).seed(0)
                .engine(engine).build())

    def chaos_build(engine):
        # fig14-shaped: faults + stalls + a mid-run brownout with the
        # stage watchdog armed — exercises the kill/retry hot paths
        return (make_server(table2_taskset("resnet18"), mps_cfg(6, 6.0),
                            horizon_ms=h)
                .chaos(seed=3, stage_fault_rate=0.02, stall_rate=0.05,
                       stall_ms=3.0, watchdog_kappa=6.0,
                       brownouts=(Brownout(0.25 * h, 0.55 * h, device=0,
                                           slow_factor=2.0),))
                .engine(engine).build())

    def fleet_build(engine):
        # 64-device fleet replaying a diurnal trace: the epoch engine's
        # showpiece (hundreds of concurrent lanes per array pass)
        n_dev, per_dev = 64, 3
        specs = [TaskSpec(name=f"svc{i:03d}", period_ms=24.0, priority=LP,
                          stages=[StageProfile(name=f"svc{i:03d}/s0",
                                               t_alone_ms=2.0,
                                               n_sat=20.0, mem_frac=0.3),
                                  StageProfile(name=f"svc{i:03d}/s1",
                                               t_alone_ms=2.0,
                                               n_sat=20.0, mem_frac=0.3)])
                 for i in range(n_dev * per_dev)]
        cfg = (ServerConfig.cluster(n_dev)
               .tasks(specs)
               .contexts(4).streams(1).oversubscribe(4.0)
               .device(device()).horizon_ms(h).seed(0)
               .engine(engine))
        for i, s in enumerate(specs):
            rng = np.random.default_rng(9000 + i)
            cfg.arrival(s.name,
                        TraceArrival(_diurnal_trace(rng, 1.0 / 24.0, h)))
        return cfg.build()

    rn18_over_jps = TABLE1["resnet18"][1] * 1.5 / 30
    return {
        "mps_rn18_6x1_os6": lambda e="heap": build(
            table2_taskset("resnet18"), mps_cfg(6, 6.0), engine=e),
        "mps_incv3_8x1_os8": lambda e="heap": build(
            table2_taskset("inceptionv3"), mps_cfg(8, 8.0), engine=e),
        "str_unet_6": lambda e="heap": build(
            table2_taskset("unet"), str_cfg(6), engine=e),
        "mps_str_rn18_3x3_os3": lambda e="heap": build(
            table2_taskset("resnet18"), mps_str_cfg(3, 3, 3.0), engine=e),
        "batch_incv3_6x1_os6": lambda e="heap": build(
            table2_taskset("inceptionv3"),
            mps_cfg(6, 6.0, batch_policy=BatchPolicy(max_batch=8)),
            engine=e),
        "overload_rn18_hpa": lambda e="heap": build(
            ratio_taskset("resnet18", 0.66, 30, rn18_over_jps),
            mps_cfg(6, 6.0, overload_hpa=True), engine=e),
        "cluster_rn18_4gpu": cluster_build,
        "chaos_rn18_6x1_os6": chaos_build,
        "fleet_64dev_diurnal": fleet_build,
    }


def run_scenario(build, repeat: int = 1, engine: str = "heap") -> dict:
    """Best-of-``repeat`` measurement: scenarios are deterministic, so
    event counts are identical across repeats and the fastest wall time
    is the least-noisy estimate — fast-mode runs are short enough that
    shared-runner noise would otherwise dominate a single shot."""
    best = None
    for _ in range(max(repeat, 1)):
        r = _run_scenario_once(build, engine)
        if best is None or r["wall_s"] < best["wall_s"]:
            best = r
    return best


def _run_scenario_once(build, engine: str = "heap") -> dict:
    server = build(engine)
    core = server.core
    counts = {"releases": 0, "stage_completions": 0}

    orig_advance = core.backend.advance
    orig_release = core._handle_release

    def advance(cap_ms):
        out = orig_advance(cap_ms)
        counts["stage_completions"] += len(out)
        return out

    def handle_release(task, proc, t, handle=None):
        counts["releases"] += 1
        return orig_release(task, proc, t, handle)

    core.backend.advance = advance
    core._handle_release = handle_release
    t0 = time.perf_counter()
    m = server.run()
    wall = time.perf_counter() - t0
    events = counts["releases"] + counts["stage_completions"]
    return {
        "events": events,
        "releases": counts["releases"],
        "stage_completions": counts["stage_completions"],
        "wall_s": round(wall, 4),
        "events_per_sec": round(events / max(wall, 1e-9), 1),
        "jps": round(m.jps, 2),
    }


def measure(fast: bool, repeat: int = 1, engine: str = "heap") -> dict:
    out = {"meta": {"fast": fast, "engine": engine}, "scenarios": {}}
    for name, build in _scenarios(fast).items():
        r = run_scenario(build, repeat, engine)
        out["scenarios"][name] = r
        print(f"# [{engine}] {name}: {r['events']} events in "
              f"{r['wall_s']:.2f}s -> {r['events_per_sec']:.0f} ev/s",
              file=sys.stderr)
    return out


def _geomean(xs) -> float:
    xs = [max(x, 1e-9) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def check(fresh: dict, baseline: dict, tolerance: float,
          abs_tolerance: float = 0.30, engine: str = "heap") -> int:
    """Exit code 1 on regression.

    Absolute events/sec moves with host hardware (the committed baseline
    was measured on a developer machine; CI runners are often 2-3x
    slower), so a scenario passes if EITHER of two views is healthy:

    * shape-normalized: its events/sec relative to the run's geometric
      mean, vs the same ratio in the baseline — hardware-independent,
      catches one hot path regressing (e.g. the MPS+STR pathology
      returning) on any machine;
    * absolute: its events/sec within ``abs_tolerance`` of the
      committed number — so a large speedup of ONE scenario (which
      shifts the geomean and lowers every other scenario's ratio) does
      not flag the unchanged ones as regressions.

    A true regression fails both: it drops relative to its siblings AND
    below its absolute floor. The residual blind spot is a uniform
    slowdown measured on much slower hardware — refresh the baseline
    with ``--write-baseline`` when hardware or engine generations
    change.

    ``engine`` selects which baseline columns to gate against: the heap
    engine's numbers live in the standard ``events_per_sec`` fields, the
    epoch engine's in ``epoch_events_per_sec`` (written by
    ``--write-baseline --engine epoch`` / ``both``)."""
    if fresh["meta"].get("fast") != baseline.get("meta", {}).get("fast"):
        print("# baseline fidelity (meta.fast) does not match this run; "
              "refresh it with the same mode (--write-baseline)",
              file=sys.stderr)
        return 1
    key = ("events_per_sec" if engine == "heap"
           else "epoch_events_per_sec")
    base = baseline.get("scenarios", {})
    common = [n for n in fresh["scenarios"]
              if n in base and key in base[n]]
    for name in fresh["scenarios"]:
        if name not in common:
            print(f"# {name}: no committed {engine} baseline, skipping",
                  file=sys.stderr)
    if not common:
        return 0
    f_gm = _geomean([fresh["scenarios"][n]["events_per_sec"]
                     for n in common])
    b_gm = _geomean([base[n][key] for n in common])
    failed = 0
    for name in common:
        r, b = fresh["scenarios"][name], base[name][key]
        rel_fresh = r["events_per_sec"] / f_gm
        rel_base = b / b_gm
        rel_ok = rel_fresh >= rel_base * (1.0 - tolerance)
        abs_ok = r["events_per_sec"] >= b * (1.0 - abs_tolerance)
        ok = rel_ok or abs_ok
        print(f"# [{engine}] {name}: {r['events_per_sec']:.0f} ev/s "
              f"(norm {rel_fresh:.2f} vs baseline {rel_base:.2f}; "
              f"committed {b:.0f}) "
              f"{'OK' if ok else 'REGRESSION'}", file=sys.stderr)
        failed += 0 if ok else 1
    return 1 if failed else 0


def _merge_baseline(old: dict, fresh: dict, engine: str,
                    refresh_before: bool) -> None:
    """Fold one engine's fresh measurements into the committed baseline
    dict (in place). Heap numbers own the standard fields; epoch numbers
    land in ``epoch_*`` columns of the same scenario entry so the two
    engines read side by side."""
    for name, r in fresh["scenarios"].items():
        prev = old["scenarios"].get(name, {})
        if engine == "heap":
            merged = dict(prev)
            merged.update(r)
            for k in ("before_events_per_sec", "before_wall_s"):
                if refresh_before:
                    merged[k] = r[k.replace("before_", "")]
                elif k in prev:
                    merged[k] = prev[k]
            old["scenarios"][name] = merged
        else:
            prev["epoch_events_per_sec"] = r["events_per_sec"]
            prev["epoch_wall_s"] = r["wall_s"]
            old["scenarios"][name] = prev
    meta = old.get("meta", {})
    meta["fast"] = fresh["meta"]["fast"]
    if engine == "heap" and refresh_before:
        meta["note"] = (
            "before_* re-baselined to the heap engine at the epoch-engine "
            "PR head (current host); epoch_* columns are the "
            "array-programmed engine on the same host. Refresh with "
            "perf_engine --fast --engine both --write-baseline "
            "[--refresh-before]")
    old["meta"] = meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--engine", choices=("heap", "epoch", "both"),
                    default="heap",
                    help="which sim engine to measure (both = heap then "
                         "epoch; epoch numbers go to epoch_* columns)")
    ap.add_argument("--check", action="store_true",
                    help="compare against the committed baseline")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="max normalized events/sec drop per scenario")
    ap.add_argument("--abs-tolerance", type=float, default=0.30,
                    help="absolute events/sec floor; a scenario passes "
                         "on EITHER the normalized or the absolute view")
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh benchmarks/BENCH_engine.json (keeps "
                         "before_* fields)")
    ap.add_argument("--refresh-before", action="store_true",
                    help="with --write-baseline: re-baseline before_* "
                         "from this run's heap numbers (use after an "
                         "engine generation or host change)")
    ap.add_argument("--repeat", type=int, default=0,
                    help="best-of-N per scenario (default: 3 with "
                         "--check, else 1)")
    ap.add_argument("--out", default=str(OUT))
    args = ap.parse_args()

    repeat = args.repeat or (3 if args.check else 1)
    engines = (("heap", "epoch") if args.engine == "both"
               else (args.engine,))
    runs = {e: measure(args.fast, repeat, e) for e in engines}

    primary = runs[engines[0]]
    out_payload = json.loads(json.dumps(primary))
    if "epoch" in runs and len(engines) > 1:
        for name, r in runs["epoch"]["scenarios"].items():
            out_payload["scenarios"][name]["epoch_events_per_sec"] = \
                r["events_per_sec"]
            out_payload["scenarios"][name]["epoch_wall_s"] = r["wall_s"]
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(out_payload, indent=1))
    print(f"# wrote {out}", file=sys.stderr)

    if args.write_baseline:
        old = (json.loads(BASELINE.read_text()) if BASELINE.exists()
               else {"scenarios": {}, "meta": {}})
        for e in engines:
            _merge_baseline(old, runs[e], e, args.refresh_before)
        BASELINE.write_text(json.dumps(old, indent=1))
        print(f"# wrote {BASELINE}", file=sys.stderr)

    if args.check:
        if not BASELINE.exists():
            print("# no committed baseline; nothing to check",
                  file=sys.stderr)
            return
        baseline = json.loads(BASELINE.read_text())
        rc = max(check(runs[e], baseline, args.tolerance,
                       args.abs_tolerance, engine=e) for e in engines)
        sys.exit(rc)

    for e in engines:
        for name, r in runs[e]["scenarios"].items():
            print(f"perf_engine/{e}/{name},{r['wall_s']*1e6:.0f},"
                  f"{r['events_per_sec']:.0f}")


if __name__ == "__main__":
    main()
