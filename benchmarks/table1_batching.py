"""Paper Table I: single-stream vs batched throughput per DNN.

min JPS = single stream alone (by construction of the calibration);
max JPS = large-batch single tenant. The batching CURVE (b = 1..32) is the
model's prediction; min/max anchor the calibration inputs, the in-between
shape is emergent.

The sim cross-check drives the *dynamic* batching path: one task releasing
single-input jobs at an UNSCALED, oversaturating rate, with a
``BatchPolicy`` letting the scheduler coalesce the backlog into batches
(up to 32). Steady-state input throughput should approach the analytic
batched maximum — validating that runtime batch formation, not load
pre-scaling, reproduces the Table I gains.
"""
from __future__ import annotations

from repro.api import BatchPolicy
from repro.core.task import HP
from repro.serving.profiles import (TABLE1, effective_batch_profile,
                                    make_task)

from .common import cache_json, run_sim, str_cfg

PAPER = {"resnet18": (627, 1025, 1.63), "resnet50": (250, 433, 1.73),
         "unet": (241, 260, 1.08), "inceptionv3": (142, 446, 3.13)}


def load_cached(fast: bool = False):
    return None        # cheap analytic table: always recomputed


def run() -> list:
    rows = []
    for dnn, (mn, mx) in TABLE1.items():
        curve = {}
        for b in (1, 2, 4, 8, 16, 32):
            t_b, _ = effective_batch_profile(dnn, b)
            curve[b] = 1000.0 * b / t_b
        # dynamic-batching sim cross-check: oversaturate one lane with
        # unscaled single-input releases; the scheduler forms the batches
        rate = 1.2 * curve[32]
        spec = make_task(dnn, priority=HP, jps=rate)
        s = run_sim([spec], str_cfg(1, batch_policy=BatchPolicy(max_batch=32)),
                    horizon_ms=4000.0)
        gain = curve[32] / curve[1]
        rows.append({
            "dnn": dnn, "min_jps_model": curve[1], "max_jps_model": curve[32],
            "gain_model": gain,
            "paper_min": PAPER[dnn][0], "paper_max": PAPER[dnn][1],
            "paper_gain": PAPER[dnn][2],
            "sim_dynamic_jps_inputs": s["jps_inputs"],
            "sim_mean_batch": s["mean_batch"],
            "curve": curve,
            "wall_s": s["wall_s"],
        })
    cache_json("table1", {"rows": rows})
    return rows


def csv_lines(rows) -> list:
    out = []
    for r in rows:
        out.append(f"table1/{r['dnn']}_gain,{r['wall_s']*1e6:.0f},"
                   f"{r['gain_model']:.2f}")
        out.append(f"table1/{r['dnn']}_dynamic_jps_inputs,0,"
                   f"{r['sim_dynamic_jps_inputs']:.0f}")
    return out
