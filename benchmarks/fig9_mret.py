"""Paper Fig 9 / §VI-G: MRET tracking of actual execution times.

Runs the best-throughput and worst-DMR configs, collects per-stage
(actual, predicted-MRET) pairs for one ResNet18 HP task, and reports
coverage (fraction of executions under the MRET prediction) + mean
overprovision. Paper: ws=5; smaller ws -> DMR up, larger -> throughput down
(we sweep ws in {2, 5, 10}).
"""
from __future__ import annotations

from repro.core.scheduler import DarisScheduler, SchedulerConfig
from repro.serving.requests import table2_taskset

from .common import cache_json, load_json, make_server


class TracingScheduler(DarisScheduler):
    def __init__(self, *a, trace_task: str = "resnet18-hp0", **kw):
        self.trace = []
        self._trace_task = trace_task
        super().__init__(*a, **kw)

    def on_stage_finish(self, inst, now, et_ms):
        if inst.task.name == self._trace_task:
            pred = inst.task.mret.stage_mret(inst.job.stage_idx)
            self.trace.append((now, inst.job.stage_idx, et_ms, pred))
        return super().on_stage_finish(inst, now, et_ms)


def _run_cfg(nc, os_, ws) -> dict:
    server = make_server(
        table2_taskset("resnet18"),
        SchedulerConfig(n_contexts=nc, n_streams=1, oversubscription=os_,
                        mret_window=ws),
        scheduler_cls=TracingScheduler).build()
    m = server.run()
    tr = server.scheduler.trace
    covered = sum(1 for _, _, et, pred in tr if et <= pred + 1e-9)
    over = [pred / et for _, _, et, pred in tr if et > 0]
    s = m.summary()
    return {
        "jps": s["jps"], "dmr_lp": s["dmr_lp"], "dmr_hp": s["dmr_hp"],
        "n_obs": len(tr),
        "mret_coverage": covered / max(len(tr), 1),
        "mret_overprovision_mean": sum(over) / max(len(over), 1),
        "trace_head": tr[:50],
    }


def load_cached(fast: bool = False):
    return load_json("fig9")


def run() -> dict:
    cached = load_cached()
    if cached:
        return cached
    out = {
        "best_throughput_6x1_6": _run_cfg(6, 6.0, 5),
        "worst_dmr_3x3_1": None,   # 3x3 is MPS+STR; approximate with 3 ctx
        "ws_sweep": {ws: _run_cfg(8, 8.0, ws) for ws in (2, 5, 10)},
    }
    from .common import run_sim, mps_str_cfg
    from repro.serving.requests import table2_taskset as ts
    out["worst_dmr_3x3_1"] = run_sim(ts("resnet18"), mps_str_cfg(3, 3, 1.0))
    cache_json("fig9", out)
    return out


def csv_lines(out) -> list:
    b = out["best_throughput_6x1_6"]
    return [
        f"fig9/mret_coverage_6x1_6,0,{b['mret_coverage']:.3f}",
        f"fig9/mret_overprovision,0,{b['mret_overprovision_mean']:.3f}",
    ] + [f"fig9/ws{ws}_dmr_lp,0,{v['dmr_lp']:.4f}"
         for ws, v in out["ws_sweep"].items()]
