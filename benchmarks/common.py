"""Shared benchmark plumbing: run one DARIS sim config, cache JSON."""
from __future__ import annotations

import json
import pathlib
import time

from repro.core.scheduler import DarisScheduler, SchedulerConfig
from repro.runtime.sim import FaultPlan, SimEngine
from repro.serving.profiles import device
from repro.serving.requests import mixed_taskset, ratio_taskset, table2_taskset

ART = pathlib.Path("artifacts/bench")
HORIZON_MS = 6000.0


def run_sim(specs, sched_cfg: SchedulerConfig, *, horizon_ms: float = HORIZON_MS,
            seed: int = 0, fault_plan=None) -> dict:
    t0 = time.time()
    sched = DarisScheduler(specs, sched_cfg, device())
    eng = SimEngine(sched, horizon_ms=horizon_ms, seed=seed,
                    fault_plan=fault_plan)
    m = eng.run()
    s = m.summary()
    s["wall_s"] = time.time() - t0
    return s


def cache_json(name: str, payload: dict) -> None:
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(payload, indent=1))


def load_json(name: str):
    p = ART / f"{name}.json"
    if p.exists():
        return json.loads(p.read_text())
    return None


def mps_cfg(nc: int, os_: float, **kw) -> SchedulerConfig:
    return SchedulerConfig(n_contexts=nc, n_streams=1, oversubscription=os_,
                           **kw)


def str_cfg(ns: int, **kw) -> SchedulerConfig:
    return SchedulerConfig(n_contexts=1, n_streams=ns, oversubscription=1.0,
                           **kw)


def mps_str_cfg(nc: int, ns: int, os_: float, **kw) -> SchedulerConfig:
    return SchedulerConfig(n_contexts=nc, n_streams=ns, oversubscription=os_,
                           **kw)
