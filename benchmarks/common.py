"""Shared benchmark plumbing: run one DARIS config via the ``repro.api``
facade (no benchmark constructs an engine directly), cache JSON."""
from __future__ import annotations

import json
import pathlib

from repro.api import FaultPlan, ServerConfig, run_and_summarize
from repro.core.scheduler import SchedulerConfig
from repro.serving.profiles import device

ART = pathlib.Path("artifacts/bench")
HORIZON_MS = 6000.0


def make_server(specs, sched_cfg: SchedulerConfig, *,
                horizon_ms: float = HORIZON_MS, seed: int = 0,
                fault_plan=None, scheduler_cls=None,
                **scheduler_cls_kw) -> ServerConfig:
    cfg = (ServerConfig.sim()
           .tasks(specs)
           .scheduler_config(sched_cfg)
           .device(device())
           .horizon_ms(horizon_ms)
           .seed(seed))
    if fault_plan is not None:
        cfg.fault_plan(fault_plan)
    if scheduler_cls is not None:
        cfg.scheduler_cls(scheduler_cls, **scheduler_cls_kw)
    return cfg


def run_sim(specs, sched_cfg: SchedulerConfig, *, horizon_ms: float = HORIZON_MS,
            seed: int = 0, fault_plan=None) -> dict:
    server = make_server(specs, sched_cfg, horizon_ms=horizon_ms, seed=seed,
                         fault_plan=fault_plan).build()
    return run_and_summarize(server)


def cache_json(name: str, payload: dict) -> None:
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(payload, indent=1))


def load_json(name: str):
    p = ART / f"{name}.json"
    if p.exists():
        return json.loads(p.read_text())
    return None


def mps_cfg(nc: int, os_: float, **kw) -> SchedulerConfig:
    return SchedulerConfig(n_contexts=nc, n_streams=1, oversubscription=os_,
                           **kw)


def str_cfg(ns: int, **kw) -> SchedulerConfig:
    return SchedulerConfig(n_contexts=1, n_streams=ns, oversubscription=1.0,
                           **kw)


def mps_str_cfg(nc: int, ns: int, os_: float, **kw) -> SchedulerConfig:
    return SchedulerConfig(n_contexts=nc, n_streams=ns, oversubscription=os_,
                           **kw)
