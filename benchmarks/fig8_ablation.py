"""Paper Fig 8 / §VI-F: DARIS module ablations on ResNet18.

  No Staging : whole-task units (paper: -33% throughput, 5.5%/22.5% DMR)
  No Last    : last stage not boosted (paper: +38% HP worst response)
  No Prior   : no missed-vdl boost (paper: higher mean responses)
  No Fixed   : no HP/LP differentiation (paper: 2.5% DMR both classes)
"""
from __future__ import annotations

from repro.serving.requests import table2_taskset

from .common import cache_json, load_json, mps_cfg, run_sim

BEST = dict(nc=8, os_=8.0)


def load_cached(fast: bool = False):
    return load_json("fig8")


def run() -> dict:
    cached = load_cached()
    if cached:
        return cached
    variants = {
        "daris": {},
        "no_staging": {"no_staging": True},
        "no_last": {"no_last": True},
        "no_prior": {"no_prior": True},
        "no_fixed": {"no_fixed": True},
    }
    rows = {}
    for name, kw in variants.items():
        s = run_sim(table2_taskset("resnet18"),
                    mps_cfg(BEST["nc"], BEST["os_"], **kw))
        rows[name] = s
    base = rows["daris"]["jps"]
    for name in rows:
        rows[name]["jps_vs_daris"] = rows[name]["jps"] / base
    out = {"rows": rows, "config": BEST}
    cache_json("fig8", out)
    return out


def csv_lines(out) -> list:
    lines = []
    for name, s in out["rows"].items():
        lines.append(f"fig8/{name}_jps,{s['wall_s']*1e6:.0f},{s['jps']:.0f}")
        lines.append(f"fig8/{name}_resp_hp_p99,0,"
                     f"{s['resp_hp']['p99']:.2f}")
    return lines
