"""Fig 12 (beyond-paper): live elastic repartitioning under shifting load.

Three scenarios drive the online reconfiguration controller
(``scheduler.reconfigure`` — Eq. 9 re-derived mid-run, Algorithm 1
re-placement, zero-delay stage-boundary migration):

  * step    — offered load doubles mid-run (per-task step traces); the
              utilization-driven autoscaler grows the partition, compared
              against static under- and over-provisioned servers.
  * diurnal — a ramp of timed ``reconfigure_at`` events (grow for the
              peak, shrink after, oversubscription retuned each time).
  * chaos   — fault + scale-out + repartition in a single run: ctx0 dies,
              a context is added, then the whole geometry is reshaped.
              The acceptance bar: ZERO HP deadline misses end to end.

Every row carries the migration count and HP DMR next to throughput —
the two columns that show reshaping is actually free for HP work.
"""
from __future__ import annotations

from repro.api import ServerConfig
from repro.serving.profiles import device
from repro.serving.requests import table2_taskset

from .common import HORIZON_MS, cache_json, load_json

DNN = "resnet18"


def load_cached(fast: bool = False):
    cached = load_json("fig12")
    # cache is fidelity-keyed: a full-horizon cache must not satisfy a
    # --fast run (and vice versa) — same contract as fig10
    if cached and cached.get("_meta", {}).get("fast") == fast:
        return cached
    return None


def _base(specs, nc: int, os_: float, horizon: float) -> ServerConfig:
    return (ServerConfig.sim()
            .tasks(specs)
            .contexts(nc).streams(1).oversubscribe(os_)
            .device(device())
            .horizon_ms(horizon).seed(0))


def _row(name: str, server) -> dict:
    m = server.run()
    s = m.summary()
    live = sum(1 for c in server.scheduler.contexts if c.alive)
    return dict(name=name, live_contexts=live, **s)


def _step_traces(specs, horizon: float):
    """Per-task step traces: period T up to the midpoint, T/2 after —
    offered load doubles at horizon/2."""
    half = horizon / 2.0
    traces = {}
    for i, spec in enumerate(specs):
        t = (i / max(len(specs), 1)) * spec.period_ms   # staggered phases
        times = []
        while t <= horizon:
            times.append(t)
            t += spec.period_ms if t < half else spec.period_ms / 2.0
        traces[spec.name] = times
    return traces


def run_step(horizon: float) -> list:
    """Step load: autoscaler vs static small vs static big."""
    from repro.api import TraceArrival
    rows = []
    variants = {
        "step_static2": lambda c: c,
        "step_static6": lambda c: c,
        "step_autoscale": lambda c: c.autoscale(
            0.35, 0.8, check_every_ms=max(horizon / 24.0, 100.0),
            min_contexts=2, max_contexts=8,
            cooldown_ms=max(horizon / 12.0, 200.0)),
    }
    for name, decorate in variants.items():
        nc = 6 if name.endswith("6") else 2
        specs = table2_taskset(DNN, load_scale=0.5)
        cfg = decorate(_base(specs, nc, float(nc), horizon))
        for task_name, times in _step_traces(specs, horizon).items():
            cfg.arrival(task_name, TraceArrival(times))
        rows.append(_row(name, cfg.build()))
    return rows


def run_diurnal(horizon: float) -> list:
    """Diurnal ramp: timed repartitions track a known load curve."""
    specs = table2_taskset(DNN)
    plain = _base(specs, 4, 4.0, horizon)
    ramp = (_base(specs, 4, 4.0, horizon)
            .reconfigure_at(horizon * 0.25, n_contexts=6,
                            oversubscription=6.0)
            .reconfigure_at(horizon * 0.60, n_contexts=8,
                            oversubscription=8.0)
            .reconfigure_at(horizon * 0.85, n_contexts=3,
                            oversubscription=3.0))
    return [_row("diurnal_static4", plain.build()),
            _row("diurnal_ramp", ramp.build())]


def run_chaos(horizon: float) -> list:
    """Fail + scale-out + repartition in one run; HP must never miss."""
    specs = table2_taskset(DNN)
    chaos = (_base(specs, 6, 6.0, horizon)
             .fail_context_at(0, horizon * 0.3)
             .scale_out_at(horizon * 0.5)
             .reconfigure_at(horizon * 0.7, n_contexts=6,
                             oversubscription=5.0))
    return [_row("chaos_fault_scale_reconfig", chaos.build())]


def run(fast: bool = False) -> dict:
    cached = load_cached(fast)
    if cached:
        return cached
    horizon = 2000.0 if fast else HORIZON_MS
    out = {"_meta": {"fast": fast},
           "step": run_step(horizon),
           "diurnal": run_diurnal(horizon),
           "chaos": run_chaos(horizon)}
    cache_json("fig12", out)
    return out


def csv_lines(out) -> list:
    lines = []
    for key, rows in out.items():
        if key == "_meta":
            continue
        for r in rows:
            lines.append(f"fig12/{r['name']}_jps,0,{r['jps']:.0f}")
            lines.append(f"fig12/{r['name']}_dmr_hp,0,{r['dmr_hp']:.4f}")
            lines.append(f"fig12/{r['name']}_migrations,0,{r['migrations']}")
            lines.append(
                f"fig12/{r['name']}_reconfigures,0,{r['reconfigures']}")
    return lines
