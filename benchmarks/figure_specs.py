"""Named, analyzable figure scenarios: spec -> unbuilt ServerConfig.

The schedcheck CLI (``python -m repro.analysis.schedcheck --figure NAME``)
and the differential oracle resolve scenario names through this registry.
Each factory returns an **unbuilt** ``ServerConfig`` mirroring one cell of
the fig4_6 / fig12 / fig13 benchmark sweeps (smoke-sized horizons, seed
0), so the static analyzer and the simulator see the exact same
configuration object.

``*_light`` scenarios are intentionally under-loaded so their HP verdict
is GUARANTEED — they give the oracle a non-vacuous finite bound to
falsify and CI a shipped config that must stay GUARANTEED.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

from repro.api import ServerConfig, TraceArrival
from repro.serving.profiles import device, make_task
from repro.serving.requests import table2_taskset

SMOKE_HORIZON_MS = 2000.0


def _base(specs, nc: int, os_: float,
          horizon: float = SMOKE_HORIZON_MS) -> ServerConfig:
    return (ServerConfig.sim()
            .tasks(specs)
            .contexts(nc).streams(1).oversubscribe(os_)
            .device(device())
            .horizon_ms(horizon).seed(0))


def _light_specs(n_hp: int = 2, n_lp: int = 2, jps: float = 30.0):
    return ([make_task("resnet18", priority=0, jps=jps, tag=f"-hp{i}")
             for i in range(n_hp)]
            + [make_task("resnet18", priority=1, jps=jps, tag=f"-lp{i}")
               for i in range(n_lp)])


# ------------------------------------------------------------------ fig4_6
def fig4_6_light() -> ServerConfig:
    """Under-loaded MPS 2x1 os=2 cell: HP GUARANTEED, finite bound."""
    return _base(_light_specs(), 2, 2.0)


def fig4_6_resnet18_mps6() -> ServerConfig:
    """The paper's headline RN18 MPS 6x1 os=6 cell at full Table II load
    (150% offered): LP is overloaded by design -> CONDITIONAL."""
    return _base(table2_taskset("resnet18"), 6, 6.0)


def fig4_6_unet_mps6() -> ServerConfig:
    return _base(table2_taskset("unet"), 6, 2.0)


def fig4_6_inceptionv3_mps8() -> ServerConfig:
    return _base(table2_taskset("inceptionv3"), 8, 8.0)


# ------------------------------------------------------------------ fig12
def fig12_diurnal() -> ServerConfig:
    """Timed reconfigure ramp (fig12 run_diurnal shape, smoke horizon)."""
    h = SMOKE_HORIZON_MS
    return (_base(table2_taskset("resnet18"), 4, 4.0, h)
            .reconfigure_at(h * 0.25, n_contexts=6, oversubscription=6.0)
            .reconfigure_at(h * 0.60, n_contexts=8, oversubscription=8.0)
            .reconfigure_at(h * 0.85, n_contexts=3, oversubscription=3.0))


def fig12_chaos() -> ServerConfig:
    """Fault + scale-out + repartition in one run (fig12 run_chaos)."""
    h = SMOKE_HORIZON_MS
    return (_base(table2_taskset("resnet18"), 6, 6.0, h)
            .fail_context_at(0, h * 0.3)
            .scale_out_at(h * 0.5)
            .reconfigure_at(h * 0.7, n_contexts=6, oversubscription=5.0))


def fig12_step() -> ServerConfig:
    """Offered load doubles mid-run via per-task step traces (the
    analyzer treats each trace as sporadic at its min release gap)."""
    h = SMOKE_HORIZON_MS
    specs = _light_specs()
    cfg = _base(specs, 3, 3.0, h)
    half = h / 2.0
    for i, spec in enumerate(specs):
        t = (i / len(specs)) * spec.period_ms
        times: List[float] = []
        while t <= h:
            times.append(t)
            t += spec.period_ms if t < half else spec.period_ms / 2.0
        cfg.arrival(spec.name, TraceArrival(times))
    return cfg


# ------------------------------------------------------------------ fig13
def _fleet_taskset(n_gpus: int, load_scale: float):
    import dataclasses
    out = []
    for g in range(n_gpus):
        for spec in table2_taskset("resnet18", load_scale=load_scale):
            out.append(dataclasses.replace(spec, name=f"g{g}-{spec.name}"))
    return out


def _cluster(n_gpus: int, specs, **cluster_kw) -> ServerConfig:
    return (ServerConfig.cluster(n_gpus, **cluster_kw)
            .tasks(specs)
            .contexts(4).streams(1).oversubscribe(4.0)
            .device(device())
            .horizon_ms(SMOKE_HORIZON_MS).seed(0))


def fig13_light() -> ServerConfig:
    """Under-loaded 2-GPU fleet: a light HP/LP set per device keeps the
    cluster bound finite (non-vacuous oracle coverage)."""
    import dataclasses
    specs = []
    for g in range(2):
        for spec in _light_specs(n_hp=1, n_lp=1):
            specs.append(dataclasses.replace(spec, name=f"g{g}-{spec.name}"))
    return (ServerConfig.cluster(2)
            .tasks(specs)
            .contexts(2).streams(1).oversubscribe(2.0)
            .device(device())
            .horizon_ms(SMOKE_HORIZON_MS).seed(0))


def fig13_homo_2gpu() -> ServerConfig:
    return _cluster(2, _fleet_taskset(2, 0.5))


def fig13_fail_1of4() -> ServerConfig:
    return (_cluster(4, _fleet_taskset(4, 0.5))
            .fail_device_at(1, SMOKE_HORIZON_MS * 0.3))


def fig13_hetero() -> ServerConfig:
    return _cluster(
        4, _fleet_taskset(4, 0.5),
        device_models=["a100", "v100", "rtx2080ti", "l4"])


_REGISTRY: Dict[str, Callable[[], ServerConfig]] = {
    "fig4_6_light": fig4_6_light,
    "fig4_6_resnet18_mps6": fig4_6_resnet18_mps6,
    "fig4_6_unet_mps6": fig4_6_unet_mps6,
    "fig4_6_inceptionv3_mps8": fig4_6_inceptionv3_mps8,
    "fig12_diurnal": fig12_diurnal,
    "fig12_chaos": fig12_chaos,
    "fig12_step": fig12_step,
    "fig13_light": fig13_light,
    "fig13_homo_2gpu": fig13_homo_2gpu,
    "fig13_fail_1of4": fig13_fail_1of4,
    "fig13_hetero": fig13_hetero,
}

ORACLE_SMOKE = ("fig4_6_light", "fig4_6_resnet18_mps6", "fig12_diurnal",
                "fig12_chaos", "fig12_step", "fig13_light",
                "fig13_fail_1of4")


def names() -> List[str]:
    return sorted(_REGISTRY)


def scenario(name: str) -> ServerConfig:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(
            f"unknown figure scenario {name!r}; known: {', '.join(names())}"
        ) from None


def oracle_suite(names_: Tuple[str, ...] = ORACLE_SMOKE
                 ) -> List[Tuple[str, ServerConfig]]:
    """(label, unbuilt config) pairs for the differential oracle."""
    return [(n, scenario(n)) for n in names_]
