"""Fig 13 (beyond-paper): multi-GPU DARIS — throughput scaling, device
heterogeneity, and whole-GPU failure recovery.

Three scenario families over the cluster layer (repro.cluster):

  * scaling — 1 -> 8 homogeneous GPUs, workload scaled with the fleet
              (each GPU carries one Table II ResNet18 set at half load).
              The acceptance bar: >= 3.5x aggregate jobs/sec at 4 GPUs
              vs 1 GPU with ZERO HP deadline misses at both points.
  * hetero  — the same aggregate workload on a mixed fleet (A100 + V100
              + the calibration 2080 Ti + an L4-class part): HP-first
              placement by least-loaded device must keep HP misses at
              zero while per-device completions track speed factors.
  * failure — 4 GPUs, one dies mid-run: every task homed there re-places
              HP-first onto survivors via cross-GPU migration
              (migrations counted) with zero HP misses end to end.

Every row carries HP DMR, migration and inter-GPU transfer counts, and
per-device p99s where the scenario cares — the columns that show the
cluster layer scales without costing HP its deadlines.
"""
from __future__ import annotations

import dataclasses

from repro.api import ServerConfig
from repro.serving.profiles import device
from repro.serving.requests import table2_taskset

from .common import HORIZON_MS, cache_json, load_json

DNN = "resnet18"
LOAD_SCALE = 0.5          # per-GPU offered load (HP must never miss)
GPU_POINTS = (1, 2, 4, 8)
GPU_POINTS_FAST = (1, 2, 4)


def load_cached(fast: bool = False):
    cached = load_json("fig13")
    if cached and cached.get("_meta", {}).get("fast") == fast:
        return cached
    return None


def fleet_taskset(n_gpus: int):
    """n_gpus replicas of the per-GPU task set, uniquely named so
    per-name arrival overrides and handles stay unambiguous."""
    out = []
    for g in range(n_gpus):
        for spec in table2_taskset(DNN, load_scale=LOAD_SCALE):
            out.append(dataclasses.replace(spec, name=f"g{g}-{spec.name}"))
    return out


def _cluster(n_gpus: int, specs, horizon: float, **cluster_kw):
    return (ServerConfig.cluster(n_gpus, **cluster_kw)
            .tasks(specs)
            .contexts(4).streams(1).oversubscribe(4.0)
            .device(device())
            .horizon_ms(horizon).seed(0))


def _row(name: str, server) -> dict:
    m = server.run()
    s = m.summary()
    sched = server.scheduler
    return dict(name=name,
                n_gpus=len(sched.live_devices()),
                transfers=m.transfers,
                **{k: v for k, v in s.items()
                   if k not in ("per_device", "transfers")},
                per_device=s.get("per_device", {}))


def run_scaling(horizon: float, points) -> list:
    rows = []
    for n in points:
        srv = _cluster(n, fleet_taskset(n), horizon).build()
        rows.append(_row(f"homo_{n}gpu", srv))
    return rows


def run_hetero(horizon: float) -> list:
    """Same 4-GPU aggregate load, mixed fleet: speed factors 2.1 / 1.3 /
    1.0 / 0.8 — placement skews toward the fast parts, HP stays clean."""
    specs = fleet_taskset(4)
    srv = _cluster(4, specs, horizon,
                   device_models=["a100", "v100", "rtx2080ti", "l4"]).build()
    return [_row("hetero_4gpu", srv)]


def run_failure(horizon: float) -> list:
    """One GPU dies at 30% of the horizon; survivors inherit its tasks
    via cross-GPU migration and HP never misses."""
    specs = fleet_taskset(4)
    srv = (_cluster(4, specs, horizon)
           .fail_device_at(1, horizon * 0.3)
           .build())
    row = _row("fail_1of4", srv)
    row["dead_devices"] = [d for d, s in
                           srv.scheduler.device_summary().items()
                           if not s["alive"]]
    return [row]


def run(fast: bool = False) -> dict:
    cached = load_cached(fast)
    if cached:
        return cached
    horizon = 1500.0 if fast else HORIZON_MS
    points = GPU_POINTS_FAST if fast else GPU_POINTS
    scaling = run_scaling(horizon, points)
    jps = {r["n_gpus"]: r["jps"] for r in scaling}
    out = {"_meta": {"fast": fast},
           "scaling": scaling,
           "scaling_4x": jps.get(4, 0.0) / max(jps.get(1, 0.0), 1e-9),
           "hetero": run_hetero(horizon),
           "failure": run_failure(horizon)}
    cache_json("fig13", out)
    return out


def csv_lines(out) -> list:
    lines = []
    for r in out["scaling"]:
        lines.append(f"fig13/{r['name']}_jps,0,{r['jps']:.0f}")
        lines.append(f"fig13/{r['name']}_dmr_hp,0,{r['dmr_hp']:.4f}")
        lines.append(f"fig13/{r['name']}_p99_hp,0,{r['resp_hp_p99']:.3f}")
    lines.append(f"fig13/scaling_4x,0,{out['scaling_4x']:.2f}")
    for key in ("hetero", "failure"):
        for r in out[key]:
            lines.append(f"fig13/{r['name']}_jps,0,{r['jps']:.0f}")
            lines.append(f"fig13/{r['name']}_dmr_hp,0,{r['dmr_hp']:.4f}")
            lines.append(f"fig13/{r['name']}_migrations,0,{r['migrations']}")
            lines.append(f"fig13/{r['name']}_transfers,0,{r['transfers']}")
    return lines
