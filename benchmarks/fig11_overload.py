"""Paper Fig 11 / §VI-I: HP:LP ratios, full load vs overload, Overload+HPA.

Paper behaviour to reproduce: throughput stable across ratios; full load ->
no misses (~5% throughput dip with LP present); overload without HP
admission -> HP DMR explodes once HP load > 100%; Overload+HPA -> zero HP
misses at the cost of HP rejections + higher LP DMR.
"""
from __future__ import annotations

from repro.serving.profiles import TABLE1, t_alone_ms
from repro.serving.requests import ratio_taskset

from .common import cache_json, load_json, mps_cfg, run_sim


def load_cached(fast: bool = False):
    return load_json("fig11")


def run() -> dict:
    cached = load_cached()
    if cached:
        return cached
    out = {}
    for dnn in ("resnet18", "unet"):
        upper = TABLE1[dnn][1]
        rows = []
        for hp_frac in (0.33, 0.5, 0.66):
            for load, tag in ((1.0, "full"), (1.5, "overload")):
                total_tasks = 30 if dnn == "resnet18" else 12
                jps = upper * load / total_tasks
                for hpa in (False, True):
                    if tag == "full" and hpa:
                        continue
                    specs = ratio_taskset(dnn, hp_frac, total_tasks, jps)
                    s = run_sim(specs, mps_cfg(6, 6.0, overload_hpa=hpa))
                    rows.append(dict(hp_frac=hp_frac, load=tag, hpa=hpa, **s))
        out[dnn] = rows
    cache_json("fig11", out)
    return out


def csv_lines(out) -> list:
    lines = []
    for dnn, rows in out.items():
        over = [r for r in rows if r["load"] == "overload" and not r["hpa"]
                and r["hp_frac"] > 0.6]
        hpa = [r for r in rows if r["load"] == "overload" and r["hpa"]
               and r["hp_frac"] > 0.6]
        if over:
            lines.append(f"fig11/{dnn}_overload_dmr_hp,0,{over[0]['dmr_hp']:.4f}")
        if hpa:
            lines.append(f"fig11/{dnn}_overload_hpa_dmr_hp,0,{hpa[0]['dmr_hp']:.4f}")
    return lines
