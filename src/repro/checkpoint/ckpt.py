"""Lightweight atomic checkpointer (orbax is unavailable offline).

Pytrees save as one .npz (flattened '/'-joined paths) + a json manifest;
writes go to a tmp dir and rename atomically, so a crash mid-save never
corrupts the latest checkpoint. Scheduler state (MRET windows, context
assignments — what lets a restarted server skip the AFET cold-start,
DESIGN.md §7) serializes via msgpack.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
from typing import Optional

import msgpack
import numpy as np

import jax


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(tree, path: str, step: Optional[int] = None) -> str:
    """Atomic save: the previous checkpoint survives every crash window.

    The write sequence is stage -> sidestep -> swap -> reap:

      1. materialize the new checkpoint in a fresh staging dir,
      2. rename the existing ``.ckpt`` (if any) out of the way to ``.old``,
      3. rename staging to ``.ckpt``,
      4. delete ``.old``.

    ``os.rename`` is the only operation that touches the live name, so at
    every instant either ``.ckpt`` or ``.old`` holds a complete
    checkpoint — the historic code ``rmtree``'d the final dir *before*
    renaming the staging dir in, so a crash between the two lost the
    latest checkpoint entirely. ``load_pytree`` falls back to ``.old``
    when only the sidestep survived (crash between steps 2 and 3).
    """
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {k: {"shape": list(v.shape),
                                             "dtype": str(v.dtype)}
                                         for k, v in flat.items()}}
    final = p.with_suffix(".ckpt")
    old = p.parent / (final.name + ".old")
    # reap staging dirs orphaned by earlier crashed saves (SIGKILL skips
    # the except-cleanup below, and every save stages under a fresh name)
    for stale in p.parent.glob(p.name + ".tmp*"):
        shutil.rmtree(stale, ignore_errors=True)
    # staging lives outside any context manager: TemporaryDirectory's
    # cleanup used to race on the directory we had just renamed away
    staging = pathlib.Path(tempfile.mkdtemp(dir=p.parent,
                                            prefix=p.name + ".tmp"))
    try:
        np.savez(staging / "data.npz", **{k: v for k, v in flat.items()})
        (staging / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            # only now is .old redundant: final is a complete checkpoint.
            # When final is MISSING (a crash landed between sidestep and
            # swap last time), .old is the sole survivor — leave it alone
            # until the swap below completes.
            if old.exists():
                shutil.rmtree(old)
            os.rename(final, old)
        os.rename(staging, final)
    except BaseException:
        shutil.rmtree(staging, ignore_errors=True)
        raise
    if old.exists():
        shutil.rmtree(old)
    return str(final)


def load_pytree(template, path: str):
    """Restore into the structure of ``template`` (shapes must match).
    Falls back to the ``.old`` sidestep if a crash interrupted
    ``save_pytree`` between sidestep and swap."""
    final = pathlib.Path(path).with_suffix(".ckpt")
    if not final.exists():
        old = final.parent / (final.name + ".old")
        if old.exists():
            final = old
    data = np.load(final / "data.npz")
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_t:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        arr = data[key]
        assert arr.shape == tuple(np.shape(leaf)), (key, arr.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


# ------------------------------------------------------- scheduler state
def save_scheduler_state(sched, path: str, *, chaos=None) -> str:
    """Serialize everything a restarted scheduler needs to reproduce this
    one's placement exactly: per-task MRET windows and context
    assignments, the migration counter, the runtime shape, and the FULL
    partition geometry — including retired contexts, so task ``ctx``
    indices stay meaningful after fail_context / reconfigure events."""
    state = {
        "tasks": [
            {
                "name": t.name, "ctx": t.ctx, "fixed": t.fixed_ctx,
                "mret_windows": [list(s.window) for s in t.mret.stages],
                "afets": [s.afet_ms for s in t.mret.stages],
            }
            for t in sched.tasks
        ],
        "migrations": sched.migrations,
        "contexts": [
            {"index": c.index, "alive": c.alive, "n_streams": c.n_streams,
             "units": sorted(c.units)}
            for c in sched.contexts
        ],
        "shape": {"n_contexts": sched.cfg.n_contexts,
                  "n_streams": sched.cfg.n_streams,
                  "oversubscription": sched.cfg.oversubscription},
    }
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_suffix(".tmp")
    blob = msgpack.packb(state)
    attempts = 1 + (chaos.plan.io_max_retries if chaos is not None else 0)
    for i in range(attempts):
        try:
            if chaos is not None and chaos.io_fails():
                raise OSError("chaos: injected checkpoint write failure")
            tmp.write_bytes(blob)
            os.replace(tmp, p)
            break
        except OSError:
            if i + 1 >= attempts:
                raise
    return str(p)


def load_scheduler_state(sched, path: str) -> None:
    """Inverse of ``save_scheduler_state``: restores MRET history, task
    placement, the migration counter, and (when present) the saved
    partition geometry — contexts beyond the constructor-built set are
    created, geometries overwritten, dead ones retired — so a scheduler
    restored after fail_context/reconfigure events places work
    identically to the one that was saved. Raises ``ValueError`` when a
    task's saved MRET windows don't match its current stage count (a
    silently truncating ``zip`` here used to corrupt the estimators)."""
    state = msgpack.unpackb(pathlib.Path(path).read_bytes())
    by_name = {t["name"]: t for t in state["tasks"]}
    for t in sched.tasks:
        if t.name not in by_name:
            continue
        rec = by_name[t.name]
        if len(rec["mret_windows"]) != len(t.mret.stages):
            raise ValueError(
                f"checkpoint shape mismatch for task {t.name!r}: saved "
                f"{len(rec['mret_windows'])} stage windows, scheduler has "
                f"{len(t.mret.stages)} stages (was the task set or "
                f"no_staging changed since the save?)")
        t.ctx = rec["ctx"]
        t.fixed_ctx = rec["fixed"]
        for s, win in zip(t.mret.stages, rec["mret_windows"]):
            s.window.clear()
            s.window.extend(win)
        t.mret.invalidate()   # windows were mutated behind the memo
    sched.migrations = state.get("migrations", sched.migrations)
    shape = state.get("shape")
    if shape:
        sched.cfg.n_contexts = shape["n_contexts"]
        sched.cfg.n_streams = shape["n_streams"]
        sched.cfg.oversubscription = shape["oversubscription"]
    for rec in state.get("contexts", []):
        idx = rec["index"]
        while idx >= len(sched.contexts):
            # geometry is overwritten from the record below
            from ..core.partition import Context
            ctx = Context(index=len(sched.contexts), units=set(),
                          n_streams=rec["n_streams"])
            sched._install_context(ctx)
        ctx = sched.contexts[idx]
        if ctx.n_streams != rec["n_streams"]:
            # a constructor-built context's lane table cannot be resized
            # here; silently adopting the saved stream count would skew
            # Eq. 11 (n_streams) against the lanes that actually exist
            raise ValueError(
                f"checkpoint shape mismatch for context {idx}: saved "
                f"n_streams={rec['n_streams']}, scheduler built with "
                f"{ctx.n_streams} (restore into a server configured like "
                f"the saved one)")
        ctx.units = set(rec["units"])
        if ctx.alive and not rec["alive"]:
            sched.lanes.retire_ctx(idx)
        ctx.alive = rec["alive"]
    if state.get("contexts"):
        sched._invalidate_live()
