"""Lightweight atomic checkpointer (orbax is unavailable offline).

Pytrees save as one .npz (flattened '/'-joined paths) + a json manifest;
writes go to a tmp dir and rename atomically, so a crash mid-save never
corrupts the latest checkpoint. Scheduler state (MRET windows, context
assignments — what lets a restarted server skip the AFET cold-start,
DESIGN.md §7) serializes via msgpack.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
from typing import Optional

import msgpack
import numpy as np

import jax


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(tree, path: str, step: Optional[int] = None) -> str:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {k: {"shape": list(v.shape),
                                             "dtype": str(v.dtype)}
                                         for k, v in flat.items()}}
    with tempfile.TemporaryDirectory(dir=p.parent) as tmp:
        tmp_npz = pathlib.Path(tmp) / "data.npz"
        np.savez(tmp_npz, **{k: v for k, v in flat.items()})
        (pathlib.Path(tmp) / "manifest.json").write_text(
            json.dumps(manifest, indent=1))
        final = p.with_suffix(".ckpt")
        staging = p.parent / (p.name + ".tmp")
        if staging.exists():
            import shutil
            shutil.rmtree(staging)
        os.rename(tmp, staging)
    if final.exists():
        import shutil
        shutil.rmtree(final)
    os.rename(staging, final)
    return str(final)


def load_pytree(template, path: str):
    """Restore into the structure of ``template`` (shapes must match)."""
    final = pathlib.Path(path).with_suffix(".ckpt")
    data = np.load(final / "data.npz")
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_t:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        arr = data[key]
        assert arr.shape == tuple(np.shape(leaf)), (key, arr.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


# ------------------------------------------------------- scheduler state
def save_scheduler_state(sched, path: str) -> str:
    state = {
        "tasks": [
            {
                "name": t.name, "ctx": t.ctx, "fixed": t.fixed_ctx,
                "mret_windows": [list(s.window) for s in t.mret.stages],
                "afets": [s.afet_ms for s in t.mret.stages],
            }
            for t in sched.tasks
        ],
        "migrations": sched.migrations,
    }
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_suffix(".tmp")
    tmp.write_bytes(msgpack.packb(state))
    os.replace(tmp, p)
    return str(p)


def load_scheduler_state(sched, path: str) -> None:
    state = msgpack.unpackb(pathlib.Path(path).read_bytes())
    by_name = {t["name"]: t for t in state["tasks"]}
    for t in sched.tasks:
        if t.name not in by_name:
            continue
        rec = by_name[t.name]
        t.ctx = rec["ctx"]
        t.fixed_ctx = rec["fixed"]
        for s, win in zip(t.mret.stages, rec["mret_windows"]):
            s.window.clear()
            s.window.extend(win)
        t.mret.invalidate()   # windows were mutated behind the memo
