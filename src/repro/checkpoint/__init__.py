from .ckpt import (load_pytree, load_scheduler_state, save_pytree,
                   save_scheduler_state)  # noqa: F401
