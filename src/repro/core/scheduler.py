"""DARIS scheduler: offline phase (AFET + Algorithm 1) + online phase
(admission Eq. 11-12, migration, 8-level stage dispatch) — paper §IV.

The scheduler is engine-agnostic: the shared ``EngineCore`` loop
(runtime/engine_core.py) drives it over any ``ExecutionBackend`` — the
fluid simulator and the real JAX executor alike — through the same
callbacks:

    on_release(task, now)        periodic job release -> admission test
    on_stage_finish(inst, now)   MRET update, vdl bookkeeping, next stage
    next_for_lane(ctx, now)      dispatch decision for a free lane

Policies (paper §V): STR = 1 context x N_s streams (single global queue);
MPS = N_c x 1; MPS+STR = N_c x N_s. Oversubscription per Eq. 9.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from ..runtime.contention import ContentionModel, DeviceModel, batch_cost
from .batching import BatchCoalescer, BatchPolicy
from .mret import TaskMret
from .partition import (Context, ContextTable, CtxKey, make_contexts,
                        reconfigure as derive_contexts)
from .stage_queue import QueueConfig, StageQueue
from .task import HP, LP, Job, StageInstance, Task, TaskSpec


@dataclasses.dataclass
class SchedulerConfig:
    n_contexts: int = 4
    n_streams: int = 1
    oversubscription: float = 2.0
    mret_window: int = 5
    overload_hpa: bool = False        # admission-test HP too (paper §VI-I)
    no_staging: bool = False          # ablations (paper §VI-F)
    no_last: bool = False
    no_prior: bool = False
    no_fixed: bool = False
    straggler_kappa: float = 3.0      # beyond-paper: straggler threshold
    batch_policy: Optional[BatchPolicy] = None   # dynamic batching (off =
                                                 # pre-batching behavior)

    @property
    def queue_cfg(self) -> QueueConfig:
        return QueueConfig(no_last=self.no_last, no_prior=self.no_prior,
                           no_fixed=self.no_fixed)


@dataclasses.dataclass
class Rejection:
    task: str
    t_ms: float
    priority: int


def hp_first(tasks, now: float) -> List[Task]:
    """Algorithm 1's placement ordering: HP before LP, each class by
    descending utilization. THE ordering for every (re-)placement pass —
    offline population, fault recovery, online reconfigure, and the
    cluster layer's global passes all call this one function; a tie-break
    change here changes them all together."""
    return (sorted([t for t in tasks if t.priority == HP],
                   key=lambda t: -t.utilization(now))
            + sorted([t for t in tasks if t.priority == LP],
                     key=lambda t: -t.utilization(now)))


class LaneMap(dict):
    """Lane occupancy table ``(ctx, slot) -> StageInstance | None`` with
    free/busy indexes maintained on assignment.

    ``free_lanes``/``predicted_finish`` used to scan every lane on every
    engine iteration; the indexes make both reads O(result). Plain
    ``lanes[lane] = inst`` assignment (engine, backends, tests) keeps the
    indexes coherent because ``__setitem__`` is the single write path.
    Iteration order everywhere is sorted lane order — identical to the
    historic insertion order (contexts ascending, slots ascending), which
    the bit-exactness guarantee relies on."""

    def __init__(self):
        super().__init__()
        self._free: set = set()
        self._busy_by_ctx: Dict[int, Dict[tuple, StageInstance]] = {}
        self._dead: set = set()

    def __setitem__(self, lane: tuple, inst: Optional[StageInstance]) -> None:
        dict.__setitem__(self, lane, inst)
        ctx = lane[0]
        busy = self._busy_by_ctx.setdefault(ctx, {})
        if inst is None:
            busy.pop(lane, None)
            if ctx not in self._dead:
                self._free.add(lane)
        else:
            busy.pop(lane, None)
            busy[lane] = inst
            self._free.discard(lane)

    def retire_ctx(self, ctx: int) -> None:
        """Mark a context dead: its lanes never report free again."""
        self._dead.add(ctx)
        self._free = {ln for ln in self._free if ln[0] != ctx}

    def free_lanes(self) -> List[tuple]:
        return sorted(self._free)

    def free_set(self) -> set:
        """Live free lanes, unordered — the dispatch loop filters by
        hot context first, then sorts the (much smaller) remainder."""
        return self._free

    def busy_in_ctx(self, ctx: int) -> List[tuple]:
        """Sorted (lane, inst) pairs of occupied lanes in one context."""
        return sorted(self._busy_by_ctx.get(ctx, {}).items())


class DarisScheduler:
    """One device's DARIS scheduler.

    ``ctx_ns`` makes the scheduler *device-relative*: when set (by the
    cluster layer, repro/cluster), every context index it mints becomes a
    ``(ctx_ns, k)`` tuple instead of a bare int, so N workers can share
    one lane/queue/job namespace without collisions. Single-device
    construction (``ctx_ns=None``) keeps the historic int indices and is
    bit-identical to the pre-cluster scheduler."""

    def __init__(self, specs: List[TaskSpec], cfg: SchedulerConfig,
                 device: Optional[DeviceModel] = None, *,
                 ctx_ns: Optional[int] = None):
        self.cfg = cfg
        self.device = device or DeviceModel()
        self.speed = self.device.speed
        self.contention = ContentionModel(self.device)
        self.ctx_ns = ctx_ns
        if cfg.no_staging:
            specs = [self._merge_stages(s) for s in specs]
        self.tasks: List[Task] = [Task(spec=s, index=i)
                                  for i, s in enumerate(specs)]
        self.contexts: ContextTable = ContextTable()
        for c in make_contexts(cfg.n_contexts, cfg.n_streams,
                               cfg.oversubscription,
                               int(self.device.n_units)):
            c.index = self._key(c.index)
            self.contexts.append(c)
        # live-context cache: reconfigure-heavy runs accumulate retired
        # contexts (indices must stay addressable for draining work), so
        # hot paths that only want live ones must not rescan the full
        # history each release
        self._live_cache: Optional[List[Context]] = None
        self.queues: Dict[CtxKey, StageQueue] = {
            c.index: StageQueue(cfg.queue_cfg) for c in self.contexts}
        # dispatch index: context keys whose queue currently holds work
        # (maintained by the queues themselves — see StageQueue.register_hot)
        self.hot_queues: set = set()
        for k, q in self.queues.items():
            q.register_hot(k, self.hot_queues)
        # lane occupancy: (ctx, slot) -> StageInstance | None (indexed)
        self.lanes = LaneMap()
        for c in self.contexts:
            for s in range(c.n_streams):
                self.lanes[(c.index, s)] = None
        # per-context insertion-ordered job sets (Job hashes by identity):
        # membership tests and removals are O(1) where list.remove used to
        # walk — and value-compare — every active job
        self.active_jobs: Dict[CtxKey, Dict[Job, None]] = {
            c.index: {} for c in self.contexts}
        self.rejections: List[Rejection] = []
        self.rejected_counts: Dict[int, int] = {HP: 0, LP: 0}
        self.migrations = 0
        self.coalesced = 0            # releases absorbed into batched jobs
        self._coalescer = (BatchCoalescer(cfg.batch_policy)
                           if cfg.batch_policy is not None else None)
        # next time the drive loop is guaranteed to call dispatch again
        # (EngineCore refreshes it every iteration); inf = no pending
        # events, so batch heads must never be held back
        self.next_wake_ms: float = math.inf
        # lazy work-accounting hook (runtime/epoch.py): the epoch engine
        # integrates work_done in slot arrays and only flushes a
        # context's StageInstances right before predicted_finish reads
        # them. None (heap engine, realtime) = work_done is always live.
        self.work_sync = None
        # degradation-controller batching knob (repro.chaos): multiplies
        # the batch policy's max_wait_ms while the server is degraded, so
        # heads grow larger under brownout. 1.0 = no effect (chaos off).
        self.batch_widen: float = 1.0
        self._offline_phase()

    def _key(self, i: int) -> CtxKey:
        """Context index for the i-th context this scheduler ever mints:
        a bare int on a single device, ``(device, i)`` under a cluster."""
        return i if self.ctx_ns is None else (self.ctx_ns, i)

    # ------------------------------------------------------------- offline
    @staticmethod
    def _merge_stages(spec: TaskSpec) -> TaskSpec:
        from .task import StageProfile
        st = spec.stages
        merged = StageProfile(
            name=f"{spec.name}/whole",
            t_alone_ms=sum(s.t_alone_ms for s in st),
            n_sat=max(s.n_sat for s in st),
            mem_frac=sum(s.mem_frac * s.t_alone_ms for s in st)
            / max(sum(s.t_alone_ms for s in st), 1e-9),
            overhead_ms=st[0].overhead_ms,   # one sync instead of n_i
        )
        return dataclasses.replace(spec, stages=[merged])

    def _seed_mret(self, task: Task) -> None:
        """AFET seeding (§IV-A1): pessimistic full-load execution times
        (reference-speed units; see ``DeviceModel.speed``)."""
        n_p = self.cfg.n_contexts * self.cfg.n_streams
        cap0 = next(iter(self.contexts)).cap
        afets = [self.contention.full_load_time(
            p, cap0, self.cfg.n_streams, n_p) for p in task.spec.stages]
        task.mret = TaskMret(afets, ws=self.cfg.mret_window)

    def _offline_phase(self) -> None:
        """AFET seeding (§IV-A1) + Algorithm 1 context population."""
        for t in self.tasks:
            self._seed_mret(t)
        # Algorithm 1: HP first, then LP, each to the min-utilization context
        util = {c.index: 0.0 for c in self.contexts}
        for t in hp_first(self.tasks, 0.0):
            k = min(util, key=util.get)
            t.ctx = k
            t.fixed_ctx = t.priority == HP
            util[k] += t.utilization(0.0)

    def live_contexts(self) -> List[Context]:
        """Live contexts in ascending index order (cached; identical to
        filtering ``self.contexts`` on ``alive``)."""
        if self._live_cache is None:
            self._live_cache = [c for c in self.contexts if c.alive]
        return self._live_cache

    def _invalidate_live(self) -> None:
        self._live_cache = None

    def geometry_snapshot(self) -> Dict:
        """Static view of the live Eq. 9 geometry for offline analysis
        (repro.analysis.schedcheck): per-context capacity/streams plus the
        oversubscription interference structure (which contexts share SMs,
        worst per-unit co-residency). Pure introspection — no state change."""
        from .partition import interference_sets, max_coresidency
        live = self.live_contexts()
        inter = interference_sets(live)
        cores = max_coresidency(live)
        return {
            "kind": "device",
            "n_units": self.device.n_units,
            "speed": self.speed,
            "oversubscription": self.cfg.oversubscription,
            "total_streams": sum(c.n_streams for c in live),
            "total_cap": sum(c.cap for c in live),
            "max_coresidency": cores,
            "contexts": [
                {"ctx": str(c.index), "cap": c.cap, "n_streams": c.n_streams,
                 "shares_units_with": [str(k) for k in inter[c.index]]}
                for c in live],
            "summary": (f"{len(live)} ctx x {self.cfg.n_streams} streams, "
                        f"os={self.cfg.oversubscription:g}, "
                        f"{int(self.device.n_units)} units, "
                        f"co-residency {cores}"),
        }

    def make_task(self, spec: TaskSpec, index: int) -> Task:
        """Create (but do not place) a task: same staging/AFET treatment
        as constructor-registered tasks. The cluster layer uses this to
        seed a task against a *chosen* device before adopting it."""
        if self.cfg.no_staging:
            spec = self._merge_stages(spec)
        task = Task(spec=spec, index=index)
        self._seed_mret(task)
        return task

    def place_task(self, task: Task, now: float) -> Task:
        """Algorithm-1-style placement on the least-utilized live context
        of THIS device + registration in the task list."""
        alive = [c.index for c in self.live_contexts()]
        util = {k: self.util_hp_total(k, now) + self.util_lp_active(k, now)
                for k in alive}
        task.ctx = min(util, key=util.get)
        task.fixed_ctx = task.priority == HP
        self.tasks.append(task)
        return task

    def add_task(self, spec: TaskSpec, now: float = 0.0) -> Task:
        """Late task registration (the ``DarisServer.submit`` path)."""
        return self.place_task(self.make_task(spec, len(self.tasks)), now)

    # ----------------------------------------------------- utilization (Eq. 4-7)
    @staticmethod
    def spec_batch_cost(spec: TaskSpec, n_inputs: int) -> float:
        """Device-time multiplier of a b-input job of ``spec`` vs a single
        release: per-stage b / g(b), weighted by stage work (stages may
        carry different batch gains). Exactly 1.0 for b = 1, so the
        paper's utilization math is unchanged when batching is off."""
        if n_inputs <= 1:
            return 1.0
        tot = sum(s.t_alone_ms for s in spec.stages)
        if tot <= 0:
            return batch_cost(spec.stages[0], n_inputs)
        return sum(s.t_alone_ms * batch_cost(s, n_inputs)
                   for s in spec.stages) / tot

    @classmethod
    def job_cost(cls, job: Job) -> float:
        return cls.spec_batch_cost(job.task.spec, job.n_inputs)

    def util_hp_total(self, k: CtxKey, now: float) -> float:
        """Device-local HP utilization: reference-units sum, scaled by the
        device's speed factor (a 2x device hosts 2x the reference load in
        the same headroom). ``/1.0`` on the calibration device is exact,
        so single-GPU admission keeps its historic bits."""
        u = sum(t.utilization(now) for t in self.tasks
                if t.ctx == k and t.priority == HP)
        return u if self.speed == 1.0 else u / self.speed

    def util_lp_active(self, k: CtxKey, now: float) -> float:
        u = sum(j.task.utilization(now) * self.job_cost(j)
                for j in self.active_jobs[k] if j.task.priority == LP)
        return u if self.speed == 1.0 else u / self.speed

    def remaining_util(self, k: CtxKey, now: float) -> float:
        """Eq. 11: U_r = N_s - U_h,t."""
        ctx = self.contexts[k]
        return ctx.n_streams - self.util_hp_total(k, now)

    def admits(self, k: CtxKey, task: Task, now: float) -> bool:
        """Eq. 12: U_l,a + u_j < U_r (u_j in device-local units)."""
        if not self.contexts[k].alive:
            return False
        u_j = task.utilization(now)
        if self.speed != 1.0:
            u_j /= self.speed
        return (self.util_lp_active(k, now) + u_j
                < self.remaining_util(k, now))

    def predicted_finish(self, k: CtxKey, now: float) -> float:
        """Backlog-based earliest-finish estimate for migration targets.
        Batched stages cost b/g(b) x their normalized MRET, here and in
        ``StageQueue.backlog_ms``; faster devices drain the same backlog
        proportionally sooner."""
        if self.work_sync is not None:
            self.work_sync(k)
        ctx = self.contexts[k]
        rem = 0.0
        for _, inst in self.lanes.busy_in_ctx(k):
            # running instances always entered through StageQueue.push,
            # so their cached estimator/cost fields are populated. MRET is
            # reference-speed but work_done accrues in device-local wall
            # ms (SimBackend.launch divides work by speed), so the MRET
            # must land in device units BEFORE the subtraction
            mret = inst.smret.value() * inst.cost_b
            if self.speed != 1.0:
                mret /= self.speed
            rem += max(mret - inst.work_done, 0.0)
        backlog = self.queues[k].backlog_ms()
        if self.speed != 1.0:
            backlog /= self.speed
        rem += backlog
        return now + rem / max(ctx.n_streams, 1)

    def migration_eta(self, k: CtxKey, now: float, src: CtxKey,
                      job: Optional[Job] = None) -> float:
        """ETA the migration machinery compares when moving work from
        ``src`` to ``k``. On one device it IS ``predicted_finish``; the
        cluster layer adds the inter-GPU transfer charge for candidates
        that would have to fetch ``job``'s inter-stage state."""
        return self.predicted_finish(k, now)

    # ------------------------------------------- device-relative interface
    # (the backend talks to schedulers only through these, so one
    # SimBackend clock can drive a single device and a cluster alike)
    def contention_of(self, k: CtxKey) -> ContentionModel:
        """Contention model of the device hosting context ``k``."""
        return self.contention

    def rate_groups(self, entries):
        """Partition running-set entries ``(lane, entry)`` into per-device
        rate-computation groups ``(contention, contexts, entries)``.
        Lanes on different devices never contend with each other; a
        single device is exactly one group."""
        return ((self.contention, self.contexts, entries),)

    def scale_units(self) -> int:
        """How many units the autoscaler grows/shrinks by one: contexts
        on a single device, whole GPUs under the cluster layer."""
        return len(self.live_contexts())

    def scale_kwargs(self, n: int) -> Dict:
        """``reconfigure`` kwargs that set the autoscaler unit count."""
        return {"n_contexts": n}

    # --------------------------------------------------------------- online
    def on_release(self, task: Task, now: float) -> Optional[Job]:
        """Coalesce into an open batch head (if policy allows), else
        admission test + (possibly migrated) enqueue. None = rejected."""
        if self._coalescer is not None:
            head = self._try_coalesce(task, now)
            if head is not None:
                return head
        job = Job(task=task, release_ms=now)
        needs_test = task.priority == LP or self.cfg.overload_hpa
        k = task.ctx
        if needs_test and not self.admits(k, task, now):
            # migration candidates: every other live context (Eq. 12),
            # earliest predicted finish wins (paper §IV-B1)
            cands = [c.index for c in self.live_contexts()
                     if c.index != k and self.admits(c.index, task, now)]
            if not cands:
                self.rejections.append(Rejection(task.name, now, task.priority))
                self.rejected_counts[task.priority] += 1
                return None
            k = min(cands, key=lambda c: self.predicted_finish(c, now))
            if task.priority == LP and not task.fixed_ctx:
                task.ctx = k          # sticky migration (zero-delay: the job
                self.migrations += 1  # simply enqueues on the new partition)
        job.ctx = k
        self.active_jobs[k][job] = None
        inst = self._enqueue_stage(job, now)
        if self._coalescer is not None:
            self._coalescer.register(task, inst)
        return job

    def _try_coalesce(self, task: Task, now: float) -> Optional[Job]:
        """Join this release onto its group's open batch head if the
        policy, the head's virtual deadline, and admission (Eq. 12) all
        allow it. Returns the (grown) head job, or None to fall through
        to the normal release path."""
        pol = self._coalescer.policy
        inst = self._coalescer.head(task)
        if inst is None:
            return None
        job = inst.job
        if inst.lane is not None or job.stage_idx != 0:
            self._coalescer.close(task)          # stale head: already runs
            return None
        if job.ctx not in self.contexts:
            # cluster re-place moved the head's job to another device:
            # this worker can neither admit nor refresh it (its context
            # table has no such key) — seal the stale head. Never fires
            # on a single device (job.ctx is always a local context).
            self._coalescer.close(task)
            return None
        if task.fixed_ctx and job.ctx != task.ctx:
            # an HP task's context is fixed (Algorithm 1): its inputs may
            # only ride batches executing on its own partition — Eq. 11
            # charges HP load by task.ctx, so cross-context joins would
            # execute work the admission math attributes elsewhere
            return None
        if job.n_inputs >= pol.max_batch:
            self._coalescer.close(task)          # full: seal the batch
            return None
        if (pol.max_wait_ms is not None
                and now - job.release_ms > pol.max_wait_ms * self.batch_widen):
            self._coalescer.close(task)
            return None
        # slack bound: the enlarged batch must still be predicted to meet
        # the earliest member's stage-0 virtual deadline — unless the head
        # already cannot, in which case waiting is free (throughput mode).
        # The head's task owns the deadline, so its profile/MRET govern
        # (identical to the joiner's under scope="task"; same-model under
        # scope="model").
        prof = job.task.spec.stages[0]
        mret0 = job.task.mret.stage_mret(0)
        if self.speed != 1.0:
            mret0 /= self.speed   # wall-clock prediction on THIS device
        cost_now = batch_cost(prof, job.n_inputs)
        cost_join = batch_cost(prof, job.n_inputs + 1)
        fits = now + mret0 * cost_join <= inst.virtual_deadline_ms
        late_anyway = now + mret0 * cost_now > inst.virtual_deadline_ms
        if not fits and not late_anyway:
            return None
        # admission charges the *incremental* batched utilization (Eq. 12)
        # — job-level (work-weighted over stages), unlike the stage-0
        # costs above which predict stage-0 completion only
        if task.priority == LP or self.cfg.overload_hpa:
            du = task.utilization(now) * (
                self.spec_batch_cost(job.task.spec, job.n_inputs + 1)
                - self.spec_batch_cost(job.task.spec, job.n_inputs))
            if self.speed != 1.0:
                du /= self.speed      # device-local units, as in admits()
            k = job.ctx
            if (not self.contexts[k].alive
                    or self.util_lp_active(k, now) + du
                    >= self.remaining_util(k, now)):
                return None
        job.extra_release_ms.append(now)
        job.extra_member_idx.append(task.index)
        # the head instance is still queued: refresh its cached backlog
        # cost to the grown batch size (see StageInstance.cost_b) — and
        # tell the queue its memoized backlog total is stale
        inst.cost_b = batch_cost(inst.profile, job.n_inputs)
        self.queues[job.ctx].touch()
        self.coalesced += 1
        return job

    def _enqueue_stage(self, job: Job, now: float) -> StageInstance:
        vdls = job.task.mret.virtual_deadlines(job.task.spec.deadline_ms)
        abs_vdl = job.release_ms + sum(vdls[:job.stage_idx + 1])
        inst = StageInstance(job=job, enqueue_ms=now,
                             virtual_deadline_ms=abs_vdl)
        self.queues[job.ctx].push(inst)
        return inst

    def on_stage_finish(self, inst: StageInstance, now: float,
                        et_ms: float) -> Optional[Job]:
        """MRET update + vdl bookkeeping. Returns the job if it completed.
        Batched executions are normalized back to single-input time before
        feeding MRET — by the finished stage's own cost, matching the
        backend's per-stage work scaling — so Eq. 1-2 keep their
        per-release semantics (and the utilization/vdl math built on
        them) whatever the batch size."""
        job = inst.job
        stage_cost = batch_cost(job.stage_profile(), job.n_inputs)
        if inst.transfer_ms:
            # the inter-GPU transfer charge is migration cost, not stage
            # execution: feeding it to MRET would inflate the sliding-
            # window max (and every deadline/utilization built on it)
            # for ws releases after every cross-GPU move. The backend
            # folds the charge into the stage's work, burned at the
            # contention rate — so its wall-clock share is its fraction
            # of the executed work, not the raw charge
            xfer_wall = inst.transfer_ms
            if inst.work_done > 0:
                xfer_wall = et_ms * (inst.transfer_ms / inst.work_done)
            et_ms = max(et_ms - xfer_wall, 0.0)
        if self.speed != 1.0:
            # MRET history is kept in reference-speed units so it stays
            # meaningful when a task migrates between heterogeneous GPUs
            et_ms = et_ms * self.speed
        job.task.mret.observe(job.stage_idx, et_ms / stage_cost)
        if job.cancelled:
            # in-flight cancel lands at the stage boundary (zero-delay
            # semantics): the finished stage's observation stands, later
            # stages never run, the admission charge unwinds here
            job.finish_ms = now
            del self.active_jobs[job.ctx][job]
            return job
        missed_vdl = now > inst.virtual_deadline_ms
        if job.is_last_stage():
            job.finish_ms = now
            del self.active_jobs[job.ctx][job]
            return job
        job.stage_idx += 1
        job.vdl_missed_prev = missed_vdl     # §IV-B2 priority boost
        self._enqueue_stage(job, now)
        return None

    # -------------------------------------------------------- cancellation
    def find_job(self, task_index: int, release_ms: float):
        """Locate the live job carrying the submission released by task
        ``task_index`` at ``release_ms``. Returns ``(job, member)``:
        ``member`` is None when the submission is the job's primary
        release, else its position in ``extra_release_ms`` (a coalesced
        batch member). ``(None, None)`` = no live job carries it (it
        completed, was rejected, or was already cancelled away).
        Iteration order is dict insertion order — deterministic, so a
        journal replay resolves cancels identically to the live run."""
        for jobs in self.active_jobs.values():
            for job in jobs:
                if (job.task.index == task_index
                        # stamp identity: the cancel echoes the exact
                        # release float  # dsan: ignore[DSAN003]
                        and job.release_ms == release_ms):
                    return job, None
                for i, (idx, rel) in enumerate(zip(job.extra_member_idx,
                                                   job.extra_release_ms)):
                    # same stamp identity  # dsan: ignore[DSAN003]
                    if idx == task_index and rel == release_ms:
                        return job, i
        return None, None

    def cancel_job(self, task_index: int, release_ms: float, now: float):
        """First-class job cancellation (the engine CANCEL event).

        Outcomes (``(outcome, job)``):
          * ``"cancelled"``  — the job was queued: its stage instance left
            the ready queue, the job left ``active_jobs`` (unwinding its
            Eq. 12 admission charge, which is computed by scanning active
            jobs), and any open batch-head registration was sealed.
          * ``"cancelling"`` — the job's current stage is executing: like
            zero-delay migration, the cancel takes effect at the stage
            boundary — the running stage finishes (its MRET observation
            stands), later stages never enqueue.
          * ``"detached"``   — a member of a still-growable stage-0 batch
            left it for real: batch size, cached backlog cost, and the
            incremental admission charge all shrink. Cancelling the
            *primary* of such a head promotes the earliest surviving
            member to primary, re-anchoring release/deadline/vdl.
          * ``"dropped"``    — a member of a sealed (dispatched or
            mid-pipeline) batch: the launched work is fixed, so the input
            rides along, but its result is discarded from accounting.
          * ``"noop"``       — the submission was already cancelled.
          * ``"absent"``     — no live job carries it (e.g. completed).
        """
        job, member = self.find_job(task_index, release_ms)
        if job is None:
            return "absent", None
        return self._cancel_found(job, member, now)

    def _cancel_found(self, job: Job, member: Optional[int], now: float):
        k = job.ctx
        q = self.queues.get(k)
        inst = q.find_inst(job) if q is not None else None
        if member is not None:
            rel = job.extra_release_ms[member]
            if rel in job.dropped_releases:
                return "noop", job
            if inst is not None and job.stage_idx == 0:
                job.extra_release_ms.pop(member)
                job.extra_member_idx.pop(member)
                # in-place cost_b change of a still-queued instance:
                # invalidate the queue's memoized backlog total
                inst.cost_b = batch_cost(inst.profile, job.n_inputs)
                q.touch()
                return "detached", job
            job.dropped_releases.append(rel)
            return "dropped", job
        # primary release
        if job.cancelled or job.release_ms in job.dropped_releases:
            return "noop", job
        if inst is not None and job.stage_idx == 0 and job.extra_release_ms:
            # queued batch head losing its primary: promote the earliest
            # surviving member — batching anchors deadline and stage-0
            # vdl on the earliest member (Job docstring), so the
            # re-anchored instance must re-enter the queue under its new
            # virtual deadline
            promo = next((i for i, r in enumerate(job.extra_release_ms)
                          if r not in job.dropped_releases), None)
            if promo is not None:
                job.release_ms = job.extra_release_ms.pop(promo)
                job.extra_member_idx.pop(promo)
                q.remove(inst)
                vdls = job.task.mret.virtual_deadlines(
                    job.task.spec.deadline_ms)
                inst.virtual_deadline_ms = job.release_ms + vdls[0]
                inst.cost_b = batch_cost(inst.profile, job.n_inputs)
                q.push(inst)
                return "detached", job
        # surviving batch members own the job's remaining work: the
        # primary's cancel can only discard its own result (mid-pipeline
        # batches cannot shed members — the launched work is fixed)
        survivors = [r for r in job.extra_release_ms
                     if r not in job.dropped_releases]
        if survivors:
            job.dropped_releases.append(job.release_ms)
            return "dropped", job
        if inst is not None:
            # current stage still queued: the whole job retires now
            q.remove(inst)
            if self._coalescer is not None:
                self._coalescer.on_pop(inst)   # seal a stale open head
            del self.active_jobs[k][job]
            job.cancelled = True
            job.finish_ms = now
            return "cancelled", job
        # current stage is on a lane: zero-delay boundary retirement
        job.cancelled = True
        return "cancelling", job

    def abort_job(self, job: Job, now: float) -> None:
        """Chaos-layer give-up (RetryPolicy exhausted, or a deadline-aware
        bail-out): the job leaves ``active_jobs`` immediately, unwinding
        its Eq. 12 admission charge exactly like a queued cancel. The
        failed stage's instance is neither queued nor on a lane when this
        runs (the engine frees the lane before deciding), so there is
        nothing to remove from the ready queue."""
        del self.active_jobs[job.ctx][job]
        job.finish_ms = now

    def next_for_lane(self, ctx_idx: int, now: float) -> Optional[StageInstance]:
        if self._coalescer is None:
            return self.queues[ctx_idx].pop()
        # lazy dispatch (D-STACK-style): a growable batch head stays queued
        # until its latest start time, as long as the drive loop will wake
        # us again before that — work behind it dispatches meanwhile
        q = self.queues[ctx_idx]
        held: List[StageInstance] = []
        inst = q.pop()
        while inst is not None and self._should_hold(inst, now):
            held.append(inst)
            inst = q.pop()
        for h in held:
            q.push(h)
        if inst is not None:
            self._coalescer.on_pop(inst)     # dispatch seals the batch
        return inst

    def _should_hold(self, inst: StageInstance, now: float) -> bool:
        """Hold a growable stage-0 batch head iff the engine's next
        wake-up still leaves time to dispatch it within its virtual
        deadline (with its current batch size)."""
        job = inst.job
        pol = self._coalescer.policy
        if job.stage_idx != 0 or self._coalescer.head(job.task) is not inst:
            return False
        if job.n_inputs >= pol.max_batch:
            return False
        if (pol.max_wait_ms is not None
                and self.next_wake_ms - job.release_ms
                > pol.max_wait_ms * self.batch_widen):
            return False
        prof = job.task.spec.stages[0]
        mret0 = job.task.mret.stage_mret(0)
        if self.speed != 1.0:
            mret0 /= self.speed   # wall-clock prediction on THIS device
        latest_start = (inst.virtual_deadline_ms
                        - mret0 * batch_cost(prof, job.n_inputs))
        return self.next_wake_ms <= latest_start

    def free_lanes(self) -> List[tuple]:
        return self.lanes.free_lanes()

    # ------------------------------------------------------ fault / elastic
    def fault_cancel_keys(self, k) -> List:
        """Backend lanes a context fault must cancel BEFORE
        ``fail_context`` runs. One device: just the faulted context. The
        cluster overrides this — losing a device's last live context
        escalates to a whole-device failure, which requeues in-flight
        stages from EVERY context of the device, so their backend
        entries must die too (else a ghost completion double-executes
        the replayed stage)."""
        return [k]

    def fail_context(self, k: int, now: float) -> List[StageInstance]:
        """Partition loss: survivors inherit tasks via Algorithm 1 re-run;
        in-flight stages replay (stage granularity bounds lost work)."""
        self.contexts[k].alive = False
        self._invalidate_live()
        self.lanes.retire_ctx(k)
        orphans = self.queues[k].drain()
        for lane, inst in self.lanes.busy_in_ctx(k):
            orphans.append(inst)
            self.lanes[lane] = None
        alive = [c.index for c in self.live_contexts()]
        if not alive:
            raise RuntimeError("all contexts failed")
        util = {a: self.util_hp_total(a, now) + self.util_lp_active(a, now)
                for a in alive}
        # Algorithm 1 re-run: HP first (descending utilization), then LP —
        # an LP task must never claim the min-utilization survivor ahead
        # of an HP task (mirrors _offline_phase)
        orphaned = [t for t in self.tasks if t.ctx == k]
        for t in hp_first(orphaned, now):
            tgt = min(util, key=util.get)
            t.ctx = tgt
            util[tgt] += t.utilization(now)
        requeued = []
        for inst in orphans:
            job = inst.job
            if job in self.active_jobs[k]:
                del self.active_jobs[k][job]
                self.active_jobs[job.task.ctx][job] = None
            job.ctx = job.task.ctx
            inst.work_done = 0.0      # replay from stage start
            inst.lane = None
            self.queues[job.ctx].push(inst)
            requeued.append(inst)
        return requeued

    def add_context(self, now: float) -> Context:
        """Elastic scale-out: append one context carrying real Eq. 9
        geometry — the last wrap-around slot of the shape the device has
        *after* this scale-out (live contexts + 1). Deterministic: the
        historic path sliced an unordered set, which made scale-out runs
        depend on hash iteration order."""
        n_live = len(self.live_contexts()) + 1
        geo = derive_contexts(n_live, self.cfg.n_streams,
                              self.cfg.oversubscription,
                              int(self.device.n_units))[-1]
        ctx = Context(index=self._key(len(self.contexts)), units=geo.units,
                      n_streams=self.cfg.n_streams)
        self._install_context(ctx)
        return ctx

    def _install_context(self, ctx: Context) -> None:
        """Register a freshly created context with every per-context
        structure (queue, active-job set, lanes)."""
        self._invalidate_live()
        self.contexts.append(ctx)
        q = StageQueue(self.cfg.queue_cfg)
        q.register_hot(ctx.index, self.hot_queues)
        self.queues[ctx.index] = q
        self.active_jobs[ctx.index] = {}
        for s in range(ctx.n_streams):
            self.lanes[(ctx.index, s)] = None

    def reconfigure(self, now: float, n_contexts: Optional[int] = None,
                    n_streams: Optional[int] = None,
                    oversubscription: Optional[float] = None) -> dict:
        """Online elastic repartitioning — the paper's oversubscribed
        geometry (Eq. 9) re-derived mid-run with zero-delay migration.

        The controller never drains: old contexts are retired in place
        (their lanes keep executing), a fresh context set with the new
        ``(n_contexts, n_streams, oversubscription)`` shape is appended at
        new indices, Algorithm 1 re-places every task (HP first, as in
        ``fail_context``), queued stage instances re-home to their task's
        new context, and in-flight stages finish on their old lane and
        migrate at the next stage boundary — stage granularity is the
        paper's zero-delay mechanism, so no running stage program is ever
        interrupted (unlike ``fail_context``, nothing replays).

        Returns a summary dict: retired/created context indices, how many
        queued instances re-homed, how many in-flight jobs will migrate at
        their next boundary, and how many of those moves changed the
        physical unit set (counted into ``self.migrations``).
        """
        old_live = list(self.live_contexts())
        n_contexts = n_contexts if n_contexts is not None else len(old_live)
        n_streams = n_streams if n_streams is not None else self.cfg.n_streams
        if oversubscription is None:
            oversubscription = self.cfg.oversubscription
        if n_streams < 1:
            raise ValueError(f"reconfigure needs n_streams >= 1, got "
                             f"{n_streams}: a zero-lane context would "
                             f"strand every queued job silently")
        self.cfg.n_contexts = n_contexts
        self.cfg.n_streams = n_streams
        self.cfg.oversubscription = oversubscription
        base = len(self.contexts)
        created = derive_contexts(n_contexts, n_streams, oversubscription,
                                  int(self.device.n_units), base_index=base)
        for ctx in created:
            ctx.index = self._key(ctx.index)
        # retire the old partition *before* installing the new one: queued
        # work drains out, running lanes stay busy until their stage ends
        orphans: List[StageInstance] = []
        old_units: Dict[int, frozenset] = {}
        for c in old_live:
            c.alive = False
            old_units[c.index] = frozenset(c.units)
            self.lanes.retire_ctx(c.index)
            orphans.extend(self.queues[c.index].drain())
        self._invalidate_live()
        for ctx in created:
            self._install_context(ctx)
        # Algorithm 1 re-run over ALL tasks onto the new shape: HP first
        # (descending utilization), then LP — identical ordering to
        # _offline_phase / fail_context
        util = {c.index: 0.0 for c in created}
        for t in hp_first(self.tasks, now):
            tgt = min(util, key=util.get)
            t.ctx = tgt
            util[tgt] += t.utilization(now)
        # re-home every live job to its task's new context. Queued stage
        # instances move queues now (in old-context order, preserving
        # each queue's drain order); in-flight jobs only re-point their
        # ``job.ctx`` — the running instance finishes on the old lane and
        # the job's NEXT stage enqueues on the new context (zero-delay).
        migrated = 0
        inflight = 0
        for k in sorted(old_units):
            for job in list(self.active_jobs[k]):
                del self.active_jobs[k][job]
                self.active_jobs[job.task.ctx][job] = None
                job.ctx = job.task.ctx
                # a sticky cross-GPU migration can point the task at
                # another device: that context isn't in THIS worker's
                # table, and the move is a unit-set change by definition
                tgt_ctx = self.contexts.get(job.ctx)
                if tgt_ctx is None or old_units[k] != tgt_ctx.units:
                    migrated += 1
        for inst in orphans:
            inst.lane = None
            self.queues[inst.job.ctx].push(inst)
        for lane, inst in self.lanes.items():
            if inst is not None and lane[0] in old_units:
                inflight += 1
        self.migrations += migrated
        return {
            "retired": sorted(old_units),
            "created": [c.index for c in created],
            "rehomed": len(orphans),
            "inflight": inflight,
            "migrated": migrated,
        }
