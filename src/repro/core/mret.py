"""MRET — Maximum Recent Execution Time (paper §III-B2, Eq. 1-2).

Per-stage sliding-window maximum over the last ``ws`` completed executions;
task MRET is the sum over stages (Eq. 2). Before any history exists the
estimator is seeded with AFET (average full-load execution time, §IV-A1),
the paper's pessimistic offline initialization.
"""
from __future__ import annotations

from collections import deque
from typing import List, Sequence


class StageMret:
    def __init__(self, afet_ms: float, ws: int = 5):
        self.ws = ws
        self.window: deque = deque(maxlen=ws)
        self.afet_ms = afet_ms

    def observe(self, et_ms: float) -> None:
        self.window.append(et_ms)

    def value(self) -> float:
        """Eq. 1: max over the recent window (AFET until history exists)."""
        if not self.window:
            return self.afet_ms
        return max(self.window)


class TaskMret:
    """Eq. 2: mret_i = sum_j mret_{i,j}; plus Eq. 8 virtual-deadline split."""

    def __init__(self, stage_afets_ms: Sequence[float], ws: int = 5):
        self.stages = [StageMret(a, ws) for a in stage_afets_ms]

    def observe(self, stage_idx: int, et_ms: float) -> None:
        self.stages[stage_idx].observe(et_ms)

    def stage_mret(self, stage_idx: int, now_ms: float = 0.0) -> float:
        return self.stages[stage_idx].value()

    def task_mret(self, now_ms: float = 0.0) -> float:
        return sum(s.value() for s in self.stages)

    def virtual_deadlines(self, deadline_ms: float) -> List[float]:
        """Eq. 8: D_{i,j} = (mret_{i,j} / mret_i) * D_i  (relative slice
        widths; caller accumulates to absolute deadlines)."""
        total = self.task_mret()
        if total <= 0:
            n = len(self.stages)
            return [deadline_ms / n] * n
        return [s.value() / total * deadline_ms for s in self.stages]
