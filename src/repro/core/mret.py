"""MRET — Maximum Recent Execution Time (paper §III-B2, Eq. 1-2).

Per-stage sliding-window maximum over the last ``ws`` completed executions;
task MRET is the sum over stages (Eq. 2). Before any history exists the
estimator is seeded with AFET (average full-load execution time, §IV-A1),
the paper's pessimistic offline initialization.

Values are memoized: the admission test (Eq. 11-12) reads ``task_mret``
for every task on a context at every release, so recomputing the window
max / stage sum each read made admission O(tasks x stages x ws).
``observe`` invalidates; reads between observations are O(1) and return
the exact same floats the uncached code produced (same max, same
left-to-right sum order).
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional, Sequence


class StageMret:
    # process-wide estimator generation: bumped whenever ANY stage
    # estimator's value may have changed. Aggregate caches over many
    # estimators (StageQueue.backlog_ms) key on it to stay O(1) without
    # tracking which queue holds which estimator.
    generation: int = 0

    def __init__(self, afet_ms: float, ws: int = 5):
        self.ws = ws
        self.window: deque = deque(maxlen=ws)
        self.afet_ms = afet_ms
        self._value: Optional[float] = afet_ms

    def observe(self, et_ms: float) -> None:
        self.window.append(et_ms)
        self._value = None
        StageMret.generation += 1

    def invalidate(self) -> None:
        """Drop the memoized max after direct ``window`` mutation
        (checkpoint restore)."""
        self._value = None
        StageMret.generation += 1

    def value(self) -> float:
        """Eq. 1: max over the recent window (AFET until history exists)."""
        if self._value is None:
            self._value = max(self.window) if self.window else self.afet_ms
        return self._value


class TaskMret:
    """Eq. 2: mret_i = sum_j mret_{i,j}; plus Eq. 8 virtual-deadline split."""

    def __init__(self, stage_afets_ms: Sequence[float], ws: int = 5):
        self.stages = [StageMret(a, ws) for a in stage_afets_ms]
        self._total: Optional[float] = None

    def observe(self, stage_idx: int, et_ms: float) -> None:
        self.stages[stage_idx].observe(et_ms)
        self._total = None

    def invalidate(self) -> None:
        """Drop all memoized values after direct window mutation."""
        for s in self.stages:
            s.invalidate()
        self._total = None

    def stage_mret(self, stage_idx: int, now_ms: float = 0.0) -> float:
        return self.stages[stage_idx].value()

    def task_mret(self, now_ms: float = 0.0) -> float:
        if self._total is None:
            self._total = sum(s.value() for s in self.stages)
        return self._total

    def virtual_deadlines(self, deadline_ms: float) -> List[float]:
        """Eq. 8: D_{i,j} = (mret_{i,j} / mret_i) * D_i  (relative slice
        widths; caller accumulates to absolute deadlines)."""
        total = self.task_mret()
        if total <= 0:
            n = len(self.stages)
            return [deadline_ms / n] * n
        return [s.value() / total * deadline_ms for s in self.stages]
