"""JPS / DMR / response-time metrics (paper §V-VI conventions).

DMR = missed deadlines / accepted jobs, per priority class. A job that
finishes after its deadline still completes (soft real-time); rejected
jobs are counted separately (admission).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from .task import HP, LP


@dataclasses.dataclass
class RunMetrics:
    horizon_ms: float
    completed: Dict[int, int]
    missed: Dict[int, int]
    rejected: Dict[int, int]
    response_ms: Dict[int, List[float]]
    migrations: int = 0
    stragglers: int = 0
    faults: int = 0
    # periodic releases skipped because the drive loop stalled past whole
    # periods (wall-clock backends under load; see PeriodicArrival)
    skipped_releases: int = 0

    @property
    def jps(self) -> float:
        return sum(self.completed.values()) / (self.horizon_ms / 1000.0)

    def jps_by(self, p: int) -> float:
        return self.completed[p] / (self.horizon_ms / 1000.0)

    def dmr(self, p: int) -> float:
        acc = self.completed[p]
        return self.missed[p] / acc if acc else 0.0

    def resp_stats(self, p: int) -> Dict[str, float]:
        r = self.response_ms[p]
        if not r:
            return {"mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0,
                    "min": 0.0, "max": 0.0}
        a = np.asarray(r)
        return {"mean": float(a.mean()), "p50": float(np.percentile(a, 50)),
                "p95": float(np.percentile(a, 95)),
                "p99": float(np.percentile(a, 99)),
                "min": float(a.min()), "max": float(a.max())}

    def summary(self) -> Dict:
        return {
            "jps": self.jps,
            "jps_hp": self.jps_by(HP), "jps_lp": self.jps_by(LP),
            "dmr_hp": self.dmr(HP), "dmr_lp": self.dmr(LP),
            "rejected_hp": self.rejected[HP], "rejected_lp": self.rejected[LP],
            "resp_hp": self.resp_stats(HP), "resp_lp": self.resp_stats(LP),
            "migrations": self.migrations, "stragglers": self.stragglers,
            "faults": self.faults, "skipped_releases": self.skipped_releases,
        }


def empty_metrics(horizon_ms: float) -> RunMetrics:
    return RunMetrics(horizon_ms=horizon_ms,
                      completed={HP: 0, LP: 0}, missed={HP: 0, LP: 0},
                      rejected={HP: 0, LP: 0},
                      response_ms={HP: [], LP: []})
