"""JPS / DMR / response-time metrics (paper §V-VI conventions).

DMR = missed deadlines / accepted jobs, per priority class. A job that
finishes after its deadline still completes (soft real-time); rejected
jobs are counted separately (admission). Jobs still queued or in flight
when the run ends are swept into ``unfinished`` — and into ``missed`` if
already past their deadline — so overload DMR is not understated by work
the horizon cut off.

Dynamic batching (core/batching.py) makes jobs and inputs distinct units:
``completed`` counts jobs, ``completed_inputs`` counts the inputs they
carried, and ``jps_inputs`` is the throughput figure comparable to the
paper's batched baselines. ``batch_hist`` maps batch size -> number of
completed jobs of that size (all-1 when batching is off).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from .task import HP, LP


@dataclasses.dataclass
class RunMetrics:
    horizon_ms: float
    completed: Dict[int, int]
    missed: Dict[int, int]
    rejected: Dict[int, int]
    response_ms: Dict[int, List[float]]
    migrations: int = 0
    stragglers: int = 0
    faults: int = 0
    # online elastic repartitions (scheduler.reconfigure invocations:
    # timed plans and autoscaler decisions alike)
    reconfigures: int = 0
    # periodic releases skipped because the drive loop stalled past whole
    # periods (wall-clock backends under load; see PeriodicArrival)
    skipped_releases: int = 0
    # jobs still queued/in-flight when the run ended (per priority)
    unfinished: Dict[int, int] = dataclasses.field(
        default_factory=lambda: {HP: 0, LP: 0})
    # inputs carried by completed jobs (== completed when batching is off)
    completed_inputs: Dict[int, int] = dataclasses.field(
        default_factory=lambda: {HP: 0, LP: 0})
    # batch size -> completed jobs of that size
    batch_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    # cluster runs: device -> {"completed"/"missed": {HP/LP: n}} (empty on
    # single-GPU servers), and the count of inter-GPU state transfers the
    # zero-delay migration machinery actually paid for
    per_device: Dict[int, Dict] = dataclasses.field(default_factory=dict)
    transfers: int = 0
    # client-cancelled submissions per priority (scheduler.cancel_job):
    # whole jobs retired plus batch members detached/dropped. A cancelled
    # job is neither completed nor missed nor rejected.
    cancelled: Dict[int, int] = dataclasses.field(
        default_factory=lambda: {HP: 0, LP: 0})
    # tenant -> accounting dict (see tenant_stats); filled by the engine
    # when any submission carried a tenant id (the serving front-end),
    # empty for plain benchmark runs
    per_tenant: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    # ---- chaos layer (repro.chaos): all zero with no ChaosPlan ----
    # transient stage faults injected by the plan
    chaos_faults: int = 0
    # failed stages re-dispatched after backoff (RetryPolicy)
    retries: int = 0
    # jobs given up on after a transient fault (attempts exhausted, or a
    # deadline-aware bail-out); aborted jobs unwind their Eq. 12 charge
    # and are neither completed nor missed nor cancelled
    aborted: Dict[int, int] = dataclasses.field(
        default_factory=lambda: {HP: 0, LP: 0})
    # in-flight stages killed by the per-stage watchdog and re-dispatched
    # at the stage boundary (each also counts into ``migrations`` when it
    # re-homed)
    watchdog_kills: int = 0
    # LP releases shed by the degradation controller: admissions refused
    # in BROWNOUT/EMERGENCY plus queued jobs cancelled on EMERGENCY entry
    shed: Dict[int, int] = dataclasses.field(
        default_factory=lambda: {HP: 0, LP: 0})
    # NORMAL/BROWNOUT/EMERGENCY mode changes (DegradationPolicy)
    degrade_transitions: int = 0

    @property
    def jps(self) -> float:
        return sum(self.completed.values()) / (self.horizon_ms / 1000.0)

    def jps_by(self, p: int) -> float:
        return self.completed[p] / (self.horizon_ms / 1000.0)

    @property
    def jps_inputs(self) -> float:
        """Input throughput — the number comparable to batched baselines."""
        return (sum(self.completed_inputs.values())
                / (self.horizon_ms / 1000.0))

    def jps_inputs_by(self, p: int) -> float:
        return self.completed_inputs[p] / (self.horizon_ms / 1000.0)

    def dmr(self, p: int) -> float:
        acc = self.completed[p] + self.unfinished[p]
        return self.missed[p] / acc if acc else 0.0

    def mean_batch(self) -> float:
        """Mean batch size over completed jobs (1.0 when batching is off)."""
        jobs = sum(self.batch_hist.values())
        if not jobs:
            return 0.0
        return sum(b * n for b, n in self.batch_hist.items()) / jobs

    def resp_stats(self, p: int) -> Dict[str, float]:
        r = self.response_ms[p]
        if not r:
            return {"mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0,
                    "min": 0.0, "max": 0.0}
        a = np.asarray(r)
        return {"mean": float(a.mean()), "p50": float(np.percentile(a, 50)),
                "p95": float(np.percentile(a, 95)),
                "p99": float(np.percentile(a, 99)),
                "min": float(a.min()), "max": float(a.max())}

    def summary(self) -> Dict:
        resp_hp = self.resp_stats(HP)
        resp_lp = self.resp_stats(LP)
        out = {
            "jps": self.jps,
            "jps_hp": self.jps_by(HP), "jps_lp": self.jps_by(LP),
            "jps_inputs": self.jps_inputs,
            "jps_hp_inputs": self.jps_inputs_by(HP),
            "jps_lp_inputs": self.jps_inputs_by(LP),
            "dmr_hp": self.dmr(HP), "dmr_lp": self.dmr(LP),
            "rejected_hp": self.rejected[HP], "rejected_lp": self.rejected[LP],
            "unfinished_hp": self.unfinished[HP],
            "unfinished_lp": self.unfinished[LP],
            "resp_hp": resp_hp, "resp_lp": resp_lp,
            # flat per-priority percentiles: the tail-latency columns the
            # figure harnesses (fig4-6, fig13) read without digging into
            # the nested resp dicts
            "resp_hp_p50": resp_hp["p50"], "resp_hp_p95": resp_hp["p95"],
            "resp_hp_p99": resp_hp["p99"],
            "resp_lp_p50": resp_lp["p50"], "resp_lp_p95": resp_lp["p95"],
            "resp_lp_p99": resp_lp["p99"],
            "mean_batch": self.mean_batch(),
            "batch_hist": dict(sorted(self.batch_hist.items())),
            "migrations": self.migrations, "stragglers": self.stragglers,
            "faults": self.faults, "reconfigures": self.reconfigures,
            "skipped_releases": self.skipped_releases,
            "cancelled_hp": self.cancelled[HP],
            "cancelled_lp": self.cancelled[LP],
        }
        # chaos block only when the chaos layer actually fired: chaos-off
        # summaries stay byte-identical to the pre-chaos goldens
        if (self.chaos_faults or self.retries or self.watchdog_kills
                or self.degrade_transitions or any(self.aborted.values())
                or any(self.shed.values())):
            out["chaos_faults"] = self.chaos_faults
            out["retries"] = self.retries
            out["aborted_hp"] = self.aborted[HP]
            out["aborted_lp"] = self.aborted[LP]
            out["watchdog_kills"] = self.watchdog_kills
            out["shed_hp"] = self.shed[HP]
            out["shed_lp"] = self.shed[LP]
            out["degrade_transitions"] = self.degrade_transitions
        if self.per_device:
            out["per_device"] = {
                str(d): s for d, s in sorted(self.per_device.items())}
            out["transfers"] = self.transfers
        if self.per_tenant:
            out["per_tenant"] = dict(sorted(self.per_tenant.items()))
        return out


def tenant_stats(handles) -> Dict[str, Dict]:
    """Per-tenant accounting over submit handles (duck-typed: needs
    ``.tenant``/``.status``/``.response_ms``). Handles without a tenant
    id (plain programmatic submits) are excluded. ``completed`` counts
    every finished job including late ones (soft real-time: a missed job
    still completes); ``missed`` is the late subset. ``pending`` covers
    queued/running/unreleased submissions at observation time."""
    out: Dict[str, Dict] = {}
    resp: Dict[str, List[float]] = {}
    for h in handles:
        if h.tenant is None:
            continue
        d = out.setdefault(h.tenant, {
            "submitted": 0, "completed": 0, "missed": 0,
            "cancelled": 0, "rejected": 0, "aborted": 0, "pending": 0})
        d["submitted"] += 1
        st = h.status
        if st in ("completed", "missed"):
            d["completed"] += 1
            if st == "missed":
                d["missed"] += 1
            if h.response_ms is not None:
                resp.setdefault(h.tenant, []).append(h.response_ms)
        elif st == "cancelled":
            d["cancelled"] += 1
        elif st == "rejected":
            d["rejected"] += 1
        elif st == "aborted":
            d["aborted"] += 1
        else:
            d["pending"] += 1
    for tenant, d in out.items():
        r = resp.get(tenant)
        if r:
            a = np.asarray(r)
            d["resp"] = {"mean": float(a.mean()),
                         "p50": float(np.percentile(a, 50)),
                         "p95": float(np.percentile(a, 95)),
                         "p99": float(np.percentile(a, 99))}
        else:
            d["resp"] = {"mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
    return out


def empty_metrics(horizon_ms: float) -> RunMetrics:
    return RunMetrics(horizon_ms=horizon_ms,
                      completed={HP: 0, LP: 0}, missed={HP: 0, LP: 0},
                      rejected={HP: 0, LP: 0},
                      response_ms={HP: [], LP: []})
