"""DARIS task model (paper §III-A).

τ_i(T_i, D_i, mret_i(t), p_i, ctx_i(t)) — periodic task = one DNN, divided
into n_i sequential stages. Two priority levels (HP/LP). D_i = T_i.

``Job`` is one periodic release; ``StageInstance`` is one stage of one job
(the schedulable unit). Virtual deadlines (Eq. 8) split the job deadline
across stages proportionally to per-stage MRET.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, TYPE_CHECKING

if TYPE_CHECKING:
    from .mret import StageMret, TaskMret

HP = 0   # high priority
LP = 1   # low priority

_job_counter = itertools.count()


@dataclasses.dataclass
class StageProfile:
    """Execution profile of one stage (drives the contention model and,
    in real mode, maps to a jitted stage function)."""
    name: str
    t_alone_ms: float          # single-stream, idle-device execution time
    n_sat: float               # device units the stage can actually use
    mem_frac: float            # memory-bandwidth-bound fraction
    overhead_ms: float = 0.05  # dispatch/sync overhead (staging cost)
    payload: Optional[object] = None   # real-mode callable
    batch_gain: float = 1.0    # asymptotic batching speedup g_inf (Table I);
                               # 1.0 = batching scales work linearly


@dataclasses.dataclass
class TaskSpec:
    """Static description of a periodic task."""
    name: str
    period_ms: float
    priority: int                     # HP | LP
    stages: List[StageProfile]
    batch: int = 1

    @property
    def deadline_ms(self) -> float:   # D_i = T_i
        return self.period_ms

    @property
    def n_stages(self) -> int:
        return len(self.stages)


@dataclasses.dataclass(eq=False)
class Task:
    """Runtime task state: MRET estimates + context assignment.

    ``eq=False``: runtime objects compare by identity. Value equality
    would recurse through spec/stage dataclasses on every membership
    test, which made ``list.remove`` on job collections quadratic."""
    spec: TaskSpec
    index: int
    ctx: int = -1                     # current context (ctx_i(t))
    fixed_ctx: bool = False           # HP tasks get fixed contexts
    # paper Eq. 1-2 estimators are attached by the scheduler (core.mret)
    mret: Optional[TaskMret] = None

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def priority(self) -> int:
        return self.spec.priority

    def utilization(self, now_ms: float) -> float:
        """Eq. 3 / Eq. 10: u_i = mret_i / T_i (AFET-seeded before history)."""
        return self.mret.task_mret(now_ms) / self.spec.period_ms


@dataclasses.dataclass(eq=False)
class Job:
    """One release of a task — or, under dynamic batching, one *batched*
    release: later releases of the same task that coalesced into this job
    (core/batching.py) append their timestamps to ``extra_release_ms`` and
    the job executes each stage once over ``n_inputs`` inputs.

    ``release_ms`` is always the EARLIEST member's release: the batched
    job inherits that member's absolute deadline and virtual-deadline
    anchoring, so batching can only ever tighten, never relax, the
    deadline the scheduler works against."""
    task: Task
    release_ms: float
    job_id: int = dataclasses.field(default_factory=lambda: next(_job_counter))
    ctx: int = -1                     # context this job was admitted to
    stage_idx: int = 0
    start_ms: Optional[float] = None
    finish_ms: Optional[float] = None
    vdl_missed_prev: bool = False     # did the previous stage miss its vdl?
    extra_release_ms: List[float] = dataclasses.field(default_factory=list)
    # task.index of each extra member, in lockstep with extra_release_ms
    # (scope="model" batches span tasks; completion must reach each
    # member's own handle)
    extra_member_idx: List[int] = dataclasses.field(default_factory=list)
    # first-class cancellation (scheduler.cancel_job): a cancelled job
    # retires instead of completing — immediately while queued, at the
    # next stage boundary while in flight (zero-delay semantics)
    cancelled: bool = False
    # release timestamps of batch members cancelled after the batch
    # sealed: the input physically rides along (the launched work is
    # fixed), but its result is discarded — response/throughput
    # accounting skips these releases
    dropped_releases: List[float] = dataclasses.field(default_factory=list)

    @property
    def n_inputs(self) -> int:
        return 1 + len(self.extra_release_ms)

    @property
    def release_times(self) -> List[float]:
        """Per-input release timestamps (earliest first) — each input's
        response time is measured from its own release."""
        return [self.release_ms, *self.extra_release_ms]

    @property
    def abs_deadline_ms(self) -> float:
        return self.release_ms + self.task.spec.deadline_ms

    def stage_profile(self) -> StageProfile:
        return self.task.spec.stages[self.stage_idx]

    def is_last_stage(self) -> bool:
        return self.stage_idx == self.task.spec.n_stages - 1


@dataclasses.dataclass(eq=False)
class StageInstance:
    """The schedulable unit: stage ``job.stage_idx`` of ``job``.
    Identity equality (``eq=False``): two instances are never "the same
    stage" unless they are the same object."""
    job: Job
    enqueue_ms: float
    virtual_deadline_ms: float        # absolute (Eq. 8 slice end)
    work_done: float = 0.0            # device-seconds already executed
    lane: Optional[tuple] = None      # (ctx, slot) while running
    start_ms: Optional[float] = None
    # backlog-estimation constants, filled on first queue entry
    # (StageQueue.push): the stage's MRET estimator and its batch cost
    # b/g(b) are fixed for the instance's lifetime, and resolving them
    # through job -> task -> spec property chains per queued stage made
    # backlog_ms the hottest loop on overload runs
    smret: Optional[StageMret] = None
    cost_b: float = 1.0
    # chaos-layer retry accounting: execution attempts this stage has
    # burned (transient stage faults, see repro.chaos). Always 0 with no
    # ChaosPlan installed.
    attempts: int = 0
    # inter-GPU migration charge (cluster layer): when this stage
    # dispatches on a different device than the one holding the job's
    # inter-stage state, the dispatcher stamps the configured transfer
    # cost here and the backend adds it to the stage's work. Always 0.0
    # on a single device.
    transfer_ms: float = 0.0

    @property
    def profile(self) -> StageProfile:
        return self.job.stage_profile()

    @property
    def task(self) -> Task:
        return self.job.task
