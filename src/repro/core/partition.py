"""Spatial partitioning with oversubscription (paper Eq. 9).

N_SM = ceil_even(OS * N_SM,max / N_c), 1 <= OS <= N_c. Units are SMs on the
paper's GPU and chips on a TPU pod slice (DESIGN.md §2) — the geometry is
identical. With OS > 1 the wrap-around allocation makes contexts overlap,
so idle capacity in one context is usable by its neighbours (the core
oversubscription benefit the paper measures).

Device-relative indices: a context index is whatever key its scheduler
assigned — a plain int on a single device, a ``(device, k)`` tuple under
the cluster layer (repro/cluster). Nothing in the geometry depends on the
key shape; ``ContextTable`` keeps both usages working through one type.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Hashable, List, Set

CtxKey = Hashable   # int (single device) | (device, int) (cluster layer)


def ceil_even(x: float) -> int:
    v = math.ceil(x)
    return v + (v % 2)if v % 2 else v


@dataclasses.dataclass
class Context:
    index: CtxKey
    units: Set[int]                 # unit ids (overlapping when OS > 1)
    n_streams: int
    alive: bool = True

    @property
    def cap(self) -> float:
        return float(len(self.units))


class ContextTable(dict):
    """Context registry keyed by context index.

    Historically ``DarisScheduler.contexts`` was a list whose positions
    doubled as indices; the cluster layer namespaces indices as
    ``(device, k)`` tuples, which no list can hold. This table keeps both
    call styles alive: it *indexes* like a mapping (``table[key]``) and
    *iterates* like the historic list (``for ctx in table`` yields
    ``Context`` objects in insertion order, which is creation order).
    ``in`` tests keys, as for any mapping."""

    def __iter__(self):
        return iter(self.values())

    def append(self, ctx: Context) -> None:
        """List-style registration: key the context by its own index."""
        self[ctx.index] = ctx


def make_contexts(n_contexts: int, n_streams: int, oversubscription: float,
                  n_units: int) -> List[Context]:
    """Eq. 9 allocation. OS=1 -> disjoint partitions; OS=N_c -> full
    sharing; intermediate values overlap neighbours (wrap-around)."""
    os_v = min(max(oversubscription, 1.0), float(n_contexts))
    per_ctx = min(ceil_even(os_v * n_units / n_contexts), n_units)
    out = []
    stride = n_units / n_contexts
    for k in range(n_contexts):
        start = int(round(k * stride)) % n_units
        units = {(start + i) % n_units for i in range(per_ctx)}
        out.append(Context(index=k, units=units, n_streams=n_streams))
    return out


def reconfigure(n_contexts: int, n_streams: int, oversubscription: float,
                n_units: int, base_index: int = 0) -> List[Context]:
    """Eq. 9 re-derivation for a new partition shape.

    Returns fresh ``Context`` objects carrying the wrap-around geometry of
    ``make_contexts`` but indexed from ``base_index``: a live scheduler
    retires its old contexts in place (their indices stay addressable for
    in-flight work) and appends these, so an online reshape never reuses
    an index and every queued/running stage keeps a valid home.
    """
    if n_contexts < 1:
        raise ValueError(f"need >= 1 context, got {n_contexts}")
    out = make_contexts(n_contexts, n_streams, oversubscription, n_units)
    for ctx in out:
        ctx.index += base_index
    return out


def overlap_matrix(contexts: List[Context]) -> List[List[int]]:
    n = len(contexts)
    return [[len(contexts[a].units & contexts[b].units) for b in range(n)]
            for a in range(n)]


# ------------------------------------------------------------ introspection
# (static analysis — repro.analysis.schedcheck — reads oversubscription
# interference through these instead of re-deriving Eq. 9 on its own)

def unit_residency(contexts: List[Context]) -> Dict[int, int]:
    """unit id -> number of the given contexts whose Eq. 9 allocation
    includes it (1 everywhere at OS=1; grows with oversubscription)."""
    res: Dict[int, int] = {}
    for c in contexts:
        for u in c.units:
            res[u] = res.get(u, 0) + 1
    return res


def max_coresidency(contexts: List[Context]) -> int:
    """Worst-case unit sharing: the max number of contexts co-resident on
    any single unit — the interference degree the oversubscribed wrap-
    around allocation creates (1 = disjoint partitions)."""
    res = unit_residency(contexts)
    return max(res.values()) if res else 0


def interference_sets(contexts: List[Context]) -> Dict[CtxKey, List[CtxKey]]:
    """ctx index -> indices of the other given contexts sharing at least
    one unit with it (the co-resident set whose busy lanes contend for
    the same SMs under OS > 1)."""
    out: Dict[CtxKey, List[CtxKey]] = {}
    for a in contexts:
        out[a.index] = [b.index for b in contexts
                        if b.index != a.index and a.units & b.units]
    return out
