"""Stage-level ready queue: 8 fixed priority levels + EDF inside each level
(paper §IV-B2).

Level bits (0 = most urgent first):
  bit2  task priority   (HP above LP)            -- ablation: no_fixed
  bit1  last stage of the task                   -- ablation: no_last
  bit0  predecessor stage missed its virtual dl  -- ablation: no_prior
EDF tie-break on the stage's absolute virtual deadline.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import List, Optional, Tuple

from ..runtime.contention import batch_cost
from .mret import StageMret
from .task import HP, StageInstance

_seq = itertools.count()


@dataclasses.dataclass
class QueueConfig:
    no_last: bool = False
    no_prior: bool = False
    no_fixed: bool = False


def stage_level(inst: StageInstance, qcfg: QueueConfig) -> int:
    hp_bit = 0 if (inst.task.priority == HP or qcfg.no_fixed) else 1
    last_bit = 0 if (inst.job.is_last_stage() and not qcfg.no_last) else 1
    prior_bit = 0 if (inst.job.vdl_missed_prev and not qcfg.no_prior) else 1
    return hp_bit * 4 + last_bit * 2 + prior_bit


class StageQueue:
    """One ready queue (per context for MPS*, global for STR)."""

    def __init__(self, qcfg: Optional[QueueConfig] = None):
        self.qcfg = qcfg or QueueConfig()
        self._heap: List[Tuple[tuple, StageInstance]] = []
        # memoized backlog_ms (see below): version counts structural
        # mutations; the cache key pairs it with the process-wide MRET
        # generation so estimator updates invalidate it too
        self._version = 0
        self._backlog_key: Tuple[int, int] = (-1, -1)
        self._backlog_total = 0.0
        # dispatch hot-set hookup (see register_hot)
        self._hot: Optional[set] = None
        self._hot_key = None

    def register_hot(self, key, hot: set) -> None:
        """Join the scheduler's dispatch index: the queue keeps ``key``
        in ``hot`` exactly while it holds work, so the engine's dispatch
        loop can skip every context with an empty queue instead of
        probing each free lane (fleet runs: hundreds of probes/event)."""
        self._hot_key = key
        self._hot = hot
        if self._heap:
            hot.add(key)
        else:
            hot.discard(key)

    def touch(self) -> None:
        """Invalidate the memoized backlog total after an in-place
        mutation the queue cannot see (a queued instance's ``cost_b``
        refresh on batch coalesce/detach)."""
        self._version += 1

    def push(self, inst: StageInstance) -> None:
        if inst.smret is None:
            job = inst.job
            mret = job.task.mret
            if mret is not None:     # bare tasks in unit tests carry none
                inst.smret = mret.stages[job.stage_idx]
                inst.cost_b = batch_cost(inst.profile, job.n_inputs)
        key = (stage_level(inst, self.qcfg), inst.virtual_deadline_ms,
               next(_seq))
        heapq.heappush(self._heap, (key, inst))
        self._version += 1
        if self._hot is not None:
            self._hot.add(self._hot_key)

    def pop(self) -> Optional[StageInstance]:
        if not self._heap:
            return None
        self._version += 1
        out = heapq.heappop(self._heap)[1]
        if not self._heap and self._hot is not None:
            self._hot.discard(self._hot_key)
        return out

    def peek(self) -> Optional[StageInstance]:
        return self._heap[0][1] if self._heap else None

    def find_inst(self, job) -> Optional[StageInstance]:
        """The queued instance of ``job``'s current stage, if any (a job
        has at most one: stages are sequential). None means the stage is
        executing on a lane (or completing this instant)."""
        for _, inst in self._heap:
            if inst.job is job:
                return inst
        return None

    def remove(self, inst: StageInstance) -> bool:
        """Remove one queued instance (cancellation path). Pop order of
        the survivors is unchanged: ordering is fully determined by the
        (level, vdl, seq) keys, which heapify preserves."""
        for i, (_, it) in enumerate(self._heap):
            if it is inst:
                last = self._heap.pop()
                if i < len(self._heap):
                    self._heap[i] = last
                    heapq.heapify(self._heap)
                self._version += 1
                if not self._heap and self._hot is not None:
                    self._hot.discard(self._hot_key)
                return True
        return False

    def __len__(self) -> int:
        return len(self._heap)

    def instances(self) -> List[StageInstance]:
        """Snapshot of queued instances (heap order, NOT pop order) —
        the degradation controller's emergency-shed enumeration."""
        return [inst for _, inst in self._heap]

    def drain(self):
        """Remove and return all queued stages (fault recovery path)."""
        items = [inst for _, inst in self._heap]
        self._heap = []
        self._version += 1
        if self._hot is not None:
            self._hot.discard(self._hot_key)
        return items

    def backlog_ms(self) -> float:
        """Sum of MRET of queued stages (migration target estimation);
        batched stages cost b/g(b) x their normalized MRET. Uses the
        per-instance cached estimator/cost (see StageInstance): same
        floats, same left-to-right order, none of the property chains.

        Memoized on (queue version, StageMret.generation): migration
        candidate scans call this once per live context per straggler
        kill, and between queue/estimator mutations the recompute would
        run the identical loop over identical floats — the cached total
        IS that loop's result, bit for bit."""
        key = (self._version, StageMret.generation)
        if key == self._backlog_key:   # dsan: ignore[DSAN003] stamp identity
            return self._backlog_total
        total = 0.0
        for _, inst in self._heap:
            total += inst.smret.value() * inst.cost_b
        self._backlog_key = key
        self._backlog_total = total
        return total
