"""Dynamic deadline-aware batching (beyond-paper; §VI-H made real).

The paper emulates "DARIS + batching" by statically pre-scaling arrival
rates (``table2_taskset(batch=b, load_scale=1/b)``) — no batch is ever
*formed* at runtime. This subsystem closes that gap the way D-STACK
(Dhakal et al.) and Dynamic Space-Time Scheduling (Jain et al.) compose
batching with spatial partitioning: while a job of task τ is still queued
at its first stage, later releases of τ may *join* it instead of becoming
jobs of their own, bounded by

  * ``max_batch``     — the widest batch a single job may carry;
  * the earliest member's virtual deadline — a release joins only if the
    enlarged batch is still predicted to meet the head's stage-0 virtual
    deadline, or the head is already past saving (throughput mode under
    overload, where waiting costs nothing);
  * ``max_wait_ms``   — an optional hard cap on how long the head may
    keep accumulating members;
  * admission (Eq. 12) — joining charges the *incremental* batched
    utilization, so batching never sneaks load past the admission test.

``scope`` picks the coalescing unit. ``"model"`` (default, the serving
semantics) batches releases of any task with an identical stage profile,
priority, and period — Table II's N periodic streams of one DNN are one
model, and that is the population a GPU serving system batches over.
``"task"`` restricts joining to the exact same arrival stream.

The batched job executes each stage once over ``n_inputs`` inputs; the
speedup curve lives in ``runtime.contention`` (calibrated from Table I
gains via ``serving.profiles``). ``BatchCoalescer`` is pure bookkeeping:
it tracks, per coalescing group, the queued stage-0 instance that new
releases may still join. The join *decision* (deadline + admission math)
lives in ``DarisScheduler._try_coalesce``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, Optional

from .task import StageInstance, Task


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Knobs for dynamic batch formation (``ServerConfig.batching``)."""
    max_batch: int = 8
    max_wait_ms: Optional[float] = None   # None = bounded by deadline only
    scope: str = "model"                  # "model" | "task"

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_wait_ms is not None and self.max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, "
                             f"got {self.max_wait_ms}")
        if self.scope not in ("model", "task"):
            raise ValueError(f"scope must be 'model' or 'task', "
                             f"got {self.scope!r}")


class BatchCoalescer:
    """Registry of open batch heads, one per coalescing group.

    A *head* is a stage-0 ``StageInstance`` that is still sitting in a
    ready queue: releases of the same group may coalesce into its job.
    Registration is closed the moment the instance is popped for dispatch
    (``DarisScheduler.next_for_lane``) — a running stage can never grow.
    A newly enqueued stage-0 job replaces its group's head: the newest
    head has the latest release, hence the most joining slack.
    """

    def __init__(self, policy: BatchPolicy):
        self.policy = policy
        self._heads: Dict[Hashable, StageInstance] = {}
        self._keys: Dict[int, Hashable] = {}    # task.index -> group key

    def key_of(self, task: Task) -> Hashable:
        key = self._keys.get(task.index)
        if key is None:
            if self.policy.scope == "task":
                key = task.index
            else:
                # same model = same numeric profile (stage names carry the
                # stream tag, so they are deliberately excluded)
                spec = task.spec
                key = (spec.priority, spec.period_ms,
                       tuple((s.t_alone_ms, s.n_sat, s.mem_frac,
                              s.overhead_ms, s.batch_gain)
                             for s in spec.stages))
            self._keys[task.index] = key
        return key

    def register(self, task: Task, inst: StageInstance) -> None:
        self._heads[self.key_of(task)] = inst

    def head(self, task: Task) -> Optional[StageInstance]:
        return self._heads.get(self.key_of(task))

    def close(self, task: Task) -> None:
        self._heads.pop(self.key_of(task), None)

    def on_pop(self, inst: StageInstance) -> None:
        """Called for every dispatched instance: dispatch seals the batch."""
        key = self.key_of(inst.task)
        if self._heads.get(key) is inst:
            del self._heads[key]
