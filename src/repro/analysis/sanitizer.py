"""DSAN runtime invariant auditor.

Every accounting identity the paper's math relies on is maintained
*incrementally* somewhere in the stack: Eq. 12 admission charges unwind
on cancel, MRET window maxima and task sums are memoized with
invalidate-on-observe, the LaneMap keeps free/busy indexes beside the
lane dict, StageQueue heaps cache per-instance estimator/cost fields,
and the cluster layer shares one lane/queue/job namespace across N
workers. The auditor recomputes all of it from scratch and cross-checks
the incremental state at a configurable cadence:

* Eq. 12 per-context utilization vs. a fresh sum over active jobs
  (including batch ``cost_b`` and cancel unwinds), recomputed from raw
  MRET windows — bypassing every memo.
* LaneMap ``_free``/``_busy_by_ctx``/``_dead`` forming an exact
  partition of the lane table, consistent with context liveness.
* StageQueue heap order, key correctness, and membership vs. the
  active-job table (every queued stage belongs to a live job on that
  context; every live job has exactly one live stage instance).
* Memoized ``StageMret._value`` / ``TaskMret._total`` /
  ``StageInstance.smret``/``cost_b`` / ``backlog_ms`` vs. recomputation.
* Virtual-clock monotonicity and timeline event-order legality
  (FAULT-before-RECONFIG, CANCEL-after-RELEASE at equal timestamps) —
  back-dated open-loop releases are *legal* (PoissonArrival pushes
  past-due successors by design), so legality is generation-qualified:
  a pop is a violation only if a larger key was popped while this event
  was already sitting in the heap.
* Cluster shared-table identity, ``_state_dev`` hygiene, per-device
  task registration, dead-device context liveness.
* Metrics conservation: admitted == completed + cancelled-retired +
  live, per priority — plus engine-vs-scheduler counter mirrors, handle
  status partition, and per-tenant submitted == completed + cancelled +
  rejected + pending.

Violations raise :class:`SanitizerViolation` carrying the divergent
values and the event cursor (step/pop counts, clock, last timeline
event); when ``DARIS_SANITIZE_REPORT_DIR`` is set each violation is
also written there as JSON (the CI artifact hook).

All checks are read-only up to idempotent memo fills (``value()`` on an
already-consistent estimator), so an audited run is bit-identical to an
unaudited one — the golden-fixture suites assert exactly that.
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, List, Optional

from ..core.scheduler import DarisScheduler
from ..core.stage_queue import stage_level
from ..core.task import HP, LP
from ..core.metrics import tenant_stats
from ..runtime.contention import batch_cost
from ..runtime.engine_core import _NON_WORK, SubmitHandle

_KIND_NAMES = ("RELEASE", "CANCEL", "FAULT", "FAIL_DEV", "ADD_CTX",
               "RECONFIG", "AUTOSCALE", "RETRY", "WATCHDOG", "CHAOS",
               "DEGRADE")
# the engine's own never-early tolerance (engine_core._step pop condition)
_EARLY_SLACK_MS = 1e-6

_HANDLE_STATUSES = frozenset((
    SubmitHandle.PENDING, SubmitHandle.REJECTED, SubmitHandle.QUEUED,
    SubmitHandle.RUNNING, SubmitHandle.COMPLETED, SubmitHandle.MISSED,
    SubmitHandle.CANCELLED, SubmitHandle.ABORTED))


def _differs(expected: float, actual: float) -> bool:
    """Exact inequality. The sanitizer compares a memo against the very
    float expression that would refill it — same values, same operation
    order — so bit-equality is the contract, not a tolerance."""
    return expected != actual


def _fresh_stage_value(s) -> float:
    """``StageMret.value()`` recomputed from the raw window, no memo."""
    return max(s.window) if s.window else s.afet_ms


def _fresh_task_mret(m) -> float:
    """``TaskMret.task_mret()`` recomputed from raw windows, no memo."""
    return sum(_fresh_stage_value(s) for s in m.stages)


class SanitizerViolation(AssertionError):
    """A scheduler invariant failed its from-scratch recomputation.

    Carries the check name, the divergent expected/actual values, and
    the event cursor — enough to localize the drift without re-running
    under a debugger."""

    def __init__(self, check: str, message: str, *,
                 expected=None, actual=None,
                 cursor: Optional[Dict] = None):
        self.check = check
        self.expected = expected
        self.actual = actual
        self.cursor = dict(cursor or {})
        detail = f"DSAN {check}: {message}"
        if expected is not None or actual is not None:
            detail += f"\n  expected: {expected!r}\n  actual:   {actual!r}"
        if self.cursor:
            cur = ", ".join(f"{k}={v}" for k, v in
                            sorted(self.cursor.items()))
            detail += f"\n  cursor:   {cur}"
        super().__init__(detail)


class Sanitizer:
    """Runtime invariant auditor for one :class:`EngineCore` run.

    ``level=1`` audits every ``cadence`` engine steps (default 256);
    ``level>=2`` audits every step. Event hooks (push/pop/release/
    cancel/done) are O(1) and always on; the full audit is O(state).

    Environment activation (``Sanitizer.from_env``)::

        DARIS_SANITIZE=1|2          level (anything non-empty, non-0)
        DARIS_SANITIZE_CADENCE=N    audit every N steps (overrides level)
        DARIS_SANITIZE_REPORT_DIR=d write violation reports as JSON
    """

    DEFAULT_CADENCE = 256

    def __init__(self, level: int = 1, cadence: Optional[int] = None,
                 report_dir: Optional[str] = None):
        self.level = max(int(level), 1)
        if cadence is None:
            cadence = 1 if self.level >= 2 else self.DEFAULT_CADENCE
        self.cadence = max(int(cadence), 1)
        self.report_dir = report_dir
        self.steps = 0
        self.audits = 0
        self.violations = 0
        self._last_now = -math.inf
        self._last_event = None          # (t_ms, kind name) of last pop
        # event-order legality: heap-entry seq -> pop generation at push
        self._pending: Dict[int, int] = {}
        self._pops = 0
        self._max_key: Optional[tuple] = None   # largest (t, kind, seq) popped
        self._max_key_pop = 0                   # pop index that popped it
        # conservation mirrors (per priority), fed by the engine hooks
        self.admitted: Dict[int, int] = {HP: 0, LP: 0}
        self.coalesced_joins: Dict[int, int] = {HP: 0, LP: 0}
        self.rejected: Dict[int, int] = {HP: 0, LP: 0}
        self.completed: Dict[int, int] = {HP: 0, LP: 0}
        self.retired: Dict[int, int] = {HP: 0, LP: 0}   # whole-job cancels
        self.cancelled_subs: Dict[int, int] = {HP: 0, LP: 0}
        # chaos-layer give-ups (engine _abort_job): a fourth terminal
        # bucket in the job-conservation law
        self.aborted: Dict[int, int] = {HP: 0, LP: 0}

    @classmethod
    def from_env(cls) -> Optional["Sanitizer"]:
        """Build from ``DARIS_SANITIZE*`` variables; None when disabled."""
        raw = os.environ.get("DARIS_SANITIZE", "")
        if raw in ("", "0"):
            return None
        try:
            level = int(raw)
        except ValueError:
            level = 1
        cad = os.environ.get("DARIS_SANITIZE_CADENCE")
        return cls(level=level, cadence=int(cad) if cad else None,
                   report_dir=os.environ.get("DARIS_SANITIZE_REPORT_DIR"))

    # ------------------------------------------------------------- failure
    def _cursor(self, engine=None) -> Dict:
        cur = {"steps": self.steps, "pops": self._pops,
               "audits": self.audits, "level": self.level}
        if self._last_event is not None:
            cur["last_event"] = (f"{self._last_event[1]}"
                                 f"@{self._last_event[0]:.6f}ms")
        if engine is not None:
            cur["now_ms"] = engine.backend.now_ms()
        return cur

    def _fail(self, check: str, message: str, *, expected=None,
              actual=None, engine=None) -> None:
        self.violations += 1
        cursor = self._cursor(engine)
        self._write_report({"check": check, "message": message,
                            "expected": expected, "actual": actual,
                            "cursor": cursor})
        raise SanitizerViolation(check, message, expected=expected,
                                 actual=actual, cursor=cursor)

    def _write_report(self, payload: Dict) -> None:
        d = self.report_dir
        if not d:
            return
        try:
            os.makedirs(d, exist_ok=True)
            path = os.path.join(
                d, f"dsan-{os.getpid()}-{self.violations}.json")
            with open(path, "w", encoding="utf-8") as f:
                json.dump(payload, f, indent=2, sort_keys=True, default=str)
        except OSError:
            pass       # reporting must never mask the violation itself

    # --------------------------------------------------------- event hooks
    def note_push(self, t: float, kind: int, seq: int) -> None:
        self._pending[seq] = self._pops

    def note_pop(self, t: float, kind: int, seq: int, now: float) -> None:
        self._pops += 1
        gen = self._pending.pop(seq, None)
        self._last_event = (t, _KIND_NAMES[kind])
        if t > now + _EARLY_SLACK_MS:
            self._fail(
                "event-never-early",
                f"{_KIND_NAMES[kind]} scheduled for t={t} fired at "
                f"now={now} — the engine dispatched an event before its "
                f"time", expected=f"now >= {t - _EARLY_SLACK_MS}",
                actual=now)
        key = (t, kind, seq)
        # legality: if a LARGER key was already popped while this entry
        # was sitting in the heap, the heap order (time, then kind:
        # RELEASE < CANCEL < FAULT < ... ) was broken. Entries pushed
        # *after* that pop (gen >= pop index) are legal — open-loop
        # Poisson successors are back-dated by design.
        if (self._max_key is not None and key < self._max_key
                and gen is not None and gen < self._max_key_pop):
            self._fail(
                "event-order",
                f"{_KIND_NAMES[kind]} (t={t}, seq={seq}) popped after "
                f"{_KIND_NAMES[self._max_key[1]]} (t={self._max_key[0]}, "
                f"seq={self._max_key[2]}) although both were queued "
                f"together — same-instant kind ordering "
                f"(RELEASE<CANCEL<FAULT<FAIL_DEV<ADD_CTX<RECONFIG) or "
                f"heap integrity is broken",
                expected=f"pop {key} before {self._max_key}",
                actual="reversed")
        if self._max_key is None or key > self._max_key:
            self._max_key = key
            self._max_key_pop = self._pops

    def note_release(self, priority: int, outcome: str) -> None:
        if outcome == "rejected":
            self.rejected[priority] += 1
        elif outcome == "coalesced":
            self.coalesced_joins[priority] += 1
        else:
            self.admitted[priority] += 1

    def note_job_done(self, job) -> None:
        p = job.task.priority
        if job.cancelled:
            self.retired[p] += 1       # in-flight cancel, boundary retire
        else:
            self.completed[p] += 1

    def note_cancel(self, outcome: str, priority: int,
                    job_retired: bool) -> None:
        # "shed" (degradation-controller emergency cancel of a handle-less
        # job) retires the job without any client submission to count
        if outcome in ("cancelled", "cancelling", "detached", "dropped"):
            self.cancelled_subs[priority] += 1
        if job_retired:
            self.retired[priority] += 1    # queued whole-job retirement

    def note_abort(self, priority: int) -> None:
        self.aborted[priority] += 1

    def after_step(self, engine) -> None:
        self.steps += 1
        now = engine.backend.now_ms()
        if now < self._last_now - 1e-9:
            self._fail("clock-monotonicity",
                       "backend clock moved backwards",
                       expected=f">= {self._last_now}", actual=now,
                       engine=engine)
        self._last_now = now
        if self.steps % self.cadence == 0:
            self.audit(engine)

    def on_finalize(self, engine) -> None:
        self.audit(engine)
        self._check_final_metrics(engine)

    # ----------------------------------------------------------- the audit
    def audit(self, engine) -> None:
        """Full from-scratch recomputation of every audited invariant."""
        self.audits += 1
        sched = engine.sched
        now = engine.backend.now_ms()
        self._check_lanes(sched, engine)
        self._check_queues(sched, engine)
        self._check_active_jobs(sched, engine)
        self._check_utilization(sched, now, engine)
        self._check_mret_memos(sched, engine)
        self._check_timeline(engine)
        self._check_backend_sync(sched, engine)
        if hasattr(sched, "workers"):
            self._check_cluster(sched, engine)
        self._check_conservation(sched, engine)
        self._check_handles(engine)

    # ---- lanes ----------------------------------------------------------
    def _check_lanes(self, sched, engine) -> None:
        lanes = sched.lanes
        free, busy_by_ctx, dead = lanes._free, lanes._busy_by_ctx, lanes._dead
        contexts = sched.contexts
        for lane, inst in lanes.items():
            ctx = lane[0]
            if ctx not in contexts:
                self._fail("lane-orphan-context",
                           f"lane {lane} references unknown context {ctx}",
                           engine=engine)
            if inst is None:
                want_free = ctx not in dead
                if (lane in free) != want_free:
                    self._fail(
                        "lanemap-free-index",
                        f"empty lane {lane} (ctx dead={ctx in dead}) "
                        f"free-index membership is wrong",
                        expected=want_free, actual=lane in free,
                        engine=engine)
                if lane in busy_by_ctx.get(ctx, {}):
                    self._fail("lanemap-busy-index",
                               f"empty lane {lane} still in busy index",
                               engine=engine)
            else:
                if lane in free:
                    self._fail("lanemap-free-index",
                               f"busy lane {lane} listed free",
                               engine=engine)
                if busy_by_ctx.get(ctx, {}).get(lane) is not inst:
                    self._fail(
                        "lanemap-busy-index",
                        f"busy lane {lane} missing or aliased in busy "
                        f"index", engine=engine)
                if inst.lane != lane:
                    self._fail(
                        "lanemap-inst-backref",
                        f"instance on lane {lane} believes it is on "
                        f"{inst.lane}", expected=lane, actual=inst.lane,
                        engine=engine)
        for ctx, busy in busy_by_ctx.items():
            for lane, inst in busy.items():
                if lanes.get(lane) is not inst:
                    self._fail("lanemap-busy-index",
                               f"busy index entry {lane} disagrees with "
                               f"lane table", engine=engine)
        for lane in free:
            if lane not in lanes or lanes[lane] is not None:
                self._fail("lanemap-free-index",
                           f"free index entry {lane} is not an empty lane",
                           engine=engine)
        for c in contexts:
            if c.alive and c.index in dead:
                self._fail("lanemap-dead-index",
                           f"live context {c.index} marked dead in "
                           f"LaneMap", engine=engine)
            if not c.alive and c.index not in dead:
                self._fail("lanemap-dead-index",
                           f"retired context {c.index} never retired in "
                           f"LaneMap", engine=engine)

    # ---- queues ---------------------------------------------------------
    def _check_queues(self, sched, engine) -> None:
        for k, q in sched.queues.items():
            heap = q._heap
            for i in range(1, len(heap)):
                if heap[i][0] < heap[(i - 1) // 2][0]:
                    self._fail(
                        "stagequeue-heap-order",
                        f"queue {k} heap property broken at index {i}",
                        expected=f">= {heap[(i - 1) // 2][0]}",
                        actual=heap[i][0], engine=engine)
            for key, inst in heap:
                job = inst.job
                level = stage_level(inst, q.qcfg)
                if key[0] != level:
                    self._fail(
                        "stagequeue-stale-level",
                        f"queued stage of {job.task.name} holds level "
                        f"{key[0]} but live state derives {level} "
                        f"(vdl_missed_prev / last-stage bit drifted "
                        f"after push)", expected=level, actual=key[0],
                        engine=engine)
                if _differs(key[1], inst.virtual_deadline_ms):
                    self._fail(
                        "stagequeue-stale-vdl",
                        f"queued stage of {job.task.name} sorted by vdl "
                        f"{key[1]} but carries {inst.virtual_deadline_ms} "
                        f"(mutated without re-push)",
                        expected=inst.virtual_deadline_ms, actual=key[1],
                        engine=engine)
                if inst.lane is not None:
                    self._fail("stagequeue-running-member",
                               f"queued stage of {job.task.name} claims "
                               f"lane {inst.lane}", engine=engine)
                if job.ctx != k:
                    self._fail(
                        "stagequeue-wrong-home",
                        f"stage of {job.task.name} queued on {k} but its "
                        f"job lives on {job.ctx}", expected=k,
                        actual=job.ctx, engine=engine)
                if job not in sched.active_jobs.get(k, {}):
                    self._fail(
                        "stagequeue-dead-member",
                        f"queued stage of {job.task.name} has no active "
                        f"job on {k} (leak or double retirement)",
                        engine=engine)
                if job.cancelled or job.finish_ms is not None:
                    self._fail(
                        "stagequeue-zombie",
                        f"finished/cancelled job of {job.task.name} "
                        f"still queued on {k}", engine=engine)
                self._check_inst_cache(inst, engine)
            self._check_backlog(q, k, engine)

    def _check_backlog(self, q, k, engine) -> None:
        fresh = 0.0
        for _, inst in q._heap:
            if inst.smret is None:
                return       # bare unit-test tasks carry no estimator
            fresh += (_fresh_stage_value(inst.smret)
                      * batch_cost(inst.profile, inst.job.n_inputs))
        actual = q.backlog_ms()
        if _differs(fresh, actual):
            self._fail(
                "backlog-memo",
                f"queue {k} backlog_ms diverges from scratch "
                f"recomputation (stale smret/cost_b cache)",
                expected=fresh, actual=actual, engine=engine)

    def _check_inst_cache(self, inst, engine) -> None:
        job = inst.job
        m = job.task.mret
        if inst.smret is None or m is None:
            return
        if inst.smret is not m.stages[job.stage_idx]:
            self._fail(
                "inst-smret-alias",
                f"stage instance of {job.task.name} caches an estimator "
                f"that is not its task's stage-{job.stage_idx} StageMret",
                engine=engine)
        expect = batch_cost(inst.profile, job.n_inputs)
        if _differs(inst.cost_b, expect):
            self._fail(
                "inst-cost-b",
                f"stage instance of {job.task.name} caches cost_b for a "
                f"different batch size than its job carries "
                f"(n_inputs={job.n_inputs}; detach/join without refresh)",
                expected=expect, actual=inst.cost_b, engine=engine)

    # ---- active jobs ----------------------------------------------------
    def _check_active_jobs(self, sched, engine) -> None:
        places: Dict[int, List] = {}
        for k, q in sched.queues.items():
            for _, inst in q._heap:
                places.setdefault(id(inst.job), []).append(("queued", k))
        for lane, inst in sched.lanes.items():
            if inst is not None:
                places.setdefault(id(inst.job), []).append(("lane", lane))
                self._check_inst_cache(inst, engine)
        active_ids = set()
        for k, jobs in sched.active_jobs.items():
            for job in jobs:
                active_ids.add(id(job))
                if job.ctx != k:
                    self._fail(
                        "active-jobs-wrong-home",
                        f"job of {job.task.name} registered under {k} "
                        f"but claims ctx {job.ctx}", expected=k,
                        actual=job.ctx, engine=engine)
                if job.finish_ms is not None:
                    self._fail(
                        "active-jobs-zombie",
                        f"finished job of {job.task.name} still active "
                        f"on {k}", engine=engine)
                where = places.get(id(job), [])
                if job.job_id in engine._retry_wait:
                    # parked between a transient stage fault and its
                    # RETRY event: the job legally has NO live instance
                    # (the pending RETRY is its work token)
                    if where:
                        self._fail(
                            "active-jobs-retry-wait",
                            f"retry-waiting job of {job.task.name} still "
                            f"has live stage instance(s) at {where}",
                            expected=0, actual=where, engine=engine)
                elif len(where) != 1:
                    self._fail(
                        "active-jobs-instance-count",
                        f"active job of {job.task.name} (stage "
                        f"{job.stage_idx}) must have exactly one live "
                        f"stage instance (queued xor on a lane)",
                        expected=1, actual=where or 0, engine=engine)
        for jid, where in places.items():
            if jid not in active_ids:
                self._fail(
                    "active-jobs-leak",
                    f"stage instance(s) at {where} belong to a job "
                    f"missing from every active set (retired without "
                    f"draining its work)", engine=engine)

    # ---- utilization (Eq. 12) ------------------------------------------
    def _worker_of(self, sched, k):
        return sched.workers[k[0]] if hasattr(sched, "workers") else sched

    def _check_utilization(self, sched, now: float, engine) -> None:
        for k in sched.active_jobs:
            w = self._worker_of(sched, k)
            u = 0.0
            computable = True
            for j in sched.active_jobs[k]:
                t = j.task
                if t.priority != LP:
                    continue
                if t.mret is None:
                    computable = False
                    break
                u += (_fresh_task_mret(t.mret) / t.spec.period_ms
                      * DarisScheduler.spec_batch_cost(t.spec, j.n_inputs))
            if computable:
                fresh = u if w.speed == 1.0 else u / w.speed
                actual = sched.util_lp_active(k, now)
                if _differs(fresh, actual):
                    self._fail(
                        "eq12-lp-utilization",
                        f"util_lp_active({k}) diverges from a fresh sum "
                        f"over active jobs (stale MRET memo or admission "
                        f"charge not unwound)", expected=fresh,
                        actual=actual, engine=engine)
            u = 0.0
            computable = True
            for t in w.tasks:
                if t.ctx == k and t.priority == HP:
                    if t.mret is None:
                        computable = False
                        break
                    u += _fresh_task_mret(t.mret) / t.spec.period_ms
            if computable:
                fresh = u if w.speed == 1.0 else u / w.speed
                actual = sched.util_hp_total(k, now)
                if _differs(fresh, actual):
                    self._fail(
                        "eq11-hp-utilization",
                        f"util_hp_total({k}) diverges from a fresh sum "
                        f"over registered tasks", expected=fresh,
                        actual=actual, engine=engine)

    # ---- MRET memos -----------------------------------------------------
    def _check_mret_memos(self, sched, engine) -> None:
        for t in sched.tasks:
            m = t.mret
            if m is None:
                continue
            for si, s in enumerate(m.stages):
                if s._value is None:
                    continue
                fresh = _fresh_stage_value(s)
                if _differs(s._value, fresh):
                    self._fail(
                        "mret-stage-memo",
                        f"{t.name} stage {si} StageMret._value diverges "
                        f"from its window (mutation without invalidate)",
                        expected=fresh, actual=s._value, engine=engine)
            if m._total is not None:
                fresh = sum(_fresh_stage_value(s) for s in m.stages)
                if _differs(m._total, fresh):
                    self._fail(
                        "mret-total-memo",
                        f"{t.name} TaskMret._total diverges from its "
                        f"stage sum (observe path skipped the "
                        f"invalidation)", expected=fresh, actual=m._total,
                        engine=engine)

    # ---- engine timeline ------------------------------------------------
    def _check_timeline(self, engine) -> None:
        tl = engine._timeline
        for i in range(1, len(tl)):
            if tl[i][:3] < tl[(i - 1) // 2][:3]:
                self._fail(
                    "timeline-heap-order",
                    f"engine timeline heap property broken at index {i}",
                    expected=f">= {tl[(i - 1) // 2][:3]}",
                    actual=tl[i][:3], engine=engine)
        n_work = sum(1 for e in tl if e[1] not in _NON_WORK)
        if n_work != engine._work_events:
            self._fail(
                "timeline-work-count",
                "engine _work_events counter diverges from the pending "
                "work-representing timeline entries (idle detection "
                "would stall or finish early)", expected=n_work,
                actual=engine._work_events, engine=engine)

    # ---- backend <-> scheduler sync ------------------------------------
    def _check_backend_sync(self, sched, engine) -> None:
        running = getattr(engine.backend, "running", None)
        if not isinstance(running, dict):
            return          # wall-clock backend: no introspectable set
        for lane, entry in running.items():
            if sched.lanes.get(lane) is not entry[0]:
                self._fail(
                    "backend-lane-sync",
                    f"backend executes an instance on {lane} that the "
                    f"LaneMap does not show there (ghost execution)",
                    engine=engine)
        for ctx, busy in sched.lanes._busy_by_ctx.items():
            for lane in busy:
                if lane not in running:
                    self._fail(
                        "backend-lane-sync",
                        f"LaneMap shows {lane} busy but the backend has "
                        f"no running entry for it (lost completion)",
                        engine=engine)

    # ---- cluster --------------------------------------------------------
    def _check_cluster(self, sched, engine) -> None:
        for d, w in sched.workers.items():
            for attr in ("lanes", "queues", "active_jobs", "rejections",
                         "rejected_counts"):
                if getattr(w, attr) is not getattr(sched, attr):
                    self._fail(
                        "cluster-shared-table",
                        f"worker {d} holds a private {attr} table — the "
                        f"shared-namespace contract is broken",
                        engine=engine)
            for t in w.tasks:
                if not isinstance(t.ctx, tuple) or t.ctx[0] != d:
                    self._fail(
                        "cluster-task-registration",
                        f"task {t.name} registered on device {d} but "
                        f"homed at ctx {t.ctx!r}", expected=d,
                        actual=t.ctx, engine=engine)
            if d in sched._dead_devs:
                alive = [c.index for c in w.contexts if c.alive]
                if alive:
                    self._fail(
                        "cluster-dead-device",
                        f"dead device {d} still has live contexts "
                        f"{alive}", engine=engine)
        worker_ids = {id(t) for w in sched.workers.values()
                      for t in w.tasks}
        global_ids = {id(t) for t in sched.tasks}
        if worker_ids != global_ids:
            self._fail(
                "cluster-task-registration",
                "union of per-worker task lists diverges from the global "
                "task list (a move lost or duplicated a registration)",
                expected=len(global_ids), actual=len(worker_ids),
                engine=engine)
        live_job_ids = {job.job_id for jobs in sched.active_jobs.values()
                        for job in jobs}
        for job_id, dev in sched._state_dev.items():
            if job_id not in live_job_ids:
                self._fail(
                    "cluster-state-dev-leak",
                    f"_state_dev holds inter-stage state for job "
                    f"{job_id} which is no longer active",
                    engine=engine)
            # a dead device is a LEGAL state home (replay re-pays the
            # transfer), but the device id must at least exist
            if dev not in sched.workers:
                self._fail(
                    "cluster-state-dev-unknown",
                    f"_state_dev points job {job_id} at device {dev} "
                    f"which was never minted", engine=engine)

    # ---- conservation ---------------------------------------------------
    def _check_conservation(self, sched, engine) -> None:
        live = {HP: 0, LP: 0}
        for jobs in sched.active_jobs.values():
            for j in jobs:
                live[j.task.priority] += 1
        m = engine.metrics
        for p, name in ((HP, "HP"), (LP, "LP")):
            want = (self.completed[p] + self.retired[p] + self.aborted[p]
                    + live[p])
            if self.admitted[p] != want:
                self._fail(
                    "job-conservation",
                    f"{name}: admitted != completed + cancelled-retired "
                    f"+ aborted + live ({self.completed[p]} + "
                    f"{self.retired[p]} + {self.aborted[p]} + {live[p]}) "
                    f"— a job leaked or retired twice",
                    expected=want, actual=self.admitted[p], engine=engine)
            if m.aborted[p] != self.aborted[p]:
                self._fail(
                    "metrics-aborted-mirror",
                    f"{name}: engine metrics.aborted diverges from the "
                    f"abort hook count", expected=self.aborted[p],
                    actual=m.aborted[p], engine=engine)
            if m.completed[p] != self.completed[p]:
                self._fail(
                    "metrics-completed-mirror",
                    f"{name}: engine metrics.completed diverges from the "
                    f"completion hook count", expected=self.completed[p],
                    actual=m.completed[p], engine=engine)
            if m.cancelled[p] != self.cancelled_subs[p]:
                self._fail(
                    "metrics-cancelled-mirror",
                    f"{name}: engine metrics.cancelled diverges from the "
                    f"cancel hook count", expected=self.cancelled_subs[p],
                    actual=m.cancelled[p], engine=engine)
            if sched.rejected_counts[p] != self.rejected[p]:
                self._fail(
                    "metrics-rejected-mirror",
                    f"{name}: scheduler rejected_counts diverges from "
                    f"the engine-side rejection count",
                    expected=self.rejected[p],
                    actual=sched.rejected_counts[p], engine=engine)
        joins = sum(self.coalesced_joins.values())
        if sched.coalesced != joins:
            self._fail(
                "metrics-coalesced-mirror",
                "scheduler coalesced counter diverges from the "
                "engine-side join count", expected=joins,
                actual=sched.coalesced, engine=engine)

    def _check_handles(self, engine) -> None:
        cancelled = {HP: 0, LP: 0}
        for h in engine._all_handles:
            if h.status not in _HANDLE_STATUSES:
                self._fail(
                    "handle-status-vocabulary",
                    f"handle for {h.task.name} carries unknown status "
                    f"{h.status!r}", engine=engine)
            if h.status == SubmitHandle.CANCELLED:
                cancelled[h.task.priority] += 1
        for p, name in ((HP, "HP"), (LP, "LP")):
            if cancelled[p] != engine.metrics.cancelled[p]:
                self._fail(
                    "handle-cancel-partition",
                    f"{name}: cancelled handle count diverges from "
                    f"metrics.cancelled (a handle changed status without "
                    f"accounting)", expected=engine.metrics.cancelled[p],
                    actual=cancelled[p], engine=engine)
        stats = tenant_stats(engine._all_handles)
        for tenant, d in stats.items():
            whole = (d["completed"] + d["cancelled"] + d["rejected"]
                     + d["aborted"] + d["pending"])
            if d["submitted"] != whole:
                self._fail(
                    "tenant-conservation",
                    f"tenant {tenant!r}: submitted != completed + "
                    f"cancelled + rejected + aborted + pending",
                    expected=whole, actual=d["submitted"], engine=engine)

    # ---- finalize-only --------------------------------------------------
    def _check_final_metrics(self, engine) -> None:
        m = engine.metrics
        live = {HP: 0, LP: 0}
        for jobs in engine.sched.active_jobs.values():
            for j in jobs:
                live[j.task.priority] += 1
        for p, name in ((HP, "HP"), (LP, "LP")):
            if m.unfinished[p] != live[p]:
                self._fail(
                    "final-unfinished-sweep",
                    f"{name}: metrics.unfinished diverges from the jobs "
                    f"still active at finalize", expected=live[p],
                    actual=m.unfinished[p], engine=engine)
            if m.rejected[p] != self.rejected[p]:
                self._fail(
                    "final-rejected",
                    f"{name}: finalized metrics.rejected diverges from "
                    f"the release-hook rejection count",
                    expected=self.rejected[p], actual=m.rejected[p],
                    engine=engine)
        if m.per_device:
            for p, name in ((HP, "HP"), (LP, "LP")):
                dev_total = sum(s["completed"][p]
                                for s in m.per_device.values())
                if dev_total != m.completed[p]:
                    self._fail(
                        "final-per-device-completed",
                        f"{name}: per-device completed sums diverge from "
                        f"the global counter", expected=m.completed[p],
                        actual=dev_total, engine=engine)
                dev_missed = sum(s["missed"][p]
                                 for s in m.per_device.values())
                if dev_missed != m.missed[p]:
                    self._fail(
                        "final-per-device-missed",
                        f"{name}: per-device missed sums diverge from "
                        f"the global counter", expected=m.missed[p],
                        actual=dev_missed, engine=engine)
