"""DSAN custom lint pass — ``python -m repro.analysis.lint [paths]``.

AST-based rules for the failure modes this codebase has actually
shipped (see CHANGES.md review rounds), which generic linters cannot
know about:

* **DSAN001** — mutation of a memoized ``window`` deque
  (``append``/``pop``/``clear``/...) in a function that never
  invalidates (``.invalidate()``/``.observe()`` call or an assignment
  to ``_value``/``_total``). Stale MRET memos silently corrupt Eq. 11/12
  admission.
* **DSAN002** — an inline ``Task(...)``/``Job(...)``/
  ``StageInstance(...)`` constructed directly as a dict subscript key or
  ``in``-test operand. These are ``eq=False`` identity dataclasses: a
  fresh instance never matches, the lookup is dead code.
* **DSAN003** — ``==``/``!=`` between time/utilization quantities
  (``*_ms``, ``util*``, ``*mret*``, ``*deadline*``, ``backlog*``,
  ``eta``...). Derived floats want tolerances; exact stamp identity is
  legal but must be declared with ``# dsan: ignore[DSAN003]``.
* **DSAN004** — wall-clock reads (``time.time``/``datetime.now``/...)
  inside deterministic sim paths (``core/``, ``cluster/``,
  ``runtime/engine_core.py``). Virtual time comes from the backend;
  wall-clock there breaks replay and the golden fixtures.
* **DSAN005** — bare ``.remove()`` on an identity-semantic collection
  (``tasks``/``jobs``). ``list.remove`` compares by value; with
  ``eq=False`` elements it happens to degrade to a linear identity
  scan, but the intent must be declared (``# dsan: ignore[DSAN005]``)
  or an O(1) identity container used instead.
* **DSAN006** — a call through an optional hook attribute
  (``self._sanitizer.…(...)`` / ``self._chaos.…(...)``) that no
  enclosing ``is not None`` check guards. The twin-path zero-overhead
  contract keeps these hooks ``None`` unless opted in; an unguarded
  call is an AttributeError waiting for the default path.
* **DSAN007** — an RNG draw from a non-chaos stream inside
  ``repro/chaos/`` code (``np.random.*`` globals, or a ``*rng``
  attribute not owned by ``self``). Chaos must draw only from its own
  seeded ``self.rng`` / ``self.io_rng`` streams — borrowing the sim
  stream breaks the chaos-off bit-identical twin path.

Suppression: ``# dsan: ignore`` (all rules) or
``# dsan: ignore[DSAN003, DSAN005]`` on the offending line.

When ruff / mypy are importable the pass chains them (CI installs
both; the pinned dev container may not have them — they are then
skipped with a note, not an error).
"""
from __future__ import annotations

import argparse
import ast
import importlib.util
import os
import re
import subprocess
import sys
from typing import Iterable, List, NamedTuple, Optional, Set


class Finding(NamedTuple):
    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"{self.rule} {self.message}")


_SUPPRESS = re.compile(r"#\s*dsan:\s*ignore(?:\[([A-Za-z0-9, ]+)\])?")

# names that denote time/utilization quantities (DSAN003)
_TIME_NAME = re.compile(
    r"(_ms$|^now$|^eta$|util|mret|deadline|backlog)", re.IGNORECASE)

# deque mutators that invalidate a sliding-window memo (DSAN001)
_WINDOW_MUTATORS = frozenset((
    "append", "appendleft", "extend", "extendleft", "insert",
    "pop", "popleft", "remove", "clear"))

# identity-semantic (eq=False) dataclass constructors (DSAN002)
_IDENTITY_CLASSES = frozenset(("Task", "Job", "StageInstance"))

# identity-semantic collection names (DSAN005)
_IDENTITY_COLLECTIONS = frozenset(("tasks", "jobs"))

# wall-clock calls (DSAN004): attribute form and from-import form
_WALL_CLOCK_ATTRS = {
    "time": frozenset(("time", "monotonic", "perf_counter",
                       "process_time", "time_ns", "monotonic_ns",
                       "perf_counter_ns", "process_time_ns")),
    "datetime": frozenset(("now", "utcnow", "today")),
}
_WALL_CLOCK_NAMES = frozenset(("monotonic", "perf_counter",
                               "process_time"))

# paths whose code must be wall-clock-free (virtual time only)
_DETERMINISTIC = re.compile(
    r"(^|[/\\])(core|cluster)[/\\]|[/\\]runtime[/\\]engine_core\.py$")

# optional hook attributes gated by the twin-path contract (DSAN006)
_HOOK_ATTRS = frozenset(("_sanitizer", "_chaos"))

# chaos code must draw from its own seeded streams (DSAN007)
_CHAOS_PATH = re.compile(r"(^|[/\\])chaos[/\\]")
_RNG_DRAWS = frozenset((
    "random", "normal", "uniform", "integers", "choice",
    "standard_normal", "lognormal", "exponential", "poisson",
    "shuffle", "permutation"))


def _name_of(node: ast.AST) -> Optional[str]:
    """Best-effort identifier for a comparison operand / receiver."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Subscript):
        sl = node.slice
        if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
            return sl.value
        return _name_of(node.value)
    if isinstance(node, ast.Call):
        return _name_of(node.func)
    return None


def _suppressed(lines: List[str], lineno: int, rule: str) -> bool:
    """Suppression on the flagged line, or on a pure-comment line
    directly above it (for lines with no room left)."""
    if not 1 <= lineno <= len(lines):
        return False
    candidates = [lines[lineno - 1]]
    if lineno >= 2 and lines[lineno - 2].lstrip().startswith("#"):
        candidates.append(lines[lineno - 2])
    for text in candidates:
        m = _SUPPRESS.search(text)
        if m:
            if m.group(1) is None:
                return True
            if rule in {r.strip().upper() for r in m.group(1).split(",")}:
                return True
    return False


class _Checker(ast.NodeVisitor):
    def __init__(self, path: str, lines: List[str]):
        self.path = path
        self.lines = lines
        self.findings: List[Finding] = []
        self.deterministic = bool(_DETERMINISTIC.search(path))
        self.chaos_path = bool(_CHAOS_PATH.search(path))

    def _flag(self, node: ast.AST, rule: str, message: str) -> None:
        if not _suppressed(self.lines, node.lineno, rule):
            self.findings.append(Finding(
                self.path, node.lineno, node.col_offset, rule, message))

    # ---- DSAN001: window mutation without invalidation ------------------
    def _check_memo_mutation(self, fn: ast.AST) -> None:
        mutations: List[ast.Call] = []
        invalidates = False
        for node in ast.walk(fn):
            if node is not fn and isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue       # nested defs are their own scope
            if isinstance(node, ast.Call):
                f = node.func
                if (isinstance(f, ast.Attribute)
                        and f.attr in _WINDOW_MUTATORS
                        and isinstance(f.value, ast.Attribute)
                        and f.value.attr == "window"):
                    mutations.append(node)
                elif (isinstance(f, ast.Attribute)
                      and f.attr in ("invalidate", "observe")):
                    invalidates = True
            elif isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if (isinstance(tgt, ast.Attribute)
                            and tgt.attr in ("_value", "_total")):
                        invalidates = True
        if not invalidates:
            for call in mutations:
                self._flag(
                    call, "DSAN001",
                    "mutates a memoized '.window' without invalidating "
                    "(call .invalidate()/.observe() or reset "
                    "_value/_total in the same function)")

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._check_memo_mutation(node)
        self._check_hook_guards(node)
        self.generic_visit(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._check_memo_mutation(node)
        self._check_hook_guards(node)
        self.generic_visit(node)

    # ---- DSAN006: unguarded optional-hook calls -------------------------
    @staticmethod
    def _hook_in_chain(node: ast.AST) -> Optional[str]:
        """Hook attr name when an attribute chain passes through
        ``<recv>._sanitizer`` / ``<recv>._chaos``."""
        while isinstance(node, ast.Attribute):
            if node.attr in _HOOK_ATTRS:
                return node.attr
            node = node.value
        return None

    def _hook_guards(self, test: ast.AST) -> tuple:
        """(hooks proven non-None when ``test`` is true, when false)."""
        pos: Set[str] = set()
        neg: Set[str] = set()
        if isinstance(test, ast.Compare) and len(test.ops) == 1:
            name = (test.left.attr
                    if isinstance(test.left, ast.Attribute)
                    and test.left.attr in _HOOK_ATTRS else None)
            comp = test.comparators[0]
            if (name and isinstance(comp, ast.Constant)
                    and comp.value is None):
                if isinstance(test.ops[0], ast.IsNot):
                    pos.add(name)
                elif isinstance(test.ops[0], ast.Is):
                    neg.add(name)
        elif isinstance(test, ast.Attribute) and test.attr in _HOOK_ATTRS:
            pos.add(test.attr)      # truthiness guard: `if self._chaos:`
        elif isinstance(test, ast.BoolOp):
            if isinstance(test.op, ast.And):
                for v in test.values:
                    p, _ = self._hook_guards(v)
                    pos |= p
            else:
                for v in test.values:
                    _, n = self._hook_guards(v)
                    neg |= n
        elif isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            p, n = self._hook_guards(test.operand)
            return n, p
        return pos, neg

    @staticmethod
    def _terminates(stmts: List[ast.stmt]) -> bool:
        return bool(stmts) and isinstance(
            stmts[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))

    def _check_hook_guards(self, fn: ast.AST) -> None:
        self._scan_hook_stmts(fn.body, set())

    def _scan_hook_stmts(self, stmts: List[ast.stmt],
                         guarded: Set[str]) -> None:
        guarded = set(guarded)
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue            # own scope, scanned separately
            if isinstance(st, ast.If):
                pos, neg = self._hook_guards(st.test)
                self._scan_hook_expr(st.test, guarded)
                self._scan_hook_stmts(st.body, guarded | pos)
                self._scan_hook_stmts(st.orelse, guarded | neg)
                # `if hook is None: return` proves the tail non-None
                if self._terminates(st.body):
                    guarded |= neg
                if st.orelse and self._terminates(st.orelse):
                    guarded |= pos
                continue
            if isinstance(st, ast.While):
                pos, _ = self._hook_guards(st.test)
                self._scan_hook_expr(st.test, guarded)
                self._scan_hook_stmts(st.body, guarded | pos)
                self._scan_hook_stmts(st.orelse, guarded)
                continue
            if isinstance(st, (ast.For, ast.AsyncFor)):
                self._scan_hook_expr(st.iter, guarded)
                self._scan_hook_stmts(st.body, guarded)
                self._scan_hook_stmts(st.orelse, guarded)
                continue
            if isinstance(st, (ast.With, ast.AsyncWith)):
                for item in st.items:
                    self._scan_hook_expr(item.context_expr, guarded)
                self._scan_hook_stmts(st.body, guarded)
                continue
            if isinstance(st, ast.Try):
                for blk in (st.body, st.orelse, st.finalbody):
                    self._scan_hook_stmts(blk, guarded)
                for h in st.handlers:
                    self._scan_hook_stmts(h.body, guarded)
                continue
            if isinstance(st, ast.Assign):
                for tgt in st.targets:
                    if (isinstance(tgt, ast.Attribute)
                            and tgt.attr in _HOOK_ATTRS):
                        guarded -= {tgt.attr}   # may have been rebound
                self._scan_hook_expr(st.value, guarded)
                continue
            self._scan_hook_expr(st, guarded)

    def _scan_hook_expr(self, node: ast.AST, guarded: Set[str]) -> None:
        if isinstance(node, ast.IfExp):
            pos, neg = self._hook_guards(node.test)
            self._scan_hook_expr(node.test, guarded)
            self._scan_hook_expr(node.body, guarded | pos)
            self._scan_hook_expr(node.orelse, guarded | neg)
            return
        if isinstance(node, ast.BoolOp) and isinstance(node.op, ast.And):
            acc = set(guarded)      # short-circuit: later operands are
            for v in node.values:   # guarded by earlier non-None tests
                self._scan_hook_expr(v, acc)
                p, _ = self._hook_guards(v)
                acc |= p
            return
        if isinstance(node, ast.Call):
            hook = self._hook_in_chain(node.func)
            if hook and hook not in guarded:
                self._flag(
                    node, "DSAN006",
                    f"call through optional hook '{hook}' without an "
                    f"`is not None` guard — the twin-path contract keeps "
                    f"it None unless opted in")
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            self._scan_hook_expr(child, guarded)

    # ---- DSAN002: identity dataclass used as value key ------------------
    @staticmethod
    def _is_identity_ctor(node: ast.AST) -> bool:
        return (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id in _IDENTITY_CLASSES)

    def visit_Subscript(self, node: ast.Subscript) -> None:
        if self._is_identity_ctor(node.slice):
            self._flag(
                node, "DSAN002",
                f"fresh {node.slice.func.id}(...) as a subscript key — "
                f"eq=False dataclasses hash by identity, a new instance "
                f"never matches an existing entry")
        self.generic_visit(node)

    # ---- DSAN002 (in/not-in) + DSAN003 (float == on time) ---------------
    def visit_Compare(self, node: ast.Compare) -> None:
        operands = [node.left] + list(node.comparators)
        for op, right in zip(node.ops, node.comparators):
            if isinstance(op, (ast.In, ast.NotIn)):
                if self._is_identity_ctor(node.left):
                    self._flag(
                        node, "DSAN002",
                        f"fresh {node.left.func.id}(...) in a membership "
                        f"test — eq=False dataclasses compare by "
                        f"identity, this is always False")
            elif isinstance(op, (ast.Eq, ast.NotEq)):
                left = operands[operands.index(right) - 1]
                self._check_time_eq(node, left, right)
        self.generic_visit(node)

    def _check_time_eq(self, node: ast.Compare, left: ast.AST,
                       right: ast.AST) -> None:
        for a, b in ((left, right), (right, left)):
            name = _name_of(a)
            if name is None or not _TIME_NAME.search(name):
                continue
            # comparing against None/str/bool is state inspection, not
            # float arithmetic
            if isinstance(b, ast.Constant) and (
                    b.value is None or isinstance(b.value, (str, bool))):
                return
            self._flag(
                node, "DSAN003",
                f"exact ==/!= on time/utilization quantity '{name}' — "
                f"derived floats need a tolerance; if this is stamp "
                f"identity, declare it with '# dsan: ignore[DSAN003]'")
            return

    # ---- DSAN004: wall clock in deterministic paths ---------------------
    def visit_Call(self, node: ast.Call) -> None:
        if self.deterministic:
            f = node.func
            if isinstance(f, ast.Attribute):
                base = _name_of(f.value)
                if (base in _WALL_CLOCK_ATTRS
                        and f.attr in _WALL_CLOCK_ATTRS[base]):
                    self._flag(
                        node, "DSAN004",
                        f"wall-clock read {base}.{f.attr}() in a "
                        f"deterministic sim path — use the backend's "
                        f"virtual clock (now_ms)")
            elif (isinstance(f, ast.Name)
                  and f.id in _WALL_CLOCK_NAMES):
                self._flag(
                    node, "DSAN004",
                    f"wall-clock read {f.id}() in a deterministic sim "
                    f"path — use the backend's virtual clock (now_ms)")
        self._check_chaos_rng(node)
        self.generic_visit(node)

    # ---- DSAN007: foreign RNG stream in chaos code ----------------------
    def _check_chaos_rng(self, node: ast.Call) -> None:
        if not self.chaos_path:
            return
        f = node.func
        if not isinstance(f, ast.Attribute) or f.attr not in _RNG_DRAWS:
            return
        recv = f.value
        if (isinstance(recv, ast.Attribute) and recv.attr == "random"
                and isinstance(recv.value, ast.Name)
                and recv.value.id in ("np", "numpy")):
            self._flag(
                node, "DSAN007",
                f"np.random.{f.attr}() draws from the global stream "
                f"inside chaos code — use the plan's seeded self.rng / "
                f"self.io_rng")
        elif (isinstance(recv, ast.Attribute) and recv.attr.endswith("rng")
              and not (isinstance(recv.value, ast.Name)
                       and recv.value.id == "self")):
            self._flag(
                node, "DSAN007",
                f"RNG draw from foreign stream '{recv.attr}' inside "
                f"chaos code — chaos must stay on its own seeded "
                f"self.rng / self.io_rng (chaos-off twin paths are "
                f"bit-identical only if no shared stream is consumed)")

    # ---- DSAN005: bare .remove on identity collections ------------------
    def visit_Expr(self, node: ast.Expr) -> None:
        call = node.value
        if (isinstance(call, ast.Call)
                and isinstance(call.func, ast.Attribute)
                and call.func.attr == "remove"):
            recv = _name_of(call.func.value)
            if recv in _IDENTITY_COLLECTIONS:
                self._flag(
                    node, "DSAN005",
                    f"bare .remove() on identity-semantic collection "
                    f"'{recv}' — value comparison on eq=False elements; "
                    f"use an identity container or declare with "
                    f"'# dsan: ignore[DSAN005]'")
        self.generic_visit(node)


def check_source(source: str, path: str = "<string>") -> List[Finding]:
    """Lint one source string; the unit under test for rule tests."""
    tree = ast.parse(source, filename=path)
    checker = _Checker(path, source.splitlines())
    checker.visit(tree)
    return sorted(checker.findings)


def check_file(path: str) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        return check_source(f.read(), path)


def iter_py_files(paths: Iterable[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for name in sorted(files):
                    if name.endswith(".py"):
                        yield os.path.join(root, name)


def _run_tool(module: str, argv: List[str]) -> int:
    """Chain a generic tool when importable; skip (rc 0) when absent."""
    if importlib.util.find_spec(module) is None:
        print(f"dsan: {module} not installed here — skipped "
              f"(CI runs it)")
        return 0
    proc = subprocess.run([sys.executable, "-m", module] + argv)
    return proc.returncode


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="DSAN repo-specific lint pass (+ ruff/mypy chain)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--no-tools", action="store_true",
                    help="run only the DSAN rules, skip ruff/mypy")
    args = ap.parse_args(argv)

    findings: List[Finding] = []
    seen: Set[str] = set()
    for path in iter_py_files(args.paths):
        real = os.path.realpath(path)
        if real in seen:
            continue
        seen.add(real)
        try:
            findings.extend(check_file(path))
        except SyntaxError as e:
            findings.append(Finding(path, e.lineno or 0, 0, "DSAN000",
                                    f"syntax error: {e.msg}"))
    for f in sorted(findings):
        print(f.render())
    rc = 1 if findings else 0
    print(f"dsan: {len(findings)} finding(s) over {len(seen)} file(s)")

    if not args.no_tools:
        rc = max(rc, _run_tool("ruff", ["check"] + list(args.paths)))
        # no path args: pyproject's [tool.mypy] files= governs scope
        rc = max(rc, _run_tool("mypy", []))
    return rc


if __name__ == "__main__":
    sys.exit(main())
