"""DSAN daemon race detector.

The serving daemon's concurrency contract (repro/serve/daemon.py) is
single-owner: exactly one pump thread drives the engine — every
scheduler/engine mutation (``begin_serving``/``pump``/``submit``/
``cancel``/``drain``/...) happens on it — while client handler threads
are restricted to the command queue and read-only handle state
(``status``/``result`` snapshots of terminal fields).

Python offers no tsan, so the discipline is asserted structurally:
:class:`ThreadAffinityGuard` wraps every state-mutating method of a
:class:`~repro.api.DarisServer` with an owner-thread check. A call from
any other thread raises :class:`RaceViolation` carrying a tsan-style
report — the offending method, both threads, and the stack that bound
the owner — instead of silently corrupting heap/queue/lane state.

The guard installs per-instance wrappers (``server.__dict__`` shadows
the class methods), so uninstrumented servers pay nothing and
``uninstall()`` restores the pristine instance.
"""
from __future__ import annotations

import threading
import traceback
from typing import List, Optional

# every DarisServer entry point that reaches scheduler/engine state.
# snapshot/save_state walk live heaps and job tables mid-mutation, so
# they are owner-only too — a handler thread wanting a snapshot must ask
# the pump thread for one (the daemon's ``stats`` verb does exactly
# that).
_GUARDED = ("begin_serving", "pump", "serving_idle", "end_serving",
            "submit", "request", "cancel", "drain", "run",
            "snapshot", "save_state", "load_state")


class RaceViolation(RuntimeError):
    """A non-owner thread called a scheduler-mutating server method."""

    def __init__(self, report: str):
        self.report = report
        super().__init__(report)


class ThreadAffinityGuard:
    """Asserts the daemon's single-owner pump-thread discipline.

    Usage (what ``ServeDaemon.run`` does when sanitizing)::

        guard = ThreadAffinityGuard(server).install()   # owner = caller
        ...
        guard.uninstall()

    ``install`` binds the calling thread as owner by default; ``bind``
    re-homes ownership (e.g. after a fork or a pump-thread restart).
    Violations raise and are also kept in ``guard.violations`` so a
    supervising test can assert the clean case.
    """

    def __init__(self, server):
        self.server = server
        self.owner: Optional[threading.Thread] = None
        self._owner_stack: List[str] = []
        self.violations: List[str] = []
        self._methods = [m for m in _GUARDED
                         if callable(getattr(server, m, None))]

    def install(self, owner: Optional[threading.Thread] = None
                ) -> "ThreadAffinityGuard":
        self.bind(owner or threading.current_thread())
        for name in self._methods:
            setattr(self.server, name, self._wrap(name))
        return self

    def bind(self, thread: threading.Thread) -> None:
        self.owner = thread
        self._owner_stack = traceback.format_stack(limit=8)[:-1]

    def uninstall(self) -> None:
        for name in self._methods:
            self.server.__dict__.pop(name, None)

    def _wrap(self, name: str):
        bound = getattr(type(self.server), name).__get__(self.server)

        def checked(*args, **kwargs):
            cur = threading.current_thread()
            if cur is not self.owner:
                report = self._report(name, cur)
                self.violations.append(report)
                raise RaceViolation(report)
            return bound(*args, **kwargs)

        checked.__name__ = name
        checked.__qualname__ = f"dsan_guard.{name}"
        return checked

    def _report(self, method: str, offender: threading.Thread) -> str:
        offender_stack = "".join(
            "    " + ln for ln in traceback.format_stack(limit=8)[:-2])
        owner_stack = "".join("    " + ln for ln in self._owner_stack)
        owner = self.owner
        return (
            f"WARNING: DSAN: data race on scheduler/engine state\n"
            f"  DarisServer.{method}() called off the pump thread\n"
            f"  offending thread: {offender.name} "
            f"(ident={offender.ident})\n"
            f"{offender_stack}"
            f"  owner (pump) thread: "
            f"{owner.name if owner else '<unbound>'} "
            f"(ident={owner.ident if owner else '-'}), bound at:\n"
            f"{owner_stack}"
            f"  rule: scheduler/engine mutation is single-owner; handler "
            f"threads may only enqueue commands and read terminal handle "
            f"state (daemon.py concurrency contract)\n")
