"""DSAN — the DARIS correctness-tooling subsystem.

Three parts, each usable on its own:

* ``sanitizer``  — opt-in runtime invariant auditor wired through the
  EngineCore drive loop (``DARIS_SANITIZE=1`` or
  ``ServerConfig.sanitize(level=...)``). Recomputes the scheduler's
  hand-maintained incremental state from scratch at a configurable
  cadence and raises a structured ``SanitizerViolation`` on divergence.
* ``races``      — lock-ownership instrumentation for the serving daemon
  asserting the single-owner pump-thread discipline, with a tsan-style
  report (``RaceViolation``) when another thread touches engine state.
* ``lint``       — AST-based repo-specific lint pass
  (``python -m repro.analysis.lint src/``) plus ruff/mypy chaining.

The sanitizer is zero-cost when disabled: the engine stores ``None`` and
every hook site is a single ``is not None`` test — no dispatch, no
allocation, no import of this package.
"""
from .sanitizer import Sanitizer, SanitizerViolation

__all__ = ["Sanitizer", "SanitizerViolation"]
