"""SchedCheck: static schedulability analysis for DARIS configurations.

Three entry points:

* ``analyze_config(cfg)`` — offline WCRT analysis of an unbuilt
  ``ServerConfig`` (never runs the engine); returns a ``Report`` with
  per-epoch, per-task ``GUARANTEED``/``CONDITIONAL``/``UNSCHEDULABLE``
  verdicts and the binding constraint for each.
* ``differential_check(cfg)`` / ``run_oracle(...)`` — the bound-vs-sim
  oracle: run the scenario and assert observed HP responses never
  exceed the static bound (CI gates on this).
* ``python -m repro.analysis.schedcheck <config.json | --figure NAME>``
  — the CLI (JSON + human reports; see ``__main__``).

``ServerConfig.verify()`` and the serve-daemon ``schedcheck`` config key
wire the same analysis in at build/startup time.
"""
from .analyzer import analyze_config
from .model import (CONDITIONAL, GUARANTEED, UNSCHEDULABLE, EpochReport,
                    Report, StageBound, TaskVerdict, UnschedulableError,
                    worst_verdict)
from .oracle import OracleResult, differential_check, run_oracle

__all__ = [
    "analyze_config", "differential_check", "run_oracle",
    "GUARANTEED", "CONDITIONAL", "UNSCHEDULABLE",
    "Report", "EpochReport", "TaskVerdict", "StageBound",
    "OracleResult", "UnschedulableError", "worst_verdict",
]
