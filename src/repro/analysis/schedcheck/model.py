"""SchedCheck report model: per-task verdicts, per-epoch reports.

Verdict semantics (the contract the CLI/CI gate on):

* ``GUARANTEED`` — the static worst-case response-time bound (WCRT,
  computed under adversarial contention, +6-sigma lognormal noise
  headroom, non-preemptive LP blocking, and one straggler-kill
  allowance per job) fits the deadline AND the Eq. 11 HP budget holds
  even at worst-case execution times. A run of this configuration is
  expected to finish with zero HP deadline misses; the differential
  oracle (schedcheck.oracle) enforces exactly that.
* ``CONDITIONAL`` — no static guarantee, but feasibility survives under
  the runtime's adaptive mechanisms (MRET tracking well below the
  worst case, Eq. 12 LP shedding, migration). The binding constraint
  names what the guarantee depends on.
* ``UNSCHEDULABLE`` — infeasible even under the most optimistic model
  (solo execution, zero co-tenant interference): the task cannot meet
  its deadline, or its context's HP set overflows Eq. 11 at solo
  speeds. Reject at build time.

Every verdict carries ``binding`` — the named constraint that decided
it (``eq11-overload``, ``wcet-exceeds-deadline``, ``lp-blocking``,
``eq11-headroom``, ``eq12-admission``, ``arrival-process``, ...).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional

GUARANTEED = "GUARANTEED"
CONDITIONAL = "CONDITIONAL"
UNSCHEDULABLE = "UNSCHEDULABLE"

_SEVERITY = {GUARANTEED: 0, CONDITIONAL: 1, UNSCHEDULABLE: 2}


def worst_verdict(verdicts: List[str]) -> str:
    """The most severe verdict of a set (GUARANTEED when empty)."""
    if not verdicts:
        return GUARANTEED
    return max(verdicts, key=lambda v: _SEVERITY[v])


def _fin(x: float) -> Optional[float]:
    """JSON-safe float: None for +/-inf (json.dumps emits bare Infinity
    otherwise, which strict parsers reject)."""
    return None if math.isinf(x) else x


@dataclasses.dataclass
class StageBound:
    """Static per-stage numbers for one task (device-local wall ms)."""

    name: str
    wc_ms: float            # worst-case single-execution bound
    solo_ms: float          # optimistic floor: alone on the context
    vdl_ms: float           # Eq. 8 virtual-deadline slice (AFET-derived)

    def to_dict(self) -> Dict:
        return {"name": self.name, "wc_ms": self.wc_ms,
                "solo_ms": self.solo_ms, "vdl_ms": self.vdl_ms}


@dataclasses.dataclass
class TaskVerdict:
    """One task's verdict within one epoch."""

    task: str
    priority: str                     # "HP" | "LP"
    ctx: str                          # context key, stringified
    device: Optional[int]             # cluster device id, None on 1 GPU
    period_ms: float
    deadline_ms: float
    wcrt_ms: float                    # full-model WCRT bound (inf = diverged)
    wcrt_nolp_ms: float               # WCRT assuming zero LP load
    solo_ms: float                    # whole-job optimistic floor
    util_wc: float                    # C_wc / T (device-local lane units)
    util_solo: float                  # C_solo / T
    verdict: str
    binding: str                      # named binding constraint
    detail: str
    stages: List[StageBound] = dataclasses.field(default_factory=list)

    @property
    def slack_ms(self) -> float:
        return self.deadline_ms - self.wcrt_ms

    def to_dict(self) -> Dict:
        return {
            "task": self.task, "priority": self.priority, "ctx": self.ctx,
            "device": self.device, "period_ms": self.period_ms,
            "deadline_ms": self.deadline_ms, "wcrt_ms": _fin(self.wcrt_ms),
            "wcrt_nolp_ms": _fin(self.wcrt_nolp_ms), "solo_ms": self.solo_ms,
            "util_wc": self.util_wc, "util_solo": self.util_solo,
            "verdict": self.verdict, "binding": self.binding,
            "detail": self.detail,
            "stages": [s.to_dict() for s in self.stages],
        }


@dataclasses.dataclass
class EpochReport:
    """Verdicts for one segment of the configured timeline.

    An epoch starts at a timeline event (build, reconfigure_at,
    fail_context_at, fail_device_at, scale_out_at, a chaos brownout
    edge) and runs to the next one; within it the partition geometry and
    the post-Algorithm-1 placement are fixed, so one WCRT analysis
    covers the whole segment."""

    t0_ms: float
    t1_ms: float
    cause: str                        # "build" | "reconfigure" | ...
    detail: str
    geometry: Dict
    tasks: List[TaskVerdict] = dataclasses.field(default_factory=list)
    contexts: List[Dict] = dataclasses.field(default_factory=list)

    @property
    def verdict(self) -> str:
        return worst_verdict([t.verdict for t in self.tasks])

    @property
    def hp_verdict(self) -> str:
        return worst_verdict([t.verdict for t in self.tasks
                              if t.priority == "HP"])

    def to_dict(self) -> Dict:
        return {
            "t0_ms": self.t0_ms, "t1_ms": _fin(self.t1_ms),
            "cause": self.cause, "detail": self.detail,
            "geometry": self.geometry,
            "verdict": self.verdict, "hp_verdict": self.hp_verdict,
            "contexts": self.contexts,
            "tasks": [t.to_dict() for t in self.tasks],
        }


@dataclasses.dataclass
class Report:
    """The full schedulability report for one configuration."""

    label: str
    horizon_ms: float
    epochs: List[EpochReport]
    # what-if epochs that are not part of the realized timeline (the
    # autoscale floor shape); they participate in the verdict — a plan
    # is only as good as its worst reachable shape — but not in the
    # realized-bound accessors the differential oracle compares against
    hypothetical: List[EpochReport] = dataclasses.field(default_factory=list)
    assumptions: List[str] = dataclasses.field(default_factory=list)

    def _all_epochs(self) -> List[EpochReport]:
        return self.epochs + self.hypothetical

    @property
    def verdict(self) -> str:
        return worst_verdict([e.verdict for e in self._all_epochs()])

    @property
    def hp_verdict(self) -> str:
        return worst_verdict([e.hp_verdict for e in self._all_epochs()])

    def hp_bound_ms(self) -> float:
        """Static HP response-time bound over the realized timeline: the
        max WCRT bound of any HP task in any epoch (inf when any HP
        busy-period diverged) — the number the differential oracle
        compares observed HP responses against."""
        bounds = [t.wcrt_ms for e in self.epochs for t in e.tasks
                  if t.priority == "HP"]
        return max(bounds) if bounds else 0.0

    def task_verdicts(self, name: str) -> List[TaskVerdict]:
        return [t for e in self._all_epochs() for t in e.tasks
                if t.task == name]

    def to_dict(self) -> Dict:
        return {
            "label": self.label, "horizon_ms": _fin(self.horizon_ms),
            "verdict": self.verdict, "hp_verdict": self.hp_verdict,
            "hp_bound_ms": _fin(self.hp_bound_ms()),
            "assumptions": list(self.assumptions),
            "epochs": [e.to_dict() for e in self.epochs],
            "hypothetical": [e.to_dict() for e in self.hypothetical],
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def render(self) -> str:
        """Human-readable multi-line report."""
        out = [f"schedcheck: {self.label}",
               f"  overall: {self.verdict}   HP: {self.hp_verdict}   "
               f"HP bound: {_fmt_ms(self.hp_bound_ms())}"]
        for e in self._all_epochs():
            hypo = "  [what-if]" if e in self.hypothetical else ""
            t1 = "end" if math.isinf(e.t1_ms) else f"{e.t1_ms:.0f}ms"
            out.append(f"  epoch [{e.t0_ms:.0f}ms, {t1}) {e.cause}"
                       f" — {e.detail}{hypo}")
            geo = e.geometry
            out.append(f"    geometry: {geo.get('summary', geo)}")
            for t in e.tasks:
                out.append(
                    f"    {t.verdict:<13} {t.task:<24} [{t.priority}] "
                    f"ctx={t.ctx} wcrt={_fmt_ms(t.wcrt_ms)} "
                    f"D={t.deadline_ms:.1f}ms  binding={t.binding}")
        if self.assumptions:
            out.append("  assumptions:")
            for a in self.assumptions:
                out.append(f"    - {a}")
        return "\n".join(out)


def _fmt_ms(x: float) -> str:
    return "unbounded" if math.isinf(x) else f"{x:.2f}ms"


class UnschedulableError(ValueError):
    """Raised by ``ServerConfig.verify()`` / the daemon gate when a
    configuration's HP workload is statically unschedulable. Carries the
    full report for diagnosis."""

    def __init__(self, report: Report):
        self.report = report
        culprits = sorted({t.task for e in report._all_epochs()
                           for t in e.tasks
                           if t.priority == "HP"
                           and t.verdict == UNSCHEDULABLE})
        super().__init__(
            f"HP workload statically unschedulable "
            f"({', '.join(culprits) or 'no HP tasks'}); "
            f"run `python -m repro.analysis.schedcheck` for the full "
            f"report\n{report.render()}")
