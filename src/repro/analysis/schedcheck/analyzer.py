"""SchedCheck analyzer: static WCRT bounds for a ServerConfig timeline.

Takes an *unbuilt* ``ServerConfig`` and — without running the engine —
computes per-task worst-case response-time (WCRT) bounds and
schedulability verdicts:

* Per-stage worst-case execution bounds from the same contention model
  the simulator runs (``repro.runtime.contention``), but with every
  adversarial input independently worst-cased: the Eq. 9 lane geometry
  gives each context's SM share, the device-wide co-resident set (max
  ``n_sat`` / ``mem_frac`` over every stage that can run concurrently)
  gives interference, and a ``+6 sigma`` lognormal headroom covers the
  sim's execution-time noise.  Each step of the contention pipeline is
  monotone in its inputs, so worst-casing them independently yields a
  sound lower bound on lane speed (``_worst_speed``); the >= 1 bubble
  gain is dropped.
* Eq. 8 virtual-deadline slices and MRET seeds come from the real
  AFET seeding path (``DarisScheduler._seed_mret``), not a re-derivation.
* Per-task WCRT via a standard response-time fixed point: own cost +
  non-preemptive LP blocking per stage + one straggler/watchdog kill
  allowance per job + batch-coalescing hold + periodic interference
  from same-context tasks spread over the context's streams.
* Eq. 11/12 headroom checks at both solo (optimistic) and worst-case
  utilizations decide the verdict class; the binding constraint is
  named on every verdict (see ``model`` for the verdict contract).

The *whole configured timeline* is analyzed: ``reconfigure_at`` /
``fail_context_at`` / ``fail_device_at`` / ``scale_out_at`` and chaos
brownout edges partition the horizon into epochs.  Each event is
replayed against a real (never-run) ``DarisScheduler`` /
``ClusterScheduler`` instance — the exact Algorithm-1 re-place the
engine would perform — and each epoch's resulting placement is
re-verified.  Autoscaling adds a *hypothetical* epoch at the scale-in
floor: a plan is only as good as its worst reachable shape.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from ...core.scheduler import DarisScheduler, SchedulerConfig
from ...core.task import HP, Task
from ...runtime.arrivals import (ManualArrival, PeriodicArrival,
                                 TraceArrival)
from ...runtime.contention import batch_speedup, batched_stage_ms
from .model import (CONDITIONAL, GUARANTEED, UNSCHEDULABLE, EpochReport,
                    Report, StageBound, TaskVerdict)

_NOISE_SIGMAS = 6.0        # lognormal headroom: bound at e^{6 sigma}
_MAX_ITER = 200            # WCRT fixed-point iteration cap
_DIVERGE_FACTOR = 10.0     # R > 10 D (+slack) => busy period diverged
_MIN_SPEED = 1e-6

PERIODIC = "periodic"
SPORADIC = "sporadic"      # min inter-release gap known, phase unknown
APERIODIC = "aperiodic"    # no inter-release lower bound (Poisson, ...)


# --------------------------------------------------------------- arrivals
@dataclasses.dataclass
class _ArrivalModel:
    kind: str
    period_ms: float           # inter-release lower bound
    note: Optional[str] = None


def _arrival_model(spec, proc, open_loop) -> _ArrivalModel:
    """Classify one task's release process for the WCRT math."""
    if proc is None and open_loop is not None:
        return _ArrivalModel(APERIODIC, spec.period_ms,
                             "open-loop Poisson arrivals")
    if proc is None:
        return _ArrivalModel(PERIODIC, spec.period_ms)
    if isinstance(proc, PeriodicArrival):
        period = proc.period_ms if proc.period_ms else spec.period_ms
        return _ArrivalModel(PERIODIC, period)
    if isinstance(proc, ManualArrival):
        return _ArrivalModel(
            SPORADIC, spec.period_ms,
            "manual arrivals analyzed at the declared rate (1/period); "
            "clients submitting faster void the verdict")
    if isinstance(proc, TraceArrival):
        times = list(proc.times)
        if len(times) < 2:
            return _ArrivalModel(SPORADIC, spec.period_ms)
        gap = min(b - a for a, b in zip(times, times[1:]))
        if gap <= 0:
            return _ArrivalModel(APERIODIC, spec.period_ms,
                                 "trace contains coincident releases")
        return _ArrivalModel(SPORADIC, gap,
                             "trace analyzed at its min inter-release gap")
    return _ArrivalModel(APERIODIC, spec.period_ms,
                         f"unknown arrival process "
                         f"{type(proc).__name__}")


# ------------------------------------------------------------------ model
@dataclasses.dataclass
class _Model:
    """Config-level inputs shared by every epoch's analysis."""

    noise_head: float                    # e^{6 sigma} (1.0 when noise off)
    max_batch: int                       # 1 when dynamic batching off
    kill_kappa: float                    # max(straggler, chaos watchdog)
    transfer_ms: float                   # cluster cross-GPU charge (else 0)
    stall_ms: float                      # chaos lane-stall charge (else 0)
    arrivals: Dict[str, _ArrivalModel]   # task name -> release model
    caps: List[Tuple[str, str]]          # config-wide (binding, note) caps
    lp_caps: List[Tuple[str, str]]       # LP-only caps (degradation)


@dataclasses.dataclass
class _TaskBounds:
    task: Task
    arrival: _ArrivalModel
    stages: List[StageBound]
    c_wc: float                # sum of stage worst cases (device wall ms)
    c_solo: float              # optimistic floor
    allow_ms: float            # one straggler/watchdog kill per job
    hold_ms: float             # batch-coalescing head-of-line hold

    @property
    def t_eff(self) -> float:
        return self.arrival.period_ms

    @property
    def deadline(self) -> float:
        return self.task.spec.deadline_ms


def _effective_nsat(prof, n_units: float, b: int) -> float:
    """Width of a b-input stage (ContentionModel.batched_profile)."""
    if b <= 1:
        return prof.n_sat
    return min(n_units, prof.n_sat * math.sqrt(batch_speedup(prof, b)))


def _worst_speed(dev, nsat: float, mf: float, share: float,
                 total_share_cap: float, m_total: int,
                 co_nsat: float, co_mf: float) -> float:
    """Sound lower bound on the contention-model rate of a stage with
    effective profile ``(nsat, mf)`` on a lane holding ``share`` units.

    Mirrors ``ContentionModel._rates_scalar`` step by step, with each
    adversarial input worst-cased independently (every step is monotone
    in the co-tenant inputs, so the composition is a lower bound):
    device-cap rescale at full subscription, unit starvation with the
    bubble-recovery gain (>= 1) dropped, bandwidth shrink against
    ``m_total - 1`` maximal co-residents, and the L2-thrash memory
    pressure denominator at maximal co-resident ``mem_frac``.
    """
    n_units = dev.n_units
    u = share
    if total_share_cap > n_units:
        u *= n_units / total_share_cap
    speed = min(1.0, min(u, nsat) / nsat)
    if m_total > 1:
        used_max = nsat + (m_total - 1) * co_nsat
        budget = n_units * (1.0 + dev.bubble * (1.0 - 1.0 / m_total))
        if used_max > budget:
            speed *= budget / used_max
        thrash = 1.0 + dev.l2_pressure * (m_total - 1)
        phi_max = thrash * (mf + (m_total - 1) * co_mf)
        if phi_max > 1.0:
            speed /= (1.0 - mf) + mf * phi_max
    return max(speed, _MIN_SPEED)


def _fixed_point(base: float, interferers: Sequence[Tuple[float, float, int]],
                 m: int, deadline: float) -> float:
    """Response-time recurrence R = base + sum_h n_h(R) C_h / m with
    n_h(R) = floor(R/T_h) + extra (extra=1 for other tasks' carry-in,
    0 for self-interference when D > T). Returns inf on divergence."""
    r = base
    limit = _DIVERGE_FACTOR * deadline + 1e4
    for _ in range(_MAX_ITER):
        interf = 0.0
        for period, cost, extra in interferers:
            interf += (math.floor(r / period) + extra) * cost
        r_new = base + interf / max(m, 1)
        if r_new <= r + 1e-9:
            return r_new
        r = r_new
        if r > limit:
            return math.inf
    return math.inf


# ------------------------------------------------------------ entry point
def analyze_config(cfg, *, label: Optional[str] = None) -> Report:
    """Statically analyze an (unbuilt) ``ServerConfig``; returns a
    ``Report``. Never runs the engine and never mutates ``cfg``."""
    cfg._validate()
    label = label or f"{cfg._backend_kind} x{len(cfg._specs)} tasks"
    assumptions: List[str] = []
    sched_cfg = dataclasses.replace(cfg._scheduler_config())

    noise_sigma = cfg._noise_sigma
    if cfg._backend_kind == "sim" and noise_sigma is None:
        noise_sigma = 0.06
    noise_head = math.exp(_NOISE_SIGMAS * (noise_sigma or 0.0))
    if noise_head > 1.0:
        assumptions.append(
            f"stage-time noise bounded at e^(6 sigma) = x{noise_head:.3f} "
            f"(sigma={noise_sigma:g}); beyond-6-sigma draws are outside "
            f"the guarantee")
    if cfg._backend_kind != "sim":
        assumptions.append(
            "realtime backend: wall-clock execution analyzed through the "
            "calibrated sim contention model")

    batch_policy = cfg._batch_policy or getattr(sched_cfg, "batch_policy",
                                                None)
    max_batch = int(getattr(batch_policy, "max_batch", 1) or 1)

    kappa_strag = (sched_cfg.straggler_kappa
                   if cfg._backend_kind == "sim" else 0.0)
    chaos = cfg._chaos_plan
    kappa_wd = float(getattr(chaos, "watchdog_kappa", 0.0) or 0.0)
    kill_kappa = max(kappa_strag or 0.0, kappa_wd)
    if kill_kappa > 0.0:
        assumptions.append(
            f"at most one straggler/watchdog kill per job "
            f"(kappa={kill_kappa:g})")

    caps: List[Tuple[str, str]] = []
    lp_caps: List[Tuple[str, str]] = []
    stall_ms = 0.0
    if chaos is not None:
        if getattr(chaos, "stage_fault_rate", 0.0) > 0.0:
            caps.append((
                "chaos-fault-rate",
                f"stage faults injected at rate "
                f"{chaos.stage_fault_rate:g}: a job can exhaust its "
                f"retry budget, so no static completion guarantee"))
        if getattr(chaos, "stall_rate", 0.0) > 0.0:
            stall_ms = float(chaos.stall_ms)
            assumptions.append(
                f"chaos lane stalls charged on every stage launch "
                f"(+{stall_ms:g}ms worst case)")
        if getattr(chaos, "degradation", None) is not None:
            lp_caps.append((
                "degradation-shedding",
                "degradation controller may shed LP admissions under "
                "overload"))
    if getattr(sched_cfg, "overload_hpa", False):
        assumptions.append(
            "overload_hpa: HP releases are admission-tested; the bound "
            "covers admitted jobs only")

    arrivals = {
        s.name: _arrival_model(s, cfg._arrivals.get(s.name), cfg._open_loop)
        for s in cfg._specs
    }
    for am in arrivals.values():
        if am.note and am.note not in assumptions:
            assumptions.append(am.note)

    transfer_ms = (float(cfg._cluster["transfer_ms"])
                   if cfg._cluster is not None else 0.0)
    if transfer_ms > 0.0:
        assumptions.append(
            f"cluster: every stage charged the worst-case cross-GPU "
            f"transfer ({transfer_ms:g}ms)")

    if cfg._sched_cls is not DarisScheduler or cfg._sched_cls_kw:
        assumptions.append(
            f"custom scheduler_cls {cfg._sched_cls.__name__} analyzed as "
            f"the base DarisScheduler placement")

    model = _Model(noise_head=noise_head, max_batch=max_batch,
                   kill_kappa=kill_kappa, transfer_ms=transfer_ms,
                   stall_ms=stall_ms, arrivals=arrivals, caps=caps,
                   lp_caps=lp_caps)

    sched = _fresh_sched(cfg, sched_cfg)
    epochs = _replay_timeline(cfg, model, sched, assumptions)
    hypothetical = _autoscale_floor(cfg, model, sched_cfg, assumptions)

    return Report(label=label, horizon_ms=cfg._horizon_ms, epochs=epochs,
                  hypothetical=hypothetical, assumptions=assumptions)


def _fresh_sched(cfg, sched_cfg: SchedulerConfig, *,
                 n_gpus: Optional[int] = None):
    """Build the analysis scheduler exactly as ``DarisServer`` would —
    Algorithm-1 placement included — but never wire it to a backend."""
    specs = list(cfg._specs)
    if cfg._cluster is not None:
        from ...cluster.scheduler import ClusterScheduler
        return ClusterScheduler(
            specs, dataclasses.replace(sched_cfg), cfg._device,
            n_gpus=n_gpus if n_gpus is not None else cfg._cluster["n_gpus"],
            device_models=cfg._cluster["device_models"],
            transfer_ms=cfg._cluster["transfer_ms"])
    return DarisScheduler(specs, dataclasses.replace(sched_cfg),
                          cfg._device)


# --------------------------------------------------------- timeline replay
def _collect_events(cfg) -> List[Tuple[float, int, str, object]]:
    """(t, kind_rank, kind, payload) — kind_rank mirrors the engine's
    same-timestamp ordering (FAULT < FAIL_DEV < ADD_CTX < RECONFIG)."""
    ev: List[Tuple[float, int, str, object]] = []
    fp = cfg._fault_plan
    if fp is not None:
        if fp.fail_ctx_at is not None:
            key, t = fp.fail_ctx_at
            ev.append((float(t), 0, "fail-context", key))
        if fp.fail_device_at is not None:
            dev, t = fp.fail_device_at
            ev.append((float(t), 1, "fail-device", dev))
        if fp.add_ctx_at is not None:
            ev.append((float(fp.add_ctx_at), 2, "scale-out", None))
        for t, kwargs in (fp.reconfigure_at or []):
            ev.append((float(t), 3, "reconfigure", dict(kwargs)))
    if cfg._chaos_plan is not None:
        for b in cfg._chaos_plan.brownouts:
            ev.append((float(b.t0_ms), 4, "brownout-start", b))
            ev.append((float(b.t1_ms), 5, "brownout-end", b))
    ev.sort(key=lambda e: (e[0], e[1]))
    return ev


def _replay_timeline(cfg, model: _Model, sched,
                     assumptions: List[str]) -> List[EpochReport]:
    horizon = cfg._horizon_ms
    events = [e for e in _collect_events(cfg) if e[0] < horizon]
    epochs: List[EpochReport] = []
    brown: List[object] = []
    carry: Dict[Optional[int], Tuple[int, float]] = {}
    t0, cause, detail = 0.0, "build", "initial Algorithm-1 placement"

    i = 0
    while True:
        t1 = events[i][0] if i < len(events) else horizon
        if t1 > t0 or not epochs:
            epochs.append(_analyze_epoch(model, sched, t0, t1, cause,
                                         detail, carry, brown))
            carry = {}
        if i >= len(events):
            return epochs
        # apply every event at this timestamp in engine order
        t0 = t1
        descs: List[str] = []
        kinds: List[str] = []
        while i < len(events) and events[i][0] == t0:
            _, _, kind, payload = events[i]
            i += 1
            try:
                desc, carry_upd = _apply_event(sched, t0, kind, payload,
                                               brown)
            except RuntimeError:
                # "all contexts failed": nothing left to schedule on
                epochs.append(_dead_epoch(sched, cfg, t0, horizon))
                return epochs
            kinds.append(kind)
            descs.append(desc)
            carry.update(carry_upd)
        if carry:
            assumptions_note = ("reconfigure: draining lanes of the "
                                "previous shape assumed to clear within "
                                "the following epoch")
            if assumptions_note not in assumptions:
                assumptions.append(assumptions_note)
        cause = "+".join(dict.fromkeys(kinds))
        detail = "; ".join(descs)
    return epochs


def _apply_event(sched, t: float, kind: str, payload, brown: List[object]
                 ) -> Tuple[str, Dict[Optional[int], Tuple[int, float]]]:
    """Replay one timeline event with the engine's skip semantics.
    Returns (description, carry-over {device: (streams, caps)})."""
    is_cluster = hasattr(sched, "workers")
    if kind == "fail-context":
        key = payload
        if is_cluster:
            if key not in sched.queues:
                return f"fault ctx {key} skipped (no such context)", {}
            esc = sched.fault_escalates_to(key)
            if esc is not None and sched.live_devices() == [esc]:
                return (f"fault ctx {key} skipped (would kill the last "
                        f"device)", {})
            sched.fail_context(key, t)
            return f"context {key} failed; survivors re-placed", {}
        if key not in sched.contexts:
            return f"fault ctx {key} skipped (no such context)", {}
        sched.fail_context(key, t)   # may raise RuntimeError (total failure)
        return f"context {key} failed; survivors re-placed", {}
    if kind == "fail-device":
        dev = payload
        if not is_cluster:
            return "fail-device skipped (single-device server)", {}
        live = sched.live_devices()
        if dev not in live:
            return f"fail device {dev} skipped (not live)", {}
        if live == [dev]:
            return f"fail device {dev} skipped (last live device)", {}
        sched.fail_device(dev, t)
        return f"device {dev} failed; fleet re-placed", {}
    if kind == "scale-out":
        ctx = sched.add_context(t)
        return f"scale-out: context {ctx.index} added", {}
    if kind == "reconfigure":
        kwargs = dict(payload)
        carry: Dict[Optional[int], Tuple[int, float]] = {}
        shape_change = any(kwargs.get(f) is not None
                           for f in ("n_contexts", "n_streams",
                                     "oversubscription"))
        if shape_change:
            # retired lanes may still be draining into the next epoch
            if is_cluster:
                for d in sched.live_devices():
                    live = sched.workers[d].live_contexts()
                    carry[d] = (sum(c.n_streams for c in live),
                                sum(c.cap for c in live))
            else:
                live = sched.live_contexts()
                carry[None] = (sum(c.n_streams for c in live),
                               sum(c.cap for c in live))
        sched.reconfigure(t, **kwargs)
        args = ", ".join(f"{k}={v}" for k, v in kwargs.items()
                         if v is not None)
        return f"reconfigure({args}); full re-place", carry
    if kind == "brownout-start":
        brown.append(payload)
        b = payload
        return (f"brownout on device {b.device} "
                f"(x{b.slow_factor:g} slowdown)", {})
    if kind == "brownout-end":
        if payload in brown:
            brown.remove(payload)
        return f"brownout on device {payload.device} cleared", {}
    raise ValueError(f"unknown timeline event kind {kind!r}")


def _dead_epoch(sched, cfg, t0: float, horizon: float) -> EpochReport:
    verdicts = [
        TaskVerdict(
            task=t.spec.name, priority="HP" if t.priority == HP else "LP",
            ctx="-", device=None, period_ms=t.spec.period_ms,
            deadline_ms=t.spec.deadline_ms, wcrt_ms=math.inf,
            wcrt_nolp_ms=math.inf, solo_ms=math.inf, util_wc=math.inf,
            util_solo=math.inf, verdict=UNSCHEDULABLE,
            binding="total-failure",
            detail="the fault plan kills every context; no capacity "
                   "remains from this point on")
        for t in sched.tasks]
    return EpochReport(t0_ms=t0, t1_ms=horizon, cause="total-failure",
                       detail="fault plan leaves zero live contexts",
                       geometry={"summary": "no live contexts"},
                       tasks=verdicts)


# ---------------------------------------------------------- epoch analysis
def _device_views(sched) -> Iterator[Tuple[Optional[int], DarisScheduler,
                                           List, List[Task]]]:
    """Yield (device, worker, live contexts, placed tasks) per device.
    Task->device mapping is derived from ctx keys (the worker task lists
    can hold stale entries across re-places)."""
    if hasattr(sched, "workers"):
        by_dev: Dict[int, List[Task]] = {}
        for t in sched.tasks:
            if t.ctx == -1:
                continue
            by_dev.setdefault(t.ctx[0], []).append(t)
        for d in sched.live_devices():
            w = sched.workers[d]
            yield d, w, w.live_contexts(), by_dev.get(d, [])
    else:
        yield (None, sched, sched.live_contexts(),
               [t for t in sched.tasks if t.ctx != -1])


def _analyze_epoch(model: _Model, sched, t0: float, t1: float, cause: str,
                   detail: str, carry: Dict[Optional[int], Tuple[int, float]],
                   brown: List[object]) -> EpochReport:
    tasks_out: List[TaskVerdict] = []
    ctx_rows: List[Dict] = []
    for dev, w, live, dev_tasks in _device_views(sched):
        dev_idx = 0 if dev is None else dev
        slow = 1.0
        for b in brown:
            if getattr(b, "device", 0) == dev_idx:
                slow = max(slow, float(b.slow_factor))
        c_streams, c_caps = carry.get(dev, (0, 0.0))
        m_total = sum(c.n_streams for c in live) + c_streams
        total_share_cap = sum(c.cap for c in live) + c_caps

        # worst co-resident stage over everything placeable on the device
        co_nsat, co_mf = 0.0, 0.0
        for t in dev_tasks:
            b_eff = model.max_batch if model.max_batch > 1 else t.spec.batch
            for prof in t.spec.stages:
                co_nsat = max(co_nsat, _effective_nsat(
                    prof, w.device.n_units, b_eff))
                co_mf = max(co_mf, prof.mem_frac)

        for c in live:
            ctx_tasks = [t for t in dev_tasks if t.ctx == c.index]
            bounds = [
                _task_bounds(model, w, c, t, m_total, total_share_cap,
                             co_nsat, co_mf, slow)
                for t in ctx_tasks]
            tasks_out.extend(
                _ctx_verdicts(model, c, bounds, dev))
            hp_b = [b for b in bounds if b.task.priority == HP]
            lp_b = [b for b in bounds if b.task.priority != HP]
            ctx_rows.append({
                "ctx": str(c.index), "device": dev,
                "cap": c.cap, "n_streams": c.n_streams,
                "hp_tasks": [b.task.spec.name for b in hp_b],
                "lp_tasks": [b.task.spec.name for b in lp_b],
                "hp_util_wc": sum(b.c_wc / b.t_eff for b in hp_b),
                "hp_util_solo": sum(b.c_solo / b.t_eff for b in hp_b),
                "lp_util_wc": sum(b.c_wc / b.t_eff for b in lp_b),
                "remaining_util_afet": w.remaining_util(c.index, 0.0),
            })
    return EpochReport(t0_ms=t0, t1_ms=t1, cause=cause, detail=detail,
                       geometry=sched.geometry_snapshot(),
                       tasks=tasks_out, contexts=ctx_rows)


def _task_bounds(model: _Model, w: DarisScheduler, ctx, task: Task,
                 m_total: int, total_share_cap: float, co_nsat: float,
                 co_mf: float, slow: float) -> _TaskBounds:
    """Per-stage worst-case/solo bounds + per-job allowances for one task."""
    spec = task.spec
    b_eff = model.max_batch if model.max_batch > 1 else spec.batch
    share = ctx.cap / max(ctx.n_streams, 1)
    vdls = task.mret.virtual_deadlines(spec.deadline_ms)
    dev = w.device
    stages: List[StageBound] = []
    max_thresh = 0.0
    for j, prof in enumerate(spec.stages):
        nsat = _effective_nsat(prof, dev.n_units, b_eff)
        alone_b = batched_stage_ms(prof, b_eff) + prof.overhead_ms
        work = alone_b * model.noise_head / w.speed
        ws = _worst_speed(dev, nsat, prof.mem_frac, share,
                          total_share_cap, m_total, co_nsat, co_mf)
        wall = (work + model.transfer_ms + model.stall_ms) / ws * slow
        solo_rate = w.contention.solo_speed(prof, ctx.cap)
        solo = alone_b / (max(solo_rate, _MIN_SPEED) * w.speed)
        stages.append(StageBound(name=prof.name, wc_ms=wall,
                                 solo_ms=solo, vdl_ms=vdls[j]))
        if model.kill_kappa > 0.0:
            # sim straggler / chaos watchdog threshold: the elapsed time
            # a doomed attempt can burn before the kill + replay
            afet_wall = (task.mret.stage_mret(j)
                         * DarisScheduler.spec_batch_cost(spec, b_eff)
                         / w.speed)
            thresh = max(model.kill_kappa * afet_wall,
                         model.kill_kappa * wall,
                         4.0 * alone_b / w.speed)
            max_thresh = max(max_thresh, thresh)
    c_wc = sum(s.wc_ms for s in stages)
    c_solo = sum(s.solo_ms for s in stages)
    hold = vdls[0] if model.max_batch > 1 else 0.0
    return _TaskBounds(task=task, arrival=model.arrivals[spec.name],
                       stages=stages, c_wc=c_wc, c_solo=c_solo,
                       allow_ms=max_thresh, hold_ms=hold)


def _ctx_verdicts(model: _Model, ctx, bounds: List[_TaskBounds],
                  dev: Optional[int]) -> List[TaskVerdict]:
    """Verdict tree for every task on one context."""
    m = ctx.n_streams
    hp_b = [b for b in bounds if b.task.priority == HP]
    lp_b = [b for b in bounds if b.task.priority != HP]
    hp_util_wc = sum(b.c_wc / b.t_eff for b in hp_b)
    hp_util_solo = sum(b.c_solo / b.t_eff for b in hp_b)
    lp_util_wc = sum(b.c_wc / b.t_eff for b in lp_b)
    blocking = max((max(s.wc_ms for s in b.stages) for b in lp_b),
                   default=0.0)
    ctx_aperiodic = any(b.arrival.kind == APERIODIC for b in bounds)

    out: List[TaskVerdict] = []
    for b in bounds:
        is_hp = b.task.priority == HP
        n_stages = len(b.stages)
        base = b.c_wc + b.allow_ms + b.hold_ms
        self_interf = ([(b.t_eff, b.c_wc, 0)]
                       if b.deadline > b.t_eff else [])
        if is_hp:
            others = [(o.t_eff, o.c_wc, 1) for o in hp_b if o is not b]
            r_nolp = _fixed_point(base, others + self_interf, m, b.deadline)
            r_full = _fixed_point(base + n_stages * blocking,
                                  others + self_interf, m, b.deadline)
        else:
            others = [(o.t_eff, o.c_wc, 1) for o in hp_b]
            others += [(o.t_eff, o.c_wc, 1) for o in lp_b if o is not b]
            r_full = _fixed_point(base, others + self_interf, m, b.deadline)
            r_nolp = r_full
        if ctx_aperiodic:
            # a co-resident open-loop task makes interference unbounded
            r_full = r_nolp = math.inf

        verdict, binding, why = _classify(
            b, is_hp, m, hp_util_wc, hp_util_solo, lp_util_wc,
            r_full, r_nolp, blocking, ctx_aperiodic)

        # config-wide caps demote GUARANTEED to CONDITIONAL
        if verdict == GUARANTEED:
            for cap_binding, cap_note in (model.caps
                                          + ([] if is_hp else model.lp_caps)):
                verdict, binding, why = CONDITIONAL, cap_binding, cap_note
                break

        out.append(TaskVerdict(
            task=b.task.spec.name, priority="HP" if is_hp else "LP",
            ctx=str(ctx.index), device=dev,
            period_ms=b.t_eff, deadline_ms=b.deadline,
            wcrt_ms=r_full, wcrt_nolp_ms=r_nolp, solo_ms=b.c_solo,
            util_wc=b.c_wc / b.t_eff, util_solo=b.c_solo / b.t_eff,
            verdict=verdict, binding=binding, detail=why,
            stages=b.stages))
    return out


def _classify(b: _TaskBounds, is_hp: bool, m: int, hp_util_wc: float,
              hp_util_solo: float, lp_util_wc: float, r_full: float,
              r_nolp: float, blocking: float, ctx_aperiodic: bool
              ) -> Tuple[str, str, str]:
    d = b.deadline
    if b.c_solo > d:
        return (UNSCHEDULABLE, "wcet-exceeds-deadline",
                f"optimistic solo cost {b.c_solo:.2f}ms already exceeds "
                f"the {d:.1f}ms deadline")
    if is_hp and hp_util_solo > m + 1e-9:
        return (UNSCHEDULABLE, "eq11-overload",
                f"HP demand {hp_util_solo:.2f} lanes at *solo* speeds "
                f"overflows the context's {m} stream(s) (Eq. 11)")
    if ctx_aperiodic:
        return (CONDITIONAL, "arrival-process",
                "an open-loop arrival process shares this context; "
                "worst-case backlog is unbounded")
    if b.arrival.kind == APERIODIC:
        return (CONDITIONAL, "arrival-process",
                b.arrival.note or "no inter-release lower bound")
    if is_hp:
        if r_full <= d and hp_util_wc <= m + 1e-9:
            return (GUARANTEED, "wcrt-within-deadline",
                    f"WCRT {r_full:.2f}ms <= D {d:.1f}ms with "
                    f"{d - r_full:.2f}ms slack; Eq. 11 holds at worst "
                    f"case ({hp_util_wc:.2f}/{m})")
        if r_nolp <= d:
            return (CONDITIONAL, "lp-blocking",
                    f"fits without LP load (WCRT {r_nolp:.2f}ms) but "
                    f"non-preemptive LP blocking (+{blocking:.2f}ms per "
                    f"stage) can overrun; depends on Eq. 12 shedding")
        if hp_util_wc > m + 1e-9:
            return (CONDITIONAL, "eq11-headroom",
                    f"worst-case HP demand {hp_util_wc:.2f} lanes "
                    f"exceeds {m} stream(s); feasible only while MRET "
                    f"tracks below the worst case")
        return (CONDITIONAL, "hp-interference",
                f"WCRT bound diverges under worst-case HP interference "
                f"(demand {hp_util_wc:.2f}/{m})")
    # LP
    robust = hp_util_wc + lp_util_wc <= m + 1e-9
    if r_full <= d and robust:
        return (GUARANTEED, "wcrt-within-deadline",
                f"WCRT {r_full:.2f}ms <= D {d:.1f}ms and Eq. 12 "
                f"admission holds at worst case "
                f"({hp_util_wc + lp_util_wc:.2f}/{m})")
    if r_full <= d:
        return (CONDITIONAL, "eq12-admission",
                f"fits when admitted (WCRT {r_full:.2f}ms) but Eq. 12 "
                f"may reject releases at worst-case load "
                f"({hp_util_wc + lp_util_wc:.2f}/{m})")
    return (CONDITIONAL, "lp-interference",
            "no static bound under worst-case co-resident load; LP "
            "completion relies on Eq. 12 admission + migration")


# ------------------------------------------------------- autoscale floor
def _autoscale_floor(cfg, model: _Model, sched_cfg: SchedulerConfig,
                     assumptions: List[str]) -> List[EpochReport]:
    auto = cfg._autoscale
    if auto is None:
        return []
    floor = int(auto.min_contexts)
    if cfg._cluster is not None:
        if floor >= cfg._cluster["n_gpus"]:
            return []
        sched = _fresh_sched(cfg, sched_cfg, n_gpus=floor)
        what = f"autoscale floor: fleet scaled in to {floor} GPU(s)"
    else:
        if floor >= sched_cfg.n_contexts:
            return []
        floor_cfg = dataclasses.replace(sched_cfg, n_contexts=floor)
        sched = DarisScheduler(list(cfg._specs), floor_cfg, cfg._device)
        what = f"autoscale floor: scaled in to {floor} context(s)"
    assumptions.append(
        "autoscale: the scale-in floor shape is verified as a what-if "
        "epoch (reachable whenever load stays below the low watermark)")
    return [_analyze_epoch(model, sched, 0.0, math.inf, "autoscale-floor",
                           what, {}, [])]
