"""CLI: ``python -m repro.analysis.schedcheck <config.json ...>``.

Analyzes serve-daemon JSON configs and/or named figure scenarios
(``--figure``, resolved through ``benchmarks.figure_specs`` — run from
the repo root so ``benchmarks`` is importable) and prints the human
report.  ``--json`` writes the machine report; ``--oracle`` also runs
each scenario in the simulator and checks the differential contract.

Exit status: 0 when every analyzed config is free of HP
``UNSCHEDULABLE`` verdicts (and, with ``--require-hp-guaranteed``,
every HP verdict is ``GUARANTEED``; with ``--oracle``, zero bound
violations); 1 otherwise; 2 on usage errors.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

from .analyzer import analyze_config
from .model import GUARANTEED, UNSCHEDULABLE, Report
from .oracle import differential_check


def _figure_registry():
    try:
        import benchmarks.figure_specs as figure_specs
    except ImportError as exc:
        raise SystemExit(
            f"--figure needs the benchmarks package on sys.path (run "
            f"from the repo root): {exc}")
    return figure_specs


def _load_scenarios(args) -> List[Tuple[str, object]]:
    out: List[Tuple[str, object]] = []
    for path in args.configs:
        from ...serve.config import load_config, server_config
        out.append((path, server_config(load_config(path))))
    if args.figure:
        reg = _figure_registry()
        for name in args.figure:
            out.append((name, reg.scenario(name)))
    if args.all_figures:
        reg = _figure_registry()
        for name in reg.names():
            out.append((name, reg.scenario(name)))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.schedcheck",
        description="static schedulability analysis (SchedCheck)")
    ap.add_argument("configs", nargs="*",
                    help="serve-daemon JSON config paths")
    ap.add_argument("--figure", action="append", default=[],
                    metavar="NAME",
                    help="named figure scenario (repeatable; see --list)")
    ap.add_argument("--all-figures", action="store_true",
                    help="analyze every registered figure scenario")
    ap.add_argument("--list", action="store_true",
                    help="list figure scenario names and exit")
    ap.add_argument("--json", metavar="PATH",
                    help="write the JSON report(s) to PATH")
    ap.add_argument("--oracle", action="store_true",
                    help="also run each scenario in sim and check the "
                         "bound-vs-sim differential contract")
    ap.add_argument("--require-hp-guaranteed", action="store_true",
                    help="exit 1 unless every HP verdict is GUARANTEED")
    args = ap.parse_args(argv)

    if args.list:
        for name in _figure_registry().names():
            print(name)
        return 0
    scenarios = _load_scenarios(args)
    if not scenarios:
        ap.print_usage(sys.stderr)
        print("error: nothing to analyze (give a config path, --figure, "
              "or --all-figures)", file=sys.stderr)
        return 2

    failed = False
    payload: List[Dict] = []
    for name, cfg in scenarios:
        if args.oracle:
            res = differential_check(cfg, label=name)
            report: Report = res.report
            print(res.render())
            failed |= not res.ok
            entry = report.to_dict()
            entry["oracle"] = {
                "ok": res.ok, "vacuous": res.vacuous,
                "observed_max_ms": res.observed_max_ms,
                "dmr_hp": res.dmr_hp,
                "violations": res.violations,
            }
        else:
            report = analyze_config(cfg, label=name)
            entry = report.to_dict()
        print(report.render())
        print()
        payload.append(entry)
        if report.hp_verdict == UNSCHEDULABLE:
            failed = True
        if args.require_hp_guaranteed and report.hp_verdict != GUARANTEED:
            print(f"require-hp-guaranteed: {name} is "
                  f"{report.hp_verdict}", file=sys.stderr)
            failed = True

    if args.json:
        doc = payload[0] if len(payload) == 1 else payload
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=2)
            fh.write("\n")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
