"""Differential oracle: static bound vs simulated behaviour.

For a scenario (an unbuilt ``ServerConfig``) the oracle

1. runs ``analyze_config`` to get the static report, then
2. builds and runs the server, and
3. asserts the contract the analyzer promises:

   * every observed HP response time is ``<=`` the static HP WCRT bound
     over the realized timeline (``Report.hp_bound_ms()``; an infinite
     bound — diverged busy period, open-loop arrivals — is trivially
     satisfied but reported as vacuous), and
   * a configuration whose HP verdict is ``GUARANTEED`` finishes with
     **zero** HP deadline misses.

Any violation is a bug in the analyzer or in the engine — there is no
third option — which makes this a cheap, high-yield CI gate: the two
implementations of the DARIS math (closed-form and discrete-event)
check each other on every push.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Optional, Tuple

from ...core.task import HP
from .analyzer import analyze_config
from .model import GUARANTEED, Report

_TOL_MS = 1e-6


@dataclasses.dataclass
class OracleResult:
    label: str
    verdict: str
    hp_verdict: str
    bound_ms: float              # static HP WCRT bound (realized timeline)
    observed_max_ms: float       # max simulated HP response
    dmr_hp: float                # simulated HP deadline-miss ratio
    vacuous: bool                # bound was infinite (nothing to falsify)
    violations: List[str]
    report: Report

    @property
    def ok(self) -> bool:
        return not self.violations

    def render(self) -> str:
        status = "OK" if self.ok else "VIOLATION"
        bound = ("unbounded" if math.isinf(self.bound_ms)
                 else f"{self.bound_ms:.2f}ms")
        line = (f"oracle[{status}] {self.label}: observed HP max "
                f"{self.observed_max_ms:.2f}ms vs bound {bound} "
                f"(hp={self.hp_verdict}, dmr_hp={self.dmr_hp:.4f})")
        return "\n".join([line] + [f"  !! {v}" for v in self.violations])


def differential_check(cfg, *, label: Optional[str] = None) -> OracleResult:
    """Analyze, then simulate, one scenario and compare (see module doc).
    ``cfg`` must be an unbuilt ``ServerConfig``; it is built here."""
    report = analyze_config(cfg, label=label)
    metrics = cfg.build().run()
    hp_resp = metrics.response_ms.get(HP, [])
    observed = max(hp_resp) if hp_resp else 0.0
    bound = report.hp_bound_ms()
    dmr_hp = metrics.dmr(HP)

    violations: List[str] = []
    if observed > bound + _TOL_MS:
        violations.append(
            f"observed HP response {observed:.3f}ms exceeds the static "
            f"bound {bound:.3f}ms — analyzer or engine bug")
    if report.hp_verdict == GUARANTEED and dmr_hp > 0.0:
        violations.append(
            f"HP verdict GUARANTEED but the simulation missed "
            f"{dmr_hp:.2%} of HP deadlines — analyzer or engine bug")
    return OracleResult(
        label=report.label, verdict=report.verdict,
        hp_verdict=report.hp_verdict, bound_ms=bound,
        observed_max_ms=observed, dmr_hp=dmr_hp,
        vacuous=math.isinf(bound) or not hp_resp,
        violations=violations, report=report)


def run_oracle(scenarios: Iterable[Tuple[str, object]]
               ) -> List[OracleResult]:
    """Differential-check a batch of (label, unbuilt ServerConfig)."""
    return [differential_check(cfg, label=name) for name, cfg in scenarios]
