"""Deterministic synthetic token pipeline (checkpointable).

Zipf-distributed token ids (long-tail like natural text) generated per-step
from (seed, step) so any step is reproducible in isolation — restart
resumes exactly by restoring the step counter. Never emits padded vocab
ids (head/vocab padding stays dead weight, api.pad_heads_for_tp).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int = 0


class TokenPipeline:
    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, zipf_a: float = 1.2):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.state = PipelineState(seed=seed)
        self.zipf_a = zipf_a

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.state.seed, self.state.step]))
        z = rng.zipf(self.zipf_a, size=(self.batch, self.seq))
        tokens = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        self.state.step += 1
        return {"tokens": tokens}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    # checkpointing
    def state_dict(self) -> dict:
        return dataclasses.asdict(self.state)

    def load_state_dict(self, d: dict) -> None:
        self.state = PipelineState(**d)
