"""Architecture config schema shared by every assigned architecture.

One ``ArchConfig`` covers all families ("dense", "moe", "ssm", "hybrid",
"encdec", "vlm"); family-specific fields default to None/0 and are only read
by the matching model builder.  Every config module in this package exposes

    CONFIG            -- the exact published configuration
    reduced()         -- a tiny same-family variant for CPU smoke tests
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- attention options -------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    logit_softcap: float = 0.0       # final-logit softcap (gemma2: 30)
    attn_softcap: float = 0.0        # attention-logit softcap (gemma2: 50)
    sliding_window: int = 0          # local-attention window (gemma2: 4096)
    local_global_alternating: bool = False   # gemma2 layer pattern
    post_block_norms: bool = False   # gemma2 extra post-norms
    mlp_act: str = "silu"            # silu | gelu | gelu_tanh
    norm_eps: float = 1e-6
    embed_scale: bool = False        # gemma-style sqrt(d) embedding scaling
    tie_embeddings: bool = False
    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_experts_active: int = 0        # top-k
    moe_d_ff: int = 0                # per-expert hidden
    n_shared_experts: int = 0
    shared_d_ff: int = 0             # total hidden of fused shared experts
    n_dense_layers: int = 0          # leading dense (non-MoE) layers
    router_norm_topk: bool = False   # normalize top-k gate weights
    ep_shards: int = 1               # EP shard width: experts pad to multiple
    # --- MLA (deepseek-v2) ---------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- SSM (mamba2 / zamba2) ----------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0              # hybrid: shared attn applied every k ssm layers
    # --- enc-dec / vlm frontends (stubs provide embeddings) -----------------
    n_encoder_layers: int = 0
    encoder_frames: int = 0          # whisper stub frame count
    n_image_tokens: int = 0          # pixtral stub patch count
    # --- numerics / serving -------------------------------------------------
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"   # "int8" enables quantized KV cache
    # ``long_500k`` applicability (pure full-attention archs skip it)
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input-shape cells assigned to every LM arch (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
