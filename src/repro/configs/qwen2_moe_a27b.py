"""qwen2-moe-a2.7b [moe] 24L d_model=2048 16H (kv=16) d_ff=1408 vocab=151936, MoE 60e top-4.

4 shared + 60 routed experts top-4. [hf:Qwen/Qwen1.5-MoE-A2.7B; hf-verified]
shared_d_ff = 5632 (published shared_expert_intermediate_size).
60 experts pad to 64 for the 16-way EP shard (4 dummy experts masked from
routing — see DESIGN.md §5); config keeps the published 60.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,               # routed-expert hidden (no separate dense layers)
    vocab_size=151936,
    qkv_bias=True,
    n_experts=60,
    n_experts_active=4,
    moe_d_ff=1408,
    n_shared_experts=4,
    shared_d_ff=5632,
    router_norm_topk=True,
    rope_theta=1_000_000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="qwen2-moe-a2.7b-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=32, vocab_size=256, n_experts=8,
        n_experts_active=2, moe_d_ff=32, shared_d_ff=64, dtype="float32")
