"""Config registry: ``--arch <id>`` resolution for every assigned architecture."""
from __future__ import annotations

from . import (deepseek_v2_236b, gemma2_27b, mamba2_27b, pixtral_12b,
               qwen15_32b, qwen2_moe_a27b, smollm_135m, stablelm_12b,
               whisper_tiny, zamba2_7b)
from .base import SHAPES, ArchConfig, ShapeCell, shape_by_name

_MODULES = {
    "qwen1.5-32b": qwen15_32b,
    "gemma2-27b": gemma2_27b,
    "stablelm-12b": stablelm_12b,
    "smollm-135m": smollm_135m,
    "zamba2-7b": zamba2_7b,
    "mamba2-2.7b": mamba2_27b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "qwen2-moe-a2.7b": qwen2_moe_a27b,
    "whisper-tiny": whisper_tiny,
    "pixtral-12b": pixtral_12b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    return _MODULES[arch_id].CONFIG


def get_reduced(arch_id: str) -> ArchConfig:
    return _MODULES[arch_id].reduced()


def cells(arch_id: str):
    """All (arch, shape) cells for this arch, with skip markers.

    Returns list of (ShapeCell, runnable: bool, reason: str).
    """
    cfg = get_config(arch_id)
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.subquadratic:
            out.append((s, False, "skipped: pure full-attention arch (DESIGN.md §4)"))
        else:
            out.append((s, True, ""))
    return out


__all__ = ["ArchConfig", "ShapeCell", "SHAPES", "ARCH_IDS", "get_config",
           "get_reduced", "cells", "shape_by_name"]
