"""mamba2-2.7b [ssm] 64L d_model=2560 (attn-free) vocab=50280, ssm_state=128.

SSD (state-space duality). [arXiv:2405.21060; unverified]
d_inner = 5120, headdim 64 -> 80 ssm heads (80/16 = 5: shards cleanly).

long_500k: RUN (attention-free; O(1) decode state).
DARIS note: attention-specific KV tricks are N/A; staging/priorities apply
unchanged (DESIGN.md §4).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    subquadratic=True,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="mamba2-2.7b-reduced", n_layers=3, d_model=64, vocab_size=256,
        ssm_state=16, ssm_headdim=16, ssm_chunk=8, dtype="float32")
