"""whisper-tiny [audio] 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.

Enc-dec; conv/audio frontend is a STUB — input_specs() provides precomputed
frame embeddings [B, 1500, 384]. [arXiv:2212.04356; unverified]
6 heads can't shard 16-way; attention weights replicated (tiny model).
decode_32k exercised structurally (beyond the published 448 positions) —
shape/compile exercise, noted in DESIGN.md. long_500k: SKIP (full attention).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,              # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    n_encoder_layers=4,
    encoder_frames=1500,
    mlp_act="gelu",
    rope_theta=0.0,          # whisper uses learned/sinusoidal positions
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="whisper-tiny-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=160, vocab_size=256, n_encoder_layers=2,
        encoder_frames=16, dtype="float32")
