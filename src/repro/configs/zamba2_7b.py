"""zamba2-7b [hybrid] 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64.

Mamba2 backbone + ONE shared-weight attention block applied every 6 mamba
layers (13 applications, distinct KV caches, weight-tied). [arXiv:2411.15242;
unverified]  81 counts the mamba blocks; the shared block is weight-tied and
not counted (DESIGN.md §4).

long_500k: RUN (hybrid — SSM state is O(1); the 13 shared-attn caches are the
only full-length state).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,            # 3584/32
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,          # d_inner 7168 -> 112 ssm heads
    attn_every=6,
    subquadratic=True,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="zamba2-7b-reduced", n_layers=5, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=160, vocab_size=256, head_dim=16,
        ssm_state=16, ssm_headdim=16, attn_every=2, ssm_chunk=8,
        dtype="float32")
