"""qwen1.5-32b [dense] 64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064, QKV bias.

[hf:Qwen/Qwen1.5-0.5B family; hf-verified]
40 heads is not divisible by the 16-way ``model`` mesh axis; the launcher pads
attention heads to 48 for tensor parallelism (see DESIGN.md §5) — config keeps
the published head count, padding is applied at sharding time.
decode_32k KV cache is 5.5 TB in bf16 and does not fit a 256x16GB pod; the
serving path uses an int8 KV cache for this arch (beyond-paper optimization).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    kv_cache_dtype="int8",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="qwen1.5-32b-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=160, vocab_size=256, dtype="float32",
        kv_cache_dtype="bfloat16")
