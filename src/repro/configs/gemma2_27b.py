"""gemma2-27b [dense] 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.

Local(4096-window)+global alternating attention, attn-logit softcap 50,
final-logit softcap 30, GeGLU, pre+post block norms, head_dim=128.
[arXiv:2408.00118; hf-verified]

long_500k: RUN — local layers are sliding-window (sub-quadratic); only the 23
global layers keep a full-length cache (see DESIGN.md §4).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    attn_softcap=50.0,
    logit_softcap=30.0,
    sliding_window=4096,
    local_global_alternating=True,
    post_block_norms=True,
    mlp_act="gelu_tanh",
    embed_scale=True,
    tie_embeddings=True,
    subquadratic=True,   # half the layers are windowed; global layers are O(1)/step at decode
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="gemma2-27b-reduced", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab_size=256, head_dim=16,
        sliding_window=16, dtype="float32")
