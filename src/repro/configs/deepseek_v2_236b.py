"""deepseek-v2-236b [moe] 60L d_model=5120 128H d_ff=1536 vocab=102400, MoE 160e top-6.

MLA kv_lora=512, 2 shared + 160 routed experts top-6, first layer dense.
[arXiv:2405.04434; hf-verified]
d_ff=1536 is the routed-expert hidden; shared experts fused hidden = 2*1536.
Dense layers use d_ff = 12288 (published intermediate_size).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: latent shared; head count for q
    d_ff=12288,              # dense-layer intermediate
    vocab_size=102400,
    n_experts=160,
    n_experts_active=6,
    moe_d_ff=1536,
    n_shared_experts=2,
    shared_d_ff=3072,        # 2 x 1536 fused
    n_dense_layers=1,
    router_norm_topk=True,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="deepseek-v2-236b-reduced", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=160, vocab_size=256, n_experts=8,
        n_experts_active=2, moe_d_ff=32, shared_d_ff=64, n_dense_layers=1,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
        qk_rope_head_dim=8, v_head_dim=16, dtype="float32")
