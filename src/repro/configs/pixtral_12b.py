"""pixtral-12b [vlm] 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.

pixtral-ViT frontend is a STUB — input_specs() provides precomputed patch
embeddings [B, 1024, 5120]; backbone is mistral-nemo style (head_dim 128).
[hf:mistralai/Pixtral-12B-2409; unverified]  long_500k: SKIP (full attention).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1_000_000_000.0,
    n_image_tokens=1024,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="pixtral-12b-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab_size=256, head_dim=16,
        n_image_tokens=8, dtype="float32")
