"""smollm-135m [dense] 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.

llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf-verified]
9 heads can't shard 16-way; attention weights are replicated across the
``model`` axis (tiny model — see DESIGN.md §5). MLP stays TP (1536/16=96).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        name="smollm-135m-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab_size=256, dtype="float32")
