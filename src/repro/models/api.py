"""Uniform Model API over every assigned architecture.

``build_model(cfg)`` returns a ``Model`` whose methods are pure functions
suitable for jit/pjit:

    init_params(seed)                 -> params pytree
    loss(params, batch)               -> scalar  (train cells)
    prefill(params, batch)            -> (logits, cache)
    decode_step(params, batch)        -> (logits, cache)
    init_cache(batch_size, max_len)   -> cache pytree
    input_specs(cell, max_len=None)   -> ShapeDtypeStruct tree per shape cell
    model_flops(cell)                 -> MODEL_FLOPS per the roofline contract
                                         (6·N_active·D train, 2·N_active·D
                                         inference; N excludes embeddings)

Head padding for tensor parallelism (qwen1.5 40->48) happens here: the
padded config drives params/compute, the published config drives
MODEL_FLOPS, so the roofline ratio exposes the padding waste honestly.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from . import encdec, transformer
from ..configs.base import ArchConfig, ShapeCell


def pad_heads_for_tp(cfg: ArchConfig, tp: int = 16) -> ArchConfig:
    """TP-alignment padding.

    * heads: pad up to a multiple of tp when close (qwen1.5 40->48); tiny
      archs (smollm 9H, whisper 6H) stay unpadded -> replicated attention.
    * vocab: pad to a multiple of tp (whisper 51865->51872, mamba2
      50280->50288) so logits/embedding shard — dummy tokens are never
      emitted by the data pipeline and their logits are dead weight.
    The published config (``Model.orig``) drives MODEL_FLOPS so padding
    waste shows up honestly in the roofline ratio."""
    if cfg.vocab_size % tp:
        cfg = cfg.replace(vocab_size=cfg.vocab_size
                          + (tp - cfg.vocab_size % tp))
    if cfg.n_heads == 0 or cfg.n_heads % tp == 0:
        return cfg
    padded = cfg.n_heads + (tp - cfg.n_heads % tp)
    if padded <= cfg.n_heads * 1.25:   # accept <=25% head padding
        kv = cfg.n_kv_heads
        if kv == cfg.n_heads:
            kv = padded
        return cfg.replace(n_heads=padded, n_kv_heads=kv,
                           head_dim=cfg.resolved_head_dim)
    return cfg


def _loss_from_logits(logits: jax.Array, targets: jax.Array,
                      mask: Optional[jax.Array] = None) -> jax.Array:
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


class Model:
    """LM families (dense/vlm/moe/ssm/hybrid) + encdec, one interface."""

    AUX_WEIGHT = 0.01

    def __init__(self, cfg: ArchConfig, orig_cfg: Optional[ArchConfig] = None,
                 dist: Optional[dict] = None):
        self.cfg = cfg
        self.orig = orig_cfg or cfg
        # distribution context (ShardingRules.dist_ctx()): activation
        # sharding constraints + shard_map expert parallelism
        self.dist = dist

    # ------------------------------------------------------------------ init
    def init_params(self, seed: int = 0) -> dict:
        key = jax.random.PRNGKey(seed)
        if self.cfg.family == "encdec":
            return encdec.init_encdec(key, self.cfg)
        return transformer.init_lm(key, self.cfg)

    def init_cache(self, batch: int, max_len: int) -> dict:
        if self.cfg.family == "encdec":
            return encdec.init_dec_cache(self.cfg, batch, max_len)
        return transformer.init_cache(self.cfg, batch, max_len)

    def _cons(self):
        if self.dist is None:
            return None
        from ..parallel.sharding import ActConstraint
        return ActConstraint(self.dist)

    # --------------------------------------------------------------- forward
    def _lm_forward(self, params, batch, cache=None, **kw):
        cfg = self.cfg
        kw.setdefault("dist", self.dist)
        if cfg.family == "vlm":
            tok_emb = params["embed"][batch["tokens"]]
            if "image_embeds" in batch and cache is None:
                embeds = jnp.concatenate(
                    [batch["image_embeds"].astype(tok_emb.dtype), tok_emb], axis=1)
            else:
                embeds = tok_emb
            return transformer.forward(params, cfg, embeds=embeds,
                                       cache=cache, **kw)
        return transformer.forward(params, cfg, batch["tokens"],
                                   cache=cache, **kw)

    def loss(self, params: dict, batch: Dict[str, jax.Array], *,
             q_chunk: int = 0, remat: str = "none") -> jax.Array:
        cfg = self.cfg
        if cfg.family == "encdec":
            cons = self._cons()
            enc_out = encdec.encode(params, batch["frames"], cfg, cons=cons)
            logits, _ = encdec.decode(params, batch["tokens"][:, :-1], enc_out,
                                      cfg, q_chunk=q_chunk, remat=remat,
                                      cons=cons)
            return _loss_from_logits(logits, batch["tokens"][:, 1:])
        logits, _, aux = self._lm_forward(params, batch, q_chunk=q_chunk,
                                          remat=remat)
        tokens = batch["tokens"]
        if cfg.family == "vlm":
            n_img = batch["image_embeds"].shape[1] if "image_embeds" in batch else 0
            logits = logits[:, n_img:]
        loss = _loss_from_logits(logits[:, :-1], tokens[:, 1:])
        if cfg.n_experts:
            loss = loss + self.AUX_WEIGHT * aux / max(cfg.n_layers, 1)
        return loss

    def prefill(self, params: dict, batch: Dict[str, jax.Array], *,
                q_chunk: int = 0):
        cfg = self.cfg
        if cfg.family == "encdec":
            cons = self._cons()
            enc_out = encdec.encode(params, batch["frames"], cfg, cons=cons)
            cache = batch["cache"]
            logits, new_cache = encdec.decode(params, batch["tokens"], enc_out,
                                              cfg, cache=cache,
                                              q_chunk=q_chunk, cons=cons)
            return logits, new_cache
        logits, new_cache, _ = self._lm_forward(params, batch,
                                                cache=batch["cache"],
                                                q_chunk=q_chunk)
        return logits, new_cache

    def decode_step(self, params: dict, batch: Dict[str, jax.Array]):
        cfg = self.cfg
        if cfg.family == "encdec":
            logits, new_cache = encdec.decode(params, batch["tokens"],
                                              batch["enc_out"], cfg,
                                              cache=batch["cache"],
                                              cons=self._cons())
            return logits, new_cache
        logits, new_cache, _ = self._lm_forward(params, batch,
                                                cache=batch["cache"])
        return logits, new_cache

    # ---------------------------------------------------------------- specs
    def input_specs(self, cell: ShapeCell) -> Dict:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        cfg = self.cfg
        b, s = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        sd = jax.ShapeDtypeStruct
        if cfg.family == "encdec":
            frames = sd((b, cfg.encoder_frames, cfg.d_model), dt)
            if cell.kind == "train":
                return {"frames": frames, "tokens": sd((b, s), i32)}
            cache = jax.eval_shape(lambda: self.init_cache(b, s))
            if cell.kind == "prefill":
                return {"frames": frames, "tokens": sd((b, s), i32),
                        "cache": cache}
            return {"tokens": sd((b, 1), i32), "cache": cache,
                    "enc_out": frames}
        if cfg.family == "vlm":
            n_img = cfg.n_image_tokens
            if cell.kind == "train":
                return {"tokens": sd((b, s - n_img), i32),
                        "image_embeds": sd((b, n_img, cfg.d_model), dt)}
            if cell.kind == "prefill":
                cache = jax.eval_shape(lambda: self.init_cache(b, s))
                return {"tokens": sd((b, s - n_img), i32),
                        "image_embeds": sd((b, n_img, cfg.d_model), dt),
                        "cache": cache}
            cache = jax.eval_shape(lambda: self.init_cache(b, s))
            return {"tokens": sd((b, 1), i32), "cache": cache}
        # plain LM families
        if cell.kind == "train":
            return {"tokens": sd((b, s), i32)}
        cache = jax.eval_shape(lambda: self.init_cache(b, s))
        if cell.kind == "prefill":
            return {"tokens": sd((b, s), i32), "cache": cache}
        return {"tokens": sd((b, 1), i32), "cache": cache}

    # --------------------------------------------------------------- flops
    def param_counts(self) -> Dict[str, float]:
        """Analytic param counts from the *published* config."""
        c = self.orig
        d = c.d_model
        counts = {"embed": c.vocab_size * d * (1 if c.tie_embeddings else 2)}
        hd = c.resolved_head_dim
        attn = d * hd * (c.n_heads * 2 + c.n_kv_heads * 2) if c.n_heads else 0
        if c.use_mla:
            qk = c.qk_nope_head_dim + c.qk_rope_head_dim
            attn = (d * c.q_lora_rank + c.q_lora_rank * c.n_heads * qk
                    + d * (c.kv_lora_rank + c.qk_rope_head_dim)
                    + c.kv_lora_rank * c.n_heads * (c.qk_nope_head_dim
                                                    + c.v_head_dim)
                    + c.n_heads * c.v_head_dim * d)
        mlp = 3 * d * c.d_ff
        ssm = 0
        if c.ssm_state:
            di = c.d_inner
            ssm = (2 * d * di + d * 2 * c.ssm_ngroups * c.ssm_state
                   + d * c.ssm_nheads + di * d)
        if c.family == "dense" or c.family == "vlm":
            per_layer = attn + mlp
            layers = c.n_layers * per_layer
            active = layers
        elif c.family == "moe":
            routed = 3 * d * c.moe_d_ff
            shared = 3 * d * c.shared_d_ff if c.shared_d_ff else 0
            moe_layer = attn + routed * c.n_experts + shared + d * c.n_experts
            dense_layer = attn + mlp
            n_moe = c.n_layers - c.n_dense_layers
            layers = n_moe * moe_layer + c.n_dense_layers * dense_layer
            active = (n_moe * (attn + routed * c.n_experts_active + shared
                               + d * c.n_experts)
                      + c.n_dense_layers * dense_layer)
        elif c.family == "ssm":
            layers = c.n_layers * ssm
            active = layers
        elif c.family == "hybrid":
            d2 = 2 * d
            shared_attn = (d2 * hd * (c.n_heads + 2 * c.n_kv_heads)
                           + c.n_heads * hd * d + d * d + 3 * d * c.d_ff)
            layers = c.n_layers * ssm + shared_attn
            n_apps = c.n_layers // c.attn_every
            active = c.n_layers * ssm + n_apps * shared_attn
        elif c.family == "encdec":
            enc_layer = attn + 2 * d * c.d_ff
            layers = (c.n_encoder_layers * enc_layer
                      + c.n_layers * (2 * attn + 2 * d * c.d_ff))
            active = layers
        else:
            raise ValueError(c.family)
        counts["layers"] = float(layers)
        counts["active"] = float(active)
        counts["total"] = float(layers) + counts["embed"]
        return counts

    def model_flops(self, cell: ShapeCell) -> float:
        """MODEL_FLOPS per the roofline contract: 6·N·D train, 2·N·D infer
        (N = active non-embedding params, D = tokens processed)."""
        n_active = self.param_counts()["active"]
        if cell.kind == "train":
            tokens = cell.global_batch * cell.seq_len
            return 6.0 * n_active * tokens
        if cell.kind == "prefill":
            tokens = cell.global_batch * cell.seq_len
            return 2.0 * n_active * tokens
        return 2.0 * n_active * cell.global_batch   # one decode step

    def param_bytes(self) -> float:
        itemsize = jnp.dtype(self.cfg.dtype).itemsize
        return self.param_counts()["total"] * itemsize

    def kv_cache_bytes(self, batch: int, seq: int) -> float:
        """Total KV/state cache bytes for the whole batch."""
        c = self.cfg
        if c.family == "ssm":
            per = (c.ssm_nheads * c.ssm_headdim * c.ssm_state * 4
                   + (c.ssm_conv_width - 1)
                   * (c.d_inner + 2 * c.ssm_ngroups * c.ssm_state) * 2)
            return batch * c.n_layers * per
        kb = 1 if c.kv_cache_dtype == "int8" else jnp.dtype(c.kv_cache_dtype).itemsize
        hd = c.resolved_head_dim
        if c.use_mla:
            per_tok = (c.kv_lora_rank + c.qk_rope_head_dim) * kb
            return batch * seq * c.n_layers * per_tok
        if c.family == "hybrid":
            n_apps = c.n_layers // max(c.attn_every, 1)
            ssm = c.ssm_nheads * c.ssm_headdim * c.ssm_state * 4
            return (batch * c.n_layers * ssm
                    + batch * seq * n_apps * 2 * c.n_kv_heads * hd * kb)
        per_tok = 2 * c.n_kv_heads * hd * kb
        if c.local_global_alternating and c.sliding_window:
            half = c.n_layers // 2
            return (batch * seq * half * per_tok
                    + batch * min(seq, c.sliding_window) * half * per_tok)
        return batch * seq * c.n_layers * per_tok

    def analytic_hbm_bytes(self, cell: ShapeCell, accum: int = 1) -> float:
        """Napkin per-step HBM traffic (whole job, summed over chips) for
        the roofline memory term. Weights/grads/optimizer traffic +
        activation read/write + cache traffic. Used instead of XLA:CPU's
        'bytes accessed' (not TPU-representative; see EXPERIMENTS.md)."""
        c = self.cfg
        p_bytes = self.param_bytes()
        tokens = cell.global_batch * cell.seq_len
        d = c.d_model
        act_unit = tokens * d * jnp.dtype(c.dtype).itemsize
        depth = max(c.n_layers, 1)
        if cell.kind == "train":
            w_traffic = 3.0 * p_bytes * accum       # fwd+bwd+remat reads
            g_traffic = 4.0 * p_bytes * accum       # grad arena rw (f32-ish)
            opt_traffic = 10.0 * p_bytes            # adam m/v rw + update
            act_traffic = 16.0 * act_unit * depth
            return w_traffic + g_traffic + opt_traffic + act_traffic
        if cell.kind == "prefill":
            cache_w = self.kv_cache_bytes(cell.global_batch, cell.seq_len)
            return p_bytes + 12.0 * act_unit * depth + cache_w
        # decode: params + full cache read dominate one step
        cache_r = self.kv_cache_bytes(cell.global_batch, cell.seq_len)
        act_dec = (cell.global_batch * d * depth * 12
                   * jnp.dtype(c.dtype).itemsize)
        return p_bytes + cache_r + act_dec


def build_model(arch_cfg: ArchConfig, *, pad_for_tp: Optional[int] = None,
                dist: Optional[dict] = None) -> Model:
    cfg = arch_cfg
    if pad_for_tp:
        cfg = pad_heads_for_tp(arch_cfg, pad_for_tp)
        if cfg.n_experts:
            cfg = cfg.replace(ep_shards=pad_for_tp)
    return Model(cfg, orig_cfg=arch_cfg, dist=dist)
