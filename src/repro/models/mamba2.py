"""Mamba2 (state-space duality) block: chunked SSD prefill + O(1) decode.

Projections are split (z / x / BC / dt) instead of one fused in_proj so the
tensor-parallel sharding is clean: x/z/dt shard over ssm heads (``model``
axis), the small B/C group projections stay replicated (DESIGN.md §5).

SSD follows the chunked algorithm of the Mamba2 paper (intra-chunk
quadratic term + inter-chunk state recurrence via lax.scan); the Pallas
kernel in ``repro.kernels.ssd_scan`` implements the same contraction with
VMEM tiling and is validated against ``ssd_reference`` here.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import InitCtx, dense_init, ones_init, rms_norm, zeros_init


def init_mamba2(ctx: InitCtx, cfg) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_nheads
    g = cfg.ssm_ngroups
    n = cfg.ssm_state
    w = cfg.ssm_conv_width
    return {
        "w_z": dense_init(ctx, (d, di)),
        "w_x": dense_init(ctx, (d, di)),
        "w_bc": dense_init(ctx, (d, 2 * g * n)),
        "w_dt": dense_init(ctx, (d, h)),
        "dt_bias": zeros_init(ctx, (h,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(ctx.dtype),
        "D": ones_init(ctx, (h,)),
        "conv_x": dense_init(ctx, (w, di), scale=0.5),
        "conv_x_b": zeros_init(ctx, (di,)),
        "conv_bc": dense_init(ctx, (w, 2 * g * n), scale=0.5),
        "conv_bc_b": zeros_init(ctx, (2 * g * n,)),
        "norm": ones_init(ctx, (di,)),
        "w_out": dense_init(ctx, (di, d), scale=1.0 / di ** 0.5),
    }


def make_ssm_cache(batch: int, cfg, dtype: str = "bfloat16") -> dict:
    w = cfg.ssm_conv_width
    return {
        "conv_x": jnp.zeros((batch, w - 1, cfg.d_inner), jnp.dtype(dtype)),
        "conv_bc": jnp.zeros((batch, w - 1, 2 * cfg.ssm_ngroups * cfg.ssm_state),
                             jnp.dtype(dtype)),
        "state": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim,
                            cfg.ssm_state), jnp.float32),
        "length": jnp.zeros((), jnp.int32),
    }


def causal_conv(x: jax.Array, kernel: jax.Array, bias: jax.Array,
                history: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv via W shifted adds. x: [B,L,C], kernel: [W,C].

    Returns (y [B,L,C], new_history [B,W-1,C])."""
    w = kernel.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], w - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
    ln = x.shape[1]
    y = sum(xp[:, i:i + ln] * kernel[i][None, None] for i in range(w))
    y = jax.nn.silu(y + bias)
    return y, xp[:, -(w - 1):]


def _segsum(dA: jax.Array) -> jax.Array:
    """dA: [..., Q] -> [..., Q, Q] with out[i,j] = sum_{j<k<=i} dA[k], -inf for j>i."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_reference(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                  b: jax.Array, c: jax.Array, chunk: int,
                  init_state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. x:[B,L,H,P] dt:[B,L,H] (post-softplus) b/c:[B,L,G,N].

    Returns (y [B,L,H,P], final_state [B,H,P,N] f32)."""
    bs, ln, h, p = x.shape
    g = b.shape[2]
    n = b.shape[3]
    assert ln % chunk == 0, f"L={ln} not divisible by chunk={chunk}"
    nc = ln // chunk
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))               # [H], negative
    xc = x.reshape(bs, nc, chunk, h, p)
    dtc = dt.reshape(bs, nc, chunk, h).astype(jnp.float32)
    bc = jnp.repeat(b.reshape(bs, nc, chunk, g, n), rep, axis=3)  # [B,nc,Q,H,N]
    cc = jnp.repeat(c.reshape(bs, nc, chunk, g, n), rep, axis=3)
    da = dtc * a[None, None, None]                        # [B,nc,Q,H]
    da_hq = jnp.moveaxis(da, -1, 2)                       # [B,nc,H,Q]
    seg = _segsum(da_hq)                                  # [B,nc,H,Q,Q]
    decay = jnp.exp(seg)
    # intra-chunk (quadratic within chunk)
    cb = jnp.einsum("bcqhn,bckhn->bchqk", cc, bc).astype(jnp.float32)
    y_intra = jnp.einsum("bchqk,bckh,bckhp->bcqhp", cb * decay, dtc,
                         xc.astype(jnp.float32))
    # per-chunk final states
    cum = jnp.cumsum(da_hq, axis=-1)                      # [B,nc,H,Q]
    decay_to_end = jnp.exp(cum[..., -1:] - cum)           # [B,nc,H,Q]
    states = jnp.einsum("bckhn,bchk,bckh,bckhp->bchpn", bc, decay_to_end,
                        dtc, xc.astype(jnp.float32))
    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[..., -1])                   # [B,nc,H]
    s0 = (jnp.zeros((bs, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(prev, inp):
        st, cdk = inp                                     # [B,H,P,N], [B,H]
        new = prev * cdk[:, :, None, None] + st
        return new, prev

    final_state, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # [B,nc,H,P,N]
    decay_from_start = jnp.exp(cum)                       # [B,nc,H,Q]
    y_inter = jnp.einsum("bcqhn,bchq,bchpn->bcqhp", cc, decay_from_start,
                         prev_states)
    y = (y_intra + y_inter).reshape(bs, ln, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    a_log: jax.Array, b: jax.Array, c: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence. x:[B,H,P] dt:[B,H] b/c:[B,G,N].

    state' = state * exp(dt*A) + dt * (B outer x);  y = C . state'"""
    h = x.shape[1]
    g = b.shape[1]
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    bh = jnp.repeat(b, rep, axis=1).astype(jnp.float32)   # [B,H,N]
    ch = jnp.repeat(c, rep, axis=1).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * a[None])                        # [B,H]
    xt = x.astype(jnp.float32)
    new_state = (state * decay[:, :, None, None]
                 + dtf[:, :, None, None] * xt[:, :, :, None] * bh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch)
    return y.astype(x.dtype), new_state


def mamba2_block(params: dict, x: jax.Array, *, cfg,
                 cache: Optional[dict] = None,
                 use_kernel: bool = False,
                 cons=None) -> Tuple[jax.Array, Optional[dict]]:
    """[B,L,d] -> ([B,L,d], new_cache). Decode when cache is given and L==1
    uses the recurrent step; otherwise chunked SSD."""
    bsz, ln, _ = x.shape
    h, p, g, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_ngroups, cfg.ssm_state
    z = jnp.einsum("bld,di->bli", x, params["w_z"])
    xin = jnp.einsum("bld,di->bli", x, params["w_x"])
    bc = jnp.einsum("bld,dj->blj", x, params["w_bc"])
    if cons is not None:
        z = cons.ssm_inner(z)
        xin = cons.ssm_inner(xin)
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", x, params["w_dt"]).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))

    hist_x = cache["conv_x"] if cache is not None else None
    hist_bc = cache["conv_bc"] if cache is not None else None
    xin, new_hist_x = causal_conv(xin, params["conv_x"], params["conv_x_b"], hist_x)
    bc, new_hist_bc = causal_conv(bc, params["conv_bc"], params["conv_bc_b"], hist_bc)

    xh = xin.reshape(bsz, ln, h, p)
    bmat = bc[..., :g * n].reshape(bsz, ln, g, n)
    cmat = bc[..., g * n:].reshape(bsz, ln, g, n)

    if cache is not None and ln == 1:
        y1, new_state = ssd_decode_step(
            cache["state"], xh[:, 0], dt[:, 0], params["A_log"],
            bmat[:, 0], cmat[:, 0])
        y = y1[:, None]
    else:
        init_state = cache["state"] if cache is not None else None
        # pad to a chunk multiple with dt=0 tokens: zero dt means zero
        # state update and unit decay, so the SSD recurrence is invariant
        pad = (-ln) % cfg.ssm_chunk
        xp, dtp, bp, cp = xh, dt, bmat, cmat
        if pad:
            pad3 = [(0, 0), (0, pad)] + [(0, 0)] * 2
            xp = jnp.pad(xh, pad3)
            dtp = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
            bp = jnp.pad(bmat, pad3)
            cp = jnp.pad(cmat, pad3)
        if use_kernel:
            from repro.kernels import ops as kops
            y, new_state = kops.ssd(xp, dtp, params["A_log"], bp, cp,
                                    cfg.ssm_chunk, init_state)
        else:
            y, new_state = ssd_reference(xp, dtp, params["A_log"], bp, cp,
                                         cfg.ssm_chunk, init_state)
        if pad:
            y = y[:, :ln]

    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(bsz, ln, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm"], eps=cfg.norm_eps)
    out = jnp.einsum("bli,id->bld", y, params["w_out"])

    new_cache = None
    if cache is not None:
        new_cache = {"conv_x": new_hist_x, "conv_bc": new_hist_bc,
                     "state": new_state,
                     "length": cache["length"] + ln}
    return out, new_cache
