"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

``input_specs`` provides precomputed frame embeddings [B, F, d] (the conv
frontend is a stub per the assignment); the encoder adds sinusoidal
positions and runs bidirectional layers. The decoder is the DARIS-staged /
scheduled path: causal self-attention (+cache) and cross-attention to the
encoder output. Whisper uses LayerNorm + plain-GELU MLPs with biases;
positions are sinusoidal (no rope). Layers are python-unrolled (4 layers,
tiny model — scan would save nothing).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention_block, init_attention, make_kv_cache
from .layers import (InitCtx, embed_init, init_mlp, layer_norm,
                     mlp, ones_init, sinusoidal_positions, zeros_init)


def _init_ln(ctx, d):
    return {"w": ones_init(ctx, (d,)), "b": zeros_init(ctx, (d,))}


def _init_enc_layer(key, cfg):
    ctx = InitCtx(key, jnp.dtype(cfg.dtype))
    d = cfg.d_model
    return {
        "ln1": _init_ln(ctx, d),
        "attn": init_attention(ctx, d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.resolved_head_dim, qkv_bias=True,
                               out_bias=True),
        "ln2": _init_ln(ctx, d),
        "mlp": init_mlp(ctx, d, cfg.d_ff),
    }


def _init_dec_layer(key, cfg):
    ctx = InitCtx(key, jnp.dtype(cfg.dtype))
    d = cfg.d_model
    return {
        "ln1": _init_ln(ctx, d),
        "self_attn": init_attention(ctx, d, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.resolved_head_dim, qkv_bias=True,
                                    out_bias=True),
        "ln_x": _init_ln(ctx, d),
        "cross_attn": init_attention(ctx, d, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.resolved_head_dim, qkv_bias=True,
                                     out_bias=True),
        "ln2": _init_ln(ctx, d),
        "mlp": init_mlp(ctx, d, cfg.d_ff),
    }


def init_encdec(key: jax.Array, cfg) -> dict:
    ctx = InitCtx(key, jnp.dtype(cfg.dtype))
    enc_keys = jax.random.split(ctx.next(), cfg.n_encoder_layers)
    dec_keys = jax.random.split(ctx.next(), cfg.n_layers)
    return {
        "embed": embed_init(ctx, cfg.vocab_size, cfg.d_model),
        "enc_layers": [_init_enc_layer(k, cfg) for k in enc_keys],
        "enc_norm": _init_ln(ctx, cfg.d_model),
        "dec_layers": [_init_dec_layer(k, cfg) for k in dec_keys],
        "dec_norm": _init_ln(ctx, cfg.d_model),
    }


def encode(params: dict, frames: jax.Array, cfg, cons=None) -> jax.Array:
    """frames: [B, F, d] stub embeddings -> encoder states [B, F, d]."""
    pos = sinusoidal_positions(frames.shape[1], cfg.d_model).astype(frames.dtype)
    x = frames + pos[None]
    f_pos = jnp.arange(frames.shape[1], dtype=jnp.int32)
    for lp in params["enc_layers"]:
        h = layer_norm(x, lp["ln1"]["w"], lp["ln1"]["b"])
        a, _ = attention_block(lp["attn"], h, positions=f_pos, rope_theta=0.0,
                               causal=False, cons=cons)
        x = x + a
        h = layer_norm(x, lp["ln2"]["w"], lp["ln2"]["b"])
        x = x + mlp(lp["mlp"], h, cfg.mlp_act)
        if cons is not None:
            x = cons.hidden(x)
    return layer_norm(x, params["enc_norm"]["w"], params["enc_norm"]["b"])


def init_dec_cache(cfg, batch: int, max_len: int) -> dict:
    return {
        "self": [make_kv_cache(batch, max_len, cfg.n_kv_heads,
                               cfg.resolved_head_dim, cfg.kv_cache_dtype)
                 for _ in range(cfg.n_layers)],
    }


def decode(params: dict, tokens: jax.Array, enc_out: jax.Array, cfg,
           cache: Optional[dict] = None,
           positions: Optional[jax.Array] = None,
           q_chunk: int = 0, remat: str = "none", cons=None
           ) -> Tuple[jax.Array, Optional[dict]]:
    """Decoder forward. tokens [B, S]; enc_out [B, F, d]."""
    x = params["embed"][tokens]
    if cons is not None:
        x = cons.hidden(x)
    if positions is None:
        start = cache["self"][0]["length"] if cache is not None else 0
        positions = start + jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x = x + _pos_embed(positions, cfg.d_model).astype(x.dtype)[None]
    new_cache = {"self": []} if cache is not None else None

    def dec_layer(x, lp, ca):
        h = layer_norm(x, lp["ln1"]["w"], lp["ln1"]["b"])
        a, nc = attention_block(lp["self_attn"], h, positions=positions,
                                rope_theta=0.0, causal=True, cache=ca,
                                q_chunk=q_chunk, cons=cons)
        x = x + a
        h = layer_norm(x, lp["ln_x"]["w"], lp["ln_x"]["b"])
        a, _ = attention_block(lp["cross_attn"], h, positions=positions,
                               rope_theta=0.0, causal=False, x_kv=enc_out,
                               q_chunk=q_chunk, cons=cons)
        x = x + a
        h = layer_norm(x, lp["ln2"]["w"], lp["ln2"]["b"])
        x = x + mlp(lp["mlp"], h, cfg.mlp_act)
        if cons is not None:
            x = cons.hidden(x)
        return x, nc

    if remat != "none":
        dec_layer = jax.checkpoint(
            dec_layer, policy=jax.checkpoint_policies.nothing_saveable)
    for i, lp in enumerate(params["dec_layers"]):
        ca = cache["self"][i] if cache is not None else None
        x, nc = dec_layer(x, lp, ca)
        if cache is not None:
            new_cache["self"].append(nc)
    x = layer_norm(x, params["dec_norm"]["w"], params["dec_norm"]["b"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    if cons is not None:
        logits = cons.logits(logits)
    return logits, new_cache


def _pos_embed(positions: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embedding for arbitrary (possibly traced) positions [S]."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    inv = 1.0 / (10000 ** (dim / max(d // 2 - 1, 1)))
    ang = positions.astype(jnp.float32)[:, None] * inv[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
