"""DeepSeek-V2 Multi-head Latent Attention (MLA).

Two execution paths:
  * naive  -- latent is up-projected to per-head K/V (train / prefill).
  * absorbed -- w_uk / w_uv are absorbed into the query / output projections
    so decode attends directly against the (kv_lora + rope) latent cache.
    This is what makes the MLA decode cache tiny: 512+64 values per token
    regardless of the 128 heads.

Cache: {"latent": [B, S, kv_lora], "k_rope": [B, S, rope_dim] (post-rope),
        "length", "slots_pos"}.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .attention import _mask_bias
from .layers import InitCtx, apply_rope, dense_init, ones_init, rms_norm


def init_mla(ctx: InitCtx, cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "q_down": dense_init(ctx, (d, cfg.q_lora_rank)),
        "q_norm": ones_init(ctx, (cfg.q_lora_rank,)),
        "q_up": dense_init(ctx, (cfg.q_lora_rank, h, qk)),
        "kv_down": dense_init(ctx, (d, cfg.kv_lora_rank + cfg.qk_rope_head_dim)),
        "kv_norm": ones_init(ctx, (cfg.kv_lora_rank,)),
        "k_up": dense_init(ctx, (cfg.kv_lora_rank, h, cfg.qk_nope_head_dim)),
        "v_up": dense_init(ctx, (cfg.kv_lora_rank, h, cfg.v_head_dim)),
        "wo": dense_init(ctx, (h, cfg.v_head_dim, d),
                         scale=1.0 / (h * cfg.v_head_dim) ** 0.5),
    }


def make_mla_cache(batch: int, max_len: int, cfg, dtype: str = "bfloat16") -> dict:
    dt = jnp.dtype(dtype)
    return {
        "latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dt),
        "length": jnp.zeros((), jnp.int32),
        "slots_pos": jnp.full((max_len,), -1, jnp.int32),
    }


def _project_q(params, x, cfg, positions):
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["q_down"]), params["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, params["q_up"])
    q_nope = q[..., :cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_latent(params, x, cfg, positions):
    ckv = jnp.einsum("bsd,dr->bsr", x, params["kv_down"])
    latent = rms_norm(ckv[..., :cfg.kv_lora_rank], params["kv_norm"])
    # shared single-head rope key
    k_rope = apply_rope(ckv[..., None, cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)[..., 0, :]
    return latent, k_rope


def mla_block(params: dict, x: jax.Array, *, cfg, positions: jax.Array,
              cache: Optional[dict] = None, q_chunk: int = 0,
              cons=None) -> tuple:
    """Returns (out, new_cache | None). Decode (with history) runs absorbed."""
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _project_q(params, x, cfg, positions)
    latent, k_rope = _project_latent(params, x, cfg, positions)
    if cons is not None:
        q_nope = cons.heads(q_nope)
        q_rope = cons.heads(q_rope)
        latent = cons.hidden(latent)

    new_cache = None
    if cache is not None:
        start = cache["length"]
        s_max = cache["latent"].shape[1]
        slot = start % s_max
        new_cache = dict(cache)
        new_cache["latent"] = jax.lax.dynamic_update_slice_in_dim(
            cache["latent"], latent.astype(cache["latent"].dtype), slot, 1)
        new_cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), slot, 1)
        pos_new = start + jnp.arange(x.shape[1], dtype=jnp.int32)
        new_cache["slots_pos"] = jax.lax.dynamic_update_slice_in_dim(
            cache["slots_pos"], pos_new, slot, 0)
        new_cache["length"] = start + x.shape[1]

    if cache is not None and x.shape[1] == 1:
        # ----- absorbed decode path over the latent cache -----
        lat = new_cache["latent"].astype(x.dtype)          # [B,T,R]
        kr = new_cache["k_rope"].astype(x.dtype)           # [B,T,Rr]
        kv_pos = new_cache["slots_pos"]
        # absorb k_up into q:  q_lat[b,s,h,r] = sum_k q_nope * k_up[r,h,k]
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["k_up"])
        s = (jnp.einsum("bshr,btr->bhst", q_lat, lat)
             + jnp.einsum("bshk,btk->bhst", q_rope, kr)).astype(jnp.float32) * scale
        s = s + _mask_bias(
            jnp.broadcast_to(positions[None] if positions.ndim == 1 else positions,
                             (x.shape[0], x.shape[1])),
            jnp.broadcast_to(kv_pos[None], (x.shape[0], kv_pos.shape[0])),
            True, 0)[:, None]
        p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        out_lat = jnp.einsum("bhst,btr->bshr", p, lat)
        # absorb v_up into the output projection
        o = jnp.einsum("bshr,rhv->bshv", out_lat, params["v_up"])
        y = jnp.einsum("bshv,hvd->bsd", o, params["wo"])
        return y, new_cache

    # ----- naive path (train / prefill; attends on fresh latents) -----
    k_nope = jnp.einsum("bsr,rhk->bshk", latent, params["k_up"])
    v = jnp.einsum("bsr,rhv->bshv", latent, params["v_up"])
    h = cfg.n_heads
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_rope.shape[:2], h, k_rope.shape[-1]))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    from .attention import mha  # local import to avoid cycle at module load
    # v may have fewer dims than qk: pad v to qk dim is wasteful; attend manually
    out = mha(q, k, _pad_v(v, q.shape[-1]), q_positions=positions,
              kv_positions=positions, causal=True, scale=scale, q_chunk=q_chunk)
    out = out[..., :cfg.v_head_dim]
    y = jnp.einsum("bshv,hvd->bsd", out, params["wo"])
    return y, new_cache


def _pad_v(v: jax.Array, dim: int) -> jax.Array:
    if v.shape[-1] == dim:
        return v
    pad = [(0, 0)] * (v.ndim - 1) + [(0, dim - v.shape[-1])]
    return jnp.pad(v, pad)
