"""Mixture-of-Experts: router + two expert-compute paths.

  * ``moe_dense_oracle`` -- every expert over every token, weighted by the
    sparse gate matrix. Exact (no capacity drops); used as the correctness
    oracle in tests and for tiny smoke configs.
  * ``moe_capacity``    -- gather -> batched-einsum -> scatter-add with a
    fixed per-expert capacity. Exact FLOPs x capacity slack, fully static
    shapes, and shard-friendly: with experts sharded over the ``model`` mesh
    axis each shard evaluates only its local expert slice (``expert_offset``
    / ``n_local``), and the surrounding TP all-reduce combines shards. No
    quadratic one-hot dispatch (DESIGN.md §5).

Params layout (stacked per layer by the transformer builder):
  router: [d, E]
  experts: {"w_gate": [E, d, f], "w_up": [E, d, f], "w_down": [E, f, d]}
  shared: gated-MLP params (optional)
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import InitCtx, act_fn, dense_init


def init_moe(ctx: InitCtx, d: int, n_experts: int, moe_d_ff: int,
             shared_d_ff: int = 0) -> dict:
    e, f = n_experts, moe_d_ff
    p = {
        "router": dense_init(ctx, (d, e)),
        "experts": {
            "w_gate": dense_init(ctx, (e, d, f)),
            "w_up": dense_init(ctx, (e, d, f)),
            "w_down": dense_init(ctx, (e, f, d), scale=1.0 / math.sqrt(f)),
        },
    }
    if shared_d_ff:
        from .layers import init_gated_mlp
        p["shared"] = init_gated_mlp(ctx, d, shared_d_ff)
    return p


def route(router_w: jax.Array, x: jax.Array, topk: int,
          norm_topk: bool, n_valid: Optional[int] = None) -> Tuple:
    """x: [N, d] -> (weights [N,k] f32, ids [N,k] i32, probs [N,E] f32).

    ``n_valid`` masks padded dummy experts (qwen2-moe pads 60 -> 64 for the
    16-way EP shard; dummies never receive tokens)."""
    logits = jnp.einsum("nd,de->ne", x, router_w).astype(jnp.float32)
    if n_valid is not None and n_valid < logits.shape[-1]:
        pad_mask = jnp.arange(logits.shape[-1]) >= n_valid
        logits = jnp.where(pad_mask[None], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, topk)
    if norm_topk:
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, ids.astype(jnp.int32), probs


def load_balance_loss(probs: jax.Array, ids: jax.Array, n_valid: int) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * P_e (over valid experts;
    padded dummies never route so they contribute 0)."""
    e_total = probs.shape[-1]
    onehot = jax.nn.one_hot(ids, e_total, dtype=jnp.float32)     # [N,k,E]
    f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)                 # fraction routed
    p = jnp.mean(probs, axis=0)
    return n_valid * jnp.sum(f * p)


def moe_dense_oracle(params: dict, x: jax.Array, topk: int,
                     norm_topk: bool = False, act: str = "silu",
                     n_valid: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """[B,S,d] -> ([B,S,d], aux_loss). Computes every expert densely."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    weights, ids, probs = route(params["router"], xf, topk, norm_topk, n_valid)
    e = params["experts"]["w_gate"].shape[0]
    gates = jnp.zeros((xf.shape[0], e), jnp.float32)
    gates = gates.at[jnp.arange(xf.shape[0])[:, None], ids].add(weights)
    g = jnp.einsum("nd,edf->nef", xf, params["experts"]["w_gate"])
    u = jnp.einsum("nd,edf->nef", xf, params["experts"]["w_up"])
    h = act_fn(act)(g) * u
    y = jnp.einsum("nef,efd->ned", h, params["experts"]["w_down"])
    out = jnp.einsum("ned,ne->nd", y, gates.astype(y.dtype))
    aux = load_balance_loss(probs, ids, e if n_valid is None else n_valid)
    return out.reshape(b, s, d), aux


def dispatch_indices(ids: jax.Array, weights: jax.Array, capacity: int,
                     expert_offset: int, n_local: int) -> Tuple:
    """Slot assignment for capacity-based dispatch over a local expert slice.

    ids/weights: [N, k]. Returns (slot_pair [E_loc*C] i32 index into the
    flattened (N*k) pair axis, slot_w [E_loc*C] f32, valid [E_loc*C] bool).
    Tokens beyond an expert's capacity are dropped (standard capacity MoE);
    pairs routed outside [offset, offset+n_local) scatter out-of-bounds and
    are dropped by ``mode="drop"``.
    """
    nk = ids.shape[0] * ids.shape[1]
    ids_f = ids.reshape(-1)                               # [N*k]
    w_f = weights.reshape(-1)
    local = ids_f - expert_offset                         # [N*k]
    sel = (local[:, None] == jnp.arange(n_local)[None])   # [N*k, E_loc]
    rank = jnp.cumsum(sel, axis=0) * sel                  # 1-based rank
    keep = sel & (rank <= capacity)
    oob = n_local * capacity
    flat_pos = jnp.min(jnp.where(keep, local[:, None] * capacity + rank - 1,
                                 oob), axis=1)            # one expert per pair
    pair_idx = jnp.arange(nk, dtype=jnp.int32)
    slot_pair = jnp.zeros((oob,), jnp.int32).at[flat_pos].set(
        pair_idx, mode="drop")
    slot_w = jnp.zeros((oob,), jnp.float32).at[flat_pos].set(w_f, mode="drop")
    valid = jnp.zeros((oob,), bool).at[flat_pos].set(True, mode="drop")
    return slot_pair, slot_w, valid


def moe_capacity(params: dict, x: jax.Array, topk: int, *,
                 capacity_factor: float = 1.25, norm_topk: bool = False,
                 act: str = "silu", n_valid: Optional[int] = None,
                 expert_offset: int = 0, n_local: Optional[int] = None,
                 precomputed_route: Optional[Tuple] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """[B,S,d] -> ([B,S,d], aux). Computes the local expert slice
    [offset, offset+n_local); with EP sharding, shards psum their outputs."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    el = params["experts"]["w_gate"].shape[0]     # local expert count
    n_local = n_local or el
    assert el == n_local, "expert param slice must match n_local"
    if precomputed_route is not None:
        weights, ids, probs = precomputed_route
    else:
        weights, ids, probs = route(params["router"], xf, topk, norm_topk, n_valid)
    e_total = probs.shape[-1]
    e_valid = n_valid or e_total
    capacity = max(1, math.ceil(n * topk * capacity_factor / e_valid))
    slot_pair, slot_w, valid = dispatch_indices(
        ids, weights, capacity, expert_offset, n_local)
    tok = slot_pair // topk
    gathered = xf[tok] * valid[:, None].astype(xf.dtype)          # [E_loc*C, d]
    gt = gathered.reshape(el, capacity, d)
    g = jnp.einsum("ecd,edf->ecf", gt, params["experts"]["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", gt, params["experts"]["w_up"])
    h = act_fn(act)(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, params["experts"]["w_down"])
    y = y.reshape(el * capacity, d) * slot_w[:, None].astype(y.dtype)
    out = jnp.zeros_like(xf).at[tok].add(y, mode="drop")
    aux = load_balance_loss(probs, ids, e_valid)
    return out.reshape(b, s, d), aux


def _expert_compute(experts: dict, xf: jax.Array, slot_pair, slot_w, valid,
                    capacity: int, act: str, topk: int) -> jax.Array:
    """Gather -> batched expert einsum -> weighted scatter-add. [N,d]->[N,d]."""
    d = xf.shape[-1]
    el = experts["w_gate"].shape[0]
    tok = slot_pair // topk
    gathered = xf[tok] * valid[:, None].astype(xf.dtype)
    gt = gathered.reshape(el, capacity, d)
    g = jnp.einsum("ecd,edf->ecf", gt, experts["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", gt, experts["w_up"])
    h = act_fn(act)(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, experts["w_down"])
    y = y.reshape(el * capacity, d) * slot_w[:, None].astype(y.dtype)
    return jnp.zeros_like(xf).at[tok].add(y, mode="drop")


def moe_ep_shardmap(params: dict, x: jax.Array, *, topk: int, mesh,
                    dp_axes, tp_axis: str = "model",
                    capacity_factor: float = 1.25, norm_topk: bool = False,
                    act: str = "silu", n_valid: Optional[int] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel routed experts via shard_map (DESIGN.md §5).

    x is replicated over the ``model`` axis (the TP all-reduce of the
    preceding attention already guarantees this); each model shard routes
    its local tokens, evaluates only its local expert slice, and a psum
    over ``model`` combines — the same all-reduce a dense TP MLP needs, so
    EP adds no extra collective.
    """
    from jax.sharding import PartitionSpec as P
    try:
        from jax.shard_map import shard_map          # jax >= 0.9
    except ImportError:
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            from jax.experimental.shard_map import shard_map

    e_padded = params["experts"]["w_gate"].shape[0]
    tp = mesh.shape[tp_axis]
    assert e_padded % tp == 0, (e_padded, tp)
    e_loc = e_padded // tp
    e_valid = n_valid or e_padded
    x_spec = P(dp_axes, None, None)
    dp_size = 1
    for a in ((dp_axes,) if isinstance(dp_axes, str) else (dp_axes or ())):
        dp_size *= mesh.shape[a]

    def local_fn(router_w, experts, xl):
        b, s, d = xl.shape
        xf = xl.reshape(-1, d)
        n = xf.shape[0]
        offset = jax.lax.axis_index(tp_axis) * e_loc
        weights, ids, probs = route(router_w, xf, topk, norm_topk, n_valid)
        capacity = max(1, math.ceil(n * topk * capacity_factor / e_valid))
        slot_pair, slot_w, valid = dispatch_indices(ids, weights, capacity,
                                                    offset, e_loc)
        out = _expert_compute(experts, xf, slot_pair, slot_w, valid,
                              capacity, act, topk)
        out = jax.lax.psum(out, tp_axis)
        aux = load_balance_loss(probs, ids, e_valid)
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)
        return out.reshape(b, s, d), aux

    expert_specs = jax.tree.map(lambda _: P(tp_axis, None, None),
                                params["experts"])
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(P(), expert_specs, x_spec),
                   out_specs=(x_spec, P()),
                   check_rep=False)
    return fn(params["router"], params["experts"], x)
