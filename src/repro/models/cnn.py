"""The paper's benchmark DNNs in JAX: ResNet18/50, UNet, InceptionV3(-lite).

These power the *real-execution* validation path (serving/engine.py): each
model exposes ``stages`` — the paper's logical stage boundaries (ResNet ->
its 4 residual stages, §III-B1) — as separately jittable callables, which
is exactly what DARIS schedules. NHWC layout, lax.conv. InceptionV3 keeps
the multi-branch A/B/C block structure at reduced depth (the property the
paper exercises — narrow parallel branches that batch well — is preserved).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from .layers import InitCtx, dense_init


def conv_init(ctx: InitCtx, kh, kw, cin, cout):
    fan = kh * kw * cin
    w = jax.random.truncated_normal(ctx.next(), -2, 2, (kh, kw, cin, cout),
                                    jnp.float32) / np.sqrt(fan)
    return w.astype(ctx.dtype)


def conv(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def bn_apply(p, x):
    """Inference-style norm (scale/bias only; stats folded)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(xf, axis=(0, 1, 2), keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def bn_init(ctx, c):
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32)}


def _convbn(ctx, kh, kw, cin, cout):
    return {"w": conv_init(ctx, kh, kw, cin, cout), "bn": bn_init(ctx, cout)}


def _convbn_apply(p, x, stride=1, act=True):
    y = bn_apply(p["bn"], conv(x, p["w"], stride))
    return jax.nn.relu(y) if act else y


# ---------------------------------------------------------------- ResNet
def _basic_block(ctx, cin, cout, stride):
    p = {"c1": _convbn(ctx, 3, 3, cin, cout), "c2": _convbn(ctx, 3, 3, cout, cout)}
    if stride != 1 or cin != cout:
        p["proj"] = _convbn(ctx, 1, 1, cin, cout)
    return p


def _basic_apply(p, x, stride):
    y = _convbn_apply(p["c1"], x, stride)
    y = _convbn_apply(p["c2"], y, act=False)
    sc = _convbn_apply(p["proj"], x, stride, act=False) if "proj" in p else x
    return jax.nn.relu(y + sc)


def _bottleneck_block(ctx, cin, cmid, stride):
    cout = cmid * 4
    p = {"c1": _convbn(ctx, 1, 1, cin, cmid),
         "c2": _convbn(ctx, 3, 3, cmid, cmid),
         "c3": _convbn(ctx, 1, 1, cmid, cout)}
    if stride != 1 or cin != cout:
        p["proj"] = _convbn(ctx, 1, 1, cin, cout)
    return p


def _bottleneck_apply(p, x, stride):
    y = _convbn_apply(p["c1"], x)
    y = _convbn_apply(p["c2"], y, stride)
    y = _convbn_apply(p["c3"], y, act=False)
    sc = _convbn_apply(p["proj"], x, stride, act=False) if "proj" in p else x
    return jax.nn.relu(y + sc)


@dataclasses.dataclass
class StagedCNN:
    name: str
    params: dict
    stages: List[Callable]            # stage_fn(params, x) -> x
    input_hw: int = 64
    n_classes: int = 100

    def forward(self, params, x):
        for st in self.stages:
            x = st(params, x)
        return x


def build_resnet(depth: int = 18, *, seed: int = 0, n_classes: int = 100,
                 width: int = 32) -> StagedCNN:
    ctx = InitCtx(jax.random.PRNGKey(seed), jnp.float32)
    basic = depth == 18
    blocks_per = {18: (2, 2, 2, 2), 50: (3, 4, 6, 3)}[depth]
    widths = (width, width * 2, width * 4, width * 8)
    params = {"stem": _convbn(ctx, 7, 7, 3, width)}
    cin = width
    for si, (n, w) in enumerate(zip(blocks_per, widths)):
        blocks = []
        for bi in range(n):
            stride = 2 if (bi == 0 and si > 0) else 1
            if basic:
                blocks.append(_basic_block(ctx, cin, w, stride))
                cin = w
            else:
                blocks.append(_bottleneck_block(ctx, cin, w, stride))
                cin = w * 4
        params[f"stage{si}"] = blocks
    params["head"] = dense_init(ctx, (cin, n_classes))

    def make_stage(si):
        def fn(p, x):
            if si == 0:
                x = _convbn_apply(p["stem"], x, 2)
                x = jax.lax.reduce_window(
                    x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1),
                    "SAME")
            for bi, bp in enumerate(p[f"stage{si}"]):
                stride = 2 if (bi == 0 and si > 0) else 1
                x = (_basic_apply(bp, x, stride) if basic
                     else _bottleneck_apply(bp, x, stride))
            if si == 3:
                x = jnp.mean(x, axis=(1, 2))
                x = x @ p["head"]
            return x
        return fn

    return StagedCNN(name=f"resnet{depth}", params=params,
                     stages=[make_stage(i) for i in range(4)],
                     n_classes=n_classes)


# ---------------------------------------------------------------- UNet
def build_unet(*, seed: int = 0, width: int = 24) -> StagedCNN:
    ctx = InitCtx(jax.random.PRNGKey(seed), jnp.float32)
    ws = (width, width * 2, width * 4, width * 8)
    params = {}
    cin = 3
    for i, w in enumerate(ws):
        params[f"down{i}"] = {"c1": _convbn(ctx, 3, 3, cin, w),
                              "c2": _convbn(ctx, 3, 3, w, w)}
        cin = w
    params["mid"] = {"c1": _convbn(ctx, 3, 3, cin, cin * 2),
                     "c2": _convbn(ctx, 3, 3, cin * 2, cin)}
    for i, w in reversed(list(enumerate(ws))):
        cin_up = ws[min(i + 1, len(ws) - 1)] + w   # upsampled x + skip
        params[f"up{i}"] = {"c1": _convbn(ctx, 3, 3, cin_up, w),
                            "c2": _convbn(ctx, 3, 3, w, w)}
    params["out"] = conv_init(ctx, 1, 1, ws[0], 2)

    def down_path(p, x, rng=(0, 2)):
        skips = x[1] if isinstance(x, tuple) else []
        x = x[0] if isinstance(x, tuple) else x
        for i in range(*rng):
            blk = p[f"down{i}"]
            x = _convbn_apply(blk["c2"], _convbn_apply(blk["c1"], x))
            skips = skips + [x]
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "SAME")
        return (x, skips)

    def stage0(p, x):
        return down_path(p, x, (0, 2))

    def stage1(p, x):
        x, skips = down_path(p, x, (2, 4))
        blk = p["mid"]
        x = _convbn_apply(blk["c2"], _convbn_apply(blk["c1"], x))
        return (x, skips)

    def up_path(p, state, rng):
        x, skips = state
        for i in rng:
            sk = skips[i]
            b, h, w, c = sk.shape
            x = jax.image.resize(x, (b, h, w, x.shape[-1]), "nearest")
            x = jnp.concatenate([x, sk], axis=-1)
            blk = p[f"up{i}"]
            x = _convbn_apply(blk["c2"], _convbn_apply(blk["c1"], x))
        return (x, skips)

    def stage2(p, state):
        return up_path(p, state, (3, 2))

    def stage3(p, state):
        x, _ = up_path(p, state, (1, 0))
        return conv(x, p["out"])

    return StagedCNN(name="unet", params=params,
                     stages=[stage0, stage1, stage2, stage3])


# ------------------------------------------------------------ InceptionV3
def _inception_a(ctx, cin, w):
    return {
        "b1": _convbn(ctx, 1, 1, cin, w),
        "b2a": _convbn(ctx, 1, 1, cin, w), "b2b": _convbn(ctx, 5, 5, w, w),
        "b3a": _convbn(ctx, 1, 1, cin, w), "b3b": _convbn(ctx, 3, 3, w, w),
        "b3c": _convbn(ctx, 3, 3, w, w),
        "bp": _convbn(ctx, 1, 1, cin, w),
    }


def _inception_a_apply(p, x):
    b1 = _convbn_apply(p["b1"], x)
    b2 = _convbn_apply(p["b2b"], _convbn_apply(p["b2a"], x))
    b3 = _convbn_apply(p["b3c"], _convbn_apply(p["b3b"],
                                               _convbn_apply(p["b3a"], x)))
    pool = jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, 3, 3, 1),
                                 (1, 1, 1, 1), "SAME") / 9.0
    bp = _convbn_apply(p["bp"], pool)
    return jnp.concatenate([b1, b2, b3, bp], axis=-1)


def build_inception(*, seed: int = 0, width: int = 24,
                    n_classes: int = 100) -> StagedCNN:
    ctx = InitCtx(jax.random.PRNGKey(seed), jnp.float32)
    params = {
        "stem1": _convbn(ctx, 3, 3, 3, width),
        "stem2": _convbn(ctx, 3, 3, width, width * 2),
    }
    cin = width * 2
    for i in range(3):
        params[f"a{i}"] = _inception_a(ctx, cin, width)
        cin = width * 4
    params["red"] = _convbn(ctx, 3, 3, cin, cin)
    for i in range(2):
        params[f"b{i}"] = _inception_a(ctx, cin, width * 2)
        cin = width * 8
    params["head"] = dense_init(ctx, (cin, n_classes))

    def stage0(p, x):
        x = _convbn_apply(p["stem1"], x, 2)
        x = _convbn_apply(p["stem2"], x, 1)
        return _inception_a_apply(p["a0"], x)

    def stage1(p, x):
        x = _inception_a_apply(p["a1"], x)
        return _inception_a_apply(p["a2"], x)

    def stage2(p, x):
        x = _convbn_apply(p["red"], x, 2)
        return _inception_a_apply(p["b0"], x)

    def stage3(p, x):
        x = _inception_a_apply(p["b1"], x)
        x = jnp.mean(x, axis=(1, 2))
        return x @ p["head"]

    return StagedCNN(name="inceptionv3", params=params,
                     stages=[stage0, stage1, stage2, stage3])


BUILDERS = {
    "resnet18": lambda **kw: build_resnet(18, **kw),
    "resnet50": lambda **kw: build_resnet(50, **kw),
    "unet": build_unet,
    "inceptionv3": build_inception,
}
