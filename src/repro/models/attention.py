"""GQA attention: rope, qkv-bias, logit softcap, sliding window, KV caches.

One generic core ``mha`` drives prefill, decode and cross-attention; masking
is derived from explicit query/key *positions* (never a materialized [S,T]
mask tensor) so 32k/500k cells stay compile-able. ``q_chunk`` blocks the
query axis through ``lax.map`` to bound the score-matrix working set for
long-sequence prefill.

KV caches are dicts ``{"k", "v", "length"}`` (+ ``"k_scale"/"v_scale"`` for
int8). int8 KV (per-token-per-head scales) is the beyond-paper optimization
that lets qwen1.5-32b decode_32k fit a 256x16GB pod (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import InitCtx, apply_rope, dense_init, zeros_init

NEG_INF = -2.0e38


def init_attention(ctx: InitCtx, d: int, n_heads: int, n_kv: int,
                   head_dim: int, qkv_bias: bool = False,
                   out_bias: bool = False) -> dict:
    p = {
        "wq": dense_init(ctx, (d, n_heads, head_dim)),
        "wk": dense_init(ctx, (d, n_kv, head_dim)),
        "wv": dense_init(ctx, (d, n_kv, head_dim)),
        "wo": dense_init(ctx, (n_heads, head_dim, d), scale=1.0 / (n_heads * head_dim) ** 0.5),
    }
    if qkv_bias:
        p["bq"] = zeros_init(ctx, (n_heads, head_dim))
        p["bk"] = zeros_init(ctx, (n_kv, head_dim))
        p["bv"] = zeros_init(ctx, (n_kv, head_dim))
    if out_bias:
        p["bo"] = zeros_init(ctx, (d,))
    return p


def _mask_bias(q_pos, kv_pos, causal: bool, window: int) -> jax.Array:
    """Additive f32 mask [.., Sq, Skv] from positions. kv_pos < 0 = invalid."""
    qp = q_pos[..., :, None].astype(jnp.int32)
    kp = kv_pos[..., None, :].astype(jnp.int32)
    ok = kp >= 0
    if causal:
        ok = ok & (kp <= qp)
    if window > 0:
        ok = ok & (qp - kp < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _attend(q, k, v, q_pos, kv_pos, *, causal, window, cap, scale):
    """q:[B,Sq,H,D] k/v:[B,Skv,KV,D] -> [B,Sq,H,D]. f32 softmax."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k).astype(jnp.float32) * scale
    if cap > 0.0:
        s = cap * jnp.tanh(s / cap)
    s = s + _mask_bias(q_pos, kv_pos, causal, window)[:, None, None]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(b, sq, h, dh)


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, q_positions: jax.Array,
        kv_positions: jax.Array, causal: bool = True, window: int = 0,
        attn_softcap: float = 0.0, scale: Optional[float] = None,
        q_chunk: int = 0) -> jax.Array:
    """Generic attention core. Positions are [B, S] (or [S] broadcast)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if q_positions.ndim == 1:
        q_positions = jnp.broadcast_to(q_positions[None], (q.shape[0], q.shape[1]))
    if kv_positions.ndim == 1:
        kv_positions = jnp.broadcast_to(kv_positions[None], (k.shape[0], k.shape[1]))
    sq = q.shape[1]
    if q_chunk and sq > q_chunk and sq % q_chunk == 0:
        nb = sq // q_chunk
        qb = q.reshape(q.shape[0], nb, q_chunk, *q.shape[2:]).swapaxes(0, 1)
        pb = q_positions.reshape(q.shape[0], nb, q_chunk).swapaxes(0, 1)
        out = jax.lax.map(
            lambda args: _attend(args[0], k, v, args[1], kv_positions,
                                 causal=causal, window=window,
                                 cap=attn_softcap, scale=scale),
            (qb, pb))
        return out.swapaxes(0, 1).reshape(q.shape)
    return _attend(q, k, v, q_positions, kv_positions, causal=causal,
                   window=window, cap=attn_softcap, scale=scale)


# ---------------------------------------------------------------------------
# KV cache (bf16 or int8 with per-token-per-head scales)
# ---------------------------------------------------------------------------
def make_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype: str = "bfloat16", ring: bool = False) -> dict:
    """``ring=True`` makes a rolling window buffer (sliding-window layers):
    slot = position % max_len, per-slot absolute positions kept in
    ``slots_pos`` so masking stays position-exact. Ring caches are what cap
    gemma2 local layers at window size for the long_500k cell."""
    del ring  # slot arithmetic below is modulo max_len, which covers both
    cache = {
        "length": jnp.zeros((), jnp.int32),
        "slots_pos": jnp.full((max_len,), -1, jnp.int32),
    }
    if dtype == "int8":
        cache.update({
            "k": jnp.zeros((batch, max_len, n_kv, head_dim), jnp.int8),
            "v": jnp.zeros((batch, max_len, n_kv, head_dim), jnp.int8),
            "k_scale": jnp.zeros((batch, max_len, n_kv), jnp.float32),
            "v_scale": jnp.zeros((batch, max_len, n_kv), jnp.float32),
        })
    else:
        cache.update({
            "k": jnp.zeros((batch, max_len, n_kv, head_dim), jnp.dtype(dtype)),
            "v": jnp.zeros((batch, max_len, n_kv, head_dim), jnp.dtype(dtype)),
        })
    return cache


def _quant(x: jax.Array):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None]).astype(jnp.int8)
    return q, scale


def _dequant(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def update_kv_cache(cache: dict, k_new: jax.Array, v_new: jax.Array,
                    start: jax.Array) -> dict:
    """Write k/v [B, S_new, KV, D] at absolute position ``start``.

    Ring caches map to slot ``start % max_len`` (single-token or
    non-wrapping block writes, which is all decode needs)."""
    out = dict(cache)
    s_new = k_new.shape[1]
    s_max = cache["k"].shape[1]
    length_new = start + s_new
    if s_new > s_max:
        # ring cache smaller than the prefill: keep only the window tail
        k_new = k_new[:, -s_max:]
        v_new = v_new[:, -s_max:]
        start = start + (s_new - s_max)
        s_new = s_max
        slot = jnp.zeros((), jnp.int32)
    else:
        slot = start % s_max
    pos_new = start + jnp.arange(s_new, dtype=jnp.int32)

    def upd(buf, val):
        return jax.lax.dynamic_update_slice_in_dim(buf, val, slot, 1)

    if cache["k"].dtype == jnp.int8:
        kq, ks = _quant(k_new)
        vq, vs = _quant(v_new)
        out["k"], out["v"] = upd(cache["k"], kq), upd(cache["v"], vq)
        out["k_scale"] = upd(cache["k_scale"], ks)
        out["v_scale"] = upd(cache["v_scale"], vs)
    else:
        out["k"] = upd(cache["k"], k_new.astype(cache["k"].dtype))
        out["v"] = upd(cache["v"], v_new.astype(cache["v"].dtype))
    out["slots_pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache["slots_pos"], pos_new, slot, 0)
    out["length"] = length_new
    return out


def read_kv_cache(cache: dict, compute_dtype) -> tuple:
    """Full-cache k/v in compute dtype + kv positions (-1 where invalid)."""
    if cache["k"].dtype == jnp.int8:
        k = _dequant(cache["k"], cache["k_scale"], compute_dtype)
        v = _dequant(cache["v"], cache["v_scale"], compute_dtype)
    else:
        k = cache["k"].astype(compute_dtype)
        v = cache["v"].astype(compute_dtype)
    return k, v, cache["slots_pos"]


def attend_cache_chunked(q: jax.Array, cache: dict, q_positions: jax.Array,
                         *, causal: bool = True, window: int = 0,
                         attn_softcap: float = 0.0, scale: float = 1.0,
                         kv_chunk: int = 4096) -> jax.Array:
    """Flash-decode over the KV cache: online softmax across KV chunks.

    Never materializes the full (dequantized) cache or the full score
    matrix — per-chunk slices only, f32 running (m, l, acc). This is what
    keeps qwen1.5-32b decode_32k inside 16 GB/chip."""
    b, sq, h, dh = q.shape
    kvh = cache["k"].shape[2]
    g = h // kvh
    t = cache["k"].shape[1]
    nc = max(t // kv_chunk, 1)
    kv_chunk = t // nc
    qg = q.reshape(b, sq, kvh, g, dh)
    if q_positions.ndim == 1:
        q_positions = jnp.broadcast_to(q_positions[None], (b, sq))
    is_int8 = cache["k"].dtype == jnp.int8

    def step(carry, idx):
        m, l, acc = carry
        off = idx * kv_chunk
        ks = jax.lax.dynamic_slice_in_dim(cache["k"], off, kv_chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(cache["v"], off, kv_chunk, 1)
        if is_int8:
            kss = jax.lax.dynamic_slice_in_dim(cache["k_scale"], off, kv_chunk, 1)
            vss = jax.lax.dynamic_slice_in_dim(cache["v_scale"], off, kv_chunk, 1)
            ks = _dequant(ks, kss, q.dtype)
            vs = _dequant(vs, vss, q.dtype)
        else:
            ks = ks.astype(q.dtype)
            vs = vs.astype(q.dtype)
        kp = jax.lax.dynamic_slice_in_dim(cache["slots_pos"], off, kv_chunk, 0)
        s = jnp.einsum("bqkgd,btkd->bkgqt", qg, ks).astype(jnp.float32) * scale
        if attn_softcap > 0.0:
            s = attn_softcap * jnp.tanh(s / attn_softcap)
        s = s + _mask_bias(q_positions, jnp.broadcast_to(kp[None], (b, kv_chunk)),
                           causal, window)[:, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bkgqt,btkd->bkgqd", p.astype(vs.dtype),
                                vs).astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  jnp.arange(nc, dtype=jnp.int32))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + core + output)
# ---------------------------------------------------------------------------
def attention_block(params: dict, x: jax.Array, *, positions: jax.Array,
                    rope_theta: float = 10000.0, causal: bool = True,
                    window: int = 0, attn_softcap: float = 0.0,
                    scale: Optional[float] = None, q_chunk: int = 0,
                    cache: Optional[dict] = None,
                    x_kv: Optional[jax.Array] = None, cons=None) -> tuple:
    """Returns (out [B,S,d], new_cache | None).

    - self-attention prefill: cache=None or fresh cache to fill.
    - decode: cache holds history; x is the new token block.
    - cross-attention: pass x_kv (encoder states), causal=False, cache=None.
    """
    src = x if x_kv is None else x_kv
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if rope_theta > 0.0 and x_kv is None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if cons is not None:
        q = cons.heads(q)
        k = cons.kv_heads(k)
        v = cons.kv_heads(v)

    if scale is None:
        scale = q.shape[-1] ** -0.5
    new_cache = None
    if cache is not None:
        start = cache["length"]
        new_cache = update_kv_cache(cache, k, v, start)
        if q.shape[1] > 1:
            # Prefill-from-scratch: attend on the *fresh* k/v (ring caches
            # hold only the window tail; reading back would also double the
            # working set). Chunked continuation-prefill is unsupported.
            out = mha(q, k, v, q_positions=positions, kv_positions=positions,
                      causal=causal, window=window, attn_softcap=attn_softcap,
                      scale=scale, q_chunk=q_chunk)
        elif new_cache["k"].shape[1] > 8192:
            out = attend_cache_chunked(q, new_cache, positions, causal=causal,
                                       window=window, attn_softcap=attn_softcap,
                                       scale=scale)
        else:
            kc, vc, kv_pos = read_kv_cache(new_cache, x.dtype)
            out = mha(q, kc, vc, q_positions=positions, kv_positions=kv_pos,
                      causal=causal, window=window, attn_softcap=attn_softcap,
                      scale=scale)
    else:
        if x_kv is not None:
            kv_pos = jnp.arange(src.shape[1], dtype=jnp.int32)
        else:
            kv_pos = positions
        out = mha(q, k, v, q_positions=positions, kv_positions=kv_pos,
                  causal=causal and x_kv is None, window=window,
                  attn_softcap=attn_softcap, scale=scale, q_chunk=q_chunk)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if "bo" in params:
        y = y + params["bo"]
    return y, new_cache
