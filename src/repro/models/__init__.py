from .api import Model, build_model, pad_heads_for_tp  # noqa: F401
