"""Common model primitives: norms, MLPs, rope, embeddings, init helpers.

Everything is a pure function over explicit param pytrees (nested dicts of
jnp arrays). Initializers take an ``InitCtx`` carrying the rng stream and
target dtype so builders stay compact.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class InitCtx:
    """Sequential rng-splitting helper for param init."""
    key: jax.Array
    dtype: jnp.dtype

    def next(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub


def dense_init(ctx: InitCtx, shape, scale: Optional[float] = None) -> jax.Array:
    """Truncated-normal init with 1/sqrt(fan_in) scaling (fan_in = shape[0])."""
    fan_in = shape[0]
    std = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    w = jax.random.truncated_normal(ctx.next(), -2.0, 2.0, shape, jnp.float32) * std
    return w.astype(ctx.dtype)


def embed_init(ctx: InitCtx, vocab: int, d: int) -> jax.Array:
    w = jax.random.normal(ctx.next(), (vocab, d), jnp.float32) * 0.02
    return w.astype(ctx.dtype)


def zeros_init(ctx: InitCtx, shape) -> jax.Array:
    return jnp.zeros(shape, ctx.dtype)


def ones_init(ctx: InitCtx, shape) -> jax.Array:
    return jnp.ones(shape, ctx.dtype)


# ---------------------------------------------------------------------------
# Norms (f32 accumulation regardless of param dtype)
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:                       # gemma-style (1 + w) parameterization
        w = 1.0 + w
    return (y * w).astype(x.dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma2-style logit soft-capping: cap * tanh(x / cap)."""
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------
def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "gelu_tanh":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name}")


def init_gated_mlp(ctx: InitCtx, d: int, d_ff: int) -> dict:
    return {
        "w_gate": dense_init(ctx, (d, d_ff)),
        "w_up": dense_init(ctx, (d, d_ff)),
        "w_down": dense_init(ctx, (d_ff, d)),
    }


def gated_mlp(params: dict, x: jax.Array, act: str = "silu",
              cons=None) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = act_fn(act)(g) * u
    if cons is not None:
        h = cons.ffn(h)
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def init_mlp(ctx: InitCtx, d: int, d_ff: int) -> dict:
    """Plain 2-layer MLP (whisper)."""
    return {
        "w_in": dense_init(ctx, (d, d_ff)),
        "b_in": zeros_init(ctx, (d_ff,)),
        "w_out": dense_init(ctx, (d_ff, d)),
        "b_out": zeros_init(ctx, (d,)),
    }


def mlp(params: dict, x: jax.Array, act: str = "gelu") -> jax.Array:
    h = act_fn(act)(jnp.einsum("...d,df->...f", x, params["w_in"]) + params["b_in"])
    return jnp.einsum("...f,fd->...d", h, params["w_out"]) + params["b_out"]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # [Dh/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(ang)[..., :, None, :]                  # [..., S, 1, Dh/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style sinusoidal position table [n, d] (f32)."""
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = 1.0 / (10000 ** (dim / max(d // 2 - 1, 1)))
    ang = pos * inv
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=1),
                       dtype=jnp.float32)
