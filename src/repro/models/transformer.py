"""Decoder-only LM assembly for all LM families.

Layers are **stacked** (leading L axis on every leaf) and driven by
``lax.scan`` so a 64-layer model compiles like one layer — essential for the
single-core dry-run of 40 (arch x shape) cells. Families:

  dense   : [attn, mlp] x L            (qwen1.5, stablelm, smollm, pixtral backbone)
  gemma2  : [(local attn, mlp), (global attn, mlp)] x L/2, softcaps, post-norms
  moe     : [attn|mla, moe] x L with optional leading dense layers (deepseek)
  ssm     : [mamba2] x L               (mamba2-2.7b)
  hybrid  : [mamba2] x L with a weight-tied shared attention block applied
            every ``attn_every`` layers (zamba2; lax.cond inside the scan)

Caches are pytrees with the same leading L axis, threaded through the scan
as xs/ys. ``mode`` is implied: cache=None -> train/loss forward;
cache given -> prefill (L>1) or decode (L==1) with absolute positions.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention_block, init_attention, make_kv_cache
from .layers import (InitCtx, dense_init, embed_init, gated_mlp,
                     init_gated_mlp, ones_init, rms_norm, softcap)
from .mamba2 import init_mamba2, make_ssm_cache, mamba2_block
from .mla import init_mla, make_mla_cache, mla_block
from .moe import init_moe, moe_capacity, moe_dense_oracle, moe_ep_shardmap


# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------
def _init_dense_layer(key, cfg, is_local: bool = False) -> dict:
    ctx = InitCtx(key, jnp.dtype(cfg.dtype))
    p = {
        "ln1": ones_init(ctx, (cfg.d_model,)),
        "attn": init_attention(ctx, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.resolved_head_dim, cfg.qkv_bias),
        "ln2": ones_init(ctx, (cfg.d_model,)),
        "mlp": init_gated_mlp(ctx, cfg.d_model, cfg.d_ff),
    }
    if cfg.post_block_norms:
        p["ln1_post"] = ones_init(ctx, (cfg.d_model,))
        p["ln2_post"] = ones_init(ctx, (cfg.d_model,))
    return p


def _init_moe_layer(key, cfg, n_experts_padded: int) -> dict:
    ctx = InitCtx(key, jnp.dtype(cfg.dtype))
    p = {"ln1": ones_init(ctx, (cfg.d_model,)),
         "ln2": ones_init(ctx, (cfg.d_model,))}
    if cfg.use_mla:
        p["attn"] = init_mla(ctx, cfg)
    else:
        p["attn"] = init_attention(ctx, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.resolved_head_dim,
                                   cfg.qkv_bias)
    p["moe"] = init_moe(ctx, cfg.d_model, n_experts_padded, cfg.moe_d_ff,
                        cfg.shared_d_ff)
    return p


def _init_ssm_layer(key, cfg) -> dict:
    ctx = InitCtx(key, jnp.dtype(cfg.dtype))
    return {"ln": ones_init(ctx, (cfg.d_model,)),
            "mamba": init_mamba2(ctx, cfg)}


def _init_shared_attn(key, cfg) -> dict:
    """zamba2 weight-tied block: attention over concat(x, x_emb0) [2d],
    output projected straight back to d."""
    ctx = InitCtx(key, jnp.dtype(cfg.dtype))
    d2 = 2 * cfg.d_model
    p = {
        "ln1": ones_init(ctx, (d2,)),
        "attn": init_attention(ctx, d2, cfg.n_heads, cfg.n_kv_heads,
                               cfg.resolved_head_dim),
        "ln2": ones_init(ctx, (cfg.d_model,)),
        "mlp": init_gated_mlp(ctx, cfg.d_model, cfg.d_ff),
    }
    hd = cfg.resolved_head_dim
    p["attn"]["wo"] = dense_init(ctx, (cfg.n_heads, hd, cfg.d_model),
                                 scale=1.0 / (cfg.n_heads * hd) ** 0.5)
    return p


def moe_padded_experts(cfg) -> int:
    """Pad expert count to a multiple of the EP shard width (qwen2 60->64
    when ep_shards=16). Dummy experts are masked from routing."""
    e, w = cfg.n_experts, max(cfg.ep_shards, 1)
    return e if e % w == 0 else e + (w - e % w)


def init_lm(key: jax.Array, cfg) -> dict:
    ctx = InitCtx(key, jnp.dtype(cfg.dtype))
    params = {"embed": embed_init(ctx, cfg.vocab_size, cfg.d_model),
              "final_norm": ones_init(ctx, (cfg.d_model,))}
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ctx, (cfg.d_model, cfg.vocab_size))

    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.local_global_alternating:
            nb = cfg.n_layers // 2
            keys = jax.random.split(ctx.next(), nb)
            params["layers"] = jax.vmap(lambda k: {
                "local": _init_dense_layer(k, cfg, True),
                "global": _init_dense_layer(jax.random.fold_in(k, 1), cfg),
            })(keys)
        else:
            keys = jax.random.split(ctx.next(), cfg.n_layers)
            params["layers"] = jax.vmap(
                lambda k: _init_dense_layer(k, cfg))(keys)
    elif fam == "moe":
        ep = moe_padded_experts(cfg)
        if cfg.n_dense_layers:
            dense_cfg_keys = jax.random.split(ctx.next(), cfg.n_dense_layers)
            params["dense_layers"] = [
                _init_dense_layer(k, cfg.replace(use_mla=False), False)
                if not cfg.use_mla else _init_mla_dense_layer(k, cfg)
                for k in dense_cfg_keys]
        n_moe = cfg.n_layers - cfg.n_dense_layers
        keys = jax.random.split(ctx.next(), n_moe)
        params["layers"] = jax.vmap(
            lambda k: _init_moe_layer(k, cfg, ep))(keys)
    elif fam == "ssm":
        keys = jax.random.split(ctx.next(), cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: _init_ssm_layer(k, cfg))(keys)
    elif fam == "hybrid":
        keys = jax.random.split(ctx.next(), cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: _init_ssm_layer(k, cfg))(keys)
        params["shared_attn"] = _init_shared_attn(ctx.next(), cfg)
    else:
        raise ValueError(f"init_lm does not handle family {fam}")
    return params


def _init_mla_dense_layer(key, cfg) -> dict:
    """deepseek leading dense layer: MLA attention + plain gated MLP."""
    ctx = InitCtx(key, jnp.dtype(cfg.dtype))
    return {
        "ln1": ones_init(ctx, (cfg.d_model,)),
        "attn": init_mla(ctx, cfg),
        "ln2": ones_init(ctx, (cfg.d_model,)),
        "mlp": init_gated_mlp(ctx, cfg.d_model, cfg.d_ff),
    }


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------
def _stack(make_one, n: int):
    one = make_one()
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n, *a.shape)).copy()
                        if hasattr(a, "shape") else a, one)


def init_cache(cfg, batch: int, max_len: int) -> dict:
    fam = cfg.family
    kvd = cfg.kv_cache_dtype
    hd = cfg.resolved_head_dim
    if fam in ("dense", "vlm"):
        if cfg.local_global_alternating:
            nb = cfg.n_layers // 2
            local_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
            return {
                "local": _stack(lambda: make_kv_cache(
                    batch, local_len, cfg.n_kv_heads, hd, kvd), nb),
                "global": _stack(lambda: make_kv_cache(
                    batch, max_len, cfg.n_kv_heads, hd, kvd), nb),
            }
        return _stack(lambda: make_kv_cache(
            batch, max_len, cfg.n_kv_heads, hd, kvd), cfg.n_layers)
    if fam == "moe":
        make_one = ((lambda: make_mla_cache(batch, max_len, cfg, kvd))
                    if cfg.use_mla else
                    (lambda: make_kv_cache(batch, max_len, cfg.n_kv_heads,
                                           hd, kvd)))
        out = {"layers": _stack(make_one, cfg.n_layers - cfg.n_dense_layers)}
        if cfg.n_dense_layers:
            out["dense_layers"] = [make_one() for _ in range(cfg.n_dense_layers)]
        return out
    if fam == "ssm":
        return _stack(lambda: make_ssm_cache(batch, cfg, cfg.dtype), cfg.n_layers)
    if fam == "hybrid":
        n_apps = cfg.n_layers // cfg.attn_every
        return {
            "mamba": _stack(lambda: make_ssm_cache(batch, cfg, cfg.dtype),
                            cfg.n_layers),
            "attn": _stack(lambda: make_kv_cache(batch, max_len,
                                                 cfg.n_kv_heads, hd, kvd),
                           n_apps),
        }
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Layer bodies (shared by train / prefill / decode)
# ---------------------------------------------------------------------------
def _dense_body(lp, x, cfg, positions, cache, window: int, q_chunk: int,
                cons=None):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps, plus_one=cfg.embed_scale)
    a, new_cache = attention_block(
        lp["attn"], h, positions=positions, rope_theta=cfg.rope_theta,
        window=window, attn_softcap=cfg.attn_softcap,
        scale=cfg.resolved_head_dim ** -0.5, q_chunk=q_chunk, cache=cache,
        cons=cons)
    if cfg.post_block_norms:
        a = rms_norm(a, lp["ln1_post"], cfg.norm_eps, plus_one=cfg.embed_scale)
    x = x + a
    if cons is not None:
        x = cons.hidden(x)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps, plus_one=cfg.embed_scale)
    m = gated_mlp(lp["mlp"], h, cfg.mlp_act, cons=cons)
    if cfg.post_block_norms:
        m = rms_norm(m, lp["ln2_post"], cfg.norm_eps, plus_one=cfg.embed_scale)
    x = x + m
    if cons is not None:
        x = cons.hidden(x)
    return x, new_cache


def _moe_body(lp, x, cfg, positions, cache, q_chunk, use_oracle: bool,
              ep=None, cons=None):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        a, new_cache = mla_block(lp["attn"], h, cfg=cfg, positions=positions,
                                 cache=cache, q_chunk=q_chunk, cons=cons)
    else:
        a, new_cache = attention_block(
            lp["attn"], h, positions=positions, rope_theta=cfg.rope_theta,
            scale=cfg.resolved_head_dim ** -0.5, q_chunk=q_chunk, cache=cache,
            cons=cons)
    x = x + a
    if cons is not None:
        x = cons.hidden(x)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    n_valid = cfg.n_experts
    if ep is not None and ep.get("mesh") is not None:
        mo, aux = moe_ep_shardmap(lp["moe"], h, topk=cfg.n_experts_active,
                                  mesh=ep["mesh"], dp_axes=ep["dp"],
                                  tp_axis=ep.get("tp", "model"),
                                  norm_topk=cfg.router_norm_topk,
                                  act=cfg.mlp_act, n_valid=n_valid)
    elif use_oracle:
        mo, aux = moe_dense_oracle(lp["moe"], h, cfg.n_experts_active,
                                   cfg.router_norm_topk, cfg.mlp_act, n_valid)
    else:
        mo, aux = moe_capacity(lp["moe"], h, cfg.n_experts_active,
                               norm_topk=cfg.router_norm_topk,
                               act=cfg.mlp_act, n_valid=n_valid)
    if "shared" in lp["moe"]:
        mo = mo + gated_mlp(lp["moe"]["shared"], h, cfg.mlp_act, cons=cons)
    x = x + mo
    if cons is not None:
        x = cons.hidden(x)
    return x, new_cache, aux


def _ssm_body(lp, x, cfg, cache, use_kernel: bool, cons=None):
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    y, new_cache = mamba2_block(lp["mamba"], h, cfg=cfg, cache=cache,
                                use_kernel=use_kernel, cons=cons)
    x = x + y
    if cons is not None:
        x = cons.hidden(x)
    return x, new_cache


def _shared_attn_body(sp, x, x0, cfg, positions, cache, q_chunk, cons=None):
    """zamba2 shared block on concat(x, original embedding)."""
    cat = jnp.concatenate([x, x0], axis=-1)
    h = rms_norm(cat, sp["ln1"], cfg.norm_eps)
    a, new_cache = attention_block(
        sp["attn"], h, positions=positions, rope_theta=cfg.rope_theta,
        scale=cfg.resolved_head_dim ** -0.5, q_chunk=q_chunk, cache=cache,
        cons=cons)
    x = x + a
    if cons is not None:
        x = cons.hidden(x)
    h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
    return x + gated_mlp(sp["mlp"], h2, cfg.mlp_act, cons=cons), new_cache


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _embed(params, cfg, tokens=None, embeds=None):
    if embeds is None:
        embeds = params["embed"][tokens]
    if cfg.embed_scale:
        embeds = embeds * jnp.asarray(cfg.d_model ** 0.5, embeds.dtype)
    return embeds


def _logits(params, cfg, h):
    h = rms_norm(h, params["final_norm"], cfg.norm_eps, plus_one=cfg.embed_scale)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, w)
    if cfg.logit_softcap > 0:
        logits = softcap(logits, cfg.logit_softcap)
    return logits


def _maybe_remat(fn, remat: str):
    if remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def forward(params: dict, cfg, tokens=None, *, embeds=None,
            cache: Optional[dict] = None, positions=None,
            q_chunk: int = 0, remat: str = "none",
            moe_oracle: Optional[bool] = None, dist=None,
            use_ssd_kernel: bool = False) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (logits, new_cache|None, aux_loss).

    cache=None: pure forward (training). cache given: prefill/decode; token
    positions default to cache length offset.
    """
    from ..parallel.sharding import ActConstraint
    cons = ActConstraint(dist) if dist else None
    ep = (dist if (dist and dist.get("mesh") is not None and cfg.n_experts
                   and dist.get("tp"))
          else None)
    x = _embed(params, cfg, tokens, embeds)
    if cons is not None:
        x = cons.hidden(x)
    bsz, sq = x.shape[0], x.shape[1]
    if positions is None:
        if cache is None:
            positions = jnp.arange(sq, dtype=jnp.int32)
        else:
            start = _cache_length(cfg, cache)
            positions = start + jnp.arange(sq, dtype=jnp.int32)
    if moe_oracle is None:
        moe_oracle = cfg.n_experts > 0 and cfg.n_experts <= 16
    aux_total = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam in ("dense", "vlm"):
        if cfg.local_global_alternating:
            def block(carry, xs):
                xx, aux = carry
                lp, ca = xs
                xx, nc_local = _dense_body(lp["local"], xx, cfg, positions,
                                           None if ca is None else ca["local"],
                                           cfg.sliding_window, q_chunk, cons)
                xx, nc_global = _dense_body(lp["global"], xx, cfg, positions,
                                            None if ca is None else ca["global"],
                                            0, q_chunk, cons)
                nc = None if ca is None else {"local": nc_local, "global": nc_global}
                return (xx, aux), nc
            x, new_cache, aux_total = _scan_layers(
                block, x, params["layers"], cache, remat)
        else:
            def block(carry, xs):
                xx, aux = carry
                lp, ca = xs
                xx, nc = _dense_body(lp, xx, cfg, positions, ca, 0, q_chunk,
                                     cons)
                return (xx, aux), nc
            x, new_cache, aux_total = _scan_layers(
                block, x, params["layers"], cache, remat)

    elif fam == "moe":
        new_dense_caches = []
        for i in range(cfg.n_dense_layers):
            lp = params["dense_layers"][i]
            ca = None if cache is None else cache["dense_layers"][i]
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            if cfg.use_mla:
                a, nc = mla_block(lp["attn"], h, cfg=cfg, positions=positions,
                                  cache=ca, q_chunk=q_chunk, cons=cons)
            else:
                a, nc = attention_block(
                    lp["attn"], h, positions=positions,
                    rope_theta=cfg.rope_theta, q_chunk=q_chunk, cache=ca,
                    cons=cons)
            x = x + a
            x = x + gated_mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                              cfg.mlp_act, cons=cons)
            if cons is not None:
                x = cons.hidden(x)
            new_dense_caches.append(nc)

        def block(carry, xs):
            xx, aux = carry
            lp, ca = xs
            xx, nc, a = _moe_body(lp, xx, cfg, positions, ca, q_chunk,
                                  moe_oracle, ep, cons)
            return (xx, aux + a), nc
        x, new_layer_cache, aux_total = _scan_layers(
            block, x, params["layers"],
            None if cache is None else cache["layers"], remat)
        if cache is None:
            new_cache = None
        else:
            new_cache = {"layers": new_layer_cache}
            if cfg.n_dense_layers:
                new_cache["dense_layers"] = new_dense_caches

    elif fam == "ssm":
        def block(carry, xs):
            xx, aux = carry
            lp, ca = xs
            xx, nc = _ssm_body(lp, xx, cfg, ca, use_ssd_kernel, cons)
            return (xx, aux), nc
        x, new_cache, aux_total = _scan_layers(
            block, x, params["layers"], cache, remat)

    elif fam == "hybrid":
        x0 = x
        sp = params["shared_attn"]
        n_apps = cfg.n_layers // cfg.attn_every

        def block(carry, xs):
            xx, attn_caches, aux = carry
            lp, ca, li = xs
            xx, nc = _ssm_body(lp, xx, cfg, ca, use_ssd_kernel, cons)
            is_app = (li % cfg.attn_every) == cfg.attn_every - 1
            app_idx = jnp.minimum(li // cfg.attn_every, n_apps - 1)

            def with_attn(args):
                xx, caches = args
                if caches is None:
                    y, _ = _shared_attn_body(sp, xx, x0, cfg, positions,
                                             None, q_chunk, cons)
                    return y, caches
                ca_i = jax.tree.map(
                    lambda l: jax.lax.dynamic_index_in_dim(l, app_idx, 0,
                                                           keepdims=False),
                    caches)
                y, nc_i = _shared_attn_body(sp, xx, x0, cfg, positions,
                                            ca_i, q_chunk, cons)
                caches = jax.tree.map(
                    lambda l, u: jax.lax.dynamic_update_index_in_dim(
                        l, u.astype(l.dtype), app_idx, 0),
                    caches, nc_i)
                return y, caches

            def without_attn(args):
                return args

            xx, attn_caches = jax.lax.cond(is_app, with_attn, without_attn,
                                           (xx, attn_caches))
            return (xx, attn_caches, aux), nc

        li_axis = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        mamba_caches = None if cache is None else cache["mamba"]
        attn_caches0 = None if cache is None else cache["attn"]
        body = _maybe_remat(block, remat)
        if cache is None:
            (x, aux_total), _ = jax.lax.scan(
                functools.partial(_hybrid_nocache_step, body),
                (x, aux_total), (params["layers"], li_axis))
            new_cache = None
        else:
            (x, new_attn_caches, aux_total), new_mamba = jax.lax.scan(
                body, (x, attn_caches0, aux_total),
                (params["layers"], mamba_caches, li_axis))
            new_cache = {"mamba": new_mamba, "attn": new_attn_caches}
    else:
        raise ValueError(fam)

    logits = _logits(params, cfg, x)
    if cons is not None:
        logits = cons.logits(logits)
    return logits, new_cache, aux_total


def _hybrid_nocache_step(body, carry, xs):
    """Adapter: run the hybrid block without caches (training path)."""
    x, aux = carry
    lp, li = xs
    (x, _, aux), _ = body((x, None, aux), (lp, None, li))
    return (x, aux), None


def _scan_layers(block, x, layers, cache, remat: str):
    """scan over stacked layers.

    Caches ride in the scan CARRY (indexed dynamic-update per layer) rather
    than as xs/ys: XLA keeps while-loop carries in place, so the multi-GB KV
    cache exists once instead of being double-buffered through the ys
    stream (halves decode-cell peak memory)."""
    aux0 = jnp.zeros((), jnp.float32)
    body = _maybe_remat(block, remat)
    if cache is None:
        def nocache(carry, lp):
            (xx, aux), _ = body(carry, (lp, None))
            return (xx, aux), None
        (x, aux), _ = jax.lax.scan(nocache, (x, aux0), layers)
        return x, None, aux

    n_layers = jax.tree.leaves(layers)[0].shape[0]

    def cached(carry, xs):
        xx, aux, caches = carry
        lp, li = xs
        ca = jax.tree.map(
            lambda l: jax.lax.dynamic_index_in_dim(l, li, 0, keepdims=False),
            caches)
        (xx, aux), nc = body((xx, aux), (lp, ca))
        caches = jax.tree.map(
            lambda l, u: jax.lax.dynamic_update_index_in_dim(
                l, u.astype(l.dtype), li, 0),
            caches, nc)
        return (xx, aux, caches), None

    li_axis = jnp.arange(n_layers, dtype=jnp.int32)
    (x, aux, new_cache), _ = jax.lax.scan(cached, (x, aux0, cache),
                                          (layers, li_axis))
    return x, new_cache, aux


def _cache_length(cfg, cache) -> jax.Array:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.local_global_alternating:
            return cache["global"]["length"][0]
        return cache["length"][0]
    if fam == "moe":
        return cache["layers"]["length"][0]
    if fam == "ssm":
        return cache["length"][0]
    if fam == "hybrid":
        return cache["mamba"]["length"][0]
    raise ValueError(fam)
