"""repro.api — the one front door to DARIS serving.

One scheduler (admission Eq. 11-12, staging, oversubscription, zero-delay
migration) serves every deployment shape; this module is the single typed
facade over it. A ``DarisServer`` is built from a fluent ``ServerConfig``
and drives the shared ``EngineCore`` loop against a pluggable
``ExecutionBackend`` — the calibrated fluid simulator or the threaded
real-JAX executor — with first-class arrival processes (periodic, Poisson
open-loop, recorded trace), dynamic deadline-aware batching
(``.batching(max_batch)``), and injectable fault / scale-out events.

    from repro.api import ServerConfig
    from repro.serving.profiles import device
    from repro.serving.requests import table2_taskset

    server = (ServerConfig.sim()
              .tasks(table2_taskset("resnet18"))
              .contexts(6).oversubscribe(6.0)
              .device(device())
              .horizon_ms(6000).seed(0)
              .build())
    metrics = server.run()

Programmatic clients submit one-shot jobs and introspect live state:

    handle = server.submit(spec, at_ms=100.0)    # admission-tested
    server.drain()                               # run until queues empty
    server.snapshot()                            # queue depths, lanes, ...

No benchmark or example constructs an engine directly anymore; the old
``SimEngine`` / ``RealtimeEngine`` classes survive one release as
deprecated shims over this machinery.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from .chaos.plan import (Brownout, ChaosPlan, DegradationPolicy,
                         RetryPolicy)
from .core.batching import BatchPolicy
from .core.metrics import RunMetrics
from .core.scheduler import DarisScheduler, SchedulerConfig
from .core.task import HP, LP, StageProfile, TaskSpec
from .runtime.arrivals import (ArrivalProcess, ManualArrival,
                               PeriodicArrival, PoissonArrival, TraceArrival)
from .runtime.backend import (ExecutionBackend, RealtimeBackend, SimBackend)
from .runtime.contention import DeviceModel
from .runtime.epoch import EpochSimBackend
from .runtime.engine_core import (AutoscalePolicy, Completion, EngineCore,
                                  FaultPlan, SubmitHandle)

__all__ = [
    "ServerConfig", "DarisServer", "FaultPlan", "AutoscalePolicy",
    "SubmitHandle",
    "ChaosPlan", "RetryPolicy", "DegradationPolicy", "Brownout",
    "ArrivalProcess", "ManualArrival", "PeriodicArrival", "PoissonArrival",
    "TraceArrival",
    "ExecutionBackend", "SimBackend", "EpochSimBackend", "RealtimeBackend",
    "SchedulerConfig", "DeviceModel", "TaskSpec", "StageProfile",
    "BatchPolicy", "HP", "LP", "RunMetrics", "EngineCore", "Completion",
]

SIM, REALTIME = "sim", "realtime"


class ServerConfig:
    """Fluent builder for ``DarisServer``. Every setter returns ``self``;
    ``build()`` validates the whole configuration at once."""

    def __init__(self, backend_kind: str = SIM):
        if backend_kind not in (SIM, REALTIME):
            raise ValueError(f"unknown backend {backend_kind!r}")
        self._backend_kind = backend_kind
        self._cluster: Optional[Dict[str, object]] = None
        self._specs: List[TaskSpec] = []
        self._sched_cfg: Optional[SchedulerConfig] = None
        self._sched_kw: Dict[str, object] = {}
        self._sched_cls: type = DarisScheduler
        self._sched_cls_kw: Dict[str, object] = {}
        self._device: Optional[DeviceModel] = None
        self._horizon_ms = 6000.0
        self._seed = 0
        self._noise_sigma: Optional[float] = None
        self._engine = "heap"
        self._phase_offsets = True
        self._arrivals: Dict[str, ArrivalProcess] = {}
        self._open_loop: Optional[tuple] = None   # (rate_jps, seed)
        self._fault_plan: Optional[FaultPlan] = None
        self._autoscale: Optional[AutoscalePolicy] = None
        self._batch_policy: Optional[BatchPolicy] = None
        self._record_decisions = False
        self._sanitize = None
        self._chaos_plan: Optional[ChaosPlan] = None
        self._input_hw = 64
        self._batch = 1
        self._input_factory = None
        self._ctx_shardings: Optional[Dict[int, object]] = None
        self._schedcheck_report = None   # set by verify()

    # -------------------------------------------------------- entry points
    @classmethod
    def sim(cls) -> "ServerConfig":
        """Calibrated fluid-simulation backend (virtual time)."""
        return cls(SIM)

    @classmethod
    def realtime(cls) -> "ServerConfig":
        """Real execution backend (wall clock, threaded lanes)."""
        return cls(REALTIME)

    @classmethod
    def cluster(cls, n_gpus: int, *,
                device_models: Optional[List] = None,
                transfer_ms: float = 0.5) -> "ServerConfig":
        """Multi-GPU serving (repro.cluster): ``n_gpus`` simulated
        devices behind one global dispatcher — per-device Eq. 11-12
        admission, HP-first placement by least-loaded device, cross-GPU
        zero-delay migration charged at ``transfer_ms`` per moved
        inter-stage payload. ``device_models`` takes DeviceModel objects
        or preset names ("a100", "v100", ...; see cluster.devices),
        cycled across devices — heterogeneous speed factors scale every
        stage cost and admission bound per device. When given, it takes
        precedence over ``.device(...)``, which then only sets the sim's
        generic device defaults for non-cluster paths; omit it and
        ``.device(...)`` becomes every GPU's model. Context/stream/
        oversubscription setters configure EACH device's partition.
        Cluster serving runs on the sim backend (one shared clock)."""
        cfg = cls(SIM)
        cfg._cluster = {"n_gpus": n_gpus,
                        "device_models": device_models,
                        "transfer_ms": transfer_ms}
        return cfg

    # ------------------------------------------------------------ workload
    def tasks(self, specs: List[TaskSpec]) -> "ServerConfig":
        self._specs.extend(specs)
        return self

    def task(self, spec: TaskSpec,
             arrival: Optional[ArrivalProcess] = None) -> "ServerConfig":
        self._specs.append(spec)
        if arrival is not None:
            self._arrivals[spec.name] = arrival
        return self

    def arrival(self, task_name: str, proc: ArrivalProcess) -> "ServerConfig":
        """Override the arrival process for one named task."""
        self._arrivals[task_name] = proc
        return self

    def open_loop(self, rate_jps: float, seed: int = 0) -> "ServerConfig":
        """Poisson open-loop arrivals for every task: each task gets its
        own stream seeded from ``seed`` + its index, so the whole arrival
        trace is reproducible across runs and across backends."""
        self._open_loop = (rate_jps, seed)
        return self

    def phase_offsets(self, enabled: bool) -> "ServerConfig":
        """Random phase offsets for periodic tasks (default on, matching
        the paper's unsynchronized release convention)."""
        self._phase_offsets = enabled
        return self

    # ----------------------------------------------------------- scheduler
    def contexts(self, n: int) -> "ServerConfig":
        self._sched_kw["n_contexts"] = n
        return self

    def streams(self, n: int) -> "ServerConfig":
        self._sched_kw["n_streams"] = n
        return self

    def oversubscribe(self, factor: float) -> "ServerConfig":
        self._sched_kw["oversubscription"] = factor
        return self

    def scheduler_options(self, **kw) -> "ServerConfig":
        """Extra ``SchedulerConfig`` fields (overload_hpa, ablations, ...)."""
        self._sched_kw.update(kw)
        return self

    def scheduler_config(self, cfg: SchedulerConfig) -> "ServerConfig":
        """Use a fully-built SchedulerConfig (overrides field setters)."""
        self._sched_cfg = cfg
        return self

    def scheduler_cls(self, cls: type, **kw) -> "ServerConfig":
        """Custom DarisScheduler subclass (tracing, research hooks)."""
        self._sched_cls = cls
        self._sched_cls_kw = kw
        return self

    def device(self, dm: DeviceModel) -> "ServerConfig":
        self._device = dm
        return self

    def batching(self, max_batch: int = 8,
                 max_wait_ms: Optional[float] = None,
                 scope: str = "model") -> "ServerConfig":
        """Dynamic deadline-aware batching (core/batching.py): while a job
        waits at its first stage, later releases of the same model (or the
        same task, ``scope="task"``) coalesce into it — up to ``max_batch``
        inputs, bounded by the earliest member's virtual deadline (and
        optionally ``max_wait_ms``), with admission charging the batched
        utilization. Composes with any backend/policy; leave unset for the
        paper's unbatched scheduler."""
        self._batch_policy = BatchPolicy(max_batch=max_batch,
                                         max_wait_ms=max_wait_ms,
                                         scope=scope)
        return self

    # --------------------------------------------------------------- run
    def horizon_ms(self, ms: float) -> "ServerConfig":
        self._horizon_ms = ms
        return self

    def seed(self, seed: int) -> "ServerConfig":
        self._seed = seed
        return self

    def noise(self, sigma: float) -> "ServerConfig":
        """Lognormal stage-time noise (sim backend only)."""
        self._noise_sigma = sigma
        return self

    def engine(self, kind: str) -> "ServerConfig":
        """Simulation engine selection (sim backend only):

        * ``"heap"`` (default) — the versioned prediction-heap engine
          (``SimBackend``), the bit-exact reference path;
        * ``"epoch"`` — the array-programmed epoch engine
          (``EpochSimBackend``, runtime/epoch.py): vectorized lane-state
          integration and cohort-ordered ETA selection, bit-identical to
          the heap path and ~an order of magnitude faster at fleet-scale
          lane counts.
        """
        if kind not in ("heap", "epoch"):
            raise ValueError(f"unknown engine {kind!r}: expected "
                             f"'heap' or 'epoch'")
        self._engine = kind
        return self

    def record_decisions(self, enabled: bool = True) -> "ServerConfig":
        """Keep an ordered log of admit/reject/dispatch/finish decisions
        (the sim-vs-real parity contract)."""
        self._record_decisions = enabled
        return self

    def sanitize(self, level: int = 1, *,
                 cadence: Optional[int] = None) -> "ServerConfig":
        """Enable the DSAN invariant auditor (repro/analysis): level 1
        audits every ``cadence`` engine steps (default 256), level >= 2
        audits every step. Equivalent to running under
        ``DARIS_SANITIZE=<level>``; violations raise
        ``SanitizerViolation``."""
        from .analysis.sanitizer import Sanitizer
        self._sanitize = Sanitizer(level=level, cadence=cadence)
        return self

    # ------------------------------------------------------ faults/elastic
    def chaos(self, plan: Optional[ChaosPlan] = None,
              **kw) -> "ServerConfig":
        """Install seeded transient-fault injection + recovery
        (repro.chaos): pass a built ``ChaosPlan`` or its fields as
        keyword arguments —

            .chaos(seed=1, stage_fault_rate=0.01,
                   retry=RetryPolicy(max_attempts=3),
                   degradation=DegradationPolicy(),
                   watchdog_kappa=6.0)

        Chaos draws use the plan's own RNG streams, never the simulation
        stream: a server built without ``.chaos(...)`` is bit-identical
        to one that never imported the chaos layer."""
        if plan is not None and kw:
            raise ValueError("chaos(): pass a ChaosPlan OR field kwargs, "
                             "not both")
        self._chaos_plan = plan if plan is not None else ChaosPlan(**kw)
        return self

    def fault_plan(self, fp: FaultPlan) -> "ServerConfig":
        self._fault_plan = fp
        return self

    def fail_context_at(self, ctx: int, t_ms: float) -> "ServerConfig":
        fp = self._fault_plan or FaultPlan()
        self._fault_plan = dataclasses.replace(fp, fail_ctx_at=(ctx, t_ms))
        return self

    def fail_device_at(self, device: int, t_ms: float) -> "ServerConfig":
        """Kill a whole GPU mid-run (cluster servers only): its in-flight
        stages are cancelled and replay on surviving devices, and every
        task homed there re-places HP-first via cross-GPU migration."""
        fp = self._fault_plan or FaultPlan()
        self._fault_plan = dataclasses.replace(fp,
                                               fail_device_at=(device, t_ms))
        return self

    def scale_out_at(self, t_ms: float) -> "ServerConfig":
        fp = self._fault_plan or FaultPlan()
        self._fault_plan = dataclasses.replace(fp, add_ctx_at=t_ms)
        return self

    def reconfigure_at(self, t_ms: float, *, n_contexts: Optional[int] = None,
                       n_streams: Optional[int] = None,
                       oversubscription: Optional[float] = None,
                       n_gpus: Optional[int] = None
                       ) -> "ServerConfig":
        """Schedule an online repartition: at ``t_ms`` the scheduler
        re-derives Eq. 9 geometry for the new shape without draining —
        queued work re-homes immediately, in-flight stages finish where
        they run and migrate at the next stage boundary (zero-delay).
        Omitted fields keep their current value; call repeatedly to build
        a schedule (a diurnal ramp, a step plan, ...). ``n_gpus``
        (cluster servers only) scales by whole devices: growth appends
        fresh GPUs, shrink retires them gracefully, and a global HP-first
        re-place follows either way."""
        kwargs = {k: v for k, v in (("n_contexts", n_contexts),
                                    ("n_streams", n_streams),
                                    ("oversubscription", oversubscription),
                                    ("n_gpus", n_gpus))
                  if v is not None}
        if not kwargs:
            raise ValueError("reconfigure_at needs at least one of "
                             "n_contexts / n_streams / oversubscription / "
                             "n_gpus")
        fp = self._fault_plan or FaultPlan()
        sched = list(fp.reconfigure_at or [])
        sched.append((t_ms, kwargs))
        self._fault_plan = dataclasses.replace(fp, reconfigure_at=sched)
        return self

    def autoscale(self, low: float = 0.3, high: float = 0.85, *,
                  check_every_ms: float = 250.0, min_contexts: int = 1,
                  max_contexts: int = 8,
                  cooldown_ms: float = 500.0) -> "ServerConfig":
        """Utilization-driven elasticity: grow/shrink by one scale unit
        whenever the mean Eq. 12 load fraction across live contexts
        crosses ``high``/``low`` (see ``AutoscalePolicy``). The unit —
        and the ``min_contexts``/``max_contexts`` bounds — is contexts on
        a single-device server and WHOLE GPUs on a cluster server.
        Composes with ``reconfigure_at`` — the autoscaler simply issues
        the same online repartitions on its own schedule."""
        self._autoscale = AutoscalePolicy(
            low=low, high=high, check_every_ms=check_every_ms,
            min_contexts=min_contexts, max_contexts=max_contexts,
            cooldown_ms=cooldown_ms)
        return self

    # ------------------------------------------------------------ realtime
    def realtime_io(self, input_hw: int = 64, batch: int = 1,
                    input_factory: Optional[Callable] = None,
                    ctx_shardings: Optional[Dict[int, object]] = None
                    ) -> "ServerConfig":
        """Input tensor shape / factory for real stage payloads.

        ``ctx_shardings`` maps live slot position -> jax sharding (slot 0
        = lowest-indexed live context; equal to the context index until
        the first fault/reshape — see ``RealtimeBackend``); when set,
        inter-stage hidden/cache state physically reshards onto the
        target partition whenever a job migrates contexts at a stage
        boundary (``serving.staging.migrate``)."""
        self._input_hw = input_hw
        self._batch = batch
        self._input_factory = input_factory
        self._ctx_shardings = ctx_shardings
        return self

    # --------------------------------------------------------------- build
    def _scheduler_config(self) -> SchedulerConfig:
        cfg = self._sched_cfg or SchedulerConfig(**self._sched_kw)
        if self._batch_policy is not None:
            cfg = dataclasses.replace(cfg, batch_policy=self._batch_policy)
        return cfg

    def _validate(self) -> None:
        if self._horizon_ms <= 0:
            raise ValueError(f"horizon_ms must be > 0, got {self._horizon_ms}")
        cfg = self._scheduler_config()   # TypeError on unknown options
        if cfg.n_contexts < 1 or cfg.n_streams < 1:
            raise ValueError(f"need >=1 context and stream, got "
                             f"{cfg.n_contexts}x{cfg.n_streams}")
        if cfg.oversubscription < 1.0:
            raise ValueError(f"oversubscription must be >= 1, got "
                             f"{cfg.oversubscription}")
        if self._noise_sigma is not None and self._backend_kind != SIM:
            raise ValueError("noise() applies to the sim backend only")
        if self._engine != "heap" and self._backend_kind != SIM:
            raise ValueError("engine() applies to the sim backend only "
                             "(the realtime backend has no sim engine)")
        if self._noise_sigma is not None and self._noise_sigma < 0:
            raise ValueError("noise sigma must be >= 0")
        if self._autoscale is not None:
            a = self._autoscale
            if not (0.0 <= a.low < a.high):
                raise ValueError(f"autoscale needs 0 <= low < high, got "
                                 f"low={a.low} high={a.high}")
            if a.min_contexts < 1 or a.max_contexts < a.min_contexts:
                raise ValueError(f"autoscale needs 1 <= min_contexts <= "
                                 f"max_contexts, got [{a.min_contexts}, "
                                 f"{a.max_contexts}]")
            if a.check_every_ms <= 0 or a.cooldown_ms < 0:
                raise ValueError(f"autoscale needs check_every_ms > 0 and "
                                 f"cooldown_ms >= 0, got "
                                 f"check_every_ms={a.check_every_ms} "
                                 f"cooldown_ms={a.cooldown_ms}")
        if self._cluster is not None:
            n_gpus = self._cluster["n_gpus"]
            if not isinstance(n_gpus, int) or n_gpus < 1:
                raise ValueError(f"cluster needs n_gpus >= 1, got {n_gpus}")
            if self._cluster["transfer_ms"] < 0:
                raise ValueError(f"cluster transfer_ms must be >= 0, got "
                                 f"{self._cluster['transfer_ms']}")
            dms = self._cluster["device_models"]
            if dms is not None and len(dms) == 0:
                raise ValueError("cluster device_models must be non-empty "
                                 "when given")
            if self._sched_cls is not DarisScheduler:
                raise ValueError("cluster servers build their own scheduler; "
                                 "scheduler_cls() is not supported")
        fp = self._fault_plan
        # a fleet can only mint NEW device ids via the autoscaler or an
        # n_gpus event exceeding the count standing at its time (a grow
        # past build size, or a regrow after a shrink — grown devices
        # get fresh monotonic ids). A monotone shrink plan can't, so it
        # must not disable the device-range/certain-death checks.
        grows = False
        if fp:
            cur = self._cluster["n_gpus"] if self._cluster else 0
            for _, kw in sorted(fp.reconfigure_at or [],
                                key=lambda e: e[0]):
                n = kw.get("n_gpus")
                if n is not None:
                    grows = grows or n > cur
                    cur = n
        may_grow = bool(fp) and (self._autoscale is not None or grows)
        if fp and fp.fail_device_at is not None:
            if self._cluster is None:
                raise ValueError("fail_device_at requires a cluster server "
                                 "(ServerConfig.cluster)")
            dev = fp.fail_device_at[0]
            # grown devices get fresh monotonic ids, so a growable fleet
            # can legitimately target ids past the build-time size (the
            # runtime no-ops on devices that never materialized)
            if not may_grow and not 0 <= dev < self._cluster["n_gpus"]:
                raise ValueError(f"fail_device_at device {dev} out of range "
                                 f"for {self._cluster['n_gpus']} GPUs")
            # without growth, a 1-GPU cluster losing its device is
            # certain death — reject at build, not RuntimeError mid-run
            if self._cluster["n_gpus"] == 1 and not may_grow:
                raise ValueError(
                    "fail_device_at on a 1-GPU cluster kills the whole "
                    "fleet; add GPUs, a reconfigure_at(n_gpus=...), or an "
                    "autoscale plan")
        if fp and fp.fail_ctx_at is not None and self._cluster is not None:
            # cluster context keys are (device, k) tuples; a bare int
            # would only blow up mid-run inside fail_context
            key = fp.fail_ctx_at[0]
            if not (isinstance(key, tuple) and len(key) == 2):
                raise ValueError(
                    f"fail_context_at on a cluster server needs a "
                    f"(device, context) tuple key, got {key!r} — or use "
                    f"fail_device_at to kill a whole GPU")
            if not may_grow and not 0 <= key[0] < self._cluster["n_gpus"]:
                raise ValueError(f"fail_context_at device {key[0]} out of "
                                 f"range for {self._cluster['n_gpus']} GPUs")
            # context indices only move past the build-time shape via a
            # planned n_contexts reshape or a scale_out_at ADD_CTX
            # (cluster autoscale adds whole GPUs, never contexts) —
            # without either, range-check statically
            reshapes = (fp.add_ctx_at is not None
                        or any("n_contexts" in kw
                               for _, kw in (fp.reconfigure_at or [])))
            nc = (self._sched_cfg.n_contexts
                  if self._sched_cfg is not None
                  else self._sched_kw.get("n_contexts",
                                          SchedulerConfig.n_contexts))
            if not reshapes and not 0 <= key[1] < nc:
                raise ValueError(f"fail_context_at context {key[1]} out of "
                                 f"range for {nc} contexts per device")
            # last-context faults escalate to whole-device failure, so a
            # 1-GPU 1-context cluster that can never grow or reshape is
            # certain death — same static rejection as fail_device_at
            if (self._cluster["n_gpus"] == 1 and nc == 1
                    and not reshapes and not may_grow):
                raise ValueError(
                    "fail_context_at on a 1-GPU, 1-context cluster kills "
                    "the whole fleet (a device's last context escalates "
                    "to device failure); add GPUs/contexts or a "
                    "reconfigure/autoscale plan")
        if fp and fp.reconfigure_at:
            seen_at: Dict[float, Dict] = {}
            for t_ms, kwargs in fp.reconfigure_at:
                prev = seen_at.get(t_ms)
                if prev is not None:
                    raise ValueError(
                        f"duplicate reconfigure_at events at t_ms={t_ms}: "
                        f"{prev} and {dict(kwargs)} would each run a full "
                        f"Algorithm-1 re-place at the same instant "
                        f"(double-counting migrations); merge them into "
                        f"one event or offset their timestamps")
                seen_at[t_ms] = dict(kwargs)
                if t_ms > self._horizon_ms:
                    raise ValueError(f"reconfigure_at t_ms={t_ms} is beyond "
                                     f"the horizon ({self._horizon_ms} ms)")
                nc = kwargs.get("n_contexts")
                if nc is not None and nc < 1:
                    raise ValueError(f"reconfigure_at needs n_contexts >= 1, "
                                     f"got {nc}")
                ns = kwargs.get("n_streams")
                if ns is not None and ns < 1:
                    raise ValueError(f"reconfigure_at needs n_streams >= 1, "
                                     f"got {ns}")
                osf = kwargs.get("oversubscription")
                if osf is not None and osf < 1.0:
                    raise ValueError(f"reconfigure_at needs oversubscription "
                                     f">= 1, got {osf}")
                ng = kwargs.get("n_gpus")
                if ng is not None and self._cluster is None:
                    raise ValueError("reconfigure_at(n_gpus=...) requires a "
                                     "cluster server (ServerConfig.cluster)")
                if ng is not None and ng < 1:
                    raise ValueError(f"reconfigure_at needs n_gpus >= 1, "
                                     f"got {ng}")
                if ng is not None and len(kwargs) > 1:
                    raise ValueError(
                        "reconfigure_at: reshape contexts/streams/"
                        "oversubscription and n_gpus in separate events "
                        "(each runs one re-place)")
        names = {s.name for s in self._specs}
        unknown = set(self._arrivals) - names
        if unknown:
            raise ValueError(f"arrival() for unknown task(s): "
                             f"{sorted(unknown)}")
        dupes = len(self._specs) - len(names)
        if dupes and self._arrivals:
            raise ValueError("per-name arrival overrides require unique "
                             "task names")

    def verify(self, *, enforce: bool = True) -> "ServerConfig":
        """Static schedulability gate (``repro.analysis.schedcheck``):
        analyze this configuration's whole timeline without running it.
        With ``enforce=True`` (default) raises ``UnschedulableError``
        when any HP task is statically UNSCHEDULABLE in any epoch; the
        full report stays readable via ``schedcheck_report`` either way.
        Fluent — chain it right before ``build()``."""
        from .analysis.schedcheck import (UNSCHEDULABLE, UnschedulableError,
                                          analyze_config)
        report = analyze_config(self)
        self._schedcheck_report = report
        if enforce and report.hp_verdict == UNSCHEDULABLE:
            raise UnschedulableError(report)
        return self

    @property
    def schedcheck_report(self):
        """The last ``verify()`` report (None until verify() runs)."""
        return self._schedcheck_report

    def build(self) -> "DarisServer":
        self._validate()
        return DarisServer(self)


class DarisServer:
    """The serving facade: one scheduler + one engine + one backend."""

    def __init__(self, cfg: ServerConfig):
        self._cfg = cfg
        sched_cfg = cfg._scheduler_config()
        if cfg._cluster is not None:
            from .cluster import ClusterScheduler
            self.scheduler = ClusterScheduler(
                list(cfg._specs), sched_cfg, cfg._device,
                n_gpus=cfg._cluster["n_gpus"],
                device_models=cfg._cluster["device_models"],
                transfer_ms=cfg._cluster["transfer_ms"])
        else:
            self.scheduler: DarisScheduler = cfg._sched_cls(
                list(cfg._specs), sched_cfg, cfg._device,
                **cfg._sched_cls_kw)
        if cfg._backend_kind == SIM:
            engine_cls = (EpochSimBackend if cfg._engine == "epoch"
                          else SimBackend)
            backend = engine_cls(
                noise_sigma=(0.06 if cfg._noise_sigma is None
                             else cfg._noise_sigma))
        else:
            backend = RealtimeBackend(input_hw=cfg._input_hw,
                                      batch=cfg._batch,
                                      input_factory=cfg._input_factory,
                                      ctx_shardings=cfg._ctx_shardings)
        self.backend = backend
        phase = "random" if cfg._phase_offsets else 0.0
        arrivals: Dict[int, ArrivalProcess] = {}
        for t in self.scheduler.tasks:
            proc = cfg._arrivals.get(t.name)
            if proc is None and cfg._open_loop is not None:
                rate, seed = cfg._open_loop
                proc = PoissonArrival(rate, seed=seed + t.index)
            if proc is None:
                proc = PeriodicArrival(phase_ms=phase)
            arrivals[t.index] = proc
        self.core = EngineCore(
            self.scheduler, backend, horizon_ms=cfg._horizon_ms,
            seed=cfg._seed, arrivals=arrivals, fault_plan=cfg._fault_plan,
            autoscale=cfg._autoscale,
            record_decisions=cfg._record_decisions,
            sanitize=cfg._sanitize, chaos=cfg._chaos_plan)

    # ------------------------------------------------------------- serving
    def run(self) -> RunMetrics:
        """Drive the configured workload to the horizon."""
        return self.core.run()

    def drain(self) -> RunMetrics:
        """Drive until all submitted/queued work completes (or the horizon
        is reached) — the natural mode for ``submit()``/trace workloads."""
        return self.core.run(until_idle=True)

    def submit(self, spec: TaskSpec, at_ms: float = 0.0,
               tenant: Optional[str] = None) -> SubmitHandle:
        """Register a one-shot job release at ``at_ms``; it goes through
        the same admission test (Eq. 12) as periodic releases. Inspect the
        returned handle after ``run()``/``drain()``."""
        return self.core.submit(spec, at_ms, tenant=tenant)

    def task_named(self, name: str):
        """The registered runtime task with spec name ``name``."""
        for t in self.scheduler.tasks:
            if t.name == name:
                return t
        known = sorted({t.name for t in self.scheduler.tasks})
        raise KeyError(f"no task named {name!r}; registered: {known}")

    def request(self, task_name: str, at_ms: float,
                tenant: Optional[str] = None) -> SubmitHandle:
        """One release of an already-registered task (the serving path:
        tasks carry MRET history and batch heads across requests). Give
        the task a ``ManualArrival`` if clients are its only source of
        releases. Legal before ``run()`` and while serving."""
        return self.core.submit_release(self.task_named(task_name), at_ms,
                                        tenant=tenant)

    def cancel(self, handle: SubmitHandle,
               at_ms: Optional[float] = None) -> None:
        """Schedule a first-class cancellation of ``handle``'s submission
        (engine CANCEL event): a queued job retires immediately — lanes
        stay free, the Eq. 12 admission charge unwinds, batch members
        detach — and an in-flight job retires at its next stage boundary
        (zero-delay semantics). ``at_ms`` defaults to the handle's
        release time (cancel as soon as the submission exists)."""
        if at_ms is None:
            at_ms = handle.release_ms if handle.release_ms is not None \
                else handle.at_ms
        self.core.submit_cancel(handle, at_ms)

    # serving mode: incremental driving for the ops daemon (repro.serve)
    def begin_serving(self) -> None:
        self.core.begin_serving()

    def pump(self, frontier_ms: Optional[float] = None) -> None:
        self.core.pump(frontier_ms)

    def serving_idle(self) -> bool:
        return self.core.serving_idle()

    def end_serving(self, until_idle: bool = True) -> RunMetrics:
        return self.core.end_serving(until_idle=until_idle)

    def snapshot(self) -> dict:
        """Queue depths, lane occupancy, context liveness, live counters."""
        return self.core.snapshot()

    def save_state(self, path: str) -> str:
        """Checkpoint the scheduler's learned/elastic state: MRET windows,
        context assignments, migration count, and the full partition
        geometry (including retired contexts), so a restore reproduces
        the exact post-fault/post-reconfigure placement."""
        if hasattr(self.scheduler, "workers"):
            raise NotImplementedError(
                "cluster checkpointing is not supported yet: checkpoint "
                "each device's state via its worker schedulers, or run "
                "single-GPU servers for save/restore workflows")
        from .checkpoint import save_scheduler_state
        return save_scheduler_state(self.scheduler, path,
                                    chaos=self.core._chaos)

    def load_state(self, path: str) -> None:
        """Restore scheduler state saved by ``save_state`` (call before
        ``run()``): placement, geometry, and MRET history all survive, so
        a restarted server skips the AFET cold-start AND lands on the
        same partition shape the saved one was using."""
        if hasattr(self.scheduler, "workers"):
            raise NotImplementedError(
                "cluster checkpointing is not supported yet: restore into "
                "a single-GPU server configured like the saved one")
        from .checkpoint import load_scheduler_state
        load_scheduler_state(self.scheduler, path)

    # ---------------------------------------------------------- inspection
    @property
    def metrics(self) -> RunMetrics:
        return self.core.metrics

    @property
    def decisions(self) -> Optional[List[str]]:
        """Ordered admit/reject/dispatch/finish log (record_decisions())."""
        return self.core.decisions


def run_and_summarize(server: DarisServer) -> dict:
    """Convenience: run a built server, return its summary dict with wall
    time attached (the shape benchmarks cache as JSON)."""
    t0 = time.time()
    m = server.run()
    s = m.summary()
    s["wall_s"] = time.time() - t0
    return s
