"""Serving launcher: DARIS over partitions of the local device set.

Laptop-scale entrypoint (real execution; the pod-scale story is the same
scheduler over sub-meshes — DESIGN.md §2):

    PYTHONPATH=src python -m repro.launch.serve --contexts 2 --os 2.0 \
        --seconds 4 --dnns resnet18,unet
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--contexts", type=int, default=2)
    ap.add_argument("--streams", type=int, default=1)
    ap.add_argument("--os", type=float, default=2.0, dest="oversub")
    ap.add_argument("--seconds", type=float, default=4.0)
    ap.add_argument("--dnns", default="resnet18,inceptionv3")
    ap.add_argument("--jps", type=float, default=10.0)
    ap.add_argument("--hw", type=int, default=32)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    from ..api import HP, LP, DeviceModel, ServerConfig
    from ..models.cnn import BUILDERS
    from ..serving.engine import staged_cnn_taskspec

    specs = []
    for name in args.dnns.split(","):
        model = BUILDERS[name](width=8)
        specs.append(staged_cnn_taskspec(model, priority=HP, jps=args.jps,
                                         input_hw=args.hw, tag="-hp"))
        specs.append(staged_cnn_taskspec(model, priority=LP, jps=args.jps,
                                         input_hw=args.hw, tag="-lp"))
    server = (ServerConfig.realtime()
              .tasks(specs)
              .contexts(args.contexts).streams(args.streams)
              .oversubscribe(args.oversub)
              .device(DeviceModel(n_units=float(args.contexts)))
              .horizon_ms(args.seconds * 1000.0)
              .phase_offsets(False)
              .realtime_io(input_hw=args.hw)
              .build())
    sched = server.scheduler
    if args.ckpt:
        import os
        from ..checkpoint import load_scheduler_state, save_scheduler_state
        if os.path.exists(args.ckpt):
            load_scheduler_state(sched, args.ckpt)
            print(f"resumed scheduler state from {args.ckpt} "
                  f"(AFET cold-start skipped)")
    m = server.run()
    s = m.summary()
    print(f"JPS {s['jps']:.1f} | DMR HP {s['dmr_hp']:.1%} LP {s['dmr_lp']:.1%}"
          f" | resp HP {s['resp_hp']['mean']:.1f}ms LP "
          f"{s['resp_lp']['mean']:.1f}ms | rejected LP {s['rejected_lp']}")
    if args.ckpt:
        from ..checkpoint import save_scheduler_state
        save_scheduler_state(sched, args.ckpt)
        print(f"scheduler state saved -> {args.ckpt}")


if __name__ == "__main__":
    main()
