import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init. Tiny-mesh subprocess tests override via env.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this emits a JSON artifact with
  * memory_analysis()   -- per-device bytes (proves the cell fits 16 GB HBM)
  * cost_analysis()     -- per-device HLO FLOPs / bytes for §Roofline
  * collective bytes    -- parsed from the post-SPMD HLO text per collective
                           op kind (roofline collective term)
  * compile wall time

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-32b --shape decode_32k --mesh multi
"""
import argparse
import json
import pathlib
import re
import time
import traceback
from collections import defaultdict

import jax
import numpy as np

from repro.configs import ARCH_IDS, cells, get_config, shape_by_name
from repro.launch.mesh import make_production_mesh, make_tiny_mesh
from repro.models import build_model
from repro.parallel.sharding import ShardingRules
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_INSTR_RE = re.compile(r"%?([\w.\-]+) = \(?([a-z0-9]+)\[([0-9,]*)\]")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
# bytes-moved multiplier per op (ring algorithms, (n-1)/n ~= 1):
#   all-reduce moves ~2x the buffer; others ~1x of the measured side
_COLL_SIDE = {"all-reduce": ("operand", 2.0), "all-gather": ("result", 1.0),
              "reduce-scatter": ("operand", 1.0), "all-to-all": ("result", 1.0),
              "collective-permute": ("result", 1.0)}


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo: str) -> dict:
    """Per-device bytes moved per collective kind (post-SPMD HLO text)."""
    sizes = {}
    pending = []
    for line in hlo.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        name, dtype, dims = m.groups()
        sizes[name] = _shape_bytes(dtype, dims)
        for op in _COLL_OPS:
            # match plain and -start forms; skip -done (operand forwarding)
            if re.search(rf"= \S+ {op}(-start)?\(", line):
                pending.append((name, op, line))
    out = defaultdict(float)
    counts = defaultdict(int)
    for name, op, line in pending:
        side, mult = _COLL_SIDE[op]
        if side == "result":
            b = sizes.get(name, 0.0)
        else:
            args = line.split("(", 1)[1]
            ops = re.findall(r"%?([\w.\-]+)", args)
            b = sum(sizes.get(o, 0.0) for o in ops if o in sizes)
        out[op] += b * mult
        counts[op] += 1
    return {"bytes_by_op": dict(out), "counts": dict(counts),
            "total_bytes": float(sum(out.values()))}


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------
def build_cell(arch_id: str, shape_name: str, mesh, *,
               moe_ep: bool = True, extra: dict | None = None):
    """Returns (fn, args_sds, in_shardings, out_shardings, model, cell)."""
    extra = extra or {}
    cell = shape_by_name(shape_name)
    cfg = get_config(arch_id)
    if extra.get("kv_dtype"):
        cfg = cfg.replace(kv_cache_dtype=extra["kv_dtype"])
    tp = mesh.shape["model"]
    dp_only = bool(extra.get("dp_only"))
    no_fsdp = bool(extra.get("no_fsdp"))
    model0 = build_model(cfg, pad_for_tp=1 if dp_only else tp)
    rules = ShardingRules(model0.cfg, mesh, no_fsdp=no_fsdp,
                          dp_only=dp_only,
                          mlp_fsdp=bool(extra.get("mlp_fsdp"))
                          ).for_batch(cell.global_batch)
    dist = rules.dist_ctx()
    if (cell.kind == "train" or extra.get("serve_seq_shard"))             and not extra.get("no_seq_shard"):
        dist["seq_shard"] = True      # Megatron-style sequence parallelism
    model = build_model(cfg, pad_for_tp=1 if dp_only else tp, dist=dist)
    ns = lambda spec: jax.sharding.NamedSharding(mesh, spec)

    specs = model.input_specs(cell)
    q_chunk = extra.get("q_chunk", 512 if cell.seq_len >= 32768 else (1024 if cell.kind == "train" and cell.seq_len >= 4096 else 0))
    remat = extra.get("remat", "dots")

    def batch_shardings(sp):
        out = {}
        for k, v in sp.items():
            if k == "tokens":
                out[k] = ns(rules.tokens_spec() if rules.dp else
                            jax.sharding.PartitionSpec(None, None))
            elif k in ("frames", "image_embeds", "enc_out"):
                out[k] = ns(rules.embeds_spec() if rules.dp else
                            jax.sharding.PartitionSpec(None, None, None))
            elif k == "cache":
                out[k] = rules.cache_tree(v)
            else:
                raise KeyError(k)
        return out

    if cell.kind == "train":
        # bf16 first moment + bf16 grad accumulation for very large MoE
        # (deepseek-v2 236B): ZeRO-sharded state still dominates 16 GB/chip
        low_mem = model.param_counts()["total"] > 1e11
        opt_cfg = (AdamWConfig(m_dtype="bfloat16") if low_mem
                   else AdamWConfig())
        params_sds = jax.eval_shape(lambda: model.init_params(0))
        opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)
        params_sh = rules.params_tree(params_sds)
        opt_sh = rules.params_tree(opt_sds)
        batch_sh = batch_shardings(specs)
        # microbatched grad accumulation bounds saved activations; full remat
        # keeps only the per-layer scan carries (DESIGN.md §5)
        accum = extra.get("accum", max(1, min(16, cell.global_batch
                                              // rules._dp_size)))
        remat = extra.get("remat", "full")
        step = make_train_step(model, opt_cfg, q_chunk=q_chunk, remat=remat,
                               accum=accum,
                               accum_dtype="bfloat16" if low_mem else "float32")
        metrics_sh = {"grad_norm": ns(jax.sharding.PartitionSpec()),
                      "lr": ns(jax.sharding.PartitionSpec()),
                      "loss": ns(jax.sharding.PartitionSpec())}
        jitted = jax.jit(step,
                         in_shardings=(params_sh, opt_sh, batch_sh),
                         out_shardings=(params_sh, opt_sh, metrics_sh),
                         donate_argnums=(0, 1))
        return jitted, (params_sds, opt_sds, specs), model, cell

    # serving cells
    params_sds = jax.eval_shape(lambda: model.init_params(0))
    params_sh = rules.params_tree(params_sds)
    batch_sh = batch_shardings(specs)
    logits_sp = (rules.logits_spec() if rules.dp else
                 jax.sharding.PartitionSpec(None, None, "model"))
    if model.cfg.vocab_size % mesh.shape["model"] != 0:
        logits_sp = jax.sharding.PartitionSpec(*logits_sp[:-1], None)
    if cell.kind == "prefill":
        fn = lambda p, b: model.prefill(p, b, q_chunk=q_chunk)
    else:
        fn = model.decode_step
    jitted = jax.jit(fn,
                     in_shardings=(params_sh, batch_sh),
                     out_shardings=(ns(logits_sp), batch_sh["cache"]),
                     donate_argnums=(1,))
    return jitted, (params_sds, specs), model, cell


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             out_dir: pathlib.Path, *, hlo_dir=None,
             extra: dict | None = None) -> dict:
    multi = mesh_kind in ("multi", "tiny-multi")
    if mesh_kind.startswith("tiny"):
        mesh = make_tiny_mesh(multi_pod=multi)
    else:
        mesh = make_production_mesh(multi_pod=multi)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    jitted, args, model, cell = build_cell(arch_id, shape_name, mesh,
                                           extra=extra)
    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    mem_d = {a: int(getattr(mem, a)) for a in
             ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes") if hasattr(mem, a)}
    peak = (mem_d.get("argument_size_in_bytes", 0)
            + mem_d.get("temp_size_in_bytes", 0)
            + mem_d.get("output_size_in_bytes", 0)
            - mem_d.get("alias_size_in_bytes", 0))
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost_d = {k: float(v) for k, v in cost.items()
              if isinstance(v, (int, float)) and k in
              ("flops", "bytes accessed", "transcendentals",
               "utilization operand", "bytes accessed output")}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    from repro.launch.hlo_cost import analyze as hlo_analyze
    try:
        hc = hlo_analyze(hlo)
    except Exception as e:
        hc = {"error": repr(e)}
    accum_used = 1
    if cell.kind == "train":
        accum_used = (extra or {}).get("accum", max(1, min(16,
            cell.global_batch // int(np.prod([mesh.shape[a] for a in
            mesh.axis_names if a != "model"])))))
    analytic_bytes = model.analytic_hbm_bytes(cell, accum=accum_used)
    if hlo_dir:
        hlo_dir = pathlib.Path(hlo_dir)
        hlo_dir.mkdir(parents=True, exist_ok=True)
        (hlo_dir / f"{arch_id}__{shape_name}__{mesh_kind}.hlo.txt"
         ).write_text(hlo)

    art = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "n_chips": n_chips,
        "extra": extra or {},
        "status": "ok",
        "memory": mem_d,
        "peak_bytes_per_device": int(peak),
        "fits_16gb": bool(peak <= 16 * 1024 ** 3),
        "cost_per_device": cost_d,
        "collectives_per_device": coll,
        "hlo_cost_per_device": hc,
        "analytic_hbm_bytes_global": analytic_bytes,
        "model_flops": model.model_flops(cell),
        "param_counts": model.param_counts(),
        "lower_s": t_lower, "compile_s": t_compile,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = (extra or {}).get("tag", "")
    suffix = f"__{tag}" if tag else ""
    fname = out_dir / f"{arch_id}__{shape_name}__{mesh_kind}{suffix}.json"
    fname.write_text(json.dumps(art, indent=1))
    return art


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "tiny", "tiny-multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--q-chunk", type=int, default=-1)
    ap.add_argument("--remat", default="")
    ap.add_argument("--accum", type=int, default=0)
    ap.add_argument("--dp_only", action="store_true")
    ap.add_argument("--no_fsdp", action="store_true")
    ap.add_argument("--serve_seq_shard", action="store_true")
    ap.add_argument("--no_seq_shard", action="store_true")
    ap.add_argument("--mlp_fsdp", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for cell, runnable, reason in cells(arch):
            if args.shape != "all" and cell.name not in args.shape.split(","):
                continue
            for mk in meshes:
                tagsuf = f"__{args.tag}" if args.tag else ""
                fname = out_dir / f"{arch}__{cell.name}__{mk}{tagsuf}.json"
                if not runnable:
                    out_dir.mkdir(parents=True, exist_ok=True)
                    fname.write_text(json.dumps({
                        "arch": arch, "shape": cell.name, "mesh": mk,
                        "status": "skipped", "reason": reason}, indent=1))
                    print(f"SKIP {arch} {cell.name} {mk}: {reason}")
                    n_skip += 1
                    continue
                if args.skip_existing and fname.exists():
                    try:
                        if json.loads(fname.read_text()).get("status") == "ok":
                            print(f"CACHED {arch} {cell.name} {mk}")
                            n_ok += 1
                            continue
                    except Exception:
                        pass
                extra = {"tag": args.tag} if args.tag else {}
                if args.q_chunk >= 0:
                    extra["q_chunk"] = args.q_chunk
                if args.remat:
                    extra["remat"] = args.remat
                if args.accum:
                    extra["accum"] = args.accum
                for flag in ("dp_only", "no_fsdp", "serve_seq_shard",
                             "no_seq_shard", "mlp_fsdp"):
                    if getattr(args, flag):
                        extra[flag] = True
                try:
                    art = run_cell(arch, cell.name, mk, out_dir,
                                   hlo_dir=args.save_hlo or None,
                                   extra=extra or None)
                    gb = art["peak_bytes_per_device"] / 2 ** 30
                    print(f"OK {arch} {cell.name} {mk}: peak {gb:.2f} GiB/dev"
                          f" fits={art['fits_16gb']}"
                          f" flops/dev={art['cost_per_device'].get('flops', 0):.3e}"
                          f" coll={art['collectives_per_device']['total_bytes']:.3e}B"
                          f" compile={art['compile_s']:.1f}s", flush=True)
                    n_ok += 1
                except Exception as e:  # record failures as artifacts too
                    out_dir.mkdir(parents=True, exist_ok=True)
                    fname.write_text(json.dumps({
                        "arch": arch, "shape": cell.name, "mesh": mk,
                        "status": "error", "error": repr(e),
                        "traceback": traceback.format_exc()[-4000:]}, indent=1))
                    print(f"FAIL {arch} {cell.name} {mk}: {e!r}", flush=True)
                    n_fail += 1
    print(f"dry-run done: ok={n_ok} skipped={n_skip} failed={n_fail}")


if __name__ == "__main__":
    main()
