"""Production mesh builders.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state. The dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
everything else sees the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_tiny_mesh(*, multi_pod: bool = False):
    """Reduced mesh for CI-sized subprocess tests (needs >= 8 devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_partition_meshes(n_contexts: int, oversubscription: float = 1.0,
                          *, multi_pod: bool = False):
    """DARIS spatial partitioning: split the pod's data axis into
    ``n_contexts`` (possibly overlapping) sub-meshes — the TPU analogue of
    MPS contexts with SM oversubscription (Eq. 9, DESIGN.md §2).

    Returns a list of device subsets (rows of the data axis per context).
    Chip allocation follows Eq. 9 with ceil_even on the row count; when
    OS > 1 the wrap-around allocation makes neighbouring contexts share
    rows."""
    import numpy as np
    mesh = make_production_mesh(multi_pod=multi_pod)
    devs = np.asarray(mesh.devices)
    if multi_pod:
        devs = devs.reshape(-1, *devs.shape[2:])   # fold pods into rows
    n_rows = devs.shape[0]
    rows_per_ctx = int(np.ceil(oversubscription * n_rows / n_contexts))
    rows_per_ctx += rows_per_ctx % 2               # ceil_even (Eq. 9)
    rows_per_ctx = max(2, min(rows_per_ctx, n_rows))
    out = []
    stride = n_rows / n_contexts
    for k in range(n_contexts):
        start = int(round(k * stride)) % n_rows
        rows = [(start + i) % n_rows for i in range(rows_per_ctx)]
        out.append(devs[rows])
    return out
