"""Roofline analysis from dry-run artifacts (no real TPU — compile-only).

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs / (chips * 197 TFLOP/s)
    memory term     = HLO_bytes / (chips * 819 GB/s)
    collective term = collective_bytes / (chips * 50 GB/s per link)

cost_analysis() and the parsed HLO are per-device (post-SPMD), so the
per-chip terms divide by peak rates directly; global HLO_FLOPs multiplies
back by chip count. CPU-backend caveats (documented in EXPERIMENTS.md):
XLA:CPU promotes bf16 dots to f32 and its "bytes accessed" over-counts
fused traffic, so the memory term is an upper bound; the collective byte
model uses ring multipliers (AR 2x operand, AG/RS/A2A ~1x, (n-1)/n ~ 1).

MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference), from the
*published* config — the HLO/MODEL ratio therefore exposes remat recompute,
capacity-factor slack and head/vocab padding honestly.
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e)
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / link (ICI)


def load_artifacts(art_dir: str) -> List[dict]:
    out = []
    for p in sorted(pathlib.Path(art_dir).glob("*.json")):
        try:
            out.append(json.loads(p.read_text()))
        except Exception:
            pass
    return out


def roofline_row(art: dict) -> Dict:
    chips = art["n_chips"]
    cost = art.get("cost_per_device", {})
    hc = art.get("hlo_cost_per_device", {})
    # while-aware HLO walk (hlo_cost.py); XLA cost_analysis counts loop
    # bodies once and is kept only as a cross-check
    flops_dev = hc.get("flops") or cost.get("flops", 0.0)
    bytes_dev = (art.get("analytic_hbm_bytes_global", 0.0) / chips
                 or cost.get("bytes accessed", 0.0))
    coll_dev = (hc.get("coll_total_bytes")
                or art.get("collectives_per_device", {}).get("total_bytes", 0.0))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    model_flops = art.get("model_flops", 0.0)
    hlo_flops_global = flops_dev * chips
    bound = max(t_compute, t_memory, t_coll)
    # fraction of roofline: useful work per chip-second at the binding rate
    roofline_frac = ((model_flops / chips / PEAK_FLOPS) / bound
                     if bound > 0 else 0.0)
    return {
        "arch": art["arch"], "shape": art["shape"], "mesh": art["mesh"],
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": model_flops / hlo_flops_global if hlo_flops_global else 0.0,
        "roofline_fraction": roofline_frac,
        "peak_gib": art.get("peak_bytes_per_device", 0) / 2 ** 30,
        "fits": art.get("fits_16gb"),
    }


def build_table(art_dir: str = "artifacts/dryrun", mesh: str = "single",
                include_tagged: bool = False) -> List[Dict]:
    rows = []
    for art in load_artifacts(art_dir):
        if art.get("status") != "ok" or art.get("mesh") != mesh:
            continue
        if not include_tagged and art.get("extra", {}).get("tag"):
            continue
        rows.append(roofline_row(art))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def fmt_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':18s} {'shape':12s} {'Tcomp(s)':>10s} {'Tmem(s)':>10s} "
           f"{'Tcoll(s)':>10s} {'dom':>5s} {'useful':>7s} {'roofl%':>7s} "
           f"{'GiB/dev':>8s} fits")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:18s} {r['shape']:12s} {r['t_compute_s']:10.3e} "
            f"{r['t_memory_s']:10.3e} {r['t_collective_s']:10.3e} "
            f"{r['dominant'][:4]:>5s} {r['useful_ratio']:7.2f} "
            f"{100*r['roofline_fraction']:6.1f}% {r['peak_gib']:8.2f} "
            f"{'Y' if r['fits'] else 'N'}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default="artifacts/roofline.json")
    args = ap.parse_args()
    rows = build_table(args.art, args.mesh)
    print(fmt_table(rows))
    pathlib.Path(args.json_out).parent.mkdir(parents=True, exist_ok=True)
    pathlib.Path(args.json_out).write_text(json.dumps(rows, indent=1))
    print(f"\n{len(rows)} cells -> {args.json_out}")


if __name__ == "__main__":
    main()
