"""Training launcher: any assigned arch, reduced or full config.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --reduced --steps 100 --batch 8 --seq 128

Full configs at pod scale go through the dry-run first
(python -m repro.launch.dryrun) — this entrypoint executes for real on the
local device(s), so keep --reduced on CPU.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from ..configs import get_config, get_reduced
    from ..data.pipeline import TokenPipeline
    from ..models import build_model
    from ..training.optimizer import AdamWConfig, adamw_init
    from ..training.train_step import make_train_step

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(0)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n/1e6:.1f}M family={cfg.family}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    opt = adamw_init(params, opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg, accum=args.accum))
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, seed=0)

    import numpy as np
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.steps):
        batch = {"tokens": jnp.asarray(pipe.next_batch()["tokens"])}
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(rng.normal(size=(
                args.batch, cfg.encoder_frames, cfg.d_model)), jnp.float32)
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.asarray(rng.normal(size=(
                args.batch, cfg.n_image_tokens, cfg.d_model)), jnp.float32)
        params, opt, metrics = step_fn(params, opt, batch)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            from ..checkpoint import save_pytree
            save_pytree(params, args.ckpt, step=i + 1)
    if args.ckpt:
        from ..checkpoint import save_pytree
        print("saved:", save_pytree(params, args.ckpt, step=args.steps))


if __name__ == "__main__":
    main()
