"""While-loop-aware cost model over post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — with
scan-over-layers and grad-accumulation scans that undercounts FLOPs by
~100-1000x, which would make the roofline meaningless. This walker:

  * parses computations from the HLO text,
  * counts dot FLOPs exactly (2 * prod(result) * prod(contracting dims)),
  * models HBM bytes as operands+result of *top-level* ops per computation
    (fusion interiors stay on-chip — closer to real HBM traffic than XLA
    CPU's "bytes accessed", which counts fused interior traffic),
  * recurses through fusion/call sites,
  * multiplies while bodies by their ``known_trip_count`` (jax scans always
    carry it; unknown trip counts count once and set a flag),
  * accumulates collective bytes with ring multipliers (all-reduce 2x
    operand; all-gather/all-to-all/permute 1x result; reduce-scatter 1x
    operand) including inside loop bodies.

Everything is per-device (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->")
_COLL = {"all-reduce": ("operand", 2.0), "all-gather": ("result", 1.0),
         "reduce-scatter": ("operand", 1.0), "all-to-all": ("result", 1.0),
         "collective-permute": ("result", 1.0)}
_OPS = ("dot", "fusion", "call", "while", "convolution",
        "conditional") + tuple(_COLL)


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _first_shapes_bytes(text: str) -> float:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(text))


class HloCost:
    def __init__(self, hlo: str):
        self.comps: Dict[str, list] = {}
        self._parse(hlo)
        self._memo: Dict[str, dict] = {}
        self.unknown_whiles = 0

    def _parse(self, hlo: str) -> None:
        cur = None
        for line in hlo.splitlines():
            if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
                m = _COMP_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                self.comps[cur].append((m.group(1), m.group(2)))

    # ------------------------------------------------------------------
    def comp_cost(self, name: str) -> dict:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = {"flops": 0.0, "bytes": 0.0,
                            "coll": defaultdict(float)}  # break cycles
        flops = 0.0
        bytes_ = 0.0
        coll = defaultdict(float)
        shapes: Dict[str, float] = {}
        instrs = self.comps.get(name, [])
        for iname, rest in instrs:
            # result bytes: shapes before the opcode's '('
            op, args = self._split_op(rest)
            idx = rest.find(f"{op}(") if op else -1
            lhs = rest if op is None else rest[:idx]
            rbytes = _first_shapes_bytes(lhs)
            shapes[iname] = rbytes
            if op is None:
                continue
            if op == "dynamic-update-slice":
                # touches the update slice twice (read+write), not the arena
                ops_ = re.findall(r"%([\w.\-]+)", args)
                upd = shapes.get(ops_[1], rbytes) if len(ops_) > 1 else rbytes
                bytes_ += 2.0 * min(upd, rbytes)
            elif op == "dynamic-slice":
                bytes_ += 2.0 * rbytes
            elif op in ("gather", "scatter"):
                bytes_ += 2.0 * rbytes
            elif op not in ("fusion", "while", "call", "conditional",
                            "parameter", "constant", "tuple",
                            "get-tuple-element", "bitcast"):
                opbytes = sum(shapes.get(o, 0.0)
                              for o in re.findall(r"%([\w.\-]+)", args)
                              if o in shapes)
                bytes_ += rbytes + opbytes
            if op == "dot":
                flops += self._dot_flops(rest, args, shapes, lhs)
            elif op == "convolution":
                flops += 2.0 * (rbytes / 2.0)   # rough: 1 MAC per output elt
            elif op in _COLL:
                side, mult = _COLL[op]
                if side == "result":
                    coll[op] += rbytes * mult
                else:
                    ob = sum(shapes.get(o, 0.0)
                             for o in re.findall(r"%([\w.\-]+)", args)
                             if o in shapes)
                    coll[op] += ob * mult
            elif op in ("fusion", "call"):
                tgt = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", rest)
                if tgt:
                    sub = self.comp_cost(tgt.group(1))
                    flops += sub["flops"]
                    # fusion interiors stay on-chip; per-parameter traffic is
                    # slice-aware (a fused dynamic-slice of a stacked arena
                    # reads one slice, not the arena)
                    traffic = self.param_traffic(tgt.group(1))
                    ops_ = [o for o in re.findall(r"%([\w.\-]+)", args)
                            if o in shapes]
                    opbytes = 0.0
                    for i, o in enumerate(ops_):
                        full = shapes[o]
                        opbytes += min(full, traffic.get(i, full))
                    bytes_ += rbytes + opbytes
                    for k, v in sub["coll"].items():
                        coll[k] += v
            elif op == "while":
                trip = 1
                tm = re.search(r'known_trip_count[^0-9]*(\d+)', rest)
                if tm:
                    trip = int(tm.group(1))
                else:
                    self.unknown_whiles += 1
                bm = re.search(r"body=%?([\w.\-]+)", rest)
                cm = re.search(r"condition=%?([\w.\-]+)", rest)
                if bm:
                    sub = self.comp_cost(bm.group(1))
                    flops += sub["flops"] * trip
                    bytes_ += sub["bytes"] * trip
                    for k, v in sub["coll"].items():
                        coll[k] += v * trip
                if cm:
                    sub = self.comp_cost(cm.group(1))
                    bytes_ += sub["bytes"] * trip
            elif op == "conditional":
                for tgt in re.findall(r"%([\w.\-]+)",
                                      rest.split("branch_computations", 1)[-1]):
                    if tgt in self.comps:
                        sub = self.comp_cost(tgt)
                        flops += sub["flops"]
                        bytes_ += sub["bytes"]
                        for k, v in sub["coll"].items():
                            coll[k] += v
        out = {"flops": flops, "bytes": bytes_, "coll": coll}
        self._memo[name] = out
        return out

    def param_traffic(self, name: str) -> Dict[int, float]:
        """Slice-aware bytes actually read per parameter of a (fused)
        computation: dynamic-slice consumers charge the slice, dynamic-
        update-slice consumers charge the update, nested fusion/call
        consumers charge what the callee actually touches (XLA's CPU
        backend wraps fusions in ``parallel_*`` call computations, so a
        one-level walk would see only an opaque ``fusion`` consumer and
        charge the whole arena), everything else charges the full
        parameter."""
        if not hasattr(self, "_traffic_cache"):
            self._traffic_cache = {}
        if name in self._traffic_cache:
            return self._traffic_cache[name]
        self._traffic_cache[name] = {}   # break call cycles
        out: Dict[int, float] = {}
        instrs = self.comps.get(name, [])
        shapes: Dict[str, float] = {}
        param_of: Dict[str, int] = {}
        consumers: Dict[str, float] = defaultdict(float)
        full: Dict[int, float] = {}
        for iname, rest in instrs:
            op, args = self._split_op(rest)
            idx = rest.find(f"{op}(") if op else -1
            lhs = rest if op is None else rest[:idx]
            rbytes = _first_shapes_bytes(lhs)
            shapes[iname] = rbytes
            pm = re.search(r"parameter\((\d+)\)", rest)
            if pm:
                param_of[iname] = int(pm.group(1))
                full[int(pm.group(1))] = rbytes
                continue
            if op is None:
                continue
            ops_ = re.findall(r"%([\w.\-]+)", args)
            for pos, o in enumerate(ops_):
                if o not in param_of:
                    continue
                if op == "dynamic-slice" and pos == 0:
                    consumers[o] += 2.0 * rbytes
                elif op == "dynamic-update-slice" and pos == 0:
                    upd = shapes.get(ops_[1], rbytes) if len(ops_) > 1 else rbytes
                    consumers[o] += 2.0 * upd
                elif op in ("fusion", "call"):
                    tgt = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", rest)
                    nested = (self.param_traffic(tgt.group(1)) if tgt
                              else {})
                    full_b = shapes.get(o, 0.0)
                    consumers[o] += min(full_b, nested.get(pos, full_b))
                else:
                    consumers[o] += shapes.get(o, 0.0)
        for pname, idx in param_of.items():
            if pname in consumers:
                out[idx] = min(consumers[pname], full.get(idx, consumers[pname]))
        self._traffic_cache[name] = out
        return out

    _OP_RE = re.compile(r"(?:^|[\s)])([a-z][a-z0-9\-]*)\(")

    @staticmethod
    def _split_op(rest: str):
        """Generic opcode extraction: the first bare lowercase token
        followed by '(' after the (possibly tuple) result type."""
        m = HloCost._OP_RE.search(rest)
        if not m:
            return None, ""
        op = m.group(1)
        return op, rest[m.end():]

    def _dot_flops(self, rest, args, shapes_bytes, lhs) -> float:
        # result elements:
        relems = 0.0
        for dt, dims in _SHAPE_RE.findall(lhs):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            relems += n
        # contracting size from lhs operand dims
        ops = re.findall(r"%([\w.\-]+)", args)
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
        k = 1.0
        if cm and ops:
            lhs_dims = self._dims.get(ops[0])
            if lhs_dims:
                for idx in cm.group(1).split(","):
                    if idx:
                        i = int(idx)
                        if i < len(lhs_dims):
                            k *= lhs_dims[i]
        return 2.0 * relems * k

    # dims registry (name -> dims tuple), built lazily on first entry walk
    @property
    def _dims(self) -> Dict[str, tuple]:
        if not hasattr(self, "_dims_cache"):
            cache = {}
            for comp in self.comps.values():
                for iname, rest in comp:
                    m = _SHAPE_RE.search(rest)
                    if m:
                        dims = tuple(int(d) for d in m.group(2).split(",") if d)
                        cache[iname] = dims
            self._dims_cache = cache
        return self._dims_cache

    def entry_cost(self) -> dict:
        entry = None
        for name in self.comps:
            if name.startswith("main") or ".main" in name or entry is None:
                entry = name if ("main" in name or entry is None) else entry
        # prefer the computation named like the module entry (largest works too)
        best = max(self.comps, key=lambda n: len(self.comps[n]))
        target = entry if entry and "main" in entry else best
        c = self.comp_cost(target)
        return {"flops": c["flops"], "bytes": c["bytes"],
                "coll_bytes_by_op": dict(c["coll"]),
                "coll_total_bytes": float(sum(c["coll"].values())),
                "unknown_whiles": self.unknown_whiles,
                "entry": target}


def analyze(hlo: str) -> dict:
    return HloCost(hlo).entry_cost()
