"""AdamW, self-built (optax is not available offline).

Moment states are f32 regardless of param dtype and inherit the param
PartitionSpecs — since params are already FSDP+TP sharded over the whole
mesh this *is* ZeRO-style fully-sharded optimizer state, which is what lets
deepseek-v2-236b's train cell fit 16 GB/chip (DESIGN.md §5).

``master=True`` additionally keeps f32 master weights (bf16 params are
round-trip cast each step).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    master: bool = False
    # moment dtypes: bf16 first moment halves optimizer memory (ZeRO'd
    # anyway); keep v in f32 for stable rsqrt
    m_dtype: str = "float32"
    v_dtype: str = "float32"


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    s = step.astype(jnp.float32)
    warm = cfg.lr * jnp.minimum(s / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < cfg.warmup_steps, warm, cfg.lr * cos)


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    state = {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.m_dtype)),
                          params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.v_dtype)),
                          params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params: Any, grads: Any, state: dict,
                 cfg: AdamWConfig) -> Tuple[Any, dict, dict]:
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-12)) if cfg.grad_clip else 1.0
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    # Single per-leaf map: each leaf's g->m->v->update chain stays one
    # fused region so its f32 temporaries die immediately (a whole-tree
    # map sequence kept every intermediate tree alive at once — 3x the
    # param bytes in f32 on the 236B MoE).
    def leaf(p, g, m, v, master=None):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        b = master.astype(jnp.float32) if master is not None else p.astype(jnp.float32)
        nb = b - lr * ((m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
                       + cfg.weight_decay * b)
        out = (nb.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype))
        if master is not None:
            out = out + (nb,)
        return out

    if cfg.master:
        tup = jax.tree.map(leaf, params, grads, state["m"], state["v"],
                           state["master"])
    else:
        tup = jax.tree.map(leaf, params, grads, state["m"], state["v"])
    is_t = lambda x: isinstance(x, tuple)
    pick = lambda i: jax.tree.map(lambda t: t[i], tup, is_leaf=is_t)
    new_params = pick(0)
    new_state = {"m": pick(1), "v": pick(2), "step": step}
    if cfg.master:
        new_state["master"] = pick(3)
    metrics = {"grad_norm": gn, "lr": lr}
    return new_params, new_state, metrics
