"""Train-step builder: loss + grad + AdamW, with remat / microbatching.

``make_train_step`` returns a pure (params, opt_state, batch) ->
(params, opt_state, metrics) function suitable for pjit. Gradient
accumulation over ``accum`` microbatches uses lax.scan so the HLO stays
one-microbatch sized.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, adamw_update


def make_loss_fn(model, *, q_chunk: int = 0, remat: str = "dots") -> Callable:
    def loss_fn(params, batch):
        return model.loss(params, batch, q_chunk=q_chunk, remat=remat)
    return loss_fn


def make_train_step(model, opt_cfg: AdamWConfig, *, q_chunk: int = 0,
                    remat: str = "dots", accum: int = 1,
                    accum_dtype: str = "float32") -> Callable:
    loss_fn = make_loss_fn(model, q_chunk=q_chunk, remat=remat)

    def train_step(params, opt_state, batch) -> tuple:
        if accum <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(carry, mb):
                acc_loss, acc_grads = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (acc_loss + l,
                        jax.tree.map(lambda a, x: a + x.astype(a.dtype),
                                     acc_grads, g)), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.dtype(accum_dtype)), params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zeros), micro_batches)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        new_params, new_opt, metrics = adamw_update(params, grads, opt_state,
                                                    opt_cfg)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return train_step
