"""Discrete-event engine driving DarisScheduler under the contention model.

Processor-sharing fluid simulation: whenever the running set changes (job
release, stage completion, fault) rates are recomputed and per-lane finish
events are re-predicted. Finish events are **version-stamped** — a rate
change bumps the lane's version so stale predictions die in O(1) instead of
cascading. Stage work carries seeded lognormal noise so MRET has real
variability to track (paper Fig. 9). Fault / straggler / elastic events are
injectable (DESIGN.md §7 — fault tolerance built on the staging boundary).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.metrics import RunMetrics, empty_metrics
from ..core.scheduler import DarisScheduler
from ..core.task import HP, LP, StageInstance, Task

_tie = itertools.count()

RELEASE, FINISH, FAULT, ADD_CTX = 0, 1, 2, 3


@dataclasses.dataclass
class FaultPlan:
    fail_ctx_at: Optional[Tuple[int, float]] = None   # (ctx, t_ms)
    add_ctx_at: Optional[float] = None


class SimEngine:
    EPS = 1e-6   # ms; snap-to-zero tolerance

    def __init__(self, sched: DarisScheduler, horizon_ms: float = 20_000.0,
                 seed: int = 0, noise_sigma: float = 0.06,
                 fault_plan: Optional[FaultPlan] = None,
                 phase_offsets: bool = True):
        self.sched = sched
        self.horizon = horizon_ms
        self.rng = np.random.default_rng(seed)
        self.noise_sigma = noise_sigma
        self.fault_plan = fault_plan
        self.metrics = empty_metrics(horizon_ms)
        self.now = 0.0
        self._heap: List[tuple] = []
        # lane -> [inst, remaining_ms, rate, version]
        self.running: Dict[tuple, list] = {}
        self.phase_offsets = phase_offsets

    def _push(self, t: float, kind: int, payload) -> None:
        heapq.heappush(self._heap, (t, kind, next(_tie), payload))

    def run(self) -> RunMetrics:
        for task in self.sched.tasks:
            offset = (self.rng.uniform(0, task.spec.period_ms)
                      if self.phase_offsets else 0.0)
            self._push(offset, RELEASE, task)
        fp = self.fault_plan
        if fp and fp.fail_ctx_at:
            self._push(fp.fail_ctx_at[1], FAULT, fp.fail_ctx_at[0])
        if fp and fp.add_ctx_at is not None:
            self._push(fp.add_ctx_at, ADD_CTX, None)

        while self._heap:
            t, kind, _, payload = heapq.heappop(self._heap)
            if t > self.horizon:
                break
            if kind == FINISH:
                lane, ver = payload
                entry = self.running.get(lane)
                if entry is None or entry[3] != ver:
                    continue                      # stale prediction
                self._advance_to(t)
                self._complete(lane)
            elif kind == RELEASE:
                self._advance_to(t)
                self._handle_release(payload)
            elif kind == FAULT:
                self._advance_to(t)
                self._handle_fault(payload)
            elif kind == ADD_CTX:
                self._advance_to(t)
                self.sched.add_context(self.now)
            self._dispatch()
            self._reschedule()
        self.metrics.migrations = self.sched.migrations
        for r in self.sched.rejections:
            self.metrics.rejected[r.priority] += 1
        return self.metrics

    # ------------------------------------------------------------ plumbing
    def _advance_to(self, t: float) -> None:
        dt = t - self.now
        if dt > 0:
            for entry in self.running.values():
                entry[1] = max(entry[1] - entry[2] * dt, 0.0)
                if entry[1] < self.EPS:
                    entry[1] = 0.0
                entry[0].work_done += entry[2] * dt
        self.now = t

    def _complete(self, lane) -> None:
        inst, _, _, _ = self.running.pop(lane)
        self.sched.lanes[lane] = None
        et = self.now - inst.start_ms
        done_job = self.sched.on_stage_finish(inst, self.now, et)
        if done_job is not None:
            p = done_job.task.priority
            self.metrics.completed[p] += 1
            self.metrics.response_ms[p].append(self.now - done_job.release_ms)
            if self.now > done_job.abs_deadline_ms:
                self.metrics.missed[p] += 1

    def _handle_release(self, task: Task) -> None:
        self.sched.on_release(task, self.now)
        nxt = self.now + task.spec.period_ms
        if nxt <= self.horizon:
            self._push(nxt, RELEASE, task)

    def _handle_fault(self, ctx_idx: int) -> None:
        for lane in list(self.running):
            if lane[0] == ctx_idx:
                del self.running[lane]
        self.sched.fail_context(ctx_idx, self.now)
        self.metrics.faults += 1

    def _dispatch(self) -> None:
        for lane in self.sched.free_lanes():
            inst = self.sched.next_for_lane(lane[0], self.now)
            if inst is None:
                continue
            prof = inst.profile
            noise = math.exp(self.rng.normal(0.0, self.noise_sigma))
            work = (prof.t_alone_ms + prof.overhead_ms) * noise
            inst.start_ms = self.now
            inst.work_done = 0.0
            inst.lane = lane
            self.sched.lanes[lane] = inst
            # version must be globally unique: a reset-to-0 counter lets a
            # stale FINISH from the lane's previous occupant fire early
            self.running[lane] = [inst, work, 0.0, next(_tie)]

    def _reschedule(self) -> None:
        """Recompute all rates; re-predict and version-stamp finishes.
        Also runs straggler mitigation (beyond-paper, DESIGN.md §7): a stage
        whose projected completion exceeds kappa x its MRET is killed and
        re-enqueued — the Eq. 12 machinery then places it on the least-
        loaded context. Stage granularity bounds the lost work."""
        if not self.running:
            return
        kappa = self.sched.cfg.straggler_kappa
        if kappa:
            for lane, entry in list(self.running.items()):
                inst = entry[0]
                if entry[2] <= 0:
                    continue
                projected = (self.now - inst.start_ms) + entry[1] / max(entry[2], 1e-6)
                mret = inst.task.mret.stage_mret(inst.job.stage_idx)
                floor = 4.0 * (inst.profile.t_alone_ms + inst.profile.overhead_ms)
                if projected > max(kappa * mret, floor) and len(self.running) > 1:
                    del self.running[lane]
                    self.sched.lanes[lane] = None
                    inst.work_done = 0.0
                    inst.lane = None
                    # re-enqueue on the least-backlogged live context
                    # (zero-delay migration at the stage boundary)
                    cands = [c.index for c in self.sched.contexts if c.alive]
                    tgt = min(cands,
                              key=lambda k: self.sched.predicted_finish(k, self.now))
                    old = inst.job.ctx
                    if inst.job in self.sched.active_jobs.get(old, []):
                        self.sched.active_jobs[old].remove(inst.job)
                        self.sched.active_jobs[tgt].append(inst.job)
                    inst.job.ctx = tgt
                    self.sched.queues[tgt].push(inst)
                    self.metrics.stragglers += 1
            self._dispatch()
        ctx_active: Dict[int, int] = {}
        for lane in self.running:
            ctx_active[lane[0]] = ctx_active.get(lane[0], 0) + 1
        entries = list(self.running.items())
        rates = self.sched.contention.rates([
            (lane, e[0].profile, self.sched.contexts[lane[0]].cap,
             ctx_active[lane[0]]) for lane, e in entries])
        for (lane, entry), rate in zip(entries, rates):
            entry[2] = max(rate, 1e-6)
            entry[3] = next(_tie)
            eta = self.now + entry[1] / entry[2]
            self._push(eta, FINISH, (lane, entry[3]))
