"""Deprecated shim: ``SimEngine`` now delegates to the unified runtime.

The discrete-event machinery that used to live here (processor-sharing
fluid rates, version-stamped finish predictions, lognormal stage noise,
straggler mitigation, fault/elastic events) moved into the shared
``EngineCore`` loop (runtime/engine_core.py) driving a ``SimBackend``
(runtime/backend.py). New code should construct servers through the
``repro.api`` facade:

    from repro.api import ServerConfig
    metrics = (ServerConfig.sim().tasks(specs).scheduler_config(cfg)
               .horizon_ms(6000).seed(0).build().run())

``SimEngine`` and ``FaultPlan`` remain importable from here for one
release so existing call sites keep working unchanged.
"""
from __future__ import annotations

import warnings
from typing import Optional

from ..core.metrics import RunMetrics
from ..core.scheduler import DarisScheduler
from .arrivals import PeriodicArrival
from .backend import SimBackend
from .engine_core import EngineCore, FaultPlan
from .epoch import EpochSimBackend

__all__ = ["SimEngine", "FaultPlan"]


class SimEngine:
    """Thin deprecated wrapper: EngineCore + SimBackend with the historic
    constructor signature. Prefer ``repro.api.DarisServer`` — which also
    exposes the engine switch as ``ServerConfig.engine("heap"|"epoch")``;
    the ``engine`` kwarg here mirrors it for legacy call sites."""

    def __init__(self, sched: DarisScheduler, horizon_ms: float = 20_000.0,
                 seed: int = 0, noise_sigma: float = 0.06,
                 fault_plan: Optional[FaultPlan] = None,
                 phase_offsets: bool = True, engine: str = "heap"):
        warnings.warn(
            "SimEngine is deprecated; build a server via repro.api."
            "ServerConfig.sim() instead", DeprecationWarning, stacklevel=2)
        if engine not in ("heap", "epoch"):
            raise ValueError(f"unknown engine {engine!r}: expected "
                             f"'heap' or 'epoch'")
        backend_cls = EpochSimBackend if engine == "epoch" else SimBackend
        phase = "random" if phase_offsets else 0.0
        self.core = EngineCore(
            sched, backend_cls(noise_sigma=noise_sigma),
            horizon_ms=horizon_ms, seed=seed, fault_plan=fault_plan,
            arrivals={t.index: PeriodicArrival(phase_ms=phase)
                      for t in sched.tasks})
        self.sched = sched

    @property
    def metrics(self) -> RunMetrics:
        return self.core.metrics

    @property
    def now(self) -> float:
        return self.core.backend.now_ms()

    def run(self) -> RunMetrics:
        return self.core.run()
