"""Array-programmed epoch engine: vectorized lane-state simulation.

``EpochSimBackend`` is the fleet-scale twin of ``SimBackend``
(runtime/backend.py). The heap engine keeps per-lane state in Python
lists and a versioned prediction heap; every running-set change costs
O(m log m) Python bytecode (one heappush per moved prediction, one
scalar rate assignment per lane). This engine keeps the hot per-lane
state — remaining work, rate, predicted ETA, integrated work, straggler
constants — in preallocated NumPy float64 columns indexed by a stable
lane-slot table, and advances the simulation in *epochs*:

  * ``advance`` computes every running lane's ETA in one array pass and
    pops the minimal-timestamp entry of the cohort (ties broken by a
    monotone prediction stamp — see the cohort-order contract below);
  * work integration (``rem -= rate*dt``, ``work += rate*dt``) is one
    vectorized pass instead of a per-lane Python loop;
  * rate recomputation re-derives only the *dirty rate-groups* (the
    devices whose running set actually changed) through the existing
    bit-exact ``rates_seq`` kernel, and above ``KERNEL_MIN`` lanes per
    group through the jitted JAX contention+ETA kernel
    (kernels/contention_eta.py).

Bit-exactness contract (locked by tests/test_epoch_engine.py)
-------------------------------------------------------------
The epoch path produces bit-identical metrics/digests to the heap path:

  * ``launch_values`` (backend.py) is the single shared per-launch
    scalar pipeline — both engines draw the same rng values in the same
    order (the module-level draw-order invariant).
  * Work integration applies the identical per-lane float sequence
    (``done = rate*dt; rem -= done; snap; work += done``) — vectorized
    elementwise IEEE-754 ops are the same ops.
  * Rates go through ``rates_seq`` per rate-group with the group built
    in the same order (lane insertion order), so every reduction sums
    the same floats left-to-right.
  * Cohort-order contract: the heap pops predictions by ``(eta, seq)``
    where ``seq`` is the push-order tie counter. Here every lane whose
    ETA *moved* during a prediction pass gets a fresh monotone stamp, in
    insertion order — exactly the order the heap engine pushes them —
    and ``advance`` breaks ETA ties by minimal stamp. Unmoved ETAs keep
    their old stamp, mirroring the heap's skip-if-unchanged incremental
    re-prediction (predict_eps=0.0).
  * Per-device dirty tracking is exact because a device's rates are a
    pure function of its own running set and its contexts' caps, which
    are immutable after creation (``add_context`` appends, an online
    ``reconfigure`` retires old Context objects in place and installs
    brand-new ones). Brownout edges and reconfigures conservatively
    mark every device dirty, exactly like the heap's global dirty bit.

Lazy work accounting: ``inst.work_done`` is only materialized from the
slot arrays when someone actually reads it — at stage completion, and
through the ``DarisScheduler.work_sync`` hook before a
``predicted_finish`` backlog scan. All other readers observe it after
one of those flush points (the watchdog/straggler kill paths reset it
to 0.0 *after* the lane left this backend, so the flush never
resurrects stale progress).
"""
from __future__ import annotations

import math
import os
from typing import Dict, List, Optional

import numpy as np

from ..core.mret import StageMret
from ..core.task import Job, StageInstance
from .engine_core import Completion, EngineCore
from .backend import launch_values

# EpochSimBackend.running entry layout (mirrors the sanitizer contract:
# entry[0] is the StageInstance):
#   [0] inst    StageInstance
#   [1] slot    row index into the per-lane state columns
#   [2] pos     position in the insertion-order table (_order/_alive)
_E_INST, _E_SLOT, _E_POS = range(3)


class EpochSimBackend:
    """Vectorized fluid-rate discrete-event substrate (virtual time).

    Drop-in twin of ``SimBackend`` behind ``ServerConfig.engine`` — see
    the module docstring for the layout and the bit-exactness contract.
    """

    EPS = 1e-6              # ms; snap-to-zero tolerance (same as SimBackend)
    KERNEL_MIN = 2048       # lanes per rate-group before the JAX kernel wins
    _ORDER_COMPACT_MIN = 64
    virtual_time = True

    def __init__(self, noise_sigma: float = 0.06,
                 rng: Optional[np.random.Generator] = None):
        self.noise_sigma = noise_sigma
        self.rng = rng
        self.core: Optional[EngineCore] = None
        self.now = 0.0
        self.running: Dict[tuple, list] = {}   # lane -> [inst, slot, pos]
        env = os.environ.get("DARIS_EPOCH_KERNEL_MIN", "")
        self._kernel_min = int(env) if env else self.KERNEL_MIN
        # per-lane state columns (slot-indexed, capacity-doubling)
        self._cap = 0
        self._rem = self._rate = self._eta = np.empty(0)
        self._work = self._start = self._cost = np.empty(0)
        self._floor = self._xfer = np.empty(0)
        self._stamp = np.empty(0, dtype=np.int64)
        self._dev = np.empty(0, dtype=np.int64)
        self._inst: List[Optional[StageInstance]] = []
        self._lane: List[Optional[tuple]] = []
        self._smret: List[Optional[StageMret]] = []
        self._eff_ns: List[float] = []      # effective profile columns as
        self._eff_mf: List[float] = []      # python floats (rates_seq input)
        self._cfail: List[bool] = []
        self._free: List[int] = []
        self._n = 0                          # slot high-water mark
        # stable insertion-order table: position -> slot, alive mask
        self._order = np.empty(0, dtype=np.int64)
        self._alive = np.empty(0, dtype=bool)
        self._order_n = 0
        self._live = 0
        # dirty rate-groups: device ids whose running set changed
        self._dirty: set = set()
        self._dirty_all = True
        self._next_stamp = 1
        # per-context lane index for the lazy work_done flush
        self._by_ctx: Dict[object, Dict[tuple, int]] = {}
        self._n_workers = -1

    # ----------------------------------------------------------- lifecycle
    def bind(self, core: EngineCore) -> None:
        self.core = core
        if self.rng is None:
            self.rng = core.rng   # shared stream: offsets then noise draws
        self._install_work_sync()

    def _install_work_sync(self) -> None:
        """Hook the lazy work_done flush into every scheduler that can
        run a ``predicted_finish`` backlog scan (cluster workers each
        run their own)."""
        sched = self.core.sched
        sched.work_sync = self._sync_ctx
        workers = getattr(sched, "workers", None)
        if workers is not None:
            for w in workers.values():
                w.work_sync = self._sync_ctx
            self._n_workers = len(workers)

    def start(self) -> None:
        self.now = 0.0

    def stop(self) -> None:
        pass

    def now_ms(self) -> float:
        return self.now

    def has_inflight(self) -> bool:
        return bool(self.running)

    # ------------------------------------------------------------- storage
    def _grow(self, cap: int) -> None:
        def f64(a):
            out = np.empty(cap)
            out[:self._n] = a[:self._n]
            return out
        self._rem, self._rate, self._eta = map(
            f64, (self._rem, self._rate, self._eta))
        self._work, self._start, self._cost = map(
            f64, (self._work, self._start, self._cost))
        self._floor, self._xfer = map(f64, (self._floor, self._xfer))
        stamp = np.empty(cap, dtype=np.int64)
        stamp[:self._n] = self._stamp[:self._n]
        self._stamp = stamp
        dev = np.empty(cap, dtype=np.int64)
        dev[:self._n] = self._dev[:self._n]
        self._dev = dev
        pad = cap - len(self._inst)
        self._inst.extend([None] * pad)
        self._lane.extend([None] * pad)
        self._smret.extend([None] * pad)
        self._eff_ns.extend([0.0] * pad)
        self._eff_mf.extend([0.0] * pad)
        self._cfail.extend([False] * pad)
        self._cap = cap

    def _alloc_slot(self) -> int:
        if self._free:
            return self._free.pop()
        if self._n == self._cap:
            self._grow(max(16, 2 * self._cap))
        s = self._n
        self._n += 1
        return s

    def _append_order(self, slot: int) -> int:
        n = self._order_n
        if n == self._order.size:
            cap = max(32, 2 * self._order.size)
            order = np.empty(cap, dtype=np.int64)
            order[:n] = self._order[:n]
            alive = np.zeros(cap, dtype=bool)
            alive[:n] = self._alive[:n]
            self._order, self._alive = order, alive
        self._order[n] = slot
        self._alive[n] = True
        self._order_n = n + 1
        self._live += 1
        return n

    def _compact_order(self) -> None:
        """Squeeze dead positions out of the insertion-order table
        (relative order of live slots — the cohort order — is
        preserved; running entries' positions are re-pointed)."""
        n = self._order_n
        live = self._order[:n][self._alive[:n]]
        k = live.size
        self._order[:k] = live
        self._alive[:k] = True
        self._alive[k:n] = False
        self._order_n = k
        for p, s in enumerate(live.tolist()):
            self.running[self._lane[s]][_E_POS] = p

    def maybe_compact(self) -> None:
        """Housekeeping hook (EngineCore pump pause path): same contract
        as SimBackend.maybe_compact — bound the dead fraction of the
        hot-path table while the daemon idles."""
        if (self._order_n > self._ORDER_COMPACT_MIN
                and 2 * self._live < self._order_n):
            self._compact_order()

    def _live_idx(self) -> np.ndarray:
        """Live slots in insertion order — the epoch cohort ordering."""
        self.maybe_compact()
        n = self._order_n
        return self._order[:n][self._alive[:n]]

    # ---------------------------------------------------------------- time
    def _integrate(self, t: float) -> None:
        """Advance the fluid integration to ``t`` in one array pass —
        the identical per-lane float sequence as SimBackend._advance_to,
        without materializing ``inst.work_done`` (lazy flush).

        Operates on the contiguous slot prefix ``[:n]`` instead of a
        live-index gather: dead slots carry rate 0.0 (``_remove``), so
        their update is an exact no-op and the pass needs no fancy
        indexing (a gather + scatter costs ~3x on these sizes)."""
        dt = t - self.now
        n = self._n
        if dt > 0 and n:
            done = self._rate[:n] * dt
            rem = self._rem[:n] - done
            self._rem[:n] = np.where(rem >= self.EPS, rem, 0.0)
            self._work[:n] += done
        self.now = t

    def advance(self, cap_ms: float) -> List[Completion]:
        n = self._n
        if self.running and n:
            self.maybe_compact()
            # dead and not-yet-predicted slots hold NaN etas; fmin's
            # reduce skips NaN without the nanmin all-NaN warning, and a
            # NaN result (no live prediction) fails the < test below
            tmin = np.fmin.reduce(self._eta[:n])
            if tmin < cap_ms:
                ties = np.flatnonzero(self._eta[:n] == tmin)
                if ties.size > 1:
                    # cohort-order contract: the heap pops equal
                    # timestamps in push order (its seq tie-break)
                    s = int(ties[np.argmin(self._stamp[ties])])
                else:
                    s = int(ties[0])
                t = float(tmin)
                self._integrate(t)
                inst = self._inst[s]
                cfail = self._cfail[s]
                # flush the completing lane's integrated work: the
                # finish hook divides transfer_ms by it
                inst.work_done = float(self._work[s])
                lane = self._lane[s]
                self._remove(lane)
                return [Completion(lane, inst, t - inst.start_ms,
                                   cfail)]
        self._integrate(cap_ms)
        return []

    def peek_eta(self) -> float:
        n = self._n
        if not self.running or n == 0:
            return math.inf
        tmin = float(np.fmin.reduce(self._eta[:n]))
        return math.inf if math.isnan(tmin) else tmin

    # ----------------------------------------------------------- execution
    @staticmethod
    def _dev_of(lane: tuple) -> int:
        # cluster lane keys are ((dev, ctx), slot); single-device keys
        # are (ctx, slot) on device 0 — same convention as the heap
        # engine's brownout lookup
        return lane[0][0] if isinstance(lane[0], tuple) else 0

    def launch(self, lane: tuple, inst: StageInstance) -> None:
        if lane in self.running:        # relaunch over a dead occupant
            self._remove(lane)
        work, eff, smret, cost, floor, xfer, cfail = launch_values(
            self.core, lane, inst, self.rng, self.noise_sigma)
        s = self._alloc_slot()
        self._rem[s] = work
        self._rate[s] = 0.0
        self._eta[s] = math.nan          # no live prediction yet
        self._work[s] = 0.0
        self._start[s] = inst.start_ms
        self._cost[s] = cost
        self._floor[s] = floor
        self._xfer[s] = xfer
        self._stamp[s] = 0
        dev = self._dev_of(lane)
        self._dev[s] = dev
        self._inst[s] = inst
        self._lane[s] = lane
        self._smret[s] = smret
        self._eff_ns[s] = eff.n_sat
        self._eff_mf[s] = eff.mem_frac
        self._cfail[s] = cfail
        pos = self._append_order(s)
        self.running[lane] = [inst, s, pos]
        self._by_ctx.setdefault(lane[0], {})[lane] = s
        self._dirty.add(dev)

    def _remove(self, lane: tuple) -> None:
        e = self.running.pop(lane, None)
        if e is None:
            return
        s, pos = e[_E_SLOT], e[_E_POS]
        self._alive[pos] = False
        self._live -= 1
        # dead slots must be inert under the contiguous [:n] passes:
        # rate 0.0 makes _integrate a no-op, NaN eta drops out of the
        # fmin reduce and the == tmin tie scan
        self._rate[s] = 0.0
        self._eta[s] = math.nan
        self._inst[s] = None
        self._smret[s] = None
        self._lane[s] = None
        self._free.append(s)
        ctx = self._by_ctx.get(lane[0])
        if ctx is not None:
            ctx.pop(lane, None)
        self._dirty.add(int(self._dev[s]))

    def cancel_ctx(self, ctx_idx) -> None:
        for lane in [ln for ln in self.running if ln[0] == ctx_idx]:
            self._remove(lane)

    def kill_lane(self, lane: tuple, inst: StageInstance) -> None:
        self._remove(lane)

    def on_job_done(self, job: Job) -> None:
        pass

    def on_chaos_edge(self) -> None:
        # a brownout window opened/closed on some device: every rate may
        # shift — conservatively recompute all groups (exactly the heap
        # engine's global dirty bit)
        self._dirty_all = True

    def on_reconfigure(self) -> None:
        self._dirty_all = True

    # -------------------------------------------------- lazy work_done sync
    def _sync_ctx(self, k) -> None:
        """Flush integrated work into ``inst.work_done`` for every lane
        of context ``k`` — called (via DarisScheduler.work_sync) right
        before a ``predicted_finish`` backlog scan reads them."""
        lanes = self._by_ctx.get(k)
        if not lanes:
            return
        work = self._work
        for lane, s in lanes.items():
            self.running[lane][_E_INST].work_done = float(work[s])

    # ------------------------------------------------------------- predict
    def _check_stragglers(self) -> None:
        """Straggler mitigation — same policy and float sequence as
        SimBackend._check_stragglers, with a vectorized prefilter: the
        kill threshold is >= floor + xfer/rate, so ``projected <= that``
        proves survival without touching the MRET estimator. Candidates
        (normally none) re-run the exact scalar comparison in insertion
        order — the heap engine's dict order."""
        sched = self.core.sched
        kappa = sched.cfg.straggler_kappa
        if not kappa:
            return
        n = self._n
        if n == 0 or not self.running:
            return
        # contiguous prefilter: dead slots carry rate 0.0, so ``pos``
        # drops them and no gather is needed
        rate = self._rate[:n]
        pos = rate > 0
        if not pos.any():
            return
        now = self.now
        safe = np.maximum(rate, 1e-6)
        projected = (now - self._start[:n]) + self._rem[:n] / safe
        cand = pos & (projected > self._floor[:n] + self._xfer[:n] / safe)
        if not cand.any():
            return
        # candidates are rare; replay them in insertion order — the
        # heap engine's dict iteration order decides the kill sequence
        cset = set(np.flatnonzero(cand).tolist())
        killed = False
        for s in self._live_idx().tolist():
            if s not in cset:
                continue
            inst = self._inst[s]
            if inst is None:
                continue
            rate_s = float(self._rate[s])
            projected_s = ((now - inst.start_ms)
                           + float(self._rem[s]) / max(rate_s, 1e-6))
            mret = self._smret[s].value() * float(self._cost[s])
            thresh = (max(kappa * mret, float(self._floor[s]))
                      + float(self._xfer[s]) / max(rate_s, 1e-6))
            if projected_s > thresh and len(self.running) > 1:
                lane = self._lane[s]
                self._remove(lane)
                sched.lanes[lane] = None
                inst.work_done = 0.0
                inst.lane = None
                old = inst.job.ctx
                if inst.task.fixed_ctx:
                    tgt = inst.task.ctx
                else:
                    cands = [c.index for c in sched.live_contexts()]
                    tgt = min(cands, key=lambda k:
                              sched.migration_eta(k, self.now, old,
                                                  inst.job))
                    if tgt != old:
                        sched.migrations += 1
                if inst.job in sched.active_jobs.get(old, {}):
                    del sched.active_jobs[old][inst.job]
                    sched.active_jobs[tgt][inst.job] = None
                inst.job.ctx = tgt
                sched.queues[tgt].push(inst)
                self.core.metrics.stragglers += 1
                killed = True
        if killed:
            self.core._dispatch()

    def _rates_for(self, contention, u, ns, mf) -> List[float]:
        """Rate-group kernel dispatch: the shared bit-exact
        ``rates_seq`` path below ``KERNEL_MIN`` lanes, the jitted JAX
        contention kernel above it (fleet-scale sweeps)."""
        if len(u) >= self._kernel_min:
            from ..kernels import contention_eta as _ck
            if _ck.available():
                return _ck.rates(contention.device, u, ns, mf)
        return contention.rates_seq(u, ns, mf)

    def _group_update(self, contention, contexts, group) -> None:
        """Recompute one rate-group — identical float sequence (and
        group order) to the heap engine's dirty-rates block."""
        ctx_active: Dict[object, int] = {}
        for lane, _ in group:
            ctx_active[lane[0]] = ctx_active.get(lane[0], 0) + 1
        u: List[float] = []
        ns: List[float] = []
        mf: List[float] = []
        for lane, s in group:
            u.append(contexts[lane[0]].cap / max(ctx_active[lane[0]], 1))
            ns.append(self._eff_ns[s])
            mf.append(self._eff_mf[s])
        rates = self._rates_for(contention, u, ns, mf)
        ch = self.core._chaos
        browned = ch is not None and bool(ch.plan.brownouts)
        for (lane, s), r in zip(group, rates):
            if browned:
                f = ch.brownout_factor(self._dev_of(lane), self.now)
                if f > 1.0:
                    r = r / f
            self._rate[s] = r if r > 1e-6 else 1e-6

    def running_set_changed(self) -> None:
        if not self.running:
            return
        self._check_stragglers()
        if not self.running:
            return
        sched = self.core.sched
        workers = getattr(sched, "workers", None)
        if workers is not None and len(workers) != self._n_workers:
            self._install_work_sync()     # elastic scale-out added a GPU
        idx = self._live_idx()
        if self._dirty_all or self._dirty:
            if self._dirty_all or workers is None:
                sel = idx      # single device: any dirt covers the group
            else:
                d = self._dev[idx]
                mask = None    # OR of == masks beats np.isin's sort path
                for dv in self._dirty:
                    m = d == dv
                    mask = m if mask is None else mask | m
                sel = idx[mask]
            entries = [(self._lane[s], s) for s in sel.tolist()]
            for contention, contexts, group in sched.rate_groups(entries):
                self._group_update(contention, contexts, group)
            self._dirty.clear()
            self._dirty_all = False
        # prediction pass: one vectorized ETA computation; fresh stamps
        # only for lanes whose ETA moved (heap: skip-if-unchanged), in
        # insertion order (heap: dict push order) — the cohort contract
        eta_new = self.now + self._rem[idx] / self._rate[idx]
        changed = ~(eta_new == self._eta[idx])   # NaN old -> changed
        ch_idx = idx[changed]
        k = ch_idx.size
        if k:
            self._eta[ch_idx] = eta_new[changed]
            self._stamp[ch_idx] = np.arange(
                self._next_stamp, self._next_stamp + k, dtype=np.int64)
            self._next_stamp += k
