"""Processor-sharing contention model (roofline-flavoured, DESIGN.md §2).

Each stage has a profile (t_alone, n_sat, mem_frac): ``n_sat`` is the
number of device units the stage's kernels can actually occupy (narrow
DNNs like InceptionV3 saturate few; wide ones like UNet use all), and
``mem_frac`` its bandwidth-bound fraction. Rates for the running set:

  1. context shares: u_i = cap_k / n_active_k  (cap_k from Eq. 9)
  2. device cap:     sum u_i <= N  (proportional scale-down -> this is
                     where oversubscription interference lives)
  3. width:          rc_i = min(u_i, n_sat_i) / n_sat_i
  4. bubbles:        multi-tenancy fills single-stream issue gaps:
                     speed_i = min(1, rc_i * (1 - beta/m) / (1 - beta))
  5. bandwidth:      phi = sum mem_frac_j * speed_j; if phi > 1,
                     speed_i /= (1 - mf_i) + mf_i * phi   (Amdahl-style)

The hot path is ``rates_arrays``: one vectorized NumPy pass over per-lane
arrays (the sim backend keeps them preallocated). Reductions (device cap,
unit budget, bandwidth phi) are evaluated in sequential left-to-right
order, NOT with NumPy's pairwise summation — that keeps every speed
bit-identical to the historic per-lane Python loops, which is what the
golden determinism tests (tests/test_engine_golden.py) lock in.

Calibration inputs are the paper's own Table I only (min JPS -> t_alone,
batching gain -> n_sat; see serving/profiles.py). The model reproduces the
phenomena the paper measures: OS=1 strands idle capacity, full sharing
maximizes throughput at higher variance, wide DNNs gain least from
batching/colocation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Sequence, Tuple

import numpy as np

from ..core.task import StageProfile


def speedup_curve(g_inf: float, n_inputs: int) -> float:
    """g(b) = 1 + (g_inf - 1)(1 - 1/b): throughput gain of a b-input batch
    over b single-input executions, approaching the asymptote ``g_inf``.
    The ONE place the curve shape lives — the dynamic batching path and
    the static pre-batched profiles (serving/profiles.py) both call it."""
    if n_inputs <= 1:
        return 1.0
    return 1.0 + (max(g_inf, 1.0) - 1.0) * (1.0 - 1.0 / n_inputs)


def batch_speedup(prof: StageProfile, n_inputs: int) -> float:
    """Stage-level g(b): ``batch_gain`` is the stage's Table-I-calibrated
    asymptote (serving/profiles.py wires max_JPS / min_JPS through here),
    so wide DNNs — UNet, g_inf 1.08 — gain least and narrow ones —
    InceptionV3, g_inf 3.13 — gain most."""
    return speedup_curve(prof.batch_gain, n_inputs)


@functools.lru_cache(maxsize=4096)
def _batch_cost_cached(g_inf: float, n_inputs: int) -> float:
    # depends on the profile only through its batch_gain asymptote
    return n_inputs / speedup_curve(g_inf, n_inputs)


def batch_cost(prof: StageProfile, n_inputs: int) -> float:
    """Device-time multiplier of a b-input stage vs a single-input one:
    b / g(b). Exactly 1.0 for unbatched jobs (bit-identical guarantee).
    Memoized on (batch_gain, b): the sim hot path (launch, straggler
    check, backlog estimation) calls this per stage instance."""
    if n_inputs <= 1:
        return 1.0
    return _batch_cost_cached(prof.batch_gain, n_inputs)


def batched_stage_ms(prof: StageProfile, n_inputs: int) -> float:
    """Single-stream-alone execution time of a b-input stage (excludes
    the per-dispatch ``overhead_ms``, which batching amortizes: one
    dispatch regardless of b)."""
    return prof.t_alone_ms * batch_cost(prof, n_inputs)


def _seq_sum(a: np.ndarray) -> float:
    """Left-to-right float sum, bit-compatible with ``builtins.sum`` over
    the same values (NumPy's pairwise reduction associates differently)."""
    return sum(a.tolist())


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    n_units: float = 68.0        # SMs (RTX 2080 Ti) | chips (pod slice)
    bubble: float = 0.18         # single-stream issue-gap waste
    l2_pressure: float = 0.09    # cache/DRAM thrash growth per co-tenant
    name: str = "rtx2080ti-like"
    # heterogeneous clusters: scalar speed factor vs the reference device
    # the StageProfiles were calibrated on (an A100-class device at 2.0
    # runs every stage in half its profiled time). MRET/utilization stay
    # in reference units; the scheduler divides by ``speed`` wherever a
    # quantity becomes device-local (admission headroom, ETAs, executed
    # stage work). 1.0 = the calibration device itself.
    speed: float = 1.0


class ContentionModel:
    # below this running-set size the scalar path beats NumPy call
    # overhead; both paths execute the identical float-op sequence
    VECTOR_MIN = 16

    def __init__(self, device: DeviceModel):
        self.device = device
        # (id(prof), b) -> (prof, effective prof); the strong ref to prof
        # in the value keeps its id from being reused by a new object
        self._batched_prof_cache: Dict[tuple, tuple] = {}
        # preallocated per-lane columns for the vectorized kernel
        self._cap = 0
        self._bu = self._bns = self._bmf = np.empty(0)

    def rates_arrays(self, u: np.ndarray, n_sat: np.ndarray,
                     mem_frac: np.ndarray) -> np.ndarray:
        """Vectorized rate kernel. ``u`` is each lane's context share
        (cap_k / n_active_k), ``n_sat``/``mem_frac`` its effective profile
        columns. Returns speed fractions (1.0 = single-stream-alone).

        All elementwise steps are plain IEEE-754 ops and the three
        reductions run in sequential order, so the output is bit-identical
        to the scalar reference implementation in ``rates``."""
        m = u.shape[0]
        if m == 0:
            return u
        dev = self.device
        total = _seq_sum(u)
        if total > dev.n_units:
            u = u * (dev.n_units / total)
        beta = dev.bubble
        bubble_gain = (1.0 - beta / m) / (1.0 - beta)
        speeds = np.minimum(1.0, np.minimum(u, n_sat) / n_sat * bubble_gain)
        # unit conservation: total busy units can't exceed the device plus
        # the bubble-recovery headroom multi-tenancy unlocks (a stream can
        # fill a neighbour's issue gaps but can't mint new SMs)
        used = _seq_sum(speeds * n_sat)
        budget = dev.n_units * (1.0 + beta * (1.0 - 1.0 / m))
        if used > budget:
            speeds = speeds * (budget / used)
        # bandwidth demand grows superlinearly with co-tenant count: more
        # resident working sets thrash L2 so each stream's effective DRAM
        # demand rises (the knee-point mechanism SGPRS reports)
        thrash = 1.0 + dev.l2_pressure * max(m - 1, 0)
        phi = _seq_sum(mem_frac * speeds) * thrash
        if phi > 1.0:
            speeds = speeds / ((1.0 - mem_frac) + mem_frac * phi)
        return speeds

    def _rates_scalar(self, u: List[float], n_sat: List[float],
                      mem_frac: List[float]) -> List[float]:
        """Scalar reference path: the exact op sequence of
        ``rates_arrays`` on Python floats. Faster below VECTOR_MIN lanes;
        bit-identical by construction (the incremental-vs-full property
        test locks the two paths together)."""
        dev = self.device
        m = len(u)
        total = sum(u)
        if total > dev.n_units:
            scale = dev.n_units / total
            u = [x * scale for x in u]
        beta = dev.bubble
        bubble_gain = (1.0 - beta / m) / (1.0 - beta)
        speeds = [min(1.0, min(ui, ns) / ns * bubble_gain)
                  for ui, ns in zip(u, n_sat)]
        used = sum(s * ns for s, ns in zip(speeds, n_sat))
        budget = dev.n_units * (1.0 + beta * (1.0 - 1.0 / m))
        if used > budget:
            shrink = budget / used
            speeds = [s * shrink for s in speeds]
        thrash = 1.0 + dev.l2_pressure * max(m - 1, 0)
        phi = sum(mf * s for mf, s in zip(mem_frac, speeds)) * thrash
        if phi > 1.0:
            speeds = [s / ((1.0 - mf) + mf * phi)
                      for s, mf in zip(speeds, mem_frac)]
        return speeds

    def rates_seq(self, u: List[float], n_sat: List[float],
                  mem_frac: List[float]) -> List[float]:
        """Rate kernel over parallel per-lane lists — the sim backend's
        entry point. Dispatches to the scalar path for small running sets
        and to the preallocated-array NumPy kernel for large ones; both
        produce identical bits."""
        m = len(u)
        if m == 0:
            return []
        if m < self.VECTOR_MIN:
            return self._rates_scalar(u, n_sat, mem_frac)
        if m > self._cap:
            self._cap = max(m, 2 * self._cap)
            self._bu = np.empty(self._cap)
            self._bns = np.empty(self._cap)
            self._bmf = np.empty(self._cap)
        self._bu[:m] = u
        self._bns[:m] = n_sat
        self._bmf[:m] = mem_frac
        return self.rates_arrays(self._bu[:m], self._bns[:m],
                                 self._bmf[:m]).tolist()

    def rates(self, running: Sequence[Tuple[object, StageProfile, float, int]]
              ) -> List[float]:
        """running: list of (key, profile, ctx_cap, n_active_in_ctx).

        Returns speed fractions (1.0 = single-stream-alone speed). List
        front-end over the kernel for callers without per-lane columns
        (tests, offline estimates)."""
        if not running:
            return []
        return self.rates_seq(
            [cap / max(n_act, 1) for _, _, cap, n_act in running],
            [p.n_sat for _, p, _, _ in running],
            [p.mem_frac for _, p, _, _ in running])

    def batched_profile(self, prof: StageProfile, n_inputs: int
                        ) -> StageProfile:
        """Effective profile of a b-input stage for the rate computation.
        The batch converts half its log-speedup into *width* (deeper SM
        occupancy -> more units demanded) and half into *per-unit
        efficiency* (amortized launches, fuller pipelines): n_sat scales
        by sqrt(g(b)). Under unit starvation a b-batch therefore still
        outruns b singles by sqrt(g(b)) — narrow DNNs (InceptionV3) keep
        most of their Table I gain under colocation, wide ones (UNet)
        keep almost none, matching §VI-H. Returns ``prof`` for b = 1.
        Memoized per (profile, b): the dataclasses.replace + sqrt work
        used to run on every launch of a batched stage."""
        if n_inputs <= 1:
            return prof
        key = (id(prof), n_inputs)
        hit = self._batched_prof_cache.get(key)
        if hit is not None and hit[0] is prof:
            return hit[1]
        ns = min(self.device.n_units,
                 prof.n_sat * batch_speedup(prof, n_inputs) ** 0.5)
        eff = dataclasses.replace(prof, n_sat=ns)
        self._batched_prof_cache[key] = (prof, eff)
        return eff

    def solo_speed(self, prof: StageProfile, units: float) -> float:
        """Speed of a stage running alone on ``units`` units."""
        rc = min(units, prof.n_sat) / prof.n_sat
        return min(1.0, rc)   # single stream keeps its bubbles (gain = 1)

    def full_load_time(self, prof: StageProfile, cap: float,
                       n_streams_busy: int, m_total: int) -> float:
        """AFET estimate (paper §IV-A1): execution time with every stream
        busy — pessimistic offline seed for MRET."""
        u = cap / max(n_streams_busy, 1)
        total_u_scale = min(1.0, self.device.n_units / max(u * m_total, 1e-9))
        u *= total_u_scale
        rc = min(u, prof.n_sat) / prof.n_sat
        beta = self.device.bubble
        speed = min(1.0, rc * (1.0 - beta / max(m_total, 1)) / (1.0 - beta))
        # assume bandwidth at the congestion knee under full load
        speed /= (1.0 - prof.mem_frac) + prof.mem_frac * max(1.0, m_total * prof.mem_frac * speed)
        speed = max(speed, 1e-3)
        return (prof.t_alone_ms + prof.overhead_ms) / speed
