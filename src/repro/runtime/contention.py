"""Processor-sharing contention model (roofline-flavoured, DESIGN.md §2).

Each stage has a profile (t_alone, n_sat, mem_frac): ``n_sat`` is the
number of device units the stage's kernels can actually occupy (narrow
DNNs like InceptionV3 saturate few; wide ones like UNet use all), and
``mem_frac`` its bandwidth-bound fraction. Rates for the running set:

  1. context shares: u_i = cap_k / n_active_k  (cap_k from Eq. 9)
  2. device cap:     sum u_i <= N  (proportional scale-down -> this is
                     where oversubscription interference lives)
  3. width:          rc_i = min(u_i, n_sat_i) / n_sat_i
  4. bubbles:        multi-tenancy fills single-stream issue gaps:
                     speed_i = min(1, rc_i * (1 - beta/m) / (1 - beta))
  5. bandwidth:      phi = sum mem_frac_j * speed_j; if phi > 1,
                     speed_i /= (1 - mf_i) + mf_i * phi   (Amdahl-style)

Calibration inputs are the paper's own Table I only (min JPS -> t_alone,
batching gain -> n_sat; see serving/profiles.py). The model reproduces the
phenomena the paper measures: OS=1 strands idle capacity, full sharing
maximizes throughput at higher variance, wide DNNs gain least from
batching/colocation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from ..core.task import StageProfile


def speedup_curve(g_inf: float, n_inputs: int) -> float:
    """g(b) = 1 + (g_inf - 1)(1 - 1/b): throughput gain of a b-input batch
    over b single-input executions, approaching the asymptote ``g_inf``.
    The ONE place the curve shape lives — the dynamic batching path and
    the static pre-batched profiles (serving/profiles.py) both call it."""
    if n_inputs <= 1:
        return 1.0
    return 1.0 + (max(g_inf, 1.0) - 1.0) * (1.0 - 1.0 / n_inputs)


def batch_speedup(prof: StageProfile, n_inputs: int) -> float:
    """Stage-level g(b): ``batch_gain`` is the stage's Table-I-calibrated
    asymptote (serving/profiles.py wires max_JPS / min_JPS through here),
    so wide DNNs — UNet, g_inf 1.08 — gain least and narrow ones —
    InceptionV3, g_inf 3.13 — gain most."""
    return speedup_curve(prof.batch_gain, n_inputs)


def batch_cost(prof: StageProfile, n_inputs: int) -> float:
    """Device-time multiplier of a b-input stage vs a single-input one:
    b / g(b). Exactly 1.0 for unbatched jobs (bit-identical guarantee)."""
    if n_inputs <= 1:
        return 1.0
    return n_inputs / batch_speedup(prof, n_inputs)


def batched_stage_ms(prof: StageProfile, n_inputs: int) -> float:
    """Single-stream-alone execution time of a b-input stage (excludes
    the per-dispatch ``overhead_ms``, which batching amortizes: one
    dispatch regardless of b)."""
    return prof.t_alone_ms * batch_cost(prof, n_inputs)


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    n_units: float = 68.0        # SMs (RTX 2080 Ti) | chips (pod slice)
    bubble: float = 0.18         # single-stream issue-gap waste
    l2_pressure: float = 0.09    # cache/DRAM thrash growth per co-tenant
    name: str = "rtx2080ti-like"


class ContentionModel:
    def __init__(self, device: DeviceModel):
        self.device = device

    def rates(self, running: Sequence[Tuple[object, StageProfile, float, int]]
              ) -> List[float]:
        """running: list of (key, profile, ctx_cap, n_active_in_ctx).

        Returns speed fractions (1.0 = single-stream-alone speed)."""
        if not running:
            return []
        dev = self.device
        m = len(running)
        u = [cap / max(n_act, 1) for _, _, cap, n_act in running]
        total = sum(u)
        if total > dev.n_units:
            scale = dev.n_units / total
            u = [x * scale for x in u]
        beta = dev.bubble
        bubble_gain = (1.0 - beta / m) / (1.0 - beta)
        speeds = []
        for (_, prof, _, _), ui in zip(running, u):
            rc = min(ui, prof.n_sat) / prof.n_sat
            speeds.append(min(1.0, rc * bubble_gain))
        # unit conservation: total busy units can't exceed the device plus
        # the bubble-recovery headroom multi-tenancy unlocks (a stream can
        # fill a neighbour's issue gaps but can't mint new SMs)
        used = sum(s * p.n_sat for (_, p, _, _), s in zip(running, speeds))
        budget = dev.n_units * (1.0 + beta * (1.0 - 1.0 / m))
        if used > budget:
            shrink = budget / used
            speeds = [s * shrink for s in speeds]
        # bandwidth demand grows superlinearly with co-tenant count: more
        # resident working sets thrash L2 so each stream's effective DRAM
        # demand rises (the knee-point mechanism SGPRS reports)
        thrash = 1.0 + dev.l2_pressure * max(m - 1, 0)
        phi = sum(p.mem_frac * s for (_, p, _, _), s in zip(running, speeds))
        phi *= thrash
        if phi > 1.0:
            speeds = [s / ((1.0 - p.mem_frac) + p.mem_frac * phi)
                      for (_, p, _, _), s in zip(running, speeds)]
        return speeds

    def batched_profile(self, prof: StageProfile, n_inputs: int
                        ) -> StageProfile:
        """Effective profile of a b-input stage for the rate computation.
        The batch converts half its log-speedup into *width* (deeper SM
        occupancy -> more units demanded) and half into *per-unit
        efficiency* (amortized launches, fuller pipelines): n_sat scales
        by sqrt(g(b)). Under unit starvation a b-batch therefore still
        outruns b singles by sqrt(g(b)) — narrow DNNs (InceptionV3) keep
        most of their Table I gain under colocation, wide ones (UNet)
        keep almost none, matching §VI-H. Returns ``prof`` for b = 1."""
        if n_inputs <= 1:
            return prof
        ns = min(self.device.n_units,
                 prof.n_sat * batch_speedup(prof, n_inputs) ** 0.5)
        return dataclasses.replace(prof, n_sat=ns)

    def solo_speed(self, prof: StageProfile, units: float) -> float:
        """Speed of a stage running alone on ``units`` units."""
        rc = min(units, prof.n_sat) / prof.n_sat
        return min(1.0, rc)   # single stream keeps its bubbles (gain = 1)

    def full_load_time(self, prof: StageProfile, cap: float,
                       n_streams_busy: int, m_total: int) -> float:
        """AFET estimate (paper §IV-A1): execution time with every stream
        busy — pessimistic offline seed for MRET."""
        u = cap / max(n_streams_busy, 1)
        total_u_scale = min(1.0, self.device.n_units / max(u * m_total, 1e-9))
        u *= total_u_scale
        rc = min(u, prof.n_sat) / prof.n_sat
        beta = self.device.bubble
        speed = min(1.0, rc * (1.0 - beta / max(m_total, 1)) / (1.0 - beta))
        # assume bandwidth at the congestion knee under full load
        speed /= (1.0 - prof.mem_frac) + prof.mem_frac * max(1.0, m_total * prof.mem_frac * speed)
        speed = max(speed, 1e-3)
        return (prof.t_alone_ms + prof.overhead_ms) / speed
