"""EngineCore: the one drive loop behind every DARIS deployment shape.

Historically the repo had two hand-rolled loops — the discrete-event
simulator and the wall-clock JAX executor — each re-implementing release,
dispatch, harvest, and metrics. EngineCore lifts that shared logic into a
single engine that talks to an ``ExecutionBackend`` (runtime/backend.py):
the backend owns *time* and *stage execution*, the core owns everything
the paper calls scheduling — admission (Eq. 11-12), release bookkeeping,
lane dispatch, MRET-feeding completions, fault/elastic events, metrics.

The loop is event-driven for both backends:

    t_evt = earliest pending timeline event (release / fault / scale-out)
    completions = backend.advance(min(t_evt, horizon))
    handle completions, else handle the due event
    dispatch free lanes; backend.running_set_changed()

``advance`` either returns stage completions that occur strictly before
the cap (virtual time jumps there; wall-clock time blocks until then) or
advances time to the cap and returns nothing. Construct via
``repro.api.DarisServer`` unless you are building a new backend.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..chaos.plan import BROWNOUT, EMERGENCY, NORMAL, ChaosState
from ..core.metrics import RunMetrics, empty_metrics, tenant_stats
from ..core.scheduler import DarisScheduler, Rejection
from ..core.task import HP, LP, Job, StageInstance, Task, TaskSpec
from .arrivals import ArrivalProcess

_seq = itertools.count()

# timeline event kinds; ordering at equal timestamps mirrors the historic
# simulator heap (releases before faults before scale-outs before
# repartitions before autoscaler checks). Whole-device failures sort WITH
# context faults — a fault and a reconfigure at the same instant must
# fail first, or the re-place would move tasks onto the dying device
# only to replay them one event later. Only relative order matters.
# CANCEL sits between RELEASE and FAULT: a release and its own cancel at
# the same instant must release first (the cancel then finds a live job),
# and a cancel racing a fault must unwind cleanly before the fault
# re-homes whatever survives.
# The chaos kinds (PR 8) sort after AUTOSCALE: RETRY re-dispatches a
# failed stage after its backoff, WATCHDOG audits one armed lane, CHAOS
# marks a brownout window edge (backend re-rate), DEGRADE is the
# degradation controller's periodic check.
(RELEASE, CANCEL, FAULT, FAIL_DEV, ADD_CTX, RECONFIG, AUTOSCALE,
 RETRY, WATCHDOG, CHAOS, DEGRADE) = range(11)

# kinds that never *represent* pending work: autoscale/degrade checks
# re-arm themselves forever, watchdogs are stale once their stage ends,
# brownout edges only re-rate. RETRY is NOT here — during its backoff a
# job's only token is the RETRY event, so idleness must see it.
_NON_WORK = frozenset((AUTOSCALE, WATCHDOG, CHAOS, DEGRADE))

_EPS = 1e-9


def _resolve_sanitizer(sanitize):
    """Normalize the ``sanitize`` knob to a Sanitizer instance or None.

    Accepts None (defer to the ``DARIS_SANITIZE`` environment), bools,
    an int level, or a pre-built ``analysis.Sanitizer``. The analysis
    package is imported lazily and only when enabling — a disabled
    engine never even loads it, and every hook site below is a single
    ``is not None`` test (the zero-overhead contract)."""
    if sanitize is None:
        if os.environ.get("DARIS_SANITIZE", "") in ("", "0"):
            return None
        from ..analysis.sanitizer import Sanitizer
        return Sanitizer.from_env()
    if sanitize is False or sanitize == 0:
        return None
    if sanitize is True:
        from ..analysis.sanitizer import Sanitizer
        return Sanitizer()
    if isinstance(sanitize, int):
        from ..analysis.sanitizer import Sanitizer
        return Sanitizer(level=sanitize)
    return sanitize


@dataclasses.dataclass
class FaultPlan:
    """Injectable fault / elastic events (DESIGN.md §7).

    ``reconfigure_at`` holds timed online repartitions: each entry is
    ``(t_ms, kwargs)`` where kwargs are forwarded to
    ``DarisScheduler.reconfigure`` (n_contexts / n_streams /
    oversubscription — plus n_gpus under the cluster layer; omitted
    fields keep their current value). ``fail_device_at`` kills a whole
    GPU (cluster servers only): every in-flight stage on it is
    cancelled and its tasks re-place onto surviving devices."""
    fail_ctx_at: Optional[Tuple[int, float]] = None   # (ctx, t_ms)
    add_ctx_at: Optional[float] = None
    reconfigure_at: Optional[List[Tuple[float, Dict]]] = None
    fail_device_at: Optional[Tuple[int, float]] = None   # (device, t_ms)


@dataclasses.dataclass
class AutoscalePolicy:
    """Utilization-driven elastic policy over ``scheduler.reconfigure``.

    Every ``check_every_ms`` the engine reads the Eq. 12 headroom of each
    live context — used fraction = (U_h + U_l,a) / N_s, i.e. how much of
    ``remaining_util`` the active load consumes — and averages it. Above
    ``high`` the partition grows by one context; below ``low`` it shrinks
    by one (within [min_contexts, max_contexts], at most one decision per
    ``cooldown_ms``). Each decision re-derives Eq. 9 geometry for the new
    count, so grow/shrink reshapes every context, not just the edge one.
    """
    low: float = 0.3
    high: float = 0.85
    check_every_ms: float = 250.0
    min_contexts: int = 1
    max_contexts: int = 8
    cooldown_ms: float = 500.0


@dataclasses.dataclass
class Completion:
    """One finished stage execution, reported by a backend. ``failed``
    marks a chaos-injected transient stage fault: the full execution
    time was paid but the result is garbage — the engine must retry or
    abort instead of advancing the pipeline. Always False with no
    ``ChaosPlan`` installed."""
    lane: tuple
    inst: StageInstance
    et_ms: float
    failed: bool = False


class SubmitHandle:
    """Outcome tracker for one submitted request — the job-state
    vocabulary shared by in-process callers and the serving daemon.

    Lifecycle::

        pending -> rejected                       (Eq. 11-12 said no)
                -> queued -> running -> completed (on time)
                                     -> missed    (finished late)
                -> cancelled                      (client cancel, any
                                                   pre-terminal state)
                -> aborted                        (chaos layer gave up:
                                                   retries exhausted or
                                                   deadline-aware bail)

    ``queued`` means admitted and waiting in the stage queue; ``running``
    means the job's first stage has dispatched. ``missed`` jobs still
    completed (soft real-time) — their ``response_ms`` is valid.
    ``ADMITTED`` is the historic alias for ``queued``."""

    PENDING = "pending"
    REJECTED = "rejected"
    QUEUED = "queued"
    ADMITTED = QUEUED              # pre-serving name, kept for callers
    RUNNING = "running"
    COMPLETED = "completed"
    MISSED = "missed"
    CANCELLED = "cancelled"
    ABORTED = "aborted"
    TERMINAL = frozenset((REJECTED, COMPLETED, MISSED, CANCELLED,
                          ABORTED))

    def __init__(self, task: Task, tenant: Optional[str] = None,
                 at_ms: float = 0.0):
        self.task = task
        self.tenant = tenant
        self.at_ms = at_ms              # requested release time
        self.status = self.PENDING
        self.job: Optional[Job] = None
        # actual admission timestamp — the identity the cancel machinery
        # resolves against (job.release_ms for primaries, the member's
        # extra_release_ms entry for coalesced joins)
        self.release_ms: Optional[float] = None
        self.response_ms: Optional[float] = None
        self._cancelled = False

    @property
    def done(self) -> bool:
        return self.status in self.TERMINAL

    def result(self) -> Dict:
        """Poll-friendly view (what the daemon's ``status``/``result``
        verbs serialize)."""
        return {"task": self.task.name, "tenant": self.tenant,
                "status": self.status, "at_ms": self.at_ms,
                "release_ms": self.release_ms,
                "response_ms": self.response_ms}

    def __repr__(self) -> str:
        return f"SubmitHandle({self.task.name}: {self.status})"


class EngineCore:
    """Shared release/dispatch/harvest/metrics loop over a backend."""

    def __init__(self, sched: DarisScheduler, backend, *,
                 horizon_ms: float,
                 arrivals: Optional[Dict[int, ArrivalProcess]] = None,
                 seed: int = 0,
                 fault_plan: Optional[FaultPlan] = None,
                 autoscale: Optional[AutoscalePolicy] = None,
                 record_decisions: bool = False,
                 sanitize=None, chaos=None):
        self.sched = sched
        self.backend = backend
        self.horizon = horizon_ms
        self.rng = np.random.default_rng(seed)
        self.metrics = empty_metrics(horizon_ms)
        self.fault_plan = fault_plan
        self.autoscale = autoscale
        # chaos layer (repro.chaos): ChaosPlan or pre-built ChaosState;
        # None keeps every hook below a bare is-not-None test (twin-path)
        if chaos is None or isinstance(chaos, ChaosState):
            self._chaos: Optional[ChaosState] = chaos
        else:
            self._chaos = ChaosState(chaos)
        # job_id -> (job, inst) parked between a transient stage fault
        # and its RETRY event (the job's only work token meanwhile)
        self._retry_wait: Dict[int, tuple] = {}
        self._last_scale_ms = -math.inf
        self.decisions: Optional[List[str]] = [] if record_decisions else None
        # task.index -> arrival process (tasks without one never self-release)
        self.arrivals: Dict[int, ArrivalProcess] = dict(arrivals or {})
        # job_id -> handles riding that job (primary first, then coalesced
        # members in join order); every handle ever issued, for per-tenant
        # accounting at finalize
        self._job_handles: Dict[int, List[SubmitHandle]] = {}
        self._all_handles: List[SubmitHandle] = []
        self._serving = False
        # per-device completion counters (cluster schedulers only; None
        # on a single device so the completion hot path pays one check)
        self._dev_stats: Optional[Dict[int, Dict]] = (
            {} if hasattr(sched, "workers") else None)
        self._timeline: List[tuple] = []   # (t, kind, seq, payload)
        # pending non-AUTOSCALE timeline entries: autoscale checks re-arm
        # themselves forever, so idleness must not scan the heap for them
        self._work_events = 0
        self._ran = False
        # DSAN invariant auditor (analysis/sanitizer.py); None when off —
        # the hook sites below are then a bare attribute test
        self._sanitizer = _resolve_sanitizer(sanitize)

    # ------------------------------------------------------------ plumbing
    def _push(self, t: float, kind: int, payload) -> None:
        if kind not in _NON_WORK:
            self._work_events += 1
        entry = (t, kind, next(_seq), payload)
        heapq.heappush(self._timeline, entry)
        if self._sanitizer is not None:
            self._sanitizer.note_push(t, kind, entry[2])

    def _log(self, msg: str) -> None:
        if self.decisions is not None:
            self.decisions.append(msg)

    def now_ms(self) -> float:
        return self.backend.now_ms()

    # ---------------------------------------------------------- public API
    def submit(self, spec: TaskSpec, at_ms: float = 0.0,
               tenant: Optional[str] = None) -> SubmitHandle:
        """Register a one-shot job release at ``at_ms`` (before run())."""
        if self._ran:
            raise RuntimeError("EngineCore.run() already executed")
        if at_ms > self.horizon:
            raise ValueError(
                f"submit at_ms={at_ms} is beyond the horizon "
                f"({self.horizon} ms): the release would never fire and "
                f"the handle would stay PENDING forever")
        task = self.sched.add_task(spec)
        handle = SubmitHandle(task, tenant=tenant, at_ms=at_ms)
        self._all_handles.append(handle)
        self._push(at_ms, RELEASE, (task, None, handle))
        return handle

    def submit_release(self, task: Task, at_ms: float,
                       tenant: Optional[str] = None) -> SubmitHandle:
        """Schedule one release of an EXISTING task (the serving path:
        tasks are registered once, requests arrive as releases — MRET
        history and batch coalescing accumulate across requests). Legal
        before run() and, unlike ``submit``, while serving."""
        if self._ran and not self._serving:
            raise RuntimeError("EngineCore.run() already executed")
        if at_ms > self.horizon:
            raise ValueError(
                f"submit_release at_ms={at_ms} is beyond the horizon "
                f"({self.horizon} ms)")
        handle = SubmitHandle(task, tenant=tenant, at_ms=at_ms)
        self._all_handles.append(handle)
        self._push(at_ms, RELEASE, (task, None, handle))
        return handle

    def submit_cancel(self, handle: SubmitHandle, at_ms: float) -> None:
        """Schedule a cancellation of ``handle``'s submission at
        ``at_ms`` (same clock as releases; a release and its cancel at
        the same instant release first)."""
        if self._ran and not self._serving:
            raise RuntimeError("EngineCore.run() already executed")
        self._push(at_ms, CANCEL, handle)

    def run(self, until_idle: bool = False) -> RunMetrics:
        self._begin()
        while self._step(until_idle, None):
            pass
        return self._finalize()

    # ------------------------------------------------------- serving mode
    def begin_serving(self) -> None:
        """Arm the engine for incremental driving: seed the timeline and
        start the backend, but advance nothing. Drive with ``pump``;
        close with ``end_serving``. Used by the ops daemon, where
        requests arrive while the engine runs."""
        self._serving = True
        self._begin()

    def pump(self, frontier_ms: Optional[float] = None) -> None:
        """Process everything actionable at or before ``frontier_ms``,
        then return. "Actionable" = a timeline event is due or a launched
        stage can finish; on a virtual-time backend the clock only ever
        moves to such instants, so an idle server's clock PAUSES at the
        frontier instead of slamming to the horizon. ``None`` uses the
        backend's current wall clock (realtime serving)."""
        if frontier_ms is None:
            frontier_ms = self.backend.now_ms()
        while self._step(False, frontier_ms):
            pass

    def serving_idle(self) -> bool:
        """No queued work, nothing in flight, no pending submissions."""
        return self._idle()

    def end_serving(self, until_idle: bool = True) -> RunMetrics:
        """Stop serving and finalize metrics. ``until_idle`` drains: the
        engine keeps driving (no frontier) until all accepted work
        finishes — the daemon's graceful-drain path."""
        if until_idle:
            while self._step(True, None):
                pass
        return self._finalize()

    # ---------------------------------------------------------- drive loop
    def _begin(self) -> None:
        if self._ran:
            raise RuntimeError("EngineCore.run() already executed")
        self._ran = True
        self.backend.bind(self)
        self.backend.start()
        # seed the timeline: first release per task, then injected events
        for task in self.sched.tasks:
            proc = self.arrivals.get(task.index)
            if proc is None:
                continue
            t0 = proc.start(task.spec, self.rng)
            if t0 is not None and t0 <= self.horizon:
                self._push(t0, RELEASE, (task, proc, None))
        fp = self.fault_plan
        if fp and fp.fail_ctx_at:
            self._push(fp.fail_ctx_at[1], FAULT, fp.fail_ctx_at[0])
        if fp and fp.fail_device_at:
            self._push(fp.fail_device_at[1], FAIL_DEV, fp.fail_device_at[0])
        if fp and fp.add_ctx_at is not None:
            self._push(fp.add_ctx_at, ADD_CTX, None)
        if fp and fp.reconfigure_at:
            for t_ms, kwargs in fp.reconfigure_at:
                self._push(t_ms, RECONFIG, dict(kwargs))
        if self.autoscale is not None:
            self._push(self.autoscale.check_every_ms, AUTOSCALE, None)
        if self._chaos is not None:
            for t in self._chaos.brownout_edges():
                if t <= self.horizon:
                    self._push(t, CHAOS, None)
            deg = self._chaos.plan.degradation
            if deg is not None:
                self._push(deg.check_every_ms, DEGRADE, None)

    def _step(self, until_idle: bool, frontier: Optional[float]) -> bool:
        """One drive iteration. Returns False when the loop should stop:
        idle (when asked), horizon reached, nothing can ever happen again
        — or, in serving mode, nothing is actionable at or before the
        frontier (the pump pauses; more submissions may arm it again)."""
        if until_idle and self._idle():
            return False          # before advancing time to the horizon
        t_evt = self._timeline[0][0] if self._timeline else math.inf
        if frontier is not None:
            nxt = min(t_evt, self.backend.peek_eta())
            if nxt == math.inf or nxt > frontier:
                # pause — never advance past the frontier. The pause is a
                # serving daemon's steady state, so this is also where the
                # backend gets its housekeeping window: a churny
                # cancel-heavy workload leaves stale finish predictions
                # behind, and running_set_changed (the batch-run
                # compaction site) will not run again until new work
                # arms the pump.
                compact = getattr(self.backend, "maybe_compact", None)
                if compact is not None:
                    compact()
                return False
        cap = min(t_evt, self.horizon)
        if frontier is not None and not self.backend.virtual_time:
            cap = min(cap, frontier)   # wall clock: don't block past it
        completions = self.backend.advance(cap)
        now = self.backend.now_ms()
        if completions:
            for c in completions:
                self._on_completion(c)
        elif (self._timeline and t_evt <= self.horizon
              and now >= t_evt - 1e-6):
            t, kind, seq, payload = heapq.heappop(self._timeline)
            if kind not in _NON_WORK:
                self._work_events -= 1
            if self._sanitizer is not None:
                self._sanitizer.note_pop(t, kind, seq, now)
            if kind == RELEASE:
                self._handle_release(payload[0], payload[1], t, payload[2])
            elif kind == CANCEL:
                self._handle_cancel(payload)
            elif kind == FAULT:
                self._handle_fault(payload)
            elif kind == FAIL_DEV:
                self._handle_fail_device(payload)
            elif kind == ADD_CTX:
                self.sched.add_context(now)
                self._log(f"scale-out ctx{len(self.sched.contexts) - 1}")
            elif kind == RECONFIG:
                self._handle_reconfigure(now, payload)
            elif kind == AUTOSCALE:
                self._handle_autoscale(now)
            elif kind == RETRY:
                self._handle_retry(now, payload)
            elif kind == WATCHDOG:
                self._handle_watchdog(now, payload)
            elif kind == CHAOS:
                self._handle_chaos_edge()
            elif kind == DEGRADE:
                self._handle_degrade(now)
        elif now >= self.horizon - _EPS:
            return False
        elif not self._timeline and not self.backend.has_inflight():
            return False    # nothing can ever happen again
        # tell the scheduler when this loop is guaranteed to run again
        # (lazy batch-head holds must release before then)
        self.sched.next_wake_ms = (self._timeline[0][0]
                                   if self._timeline else math.inf)
        self._dispatch()
        self.backend.running_set_changed()
        if self._sanitizer is not None:
            self._sanitizer.after_step(self)
        return True

    def _finalize(self) -> RunMetrics:
        # horizon sweep: jobs still queued/in-flight are real work the run
        # accepted — count them, and count the ones already past their
        # deadline as missed (otherwise overload DMR is understated by
        # exactly the jobs the horizon cut off)
        end_ms = self.backend.now_ms()
        for jobs in self.sched.active_jobs.values():
            for job in jobs:
                p = job.task.priority
                self.metrics.unfinished[p] += 1
                if end_ms > job.abs_deadline_ms:
                    self.metrics.missed[p] += 1
                    if self._dev_stats is not None:
                        # per-device misses must agree with the global
                        # sweep: attribute the late job to its home
                        ds = self._dev_stats.setdefault(
                            job.ctx[0], {"completed": {HP: 0, LP: 0},
                                         "missed": {HP: 0, LP: 0}})
                        ds["missed"][p] += 1
        self.metrics.migrations = self.sched.migrations
        for p, n in self.sched.rejected_counts.items():
            self.metrics.rejected[p] += n
        if self._dev_stats is not None:
            # every device appears — zeros included — so cluster
            # summaries always carry per_device/transfers even when a
            # short run completed nothing
            for d in self.sched.workers:
                self._dev_stats.setdefault(
                    d, {"completed": {HP: 0, LP: 0},
                        "missed": {HP: 0, LP: 0}})
            self.metrics.per_device = {
                d: {"completed": dict(s["completed"]),
                    "missed": dict(s["missed"])}
                for d, s in sorted(self._dev_stats.items())}
            self.metrics.transfers = getattr(self.sched, "transfers", 0)
        if any(h.tenant is not None for h in self._all_handles):
            self.metrics.per_tenant = tenant_stats(self._all_handles)
        if self._serving:
            # a serving engine's configured horizon is a far-future guard,
            # not the observation window: rate metrics (jps) divide by the
            # time actually served
            self.metrics.horizon_ms = max(end_ms, _EPS)
        if self._sanitizer is not None:
            self._sanitizer.on_finalize(self)
        self.backend.stop()
        return self.metrics

    # -------------------------------------------------------- event handlers
    def _handle_release(self, task: Task, proc: Optional[ArrivalProcess],
                        sched_t: float,
                        handle: Optional[SubmitHandle] = None) -> None:
        """``sched_t`` is when this release was *scheduled*; wall-clock
        backends may observe ``now > sched_t``, and the periodic successor
        must be anchored to the schedule, not the observation."""
        now = self.backend.now_ms()
        if handle is not None and handle._cancelled:
            # cancelled before it ever released: the submission never
            # reaches the scheduler (accounting happened at cancel time)
            self._log(f"release {task.name} skipped (cancelled)")
            return
        if (self._chaos is not None and task.priority == LP
                and self._chaos.mode != NORMAL):
            # degradation shed (BROWNOUT/EMERGENCY): LP refused at the
            # door — books it as a rejection everywhere the admission
            # path would, plus the dedicated shed counter
            self.sched.rejections.append(Rejection(task.name, now, LP))
            self.sched.rejected_counts[LP] += 1
            self.metrics.shed[LP] += 1
            self._log(f"shed {task.name} ({self._chaos.mode})")
            if handle is not None:
                handle.status = SubmitHandle.REJECTED
            if self._sanitizer is not None:
                self._sanitizer.note_release(LP, "rejected")
        else:
            pre_coalesced = self.sched.coalesced
            job = self.sched.on_release(task, now)
            if job is None:
                self._log(f"reject {task.name}")
                if handle is not None:
                    handle.status = SubmitHandle.REJECTED
            else:
                if self.sched.coalesced > pre_coalesced:
                    self._log(f"batch {task.name} -> ctx{job.ctx} "
                              f"b={job.n_inputs}")
                else:
                    self._log(f"admit {task.name} -> ctx{job.ctx}")
                if handle is not None:
                    handle.status = SubmitHandle.QUEUED
                    handle.job = job
                    # a coalesced join's member release stamp is ``now``
                    # (the value on_release appended to
                    # extra_release_ms), same as a primary's
                    # job.release_ms — either way the handle's identity
                    # for cancellation is (task.index, now)
                    handle.release_ms = now
                    if job.start_ms is not None:
                        handle.status = SubmitHandle.RUNNING
                    self._job_handles.setdefault(job.job_id,
                                                 []).append(handle)
            if self._sanitizer is not None:
                outcome = ("rejected" if job is None else
                           "coalesced"
                           if self.sched.coalesced > pre_coalesced
                           else "admitted")
                self._sanitizer.note_release(task.priority, outcome)
        if proc is not None:
            nxt, skipped = proc.next_after(sched_t, now)
            if skipped:
                self.metrics.skipped_releases += skipped
            if nxt is not None and nxt <= self.horizon:
                self._push(nxt, RELEASE, (task, proc, None))

    def _handle_cancel(self, handle: SubmitHandle) -> str:
        """CANCEL event: retire one submission. Returns the scheduler
        outcome (see ``DarisScheduler.cancel_job``) for daemon replies;
        terminal handles no-op ("absent" = already finished)."""
        now = self.backend.now_ms()
        if handle.status == SubmitHandle.CANCELLED:
            return "noop"
        if handle.done:
            return "absent"
        p = handle.task.priority
        if handle.job is None:
            # not yet released: mark it so the pending RELEASE skips
            handle._cancelled = True
            handle.status = SubmitHandle.CANCELLED
            self.metrics.cancelled[p] += 1
            self._log(f"cancel {handle.task.name} (unreleased)")
            if self._sanitizer is not None:
                self._sanitizer.note_cancel("cancelled", p, False)
            return "cancelled"
        outcome, job = self.sched.cancel_job(
            handle.task.index, handle.release_ms, now)
        if outcome in ("cancelled", "cancelling", "detached", "dropped"):
            handle._cancelled = True
            handle.status = SubmitHandle.CANCELLED
            self.metrics.cancelled[p] += 1
            if outcome == "cancelled":
                # whole job retired while queued: no completion will ever
                # arrive for it — clean backend job state now
                self.backend.on_job_done(job)
                self._job_handles.pop(job.job_id, None)
            self._log(f"cancel {handle.task.name} ({outcome})")
            if self._sanitizer is not None:
                self._sanitizer.note_cancel(outcome, p,
                                            outcome == "cancelled")
        else:
            self._log(f"cancel {handle.task.name} ({outcome})")
        return outcome

    def _handle_fault(self, ctx_idx: int) -> None:
        now = self.backend.now_ms()
        if hasattr(self.sched, "workers"):
            if ctx_idx[0] not in self.sched.live_devices():
                # cluster fail_context no-ops on a dead device; don't
                # count a fault that never happened (mirrors
                # _handle_fail_device)
                self._log(f"fault ctx{ctx_idx} (device already dead)")
                return
            if ctx_idx not in self.sched.queues:
                # a planned fault can name a context the elastic
                # machinery never minted (scale_out picks the
                # least-loaded device) — compose gracefully, like
                # faults on absent devices
                self._log(f"fault ctx{ctx_idx} skipped (no such context)")
                return
        esc = getattr(self.sched, "fault_escalates_to", None)
        dev = esc(ctx_idx) if esc is not None else None
        if dev is not None and self.sched.live_devices() == [dev]:
            # last-context fault escalating on the fleet's sole survivor
            # — skip rather than abort, like _handle_fail_device
            self._log(f"fault ctx{ctx_idx} skipped (would fail last "
                      f"live device)")
            return
        for key in self.sched.fault_cancel_keys(ctx_idx):
            self.backend.cancel_ctx(key)
        self.sched.fail_context(ctx_idx, now)
        self.metrics.faults += 1
        self._log(f"fault ctx{ctx_idx}")

    def _handle_fail_device(self, dev: int) -> None:
        """Whole-GPU failure (cluster servers): cancel every in-flight
        stage on the device, then let the cluster scheduler re-place its
        tasks HP-first onto the survivors (cross-GPU migration). A
        device the elastic machinery already retired/failed is a no-op —
        fault plans legitimately compose with autoscalers that may have
        shrunk that device away first."""
        now = self.backend.now_ms()
        live = self.sched.live_devices()
        if dev not in live:
            self._log(f"fault device{dev} (already dead)")
            return
        if live == [dev]:
            # an autoscaler/reconfigure shrink can leave the planned
            # victim as the sole survivor; losing it means no fleet at
            # all — skip the fault rather than abort the run
            self._log(f"fault device{dev} skipped (last live device)")
            return
        for key in self.sched.device_ctx_keys(dev):
            self.backend.cancel_ctx(key)
        self.sched.fail_device(dev, now)
        self.metrics.faults += 1
        self._log(f"fault device{dev}")

    def _handle_reconfigure(self, now: float, kwargs: Dict) -> None:
        info = self.sched.reconfigure(now, **kwargs)
        self.metrics.reconfigures += 1
        self._last_scale_ms = now
        hook = getattr(self.backend, "on_reconfigure", None)
        if hook is not None:
            hook()
        self._log(f"reconfigure retired={info['retired']} "
                  f"created={info['created']} rehomed={info['rehomed']} "
                  f"inflight={info['inflight']}")

    def _handle_autoscale(self, now: float) -> None:
        pol = self.autoscale
        live = self.sched.live_contexts()
        n_live = len(live)
        if n_live and now - self._last_scale_ms >= pol.cooldown_ms:
            used = [(self.sched.util_hp_total(c.index, now)
                     + self.sched.util_lp_active(c.index, now))
                    / max(c.n_streams, 1) for c in live]
            mean_used = sum(used) / n_live
            # the scale unit is scheduler-defined: contexts on one
            # device, whole GPUs under the cluster layer — min/max
            # bounds are counted in that same unit
            n_units = self.sched.scale_units()
            if mean_used > pol.high and n_units < pol.max_contexts:
                self._log(f"autoscale grow (used={mean_used:.2f})")
                self._handle_reconfigure(
                    now, self.sched.scale_kwargs(n_units + 1))
            elif mean_used < pol.low and n_units > pol.min_contexts:
                self._log(f"autoscale shrink (used={mean_used:.2f})")
                self._handle_reconfigure(
                    now, self.sched.scale_kwargs(n_units - 1))
        nxt = now + pol.check_every_ms
        if nxt <= self.horizon:
            self._push(nxt, AUTOSCALE, None)

    # ------------------------------------------------- chaos layer (PR 8)
    def _on_stage_failed(self, c: Completion, now: float) -> None:
        """A transient stage fault surfaced at completion time: the full
        execution time was paid but the result is garbage. Decide retry
        (backoff on the virtual clock, RETRY event) vs abort (attempts
        exhausted, or deadline-aware give-up). Failed stages never reach
        ``on_stage_finish`` — no MRET observation, no pipeline advance,
        no inter-stage state commit."""
        inst = c.inst
        job = inst.job
        p = job.task.priority
        self.metrics.chaos_faults += 1
        inst.attempts += 1
        pol = self._chaos.plan.retry
        delay = pol.delay_ms(inst.attempts)
        give_up = inst.attempts >= pol.max_attempts
        if not give_up and pol.deadline_aware and inst.smret is not None:
            # even an immediately-successful retry lands at now + delay +
            # predicted stage time; past the job's absolute deadline the
            # retry only burns device time a live job could use
            pred = inst.smret.value() * inst.cost_b
            spd = getattr(self.sched, "speed", 1.0)
            if spd != 1.0:
                pred /= spd
            if now + delay + pred > job.abs_deadline_ms:
                give_up = True
        if give_up:
            self._abort_job(job, now, p)
            return
        self.metrics.retries += 1
        inst.work_done = 0.0
        inst.lane = None
        inst.start_ms = None
        self._retry_wait[job.job_id] = (job, inst)
        self._push(now + delay, RETRY, job.job_id)
        self._log(f"retry {job.task.name} s{job.stage_idx} "
                  f"attempt={inst.attempts} delay={delay:.2f}")

    def _abort_job(self, job: Job, now: float, p: int) -> None:
        """Give up on a transiently-failing job: it leaves the scheduler
        immediately (unwinding the Eq. 12 charge) and every handle riding
        it goes terminal ABORTED. Neither completed nor missed nor
        cancelled — ``metrics.aborted`` is its own bucket."""
        self.sched.abort_job(job, now)
        self.backend.on_job_done(job)
        self.metrics.aborted[p] += 1
        self._log(f"abort {job.task.name} s{job.stage_idx}")
        if self._sanitizer is not None:
            self._sanitizer.note_abort(p)
        handles = self._job_handles.pop(job.job_id, None)
        if handles:
            for h in handles:
                if h._cancelled or h.done:
                    continue
                h.status = SubmitHandle.ABORTED

    def _handle_retry(self, now: float, job_id: int) -> None:
        """RETRY event: the backoff elapsed — re-enqueue the failed
        stage at the boundary (normal dispatch then re-launches it; a
        migration may re-home it exactly like any queued stage)."""
        entry = self._retry_wait.pop(job_id, None)
        if entry is None:
            return                 # aborted/cancelled away meanwhile
        job, inst = entry
        if job.cancelled:
            # the cancel landed during the backoff ("cancelling"): this
            # boundary is where the job retires — same bookkeeping as the
            # in-flight boundary retirement in _on_completion
            self.sched.abort_job(job, now)
            self.backend.on_job_done(job)
            if self._sanitizer is not None:
                self._sanitizer.note_job_done(job)
            self._job_handles.pop(job.job_id, None)
            self._log(f"retire {job.task.name} (cancelled during retry)")
            return
        self.sched.queues[job.ctx].push(inst)
        self._log(f"redispatch {job.task.name} s{job.stage_idx}")

    def _handle_watchdog(self, now: float, payload) -> None:
        """WATCHDOG event: the lane armed at dispatch time has been
        running longer than k x its predicted MRET. Kill the backend
        entry and re-dispatch the stage at the boundary via the existing
        zero-delay migration path (mirrors the sim straggler kill, but
        works on any backend — it is the engine's own timeline)."""
        lane, inst, armed_ms = payload
        if self.sched.lanes.get(lane) is not inst \
                or inst.start_ms != armed_ms:  # dsan: ignore[DSAN003] — stamp identity, not arithmetic
            return                 # stale: the stage already finished
        job = inst.job
        self.backend.kill_lane(lane, inst)
        self.sched.lanes[lane] = None
        self.metrics.watchdog_kills += 1
        inst.work_done = 0.0
        inst.lane = None
        inst.start_ms = None
        old = job.ctx
        if job.task.fixed_ctx:
            tgt = job.task.ctx
        else:
            tgt = min((c.index for c in self.sched.live_contexts()),
                      key=lambda k: self.sched.migration_eta(
                          k, now, old, job))
            if tgt != old:
                self.sched.migrations += 1
        if job in self.sched.active_jobs.get(old, {}):
            del self.sched.active_jobs[old][job]
            self.sched.active_jobs[tgt][job] = None
        job.ctx = tgt
        self.sched.queues[tgt].push(inst)
        self._log(f"watchdog kill {job.task.name} s{job.stage_idx} "
                  f"lane({lane[0]},{lane[1]}) -> ctx{tgt}")

    def _handle_chaos_edge(self) -> None:
        """CHAOS event: a brownout window opened or closed — the backend
        must recompute rates so in-flight work picks the change up."""
        hook = getattr(self.backend, "on_chaos_edge", None)
        if hook is not None:
            hook()
        self._log("brownout edge")

    def _handle_degrade(self, now: float) -> None:
        """DEGRADE event: the degradation controller's periodic check.
        Reads the same utilization signal as the autoscaler, walks the
        NORMAL/BROWNOUT/EMERGENCY hysteresis, and applies the mode's
        side effects (batch widening; EMERGENCY sheds queued LP)."""
        ch = self._chaos
        pol = ch.plan.degradation
        live = self.sched.live_contexts()
        if live:
            used = [(self.sched.util_hp_total(c.index, now)
                     + self.sched.util_lp_active(c.index, now))
                    / max(c.n_streams, 1) for c in live]
            signal = sum(used) / len(live)
            mode = ch.mode
            if mode == NORMAL:
                new = (EMERGENCY if signal >= pol.emergency_enter else
                       BROWNOUT if signal >= pol.brownout_enter else
                       NORMAL)
            elif mode == BROWNOUT:
                new = (EMERGENCY if signal >= pol.emergency_enter else
                       NORMAL if signal < pol.brownout_exit else
                       BROWNOUT)
            else:  # EMERGENCY cools off in stages: -> BROWNOUT first
                new = (BROWNOUT if signal < pol.emergency_exit else
                       EMERGENCY)
            if ch.set_mode(now, new):
                self.metrics.degrade_transitions += 1
                self.sched.batch_widen = (pol.batch_widen
                                          if new != NORMAL else 1.0)
                self._log(f"degrade {ch.transitions[-1][1]} -> {new} "
                          f"(signal={signal:.2f})")
                if new == EMERGENCY:
                    self._shed_queued_lp(now)
        nxt = now + pol.check_every_ms
        if nxt <= self.horizon:
            self._push(nxt, DEGRADE, None)

    def _shed_queued_lp(self, now: float) -> None:
        """EMERGENCY entry: cancel every queued (not yet dispatched) LP
        job through the PR 6 cancellation path — members detach first,
        then the primary retires the whole job, so admission charges
        unwind and batch heads seal exactly as client cancels do.
        In-flight LP finishes (zero-delay semantics)."""
        victims = []
        for q in self.sched.queues.values():
            for inst in q.instances():
                job = inst.job
                if job.task.priority == LP and not job.cancelled:
                    victims.append(job)
        for job in victims:
            handles = self._job_handles.get(job.job_id)
            if handles:
                # handle-carried job: cancel each submission, members
                # before the primary (the final cancel retires the job
                # and does all the accounting _handle_cancel owns)
                for h in list(handles)[::-1]:
                    self._handle_cancel(h)
            else:
                # handle-less (periodic) job: same chain straight on the
                # scheduler — detach/drop the members, retire the primary
                for idx, rel in list(zip(job.extra_member_idx,
                                         job.extra_release_ms))[::-1]:
                    self.sched.cancel_job(idx, rel, now)
                outcome, _ = self.sched.cancel_job(
                    job.task.index, job.release_ms, now)
                if outcome == "cancelled":
                    self.backend.on_job_done(job)
                    if self._sanitizer is not None:
                        # not a client cancel (no submission to count):
                        # only the job-retired ledger moves
                        self._sanitizer.note_cancel("shed", LP, True)
            self.metrics.shed[LP] += 1
            self._log(f"emergency shed {job.task.name}")

    def _on_completion(self, c: Completion) -> None:
        now = self.backend.now_ms()
        job = c.inst.job
        stage = job.stage_idx
        self.sched.lanes[c.lane] = None
        if c.failed and self._chaos is not None and not job.cancelled:
            # chaos-injected transient fault: never feeds MRET, never
            # advances the pipeline (cancelled jobs retire normally — the
            # boundary retirement outranks the failure)
            self._on_stage_failed(c, now)
            return
        done = self.sched.on_stage_finish(c.inst, now, c.et_ms)
        self._log(f"finish {job.task.name} s{stage}")
        if done is None:
            return
        self.backend.on_job_done(done)
        if self._sanitizer is not None:
            self._sanitizer.note_job_done(done)
        handles = self._job_handles.pop(done.job_id, None)
        if done.cancelled:
            # in-flight cancel retired at this stage boundary: the cancel
            # event already did the accounting; nothing completed
            self._log(f"retire {done.task.name} (cancelled)")
            return
        p = done.task.priority
        if done.dropped_releases:
            # some members were cancelled after the batch sealed: their
            # inputs rode along physically but their results are
            # discarded — throughput/response accounting covers only the
            # survivors (the job itself still completed once)
            live = [r for r in done.release_times
                    if r not in done.dropped_releases]
        else:
            live = None     # hot path: historic accounting, bit-identical
        self.metrics.completed[p] += 1
        self.metrics.completed_inputs[p] += (done.n_inputs if live is None
                                             else len(live))
        if self._dev_stats is not None:
            # attribute to the job's HOME device (job.ctx), matching the
            # horizon sweep — the only base available for unfinished
            # jobs. After a zero-delay re-home the final stage may have
            # executed on the old device's lane; the completion still
            # credits the device now responsible for the job.
            dev = done.ctx[0]
            ds = self._dev_stats.setdefault(
                dev, {"completed": {HP: 0, LP: 0},
                      "missed": {HP: 0, LP: 0}})
            ds["completed"][p] += 1
            if now > done.abs_deadline_ms:
                ds["missed"][p] += 1
        b = done.n_inputs if live is None else len(live)
        self.metrics.batch_hist[b] = self.metrics.batch_hist.get(b, 0) + 1
        # each batched input gets its own response time, measured from its
        # own release (the head's deadline governed the whole batch)
        for r_ms in (done.release_times if live is None else live):
            self.metrics.response_ms[p].append(now - r_ms)
        if now > done.abs_deadline_ms:
            self.metrics.missed[p] += 1
        if handles:
            # every handle riding this job — the primary and coalesced
            # members (which may belong to other tasks under
            # scope="model") — finishes at its own response time; a late
            # finish against the handle's OWN release+deadline is MISSED
            # (still a completion: soft real-time)
            for h in handles:
                if h._cancelled:
                    continue    # detached/dropped member: stays cancelled
                h.response_ms = now - h.release_ms
                late = now > h.release_ms + h.task.spec.deadline_ms
                h.status = (SubmitHandle.MISSED if late
                            else SubmitHandle.COMPLETED)

    def _dispatch(self) -> None:
        now = self.backend.now_ms()
        sched = self.sched
        # only contexts whose queue holds work can yield a dispatch, and
        # popping never refills another queue, so lanes of cold contexts
        # are skipped up front (their pop would return None anyway).
        # Sorting the filtered subset preserves the historic sorted-lane
        # dispatch order among the lanes that matter.
        hot = getattr(sched, "hot_queues", None)
        if hot is not None:
            if not hot:
                return
            lanes = sorted(ln for ln in sched.lanes.free_set()
                           if ln[0] in hot)
        else:                          # custom scheduler without the index
            lanes = sched.free_lanes()
        for lane in lanes:
            inst = sched.next_for_lane(lane[0], now)
            if inst is None:
                continue
            inst.start_ms = now
            inst.work_done = 0.0
            inst.lane = lane
            self.sched.lanes[lane] = inst
            if inst.job.start_ms is None:
                # first dispatch of the job: queued -> running for every
                # handle riding it
                inst.job.start_ms = now
                for h in self._job_handles.get(inst.job.job_id, ()):
                    if h.status == SubmitHandle.QUEUED:
                        h.status = SubmitHandle.RUNNING
            self._log(f"dispatch {inst.task.name} s{inst.job.stage_idx} "
                      f"lane({lane[0]},{lane[1]})")
            self.backend.launch(lane, inst)
            if (self._chaos is not None
                    and self._chaos.plan.watchdog_kappa > 0.0
                    and inst.smret is not None):
                # arm the per-stage watchdog: k x predicted MRET (plus
                # any serialized transfer charge) from this dispatch. The
                # event self-invalidates if the stage finishes first
                # (lane occupant / start stamp check in _handle_watchdog)
                pred = inst.smret.value() * inst.cost_b
                t = (now + self._chaos.plan.watchdog_kappa * pred
                     + inst.transfer_ms)
                if t <= self.horizon:
                    self._push(t, WATCHDOG, (lane, inst, now))

    def _idle(self) -> bool:
        # autoscaler check events keep the timeline populated forever;
        # they are not work, so drain() must be able to idle past them
        if self._work_events:
            return False
        if self.backend.has_inflight():
            return False
        if any(len(q) for q in self.sched.queues.values()):
            return False
        return not any(self.sched.active_jobs[k]
                       for k in self.sched.active_jobs)

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> dict:
        """Introspection for programmatic clients (live or post-run)."""
        now = self.backend.now_ms() if self._ran else 0.0
        snap = {
            "now_ms": now,
            "backend": type(self.backend).__name__,
            "contexts": [{"index": c.index, "alive": c.alive,
                          "cap": c.cap, "n_streams": c.n_streams}
                         for c in self.sched.contexts],
            "queue_depth": {k: len(q) for k, q in self.sched.queues.items()},
            "lanes_busy": sum(1 for i in self.sched.lanes.values()
                              if i is not None),
            "active_jobs": {k: len(v)
                            for k, v in self.sched.active_jobs.items()},
            "completed": dict(self.metrics.completed),
            "completed_inputs": dict(self.metrics.completed_inputs),
            "batch_hist": dict(sorted(self.metrics.batch_hist.items())),
            "coalesced": self.sched.coalesced,
            "rejected": dict(self.sched.rejected_counts),
            "migrations": self.sched.migrations,
            "reconfigures": self.metrics.reconfigures,
            "skipped_releases": self.metrics.skipped_releases,
            # per-priority response-time percentiles over completions so
            # far (live monitoring reads tail latency without waiting for
            # the run summary)
            "resp_hp": self.metrics.resp_stats(HP),
            "resp_lp": self.metrics.resp_stats(LP),
            "cancelled": dict(self.metrics.cancelled),
        }
        if any(h.tenant is not None for h in self._all_handles):
            snap["tenants"] = tenant_stats(self._all_handles)
        summary = getattr(self.sched, "device_summary", None)
        if summary is not None:
            snap["devices"] = summary(now)
            snap["transfers"] = self.sched.transfers
            if self._dev_stats is not None:
                snap["device_completed"] = {
                    d: dict(s["completed"])
                    for d, s in sorted(self._dev_stats.items())}
        return snap
