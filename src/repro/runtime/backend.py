"""ExecutionBackend protocol + the two built-in substrates.

A backend owns *time* and *stage execution* and nothing else; scheduling
policy lives entirely in ``EngineCore``/``DarisScheduler``. The contract:

    bind(core)               engine hands the backend its core reference
    start() / stop()         run lifecycle
    now_ms()                 current time (virtual or wall clock)
    advance(cap_ms)          -> [Completion] occurring strictly before cap,
                             else advance/block time to cap and return []
    launch(lane, inst)       begin executing a dispatched stage
    running_set_changed()    hook after dispatch/harvest (rate recompute)
    cancel_ctx(ctx)          drop in-flight work on a failed context
    on_job_done(job)         job-level cleanup (activation state, ...)
    has_inflight()           any launched-but-unharvested stage?

``SimBackend`` wraps the processor-sharing fluid simulation (versioned
finish predictions, lognormal stage noise, straggler mitigation);
``RealtimeBackend`` wraps pooled-thread execution of real (jitted JAX)
stage payloads on wall-clock time. Both are driven by the same EngineCore
loop, which is what makes sim-vs-real scheduler-decision parity testable.

RNG-draw-order invariant
------------------------
The sim's RNG stream is shared between arrival phase offsets (drawn when
``EngineCore.run`` seeds the timeline) and per-launch lognormal stage
noise (drawn inside ``launch``, one draw per dispatched stage, in
dispatch order). Every metric the repo treats as reproducible — and the
golden fixtures in tests/test_engine_golden.py — depends on that order.
Any engine change (vectorization, batching, reordering of dispatch) MUST
keep the number and order of draws identical; draw noise at launch, never
earlier or later, and never draw speculatively.
"""
from __future__ import annotations

import functools
import heapq
import itertools
import math
import os
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Protocol

import numpy as np

from ..core.task import Job, StageInstance
from .contention import batch_cost, batched_stage_ms
from .engine_core import Completion, EngineCore

_tie = itertools.count()

# SimBackend.running entry layout (kept as a mutable list for speed):
#   [0] inst          StageInstance
#   [1] rem           remaining work, ms of single-stream-alone time
#   [2] rate          current speed fraction
#   [3] version       stamp matching the live heap prediction
#   [4] eff_prof      effective (possibly batch-widened) StageProfile
#   [5] eta           finish time of the live heap prediction (None until
#                     the first prediction is pushed)
#   [6] smret         the instance's StageMret estimator (live ref)
#   [7] cost          batch cost b/g(b) of this stage (static per launch)
#   [8] floor         straggler kill floor, 4 x batched work (static)
#   [9] xfer          inter-GPU transfer charge folded into the work
#                     (cluster; 0.0 on a single device) — excluded from
#                     the straggler kill decision, which compares pure
#                     execution progress against MRET
#   [10] cfail        chaos-injected transient fault (repro.chaos): the
#                     stage runs to completion but the result is garbage
#                     — reported via Completion.failed. Always False
#                     with no ChaosPlan installed.
(_INST, _REM, _RATE, _VER, _EFF, _ETA, _SMRET, _COST, _FLOOR,
 _XFER, _CFAIL) = range(11)


def launch_values(core: EngineCore, lane: tuple, inst: StageInstance,
                  rng, noise_sigma: float) -> tuple:
    """The per-launch scalar pipeline shared by ``SimBackend`` and the
    array-programmed ``EpochSimBackend`` (runtime/epoch.py): noise draw,
    batched work, effective profile, straggler constants, heterogeneous
    speed scaling, transfer charge, chaos hazards. One implementation is
    what makes the two engines bit-identical by construction — and it is
    the ONLY place the shared sim rng is drawn from at launch time (see
    the draw-order invariant in the module docstring).

    Returns ``(work, eff, smret, cost, floor, xfer, cfail)``.
    """
    prof = inst.profile
    b = inst.job.n_inputs
    noise = math.exp(rng.normal(0.0, noise_sigma))
    # batched jobs carry b inputs in one dispatch: work scales by
    # b / g(b) (Table-I-calibrated curve), overhead is paid once
    alone = batched_stage_ms(prof, b)
    work = (alone + prof.overhead_ms) * noise
    # batched kernels also widen — the effective profile competes for
    # more units in the rate computation (identity object for b = 1).
    # The contention model is the LANE's device's (cluster lanes can
    # sit on heterogeneous GPUs; on one device this is sched.contention)
    con = core.sched.contention_of(lane[0])
    eff = con.batched_profile(prof, b)
    # straggler-check constants, hoisted out of the per-event loop:
    # the stage's MRET estimator, its batch cost, and its kill floor
    # are fixed for the lifetime of this launch
    smret = inst.task.mret.stages[inst.job.stage_idx]
    cost = batch_cost(prof, b)
    floor = 4.0 * (alone + prof.overhead_ms)
    spd = con.device.speed
    if spd != 1.0:
        # heterogeneous device: profiles/MRET are reference-speed, so
        # the executed work — and every wall-clock-comparable straggler
        # constant — shrinks by the device's speed factor
        work /= spd
        cost /= spd
        floor /= spd
    if inst.transfer_ms:
        # inter-GPU state migration (cluster dispatcher stamped it):
        # the transfer serializes ahead of the stage program
        work += inst.transfer_ms
    # chaos hazards draw from the plan's OWN stream (never the sim
    # rng — the draw-order invariant above stays intact): one draw
    # per configured hazard per launch, in dispatch order. A stall
    # is extra serialized work; a fault pays the full execution and
    # surfaces as Completion.failed at harvest.
    cfail = False
    ch = core._chaos
    if ch is not None:
        cfail, stall = ch.draw_launch()
        if stall:
            work += stall
    return work, eff, smret, cost, floor, inst.transfer_ms, cfail


class ExecutionBackend(Protocol):
    """Structural type for execution substrates (see module docstring)."""

    # True when the backend owns a virtual clock that only moves inside
    # advance() (the serving pump must then never advance past the next
    # actionable instant); False for wall-clock substrates
    virtual_time: bool

    def bind(self, core: EngineCore) -> None: ...
    def start(self) -> None: ...
    def stop(self) -> None: ...
    def now_ms(self) -> float: ...
    def advance(self, cap_ms: float) -> List[Completion]: ...
    def peek_eta(self) -> float: ...
    def launch(self, lane: tuple, inst: StageInstance) -> None: ...
    def running_set_changed(self) -> None: ...
    def cancel_ctx(self, ctx_idx: int) -> None: ...
    def on_job_done(self, job: Job) -> None: ...
    def has_inflight(self) -> bool: ...
    def on_reconfigure(self) -> None: ...
    # chaos layer: drop one in-flight stage (watchdog expiry). Only ever
    # called with a ChaosPlan installed.
    def kill_lane(self, lane: tuple, inst: StageInstance) -> None: ...


class SimBackend:
    """Fluid-rate discrete-event substrate (virtual time).

    Whenever the running set changes, per-lane rates are recomputed from
    the contention model — as one vectorized NumPy pass over preallocated
    per-lane arrays — and finish times re-predicted. Predictions are
    version-stamped so a rate change invalidates stale ones in O(1).
    Stage work carries seeded lognormal noise so MRET has variability to
    track (paper Fig. 9).

    Incremental re-prediction: rates are only recomputed when the running
    set actually changed (launch/harvest/cancel/straggler-kill marks the
    epoch dirty), and a lane's prediction is only re-pushed when its
    recomputed finish time moved beyond ``predict_eps`` from the one
    already in the heap. With the default ``predict_eps=0.0`` this is
    exact: the live prediction always carries the same float the full
    recompute would produce, so results are bit-identical to the historic
    push-everything engine while the heap stays near its live size
    (stale entries are compacted away once they outnumber live ones).

    ``full_repredict=True`` restores the historic behavior (recompute +
    re-push every lane on every call) — kept as the reference for the
    incremental-vs-full property test.
    """

    EPS = 1e-6   # ms; snap-to-zero tolerance
    _COMPACT_MIN = 64   # never bother compacting heaps smaller than this
    virtual_time = True

    def __init__(self, noise_sigma: float = 0.06,
                 rng: Optional[np.random.Generator] = None, *,
                 predict_eps: float = 0.0,
                 full_repredict: bool = False):
        self.noise_sigma = noise_sigma
        self.rng = rng
        self.predict_eps = predict_eps
        self.full_repredict = full_repredict
        self.core: Optional[EngineCore] = None
        self.now = 0.0
        self.running: Dict[tuple, list] = {}   # lane -> entry (layout above)
        self._heap: List[tuple] = []   # (t, seq, lane, version)
        self._rates_dirty = True

    # ----------------------------------------------------------- lifecycle
    def bind(self, core: EngineCore) -> None:
        self.core = core
        if self.rng is None:
            self.rng = core.rng   # shared stream: offsets then noise draws

    def start(self) -> None:
        self.now = 0.0

    def stop(self) -> None:
        pass

    def now_ms(self) -> float:
        return self.now

    def has_inflight(self) -> bool:
        return bool(self.running)

    # ---------------------------------------------------------------- time
    def _advance_to(self, t: float) -> None:
        dt = t - self.now
        if dt > 0:
            for entry in self.running.values():
                done = entry[_RATE] * dt
                rem = entry[_REM] - done
                entry[_REM] = rem if rem >= self.EPS else 0.0
                entry[_INST].work_done += done
        self.now = t

    def advance(self, cap_ms: float) -> List[Completion]:
        while self._heap and self._heap[0][0] < cap_ms:
            t, _, lane, ver = heapq.heappop(self._heap)
            entry = self.running.get(lane)
            if entry is None or entry[_VER] != ver:
                continue                      # stale prediction
            self._advance_to(t)
            inst = entry[_INST]
            del self.running[lane]
            self._rates_dirty = True
            return [Completion(lane, inst, t - inst.start_ms,
                               entry[_CFAIL])]
        self._advance_to(cap_ms)
        return []

    def peek_eta(self) -> float:
        """Earliest live finish prediction (inf when nothing is in
        flight). The serving pump gates ``advance`` on this so virtual
        time never runs past the next actionable instant. Stale heap
        entries encountered on the way are discarded — ``advance`` would
        skip the same ones, so pop order is untouched."""
        heap = self._heap
        while heap:
            t, _, lane, ver = heap[0]
            entry = self.running.get(lane)
            if entry is not None and entry[_VER] == ver:
                return t
            heapq.heappop(heap)
        return math.inf

    # ----------------------------------------------------------- execution
    def launch(self, lane: tuple, inst: StageInstance) -> None:
        work, eff, smret, cost, floor, xfer, cfail = launch_values(
            self.core, lane, inst, self.rng, self.noise_sigma)
        # version must be globally unique: a reset-to-0 counter lets a
        # stale FINISH from the lane's previous occupant fire early
        self.running[lane] = [inst, work, 0.0, next(_tie), eff, None,
                              smret, cost, floor, xfer, cfail]
        self._rates_dirty = True

    def cancel_ctx(self, ctx_idx: int) -> None:
        for lane in list(self.running):
            if lane[0] == ctx_idx:
                del self.running[lane]
                self._rates_dirty = True

    def on_job_done(self, job: Job) -> None:
        pass

    def kill_lane(self, lane: tuple, inst: StageInstance) -> None:
        # watchdog expiry: drop the entry; the stale heap prediction
        # self-invalidates via the version check
        if self.running.pop(lane, None) is not None:
            self._rates_dirty = True

    def on_chaos_edge(self) -> None:
        # a brownout window opened/closed: rates must be recomputed so
        # in-flight work integrates at the new factor from this instant
        self._rates_dirty = True

    def on_reconfigure(self) -> None:
        # in-flight lanes keep their (retired-context) rates, but the new
        # contexts change what the next dispatch competes against — force
        # a rate recompute at the next running-set pass
        self._rates_dirty = True

    # ------------------------------------------------------------- predict
    def _check_stragglers(self) -> None:
        """Straggler mitigation (beyond-paper, DESIGN.md §7): a stage whose
        projected completion exceeds kappa x its MRET is killed and
        re-enqueued — the Eq. 12 machinery then places it on the
        least-loaded context. Stage granularity bounds the lost work."""
        sched = self.core.sched
        kappa = sched.cfg.straggler_kappa
        if not kappa:
            return
        killed = False
        now = self.now
        for lane, entry in list(self.running.items()):
            inst = entry[_INST]
            if entry[_RATE] <= 0:
                continue
            projected = ((now - inst.start_ms)
                         + entry[_REM] / max(entry[_RATE], 1e-6))
            mret = entry[_SMRET].value() * entry[_COST]
            # the transfer charge is legitimate serialized work, not a
            # slow stage: keep it out of the kill comparison. The charge
            # sits inside rem, so the projection burns it at the
            # contention rate — the credit must scale the same way or a
            # contended transfer-charged stage gets spuriously killed
            # (and re-pays the transfer on every replay). +0.0 on a
            # single device, bit-exact.
            floor = entry[_FLOOR]
            thresh = (max(kappa * mret, floor)
                      + entry[_XFER] / max(entry[_RATE], 1e-6))
            if projected > thresh and len(self.running) > 1:
                del self.running[lane]
                self._rates_dirty = True
                sched.lanes[lane] = None
                inst.work_done = 0.0
                inst.lane = None
                # re-enqueue at the stage boundary (zero-delay): an HP
                # task's context is FIXED (Algorithm 1) — its straggler
                # replays on its own partition, never migrates. Only
                # LP jobs move, to the least-backlogged live context,
                # and each such move is a migration.
                old = inst.job.ctx
                if inst.task.fixed_ctx:
                    tgt = inst.task.ctx
                else:
                    # migration_eta == predicted_finish on one device; the
                    # cluster layer surcharges cross-GPU candidates with
                    # the inter-GPU transfer cost
                    cands = [c.index for c in sched.live_contexts()]
                    tgt = min(cands, key=lambda k:
                              sched.migration_eta(k, self.now, old,
                                                  inst.job))
                    if tgt != old:
                        sched.migrations += 1
                if inst.job in sched.active_jobs.get(old, {}):
                    del sched.active_jobs[old][inst.job]
                    sched.active_jobs[tgt][inst.job] = None
                inst.job.ctx = tgt
                sched.queues[tgt].push(inst)
                self.core.metrics.stragglers += 1
                killed = True
        if killed:
            self.core._dispatch()

    def running_set_changed(self) -> None:
        """Recompute rates (only when the running-set epoch is dirty) and
        re-push finish predictions for lanes whose predicted finish moved
        (see class docstring for the exactness argument)."""
        if not self.running:
            return
        self._check_stragglers()
        if not self.running:
            return
        sched = self.core.sched
        entries = list(self.running.items())
        if self._rates_dirty or self.full_repredict:
            # lanes on different GPUs never contend: the scheduler splits
            # the running set into per-device groups (exactly one group —
            # this whole block's historic shape — on a single device)
            for contention, contexts, group in sched.rate_groups(entries):
                ctx_active: Dict[object, int] = {}
                for lane, _ in group:
                    ctx_active[lane[0]] = ctx_active.get(lane[0], 0) + 1
                u, ns, mf = [], [], []
                for lane, e in group:
                    eff = e[_EFF]
                    u.append(contexts[lane[0]].cap
                             / max(ctx_active[lane[0]], 1))
                    ns.append(eff.n_sat)
                    mf.append(eff.mem_frac)
                rates = contention.rates_seq(u, ns, mf)
                ch = self.core._chaos
                browned = ch is not None and bool(ch.plan.brownouts)
                for (lane, entry), rate in zip(group, rates):
                    if browned:
                        # per-device brownout window (chaos layer): the
                        # whole device runs slow_factor-x slower. Cluster
                        # lane keys are ((dev, ctx), slot); single-device
                        # keys are (ctx, slot) on device 0.
                        dev = (lane[0][0] if isinstance(lane[0], tuple)
                               else 0)
                        f = ch.brownout_factor(dev, self.now)
                        if f > 1.0:
                            rate = rate / f
                    entry[_RATE] = rate if rate > 1e-6 else 1e-6
            self._rates_dirty = False
        now, eps, full = self.now, self.predict_eps, self.full_repredict
        heap = self._heap
        for lane, entry in entries:
            eta = now + entry[_REM] / entry[_RATE]
            old = entry[_ETA]
            if not full and old is not None and abs(eta - old) <= eps:
                continue        # live prediction already carries this eta
            entry[_VER] = next(_tie)
            entry[_ETA] = eta
            heapq.heappush(heap, (eta, next(_tie), lane, entry[_VER]))
        self.maybe_compact()

    def maybe_compact(self) -> None:
        """Compaction: once stale predictions outnumber live ones 2:1,
        rebuild the heap with only the live entries (pop order of
        survivors is unchanged — the seq tie-breaker is preserved).
        Runs after every prediction pass AND from the serving pump's
        pause path (EngineCore._step): an idle daemon under churny
        cancel traffic never reaches ``running_set_changed`` again, so
        without the pause-path call its stale entries accrete
        unboundedly."""
        heap = self._heap
        if (len(heap) > self._COMPACT_MIN
                and len(heap) > 2 * len(self.running)):
            running = self.running
            live = [e for e in heap
                    if (ent := running.get(e[2])) is not None
                    and ent[_VER] == e[3]]
            heapq.heapify(live)
            self._heap = live


def _default_input_factory(input_hw: int, batch: int) -> Callable[[Job], object]:
    """Image-shaped zero input matching the staged-CNN payload convention.
    A dynamically batched job widens the leading axis by ``n_inputs`` so
    the whole batch rides through the staged payload in one dispatch."""
    def make(job: Job):
        import jax
        return jax.device_put(np.zeros(
            (batch * job.n_inputs, input_hw, input_hw, 3), np.float32))
    return make


class _WorkerPool:
    """Persistent daemon-thread pool for ``RealtimeBackend``.

    The backend used to spawn one fresh thread per dispatched stage;
    thread start latency (~100-300us) landed inside every measured stage
    wall time. The pool keeps one long-lived worker per lane — sized via
    ``ensure`` so elastic scale-out grows it — and hands stages over
    through a queue, so the dispatch path is a lock-free put."""

    def __init__(self):
        self._q: "queue.Queue" = queue.Queue()
        self._threads: List[threading.Thread] = []

    def ensure(self, n: int) -> None:
        while len(self._threads) < n:
            t = threading.Thread(target=self._loop, daemon=True)
            t.start()
            self._threads.append(t)

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            fn, lane, inst = item
            try:
                fn(lane, inst)
            except Exception as e:   # noqa: BLE001 — worker must survive
                # a raising payload loses that stage (exactly what the old
                # thread-per-stage design did) but must not kill the
                # worker: a dead worker would starve every later stage
                # queued to the pool
                import sys
                print(f"worker: stage {getattr(inst.task, 'name', '?')} "
                      f"on lane {lane} raised {e!r}", file=sys.stderr)

    def submit(self, fn, lane: tuple, inst: StageInstance) -> None:
        self._q.put((fn, lane, inst))

    def stop(self, timeout_s: float = 1.0) -> None:
        for _ in self._threads:
            self._q.put(None)
        leaked = 0
        for t in self._threads:
            t.join(timeout=timeout_s)
            if t.is_alive():
                leaked += 1
        self._threads = []
        # surface workers that outlived the join window (a wedged payload
        # — e.g. a stage blocked in device sync): callers read
        # ``leaked``, the ops log gets a line, and a sanitized run fails
        # loudly instead of carrying zombie threads into the next test
        self.leaked = leaked
        if leaked:
            import sys
            print(f"worker pool: {leaked} worker thread(s) still alive "
                  f"after stop(timeout={timeout_s}s)", file=sys.stderr)
            if os.environ.get("DARIS_SANITIZE", "") not in ("", "0"):
                raise RuntimeError(
                    f"DSAN: worker pool leaked {leaked} thread(s) — a "
                    f"stage payload never returned")


class RealtimeBackend:
    """Wall-clock substrate: persistent worker pool, one lane per worker.

    Stage payloads are arbitrary callables (jitted JAX stage functions in
    production — XLA releases the GIL so lanes genuinely overlap). A stage
    whose profile has no payload is *emulated* by sleeping its ``t_alone``:
    that keeps analytic task sets runnable on the real engine, which is
    what the sim-vs-real parity test exercises.

    Scheduler state AND inter-stage activation state (``_job_state``) are
    touched only on the engine thread: workers ship their output through
    the done queue and ``advance`` commits it at harvest, so a ghost
    worker from a failed context can never clobber a replayed job's
    activations. No lock is needed.

    Zero-delay migration (``ctx_shardings``): when a job's next stage
    dispatches on a different context than the one that produced its
    inter-stage state — scheduler migration, fail_context re-homing, or an
    online ``reconfigure`` — the worker reshards the whole inter-stage
    tree (hidden activation + the remaining stages' cache slices, see
    ``serving/staging.slice_cache``) onto the target context's sharding
    via ``serving.staging.migrate`` before running the stage. This is the
    paper's zero-delay mechanism made physical: the move happens between
    stage programs, never inside one. Keys are **live slot positions**
    (0 = lowest-indexed live context), not raw context indices: an online
    reconfigure retires contexts and creates replacements at fresh
    indices, but the physical device groups behind the slots persist —
    slot keys survive any number of reshapes, raw indices would all go
    stale at the first one. Before any fault/reshape, slot == index.
    Slots without an entry keep the state where it is (single-device
    mode). ``resharded`` counts the migrations actually performed.
    """

    virtual_time = False

    def __init__(self, input_hw: int = 64, batch: int = 1,
                 input_factory: Optional[Callable[[Job], object]] = None,
                 ctx_shardings: Optional[Dict[int, object]] = None):
        self.input_factory = (input_factory
                              or _default_input_factory(input_hw, batch))
        self.ctx_shardings: Dict[int, object] = dict(ctx_shardings or {})
        self.resharded = 0
        self.core: Optional[EngineCore] = None
        self._done_q: "queue.Queue" = queue.Queue()
        self._job_state: Dict[int, object] = {}
        self._state_ctx: Dict[int, int] = {}   # job_id -> producing context
        self._inflight = 0
        self._cancelled_ctx: set = set()
        # lane -> token of the launch the engine still believes in; a
        # watchdog kill_lane drops the token so the un-interruptible
        # worker's eventual completion is discarded at harvest
        self._live_token: Dict[tuple, int] = {}
        self._t0 = 0.0
        self._pool = _WorkerPool()
        # pool sizing is by LIVE lane count (plus in-flight stages on
        # retired lanes), recomputed only when the lane table grows: a
        # reconfigure-heavy run accumulates retired lanes forever, and
        # one-worker-per-lane-ever would leak a thread per dead lane
        self._lanes_seen = -1
        self._pool_target = 0

    # ----------------------------------------------------------- lifecycle
    def bind(self, core: EngineCore) -> None:
        self.core = core

    def _ensure_pool(self) -> None:
        """Grow the worker pool to one worker per live lane (+ stages
        still finishing on retired lanes); concurrency is bounded by that
        count, so a bigger pool would only idle."""
        sched = self.core.sched
        n = len(sched.lanes)
        if n != self._lanes_seen:
            self._lanes_seen = n
            live = sum(c.n_streams for c in sched.live_contexts())
            draining = sum(1 for ln, i in sched.lanes.items()
                           if i is not None
                           and not sched.contexts[ln[0]].alive)
            self._pool_target = live + draining
        self._pool.ensure(self._pool_target)

    def start(self) -> None:
        self._ensure_pool()
        self._t0 = time.perf_counter()

    def stop(self) -> None:
        self._pool.stop()

    def now_ms(self) -> float:
        return (time.perf_counter() - self._t0) * 1000.0

    def has_inflight(self) -> bool:
        return self._inflight > 0

    # ---------------------------------------------------------------- time
    def advance(self, cap_ms: float) -> List[Completion]:
        while True:
            timeout_s = (cap_ms - self.now_ms()) / 1000.0
            try:
                if timeout_s <= 0:
                    item = self._done_q.get_nowait()
                else:
                    item = self._done_q.get(timeout=timeout_s)
            except queue.Empty:
                return []
            lane, inst, et, out, token, failed = item
            self._inflight -= 1
            if lane[0] in self._cancelled_ctx:
                # ghost completion from a failed context: fail_context
                # already re-enqueued the instance, and dead contexts never
                # launch again, so anything arriving on them is stale —
                # drop its output along with it
                continue
            if token is not None and self._live_token.get(lane) != token:
                # watchdog-killed launch: the engine already re-enqueued
                # the stage; this worker's late result is a ghost
                continue
            self._live_token.pop(lane, None)
            if not failed:
                # a chaos-failed stage's output is garbage: never commit
                # it over the job's last good inter-stage state
                self._job_state[inst.job.job_id] = out
                self._state_ctx[inst.job.job_id] = lane[0]
            return [Completion(lane, inst, et, failed)]

    def peek_eta(self) -> float:
        """Wall clock: in-flight work can complete at any instant, so the
        earliest actionable time is "now"; inf when idle (the serving
        pump then has nothing to harvest and must not spin)."""
        return self.now_ms() if self._inflight else math.inf

    # ----------------------------------------------------------- execution
    def _sharding_for(self, ctx: int):
        """Resolve a context's target sharding by its live slot position
        (see class docstring); raw index is the fallback when no core is
        bound (unit-test construction)."""
        if not self.ctx_shardings:
            return None
        if self.core is None:
            return self.ctx_shardings.get(ctx)
        for slot, c in enumerate(self.core.sched.live_contexts()):
            if c.index == ctx:
                return self.ctx_shardings.get(slot)
        return None      # retired context: never reshard onto it

    def _migrate_state(self, x: object, job_id: int, ctx: int) -> object:
        """Reshard inter-stage state produced on another context onto this
        context's partition (zero-delay: between stage programs)."""
        src = self._state_ctx.get(job_id, ctx)
        if x is None or src == ctx:
            return x
        tgt = self._sharding_for(ctx)
        if tgt is None:
            return x
        from ..serving.staging import migrate
        self.resharded += 1
        return migrate(x, tgt)

    def _worker(self, lane: tuple, inst: StageInstance, *,
                token=None, stall_ms: float = 0.0,
                failed: bool = False) -> None:
        prof = inst.profile
        t0 = time.perf_counter()
        if stall_ms:
            # chaos-injected lane stall (driver hiccup / ECC scrub): the
            # stage runs, just late — the stall serializes ahead of it
            time.sleep(stall_ms / 1000.0)
        if prof.payload is None:
            # synthetic stage: sleep the batched work (b/g(b) scaling)
            time.sleep(batched_stage_ms(prof, inst.job.n_inputs) / 1000.0)
            out = self._job_state.get(inst.job.job_id)
        else:
            x = self._job_state.get(inst.job.job_id)
            if x is None:
                x = self.input_factory(inst.job)
            else:
                x = self._migrate_state(x, inst.job.job_id, lane[0])
            out = prof.payload(x)
            try:
                import jax
                jax.block_until_ready(out)
            except ImportError:
                pass
        et_ms = (time.perf_counter() - t0) * 1000.0
        self._done_q.put((lane, inst, et_ms, out, token, failed))

    def launch(self, lane: tuple, inst: StageInstance) -> None:
        self._inflight += 1
        # elastic scale-out/reconfigure may have added lanes since start()
        self._ensure_pool()
        # chaos draws happen HERE, on the engine thread in dispatch order
        # (the deterministic stream position), never on the worker
        cfail, stall = False, 0.0
        ch = self.core._chaos
        if ch is not None:
            cfail, stall = ch.draw_launch()
        token = next(_tie)
        self._live_token[lane] = token
        self._pool.submit(
            functools.partial(self._worker, token=token, stall_ms=stall,
                              failed=cfail), lane, inst)

    def kill_lane(self, lane: tuple, inst: StageInstance) -> None:
        # workers can't be interrupted: forget the launch token so the
        # harvest loop discards the ghost completion when it lands (the
        # in-flight count still drains through advance)
        self._live_token.pop(lane, None)

    def cancel_ctx(self, ctx_idx: int) -> None:
        # workers can't be interrupted; mark the context so their
        # completions are dropped at harvest (fail_context re-enqueues the
        # instances, whose .lane is reset — that's the drop signal
        # advance() checks)
        self._cancelled_ctx.add(ctx_idx)

    def on_job_done(self, job: Job) -> None:
        self._job_state.pop(job.job_id, None)
        self._state_ctx.pop(job.job_id, None)

    def on_reconfigure(self) -> None:
        # new contexts mean new lanes: grow the worker pool to match
        # (force the recompute — lane count AND liveness both changed)
        self._lanes_seen = -1
        self._ensure_pool()

    def running_set_changed(self) -> None:
        pass
