"""ExecutionBackend protocol + the two built-in substrates.

A backend owns *time* and *stage execution* and nothing else; scheduling
policy lives entirely in ``EngineCore``/``DarisScheduler``. The contract:

    bind(core)               engine hands the backend its core reference
    start() / stop()         run lifecycle
    now_ms()                 current time (virtual or wall clock)
    advance(cap_ms)          -> [Completion] occurring strictly before cap,
                             else advance/block time to cap and return []
    launch(lane, inst)       begin executing a dispatched stage
    running_set_changed()    hook after dispatch/harvest (rate recompute)
    cancel_ctx(ctx)          drop in-flight work on a failed context
    on_job_done(job)         job-level cleanup (activation state, ...)
    has_inflight()           any launched-but-unharvested stage?

``SimBackend`` wraps the processor-sharing fluid simulation (versioned
finish predictions, lognormal stage noise, straggler mitigation);
``RealtimeBackend`` wraps threaded execution of real (jitted JAX) stage
payloads on wall-clock time. Both are driven by the same EngineCore loop,
which is what makes sim-vs-real scheduler-decision parity testable.
"""
from __future__ import annotations

import heapq
import itertools
import math
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Protocol

import numpy as np

from ..core.task import Job, StageInstance
from .contention import batch_cost, batched_stage_ms
from .engine_core import Completion, EngineCore

_tie = itertools.count()


class ExecutionBackend(Protocol):
    """Structural type for execution substrates (see module docstring)."""

    def bind(self, core: EngineCore) -> None: ...
    def start(self) -> None: ...
    def stop(self) -> None: ...
    def now_ms(self) -> float: ...
    def advance(self, cap_ms: float) -> List[Completion]: ...
    def launch(self, lane: tuple, inst: StageInstance) -> None: ...
    def running_set_changed(self) -> None: ...
    def cancel_ctx(self, ctx_idx: int) -> None: ...
    def on_job_done(self, job: Job) -> None: ...
    def has_inflight(self) -> bool: ...


class SimBackend:
    """Fluid-rate discrete-event substrate (virtual time).

    Whenever the running set changes, per-lane rates are recomputed from
    the contention model and finish times re-predicted. Predictions are
    version-stamped so a rate change invalidates stale ones in O(1).
    Stage work carries seeded lognormal noise so MRET has variability to
    track (paper Fig. 9).
    """

    EPS = 1e-6   # ms; snap-to-zero tolerance

    def __init__(self, noise_sigma: float = 0.06,
                 rng: Optional[np.random.Generator] = None):
        self.noise_sigma = noise_sigma
        self.rng = rng
        self.core: Optional[EngineCore] = None
        self.now = 0.0
        # lane -> [inst, remaining_ms, rate, version]
        self.running: Dict[tuple, list] = {}
        self._heap: List[tuple] = []   # (t, seq, lane, version)

    # ----------------------------------------------------------- lifecycle
    def bind(self, core: EngineCore) -> None:
        self.core = core
        if self.rng is None:
            self.rng = core.rng   # shared stream: offsets then noise draws

    def start(self) -> None:
        self.now = 0.0

    def stop(self) -> None:
        pass

    def now_ms(self) -> float:
        return self.now

    def has_inflight(self) -> bool:
        return bool(self.running)

    # ---------------------------------------------------------------- time
    def _advance_to(self, t: float) -> None:
        dt = t - self.now
        if dt > 0:
            for entry in self.running.values():
                entry[1] = max(entry[1] - entry[2] * dt, 0.0)
                if entry[1] < self.EPS:
                    entry[1] = 0.0
                entry[0].work_done += entry[2] * dt
        self.now = t

    def advance(self, cap_ms: float) -> List[Completion]:
        while self._heap and self._heap[0][0] < cap_ms:
            t, _, lane, ver = heapq.heappop(self._heap)
            entry = self.running.get(lane)
            if entry is None or entry[3] != ver:
                continue                      # stale prediction
            self._advance_to(t)
            inst = entry[0]
            del self.running[lane]
            return [Completion(lane, inst, t - inst.start_ms)]
        self._advance_to(cap_ms)
        return []

    # ----------------------------------------------------------- execution
    def launch(self, lane: tuple, inst: StageInstance) -> None:
        prof = inst.profile
        b = inst.job.n_inputs
        noise = math.exp(self.rng.normal(0.0, self.noise_sigma))
        # batched jobs carry b inputs in one dispatch: work scales by
        # b / g(b) (Table-I-calibrated curve), overhead is paid once
        work = (batched_stage_ms(prof, b) + prof.overhead_ms) * noise
        # batched kernels also widen — the effective profile competes for
        # more units in the rate computation (identity object for b = 1)
        eff = self.core.sched.contention.batched_profile(prof, b)
        # version must be globally unique: a reset-to-0 counter lets a
        # stale FINISH from the lane's previous occupant fire early
        self.running[lane] = [inst, work, 0.0, next(_tie), eff]

    def cancel_ctx(self, ctx_idx: int) -> None:
        for lane in list(self.running):
            if lane[0] == ctx_idx:
                del self.running[lane]

    def on_job_done(self, job: Job) -> None:
        pass

    def running_set_changed(self) -> None:
        """Recompute all rates; re-predict and version-stamp finishes.
        Also runs straggler mitigation (beyond-paper, DESIGN.md §7): a
        stage whose projected completion exceeds kappa x its MRET is
        killed and re-enqueued — the Eq. 12 machinery then places it on
        the least-loaded context. Stage granularity bounds the lost work."""
        if not self.running:
            return
        sched = self.core.sched
        kappa = sched.cfg.straggler_kappa
        if kappa:
            killed = False
            for lane, entry in list(self.running.items()):
                inst = entry[0]
                if entry[2] <= 0:
                    continue
                projected = ((self.now - inst.start_ms)
                             + entry[1] / max(entry[2], 1e-6))
                cost = batch_cost(inst.profile, inst.job.n_inputs)
                mret = (inst.task.mret.stage_mret(inst.job.stage_idx)
                        * cost)
                floor = 4.0 * (batched_stage_ms(inst.profile,
                                                inst.job.n_inputs)
                               + inst.profile.overhead_ms)
                if projected > max(kappa * mret, floor) and len(self.running) > 1:
                    del self.running[lane]
                    sched.lanes[lane] = None
                    inst.work_done = 0.0
                    inst.lane = None
                    # re-enqueue at the stage boundary (zero-delay): an HP
                    # task's context is FIXED (Algorithm 1) — its straggler
                    # replays on its own partition, never migrates. Only
                    # LP jobs move, to the least-backlogged live context,
                    # and each such move is a migration.
                    old = inst.job.ctx
                    if inst.task.fixed_ctx:
                        tgt = inst.task.ctx
                    else:
                        cands = [c.index for c in sched.contexts if c.alive]
                        tgt = min(cands, key=lambda k:
                                  sched.predicted_finish(k, self.now))
                        if tgt != old:
                            sched.migrations += 1
                    if inst.job in sched.active_jobs.get(old, []):
                        sched.active_jobs[old].remove(inst.job)
                        sched.active_jobs[tgt].append(inst.job)
                    inst.job.ctx = tgt
                    sched.queues[tgt].push(inst)
                    self.core.metrics.stragglers += 1
                    killed = True
            if killed:
                self.core._dispatch()
        ctx_active: Dict[int, int] = {}
        for lane in self.running:
            ctx_active[lane[0]] = ctx_active.get(lane[0], 0) + 1
        entries = list(self.running.items())
        rates = sched.contention.rates([
            (lane, e[4], sched.contexts[lane[0]].cap,
             ctx_active[lane[0]]) for lane, e in entries])
        for (lane, entry), rate in zip(entries, rates):
            entry[2] = max(rate, 1e-6)
            entry[3] = next(_tie)
            eta = self.now + entry[1] / entry[2]
            heapq.heappush(self._heap, (eta, next(_tie), lane, entry[3]))


def _default_input_factory(input_hw: int, batch: int) -> Callable[[Job], object]:
    """Image-shaped zero input matching the staged-CNN payload convention.
    A dynamically batched job widens the leading axis by ``n_inputs`` so
    the whole batch rides through the staged payload in one dispatch."""
    def make(job: Job):
        import jax
        return jax.device_put(np.zeros(
            (batch * job.n_inputs, input_hw, input_hw, 3), np.float32))
    return make


class RealtimeBackend:
    """Wall-clock substrate: one worker thread per dispatched stage.

    Stage payloads are arbitrary callables (jitted JAX stage functions in
    production — XLA releases the GIL so lanes genuinely overlap). A stage
    whose profile has no payload is *emulated* by sleeping its ``t_alone``:
    that keeps analytic task sets runnable on the real engine, which is
    what the sim-vs-real parity test exercises.

    Scheduler state AND inter-stage activation state (``_job_state``) are
    touched only on the engine thread: workers ship their output through
    the done queue and ``advance`` commits it at harvest, so a ghost
    worker from a failed context can never clobber a replayed job's
    activations. No lock is needed.
    """

    def __init__(self, input_hw: int = 64, batch: int = 1,
                 input_factory: Optional[Callable[[Job], object]] = None):
        self.input_factory = (input_factory
                              or _default_input_factory(input_hw, batch))
        self.core: Optional[EngineCore] = None
        self._done_q: "queue.Queue" = queue.Queue()
        self._job_state: Dict[int, object] = {}
        self._inflight = 0
        self._cancelled_ctx: set = set()
        self._t0 = 0.0

    # ----------------------------------------------------------- lifecycle
    def bind(self, core: EngineCore) -> None:
        self.core = core

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> None:
        pass

    def now_ms(self) -> float:
        return (time.perf_counter() - self._t0) * 1000.0

    def has_inflight(self) -> bool:
        return self._inflight > 0

    # ---------------------------------------------------------------- time
    def advance(self, cap_ms: float) -> List[Completion]:
        while True:
            timeout_s = (cap_ms - self.now_ms()) / 1000.0
            try:
                if timeout_s <= 0:
                    lane, inst, et, out = self._done_q.get_nowait()
                else:
                    lane, inst, et, out = self._done_q.get(timeout=timeout_s)
            except queue.Empty:
                return []
            self._inflight -= 1
            if lane[0] in self._cancelled_ctx:
                # ghost completion from a failed context: fail_context
                # already re-enqueued the instance, and dead contexts never
                # launch again, so anything arriving on them is stale —
                # drop its output along with it
                continue
            self._job_state[inst.job.job_id] = out
            return [Completion(lane, inst, et)]

    # ----------------------------------------------------------- execution
    def _worker(self, lane: tuple, inst: StageInstance) -> None:
        prof = inst.profile
        t0 = time.perf_counter()
        if prof.payload is None:
            # synthetic stage: sleep the batched work (b/g(b) scaling)
            time.sleep(batched_stage_ms(prof, inst.job.n_inputs) / 1000.0)
            out = self._job_state.get(inst.job.job_id)
        else:
            x = self._job_state.get(inst.job.job_id)
            if x is None:
                x = self.input_factory(inst.job)
            out = prof.payload(x)
            try:
                import jax
                jax.block_until_ready(out)
            except ImportError:
                pass
        et_ms = (time.perf_counter() - t0) * 1000.0
        self._done_q.put((lane, inst, et_ms, out))

    def launch(self, lane: tuple, inst: StageInstance) -> None:
        self._inflight += 1
        threading.Thread(target=self._worker, args=(lane, inst),
                         daemon=True).start()

    def cancel_ctx(self, ctx_idx: int) -> None:
        # threads can't be killed; mark the context so their completions
        # are dropped at harvest (fail_context re-enqueues the instances,
        # whose .lane is reset — that's the drop signal advance() checks)
        self._cancelled_ctx.add(ctx_idx)

    def on_job_done(self, job: Job) -> None:
        self._job_state.pop(job.job_id, None)

    def running_set_changed(self) -> None:
        pass
