"""First-class arrival processes (open- and closed-loop workload shapes).

The paper's Table II workloads are strictly periodic; production traffic is
not. An ``ArrivalProcess`` decides *when* a task releases jobs, so the same
``EngineCore`` event loop serves the paper's periodic sets, Poisson
open-loop traffic (millions-of-users shapes), and recorded traces without
touching scheduler or backend code.

Contract (driven by ``EngineCore``):

    t0 = proc.start(spec, rng)               # first release (None = never)
    t1, skipped = proc.next_after(t0, now)   # successor of the release that
                                             # was *scheduled* at t0, given
                                             # the loop observed time ``now``

``next_after`` returns an absolute time (None = no more releases) plus the
number of whole periods that had to be skipped because the loop stalled
past them (only periodic processes skip; open-loop processes deliberately
return back-dated times so the backlog builds, which is what "open loop"
means).
"""
from __future__ import annotations

from typing import List, Optional, Tuple, Union

import numpy as np

from ..core.task import TaskSpec


class ArrivalProcess:
    """Base class; subclasses override ``start`` and ``next_after``."""

    def start(self, spec: TaskSpec, rng: np.random.Generator
              ) -> Optional[float]:
        raise NotImplementedError

    def next_after(self, prev_t: float, now: float
                   ) -> Tuple[Optional[float], int]:
        raise NotImplementedError


class PeriodicArrival(ArrivalProcess):
    """Strictly periodic releases (paper §III-A): one job every ``period_ms``
    starting at ``phase_ms`` (``"random"`` draws uniform in [0, T) — the
    phase-offset convention the simulator has always used).

    Release-storm protection: if the drive loop stalls past one or more
    whole periods (wall-clock backends under load), the next release is
    clamped to ``max(prev + period, now)`` instead of bursting back-dated
    releases; fully-passed periods are reported as skipped so
    ``RunMetrics.skipped_releases`` accounts for them.
    """

    def __init__(self, period_ms: Optional[float] = None,
                 phase_ms: Union[float, str] = 0.0):
        self.period_ms = period_ms
        self.phase_ms = phase_ms
        self._period = period_ms   # resolved against the spec in start()

    def start(self, spec: TaskSpec, rng: np.random.Generator
              ) -> Optional[float]:
        self._period = self.period_ms or spec.period_ms
        if self.phase_ms == "random":
            return float(rng.uniform(0, self._period))
        return float(self.phase_ms)

    def next_after(self, prev_t: float, now: float
                   ) -> Tuple[Optional[float], int]:
        nxt = prev_t + self._period
        if nxt < now:
            skipped = int((now - nxt) // self._period)
            return now, skipped
        return nxt, 0


class PoissonArrival(ArrivalProcess):
    """Open-loop Poisson arrivals at ``rate_jps`` jobs/sec.

    Gaps are exponential with their own seeded stream (independent of the
    engine's noise RNG), so the arrival sequence is identical across
    backends and across runs with the same seed. Back-dated arrivals are
    *not* skipped: open-loop traffic keeps coming whether or not the server
    keeps up — that is the overload behaviour worth measuring.
    """

    def __init__(self, rate_jps: float, seed: int = 0):
        if rate_jps <= 0:
            raise ValueError(f"rate_jps must be > 0, got {rate_jps}")
        self.rate_jps = rate_jps
        self.seed = seed
        self._rng: Optional[np.random.Generator] = None

    def _gap(self) -> float:
        return float(self._rng.exponential(1000.0 / self.rate_jps))

    def start(self, spec: TaskSpec, rng: np.random.Generator
              ) -> Optional[float]:
        self._rng = np.random.default_rng(self.seed)   # re-arm per run
        return self._gap()

    def next_after(self, prev_t: float, now: float
                   ) -> Tuple[Optional[float], int]:
        return prev_t + self._gap(), 0


class ManualArrival(ArrivalProcess):
    """No scheduled releases at all: every job arrives through an explicit
    ``submit`` (the serving daemon's path — clients drive the arrivals,
    the engine's arrival machinery stays silent). Draws nothing from the
    RNG, so adding a manual task to a server perturbs no seeded stream."""

    def start(self, spec: TaskSpec, rng: np.random.Generator
              ) -> Optional[float]:
        return None

    def next_after(self, prev_t: float, now: float
                   ) -> Tuple[Optional[float], int]:
        return None, 0


class TraceArrival(ArrivalProcess):
    """Releases at recorded absolute times (ms). Used for replaying
    captured traffic and for the one-shot ``DarisServer.submit`` path."""

    def __init__(self, times_ms: List[float]):
        self.times = sorted(float(t) for t in times_ms)
        self._idx = 0

    def start(self, spec: TaskSpec, rng: np.random.Generator
              ) -> Optional[float]:
        self._idx = 0
        if not self.times:
            return None
        self._idx = 1
        return self.times[0]

    def next_after(self, prev_t: float, now: float
                   ) -> Tuple[Optional[float], int]:
        if self._idx >= len(self.times):
            return None, 0
        t = self.times[self._idx]
        self._idx += 1
        return t, 0
