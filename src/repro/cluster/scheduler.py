"""ClusterScheduler: multi-GPU DARIS with global admission and cross-GPU
zero-delay migration.

One ``DarisScheduler`` worker per GPU — each with its own ``DeviceModel``
(heterogeneous speed factors welcome), its own Eq. 9 partition geometry,
its own contention model — composed behind the exact scheduler interface
``EngineCore`` and the backends already speak. The composition trick is
the *shared namespace*: every worker is constructed with ``ctx_ns=dev``,
so its context indices are ``(device, k)`` tuples and its lane keys are
``((device, k), slot)``; the cluster then literally hands every worker
the SAME lane map / queue table / active-job table, and all of the
engine's hot paths (dispatch, harvest, straggler kill, idle detection)
work on cluster state without a single translation layer.

Division of labour:

    global  (this class)   task -> device placement (Algorithm 1 HP-first
                           by least-loaded schedulable device), cross-GPU
                           admission fallback + sticky migration, device
                           failure/retirement, whole-GPU elasticity,
                           inter-GPU transfer charging
    local   (workers)      everything the paper describes on one GPU:
                           Eq. 11-12 admission, 8-level stage dispatch,
                           MRET, batching, intra-device migration

Cross-GPU zero-delay migration reuses the stage-boundary mechanism of
PR 4: a migrating job's running stage finishes where it is, its next
stage enqueues at the new home, and the dispatcher stamps the configured
``transfer_ms`` onto the first stage executed on a device that does not
hold the job's inter-stage state (the backend adds it to the stage work,
and ``migration_eta`` adds it to candidate ETAs so the placement math
sees the same charge the execution will pay).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Union

from ..core.partition import Context, ContextTable, CtxKey
from ..core.scheduler import (DarisScheduler, LaneMap, SchedulerConfig,
                              hp_first)
from ..core.task import HP, LP, Job, StageInstance, Task, TaskSpec
from ..runtime.contention import ContentionModel, DeviceModel
from .devices import resolve_devices


class ClusterScheduler:
    """N per-GPU ``DarisScheduler`` workers behind one scheduler API."""

    def __init__(self, specs: List[TaskSpec], cfg: SchedulerConfig,
                 device: Optional[DeviceModel] = None, *,
                 n_gpus: int,
                 device_models: Optional[Sequence[Union[str, DeviceModel]]]
                 = None,
                 transfer_ms: float = 0.5):
        if n_gpus < 1:
            raise ValueError(f"cluster needs >= 1 GPU, got {n_gpus}")
        if transfer_ms < 0:
            raise ValueError(f"transfer_ms must be >= 0, got {transfer_ms}")
        self._cfg_template = cfg
        self.cfg = dataclasses.replace(cfg)   # backend reads e.g. kappa
        self.transfer_ms = float(transfer_ms)
        base = device or DeviceModel()
        self.device_models: List[DeviceModel] = (
            resolve_devices(device_models) if device_models else [base])
        # shared namespace: one table each, handed to every worker
        self.lanes = LaneMap()
        self.queues: Dict[CtxKey, object] = {}
        self.hot_queues: set = set()
        self.active_jobs: Dict[CtxKey, Dict[Job, None]] = {}
        self.rejections: list = []
        self.rejected_counts: Dict[int, int] = {HP: 0, LP: 0}
        self.workers: Dict[int, DarisScheduler] = {}
        self._dead_devs: set = set()
        self._next_dev = 0
        self._migrations = 0          # cross-GPU task moves (cluster-level)
        self.transfers = 0            # inter-GPU state payloads actually moved
        self._state_dev: Dict[int, int] = {}   # job_id -> device holding state
        self._next_wake = math.inf
        self._batch_widen = 1.0
        for _ in range(n_gpus):
            self._add_device()
        self.tasks: List[Task] = [
            self.workers[0].make_task(s, i) for i, s in enumerate(specs)]
        self._offline_place()

    # ------------------------------------------------------- construction
    def _device_model_for(self, d: int) -> DeviceModel:
        return self.device_models[d % len(self.device_models)]

    def _add_device(self) -> int:
        d = self._next_dev
        self._next_dev += 1
        # a device added mid-run inherits the fleet's CURRENT per-device
        # shape (a reconfigure may have reshaped it since construction)
        src_cfg = next((self.workers[x].cfg for x in self.live_devices()),
                       self._cfg_template)
        w = DarisScheduler([], dataclasses.replace(src_cfg),
                           self._device_model_for(d), ctx_ns=d)
        w.batch_widen = self._batch_widen   # fleet-wide degradation knob
        self.workers[d] = w
        self._absorb(w)
        return d

    def _absorb(self, w: DarisScheduler) -> None:
        """Fold a fresh worker's per-context structures into the shared
        namespace and point the worker at the shared tables (its keys are
        namespaced, so workers never collide)."""
        for lane, inst in w.lanes.items():
            self.lanes[lane] = inst
        w.lanes = self.lanes
        self.queues.update(w.queues)
        w.queues = self.queues
        # the dispatch hot-set is fleet-global too: re-point the fresh
        # worker's queues (and any it creates later) at the shared one
        # (register_hot is state-based, so re-registering is idempotent)
        for k, q in self.queues.items():
            q.register_hot(k, self.hot_queues)
        w.hot_queues = self.hot_queues
        self.active_jobs.update(w.active_jobs)
        w.active_jobs = self.active_jobs
        w.rejections = self.rejections
        w.rejected_counts = self.rejected_counts

    def _device_streams(self, d: int) -> int:
        return sum(c.n_streams for c in self.workers[d].live_contexts())

    def _place_ordered(self, ordered: List[Task], now: float,
                       loads: Dict[int, float],
                       utils: Dict[int, Dict[CtxKey, float]], *,
                       reseed: bool = False) -> int:
        """Greedy Algorithm-1 placement shared by every re-place pass:
        each task goes to the least-loaded device in ``loads``, then to
        that device's least-utilized context in ``utils``; both
        accumulators update incrementally (speed-normalized). ``reseed``
        re-derives AFET against the adopting device for never-placed
        tasks (offline construction). Returns device-change count."""
        migrated = 0
        for t in ordered:
            old_dev = t.ctx[0] if t.ctx != -1 else None
            d = min(loads, key=loads.get)
            w = self.workers[d]
            if reseed and old_dev is None and d != 0:
                w._seed_mret(t)
            util = utils[d]
            k = min(util, key=util.get)
            if old_dev != d:
                migrated += 1
            t.ctx = k
            w.tasks.append(t)
            u = t.utilization(now)
            util[k] += u / w.speed
            loads[d] += u / (w.speed * max(self._device_streams(d), 1))
        return migrated

    def _offline_place(self) -> None:
        """Global Algorithm 1: HP first (descending utilization), each
        task to the least-loaded schedulable device, then to that
        device's least-utilized context. Ordering uses worker-0 AFET
        seeds; a task adopted by another device is re-seeded against
        that device's own shape before placement."""
        ordered = hp_first(self.tasks, 0.0)
        for t in ordered:
            t.fixed_ctx = t.priority == HP
        loads = {d: 0.0 for d in self.workers}
        utils = {d: {c.index: 0.0 for c in self.workers[d].contexts}
                 for d in self.workers}
        self._place_ordered(ordered, 0.0, loads, utils, reseed=True)

    # ------------------------------------------------------------- views
    def live_devices(self) -> List[int]:
        return [d for d in self.workers if d not in self._dead_devs]

    def live_contexts(self) -> List[Context]:
        out: List[Context] = []
        for d in self.live_devices():
            out.extend(self.workers[d].live_contexts())
        return out

    @property
    def contexts(self) -> ContextTable:
        merged = ContextTable()
        for w in self.workers.values():
            merged.update(w.contexts)
        return merged

    def geometry_snapshot(self) -> Dict:
        """Fleet-wide static geometry view for offline analysis: per-device
        ``DarisScheduler.geometry_snapshot`` keyed by device id."""
        devices = {str(d): self.workers[d].geometry_snapshot()
                   for d in self.live_devices()}
        return {
            "kind": "cluster",
            "transfer_ms": self.transfer_ms,
            "devices": devices,
            "summary": f"{len(devices)} GPUs: " + "; ".join(
                f"dev{d}[{snap['summary']}]"
                for d, snap in devices.items()),
        }

    @property
    def migrations(self) -> int:
        return self._migrations + sum(w.migrations
                                      for w in self.workers.values())

    @migrations.setter
    def migrations(self, v: int) -> None:
        # the straggler path does ``sched.migrations += 1``; keep the
        # delta in the cluster-level counter
        self._migrations = v - sum(w.migrations
                                   for w in self.workers.values())

    @property
    def coalesced(self) -> int:
        return sum(w.coalesced for w in self.workers.values())

    @property
    def next_wake_ms(self) -> float:
        return self._next_wake

    @next_wake_ms.setter
    def next_wake_ms(self, v: float) -> None:
        self._next_wake = v
        for w in self.workers.values():
            w.next_wake_ms = v

    @property
    def batch_widen(self) -> float:
        return self._batch_widen

    @batch_widen.setter
    def batch_widen(self, v: float) -> None:
        # degradation-controller knob: every worker's coalescer must see
        # the same widened max-wait (same forwarding shape as next_wake)
        self._batch_widen = v
        for w in self.workers.values():
            w.batch_widen = v

    def device_load(self, d: int, now: float) -> float:
        """Placement load of a device: total utilization of every task
        homed there (Algorithm 1's offline flavor — placed load, not just
        Eq. 12's currently-active jobs), speed-normalized and divided by
        the device's stream count. Release-time admission still uses the
        workers' active-job Eq. 11-12 math."""
        w = self.workers[d]
        u = sum(t.utilization(now) for t in w.tasks)
        return u / (w.speed * max(self._device_streams(d), 1))

    def device_ctx_keys(self, d: int) -> List[CtxKey]:
        """ALL context keys of a device — including retired ones, which
        can still hold draining in-flight stages (a fault must cancel
        those too; cancelling an idle context is harmless)."""
        return [c.index for c in self.workers[d].contexts]

    def device_summary(self, now: float = 0.0) -> Dict[int, dict]:
        """Per-device snapshot block (engine ``snapshot()["devices"]``)."""
        out = {}
        for d, w in self.workers.items():
            live = w.live_contexts()
            out[d] = {
                "alive": d not in self._dead_devs,
                "model": w.device.name,
                "speed": w.device.speed,
                "live_contexts": len(live),
                "tasks": len(w.tasks),
                "queue_depth": sum(len(self.queues[c.index]) for c in live),
                "active_jobs": sum(len(self.active_jobs[c.index])
                                   for c in live),
                "load": self.device_load(d, now) if live else 0.0,
            }
        return out

    # ------------------------------------- device-relative backend interface
    def contention_of(self, k: CtxKey) -> ContentionModel:
        return self.workers[k[0]].contention

    def rate_groups(self, entries):
        by_dev: Dict[int, list] = {}
        for e in entries:
            by_dev.setdefault(e[0][0][0], []).append(e)
        return [(self.workers[d].contention, self.workers[d].contexts, grp)
                for d, grp in by_dev.items()]

    def scale_units(self) -> int:
        return len(self.live_devices())

    def scale_kwargs(self, n: int) -> Dict:
        return {"n_gpus": n}

    # ----------------------------------------------------- util delegates
    def util_hp_total(self, k: CtxKey, now: float) -> float:
        return self.workers[k[0]].util_hp_total(k, now)

    def util_lp_active(self, k: CtxKey, now: float) -> float:
        return self.workers[k[0]].util_lp_active(k, now)

    def admits(self, k: CtxKey, task: Task, now: float) -> bool:
        return self.workers[k[0]].admits(k, task, now)

    def predicted_finish(self, k: CtxKey, now: float) -> float:
        return self.workers[k[0]].predicted_finish(k, now)

    def migration_eta(self, k: CtxKey, now: float,
                      src: Optional[CtxKey], job: Optional[Job] = None
                      ) -> float:
        """Candidate ETA for moving work to ``k``: the device-local
        predicted finish, plus the inter-GPU transfer charge exactly
        when dispatch will pay it — the job holds inter-stage state
        (a stage completed somewhere, next_for_lane's rule) on a device
        other than ``k``'s. A fresh release (``job=None`` or no state
        yet) ships nothing, so remote candidates aren't penalized."""
        eta = self.workers[k[0]].predicted_finish(k, now)
        sd = self._state_dev.get(job.job_id) if job is not None else None
        if sd is not None and sd != k[0]:
            eta += self.transfer_ms
        return eta

    # --------------------------------------------------------------- online
    def add_task(self, spec: TaskSpec, now: float = 0.0) -> Task:
        """Late registration (``DarisServer.submit``): least-loaded live
        device, then that worker's own Algorithm-1-style placement."""
        live = self.live_devices()
        d = min(live, key=lambda dd: self.device_load(dd, now))
        w = self.workers[d]
        task = w.make_task(spec, len(self.tasks))
        w.place_task(task, now)
        self.tasks.append(task)
        return task

    def _move_task(self, task: Task, to_ctx: CtxKey) -> None:
        """Sticky cross-GPU migration: re-home the task (and its worker
        registration) onto ``to_ctx``'s device."""
        # Task is eq=False: remove() degrades to an identity scan, which
        # is exactly the intent here  # dsan: ignore[DSAN005]
        self.workers[task.ctx[0]].tasks.remove(task)
        task.ctx = to_ctx
        self.workers[to_ctx[0]].tasks.append(task)
        self._migrations += 1

    def on_release(self, task: Task, now: float) -> Optional[Job]:
        """Global dispatcher: the home device handles the release (its
        own Eq. 11-12 admission + intra-device migration); when the home
        device has no admitting context at all, the task migrates to the
        live device whose admitting context promises the earliest
        finish — DARIS's §IV-B1 migration rule lifted across GPUs. A
        fresh release ships no inter-stage state, so candidate ETAs are
        NOT transfer-charged here (the charge applies to in-flight
        moves: straggler kills and fault replays — ``migration_eta``).
        HP tasks keep their fixed (device, context) home."""
        home = self.workers[task.ctx[0]]
        needs_test = task.priority == LP or home.cfg.overload_hpa
        if (needs_test and not task.fixed_ctx
                and not home.admits(task.ctx, task, now)):
            # a release that joins an open batch head charges only the
            # incremental Eq. 12 utilization, so it can coalesce at home
            # even when full-task admission just failed — probe BEFORE
            # the cross-GPU fallback or it migrates needlessly. On the
            # common admit-at-home path home.on_release probes instead.
            if home._coalescer is not None:
                head = home._try_coalesce(task, now)
                if head is not None:
                    return head
            # home context is full; only if the whole home DEVICE has no
            # admitting context does the release go cross-GPU (the cheap
            # common case — home admits — pays one extra Eq. 12 test)
            if not any(home.admits(c.index, task, now)
                       for c in home.live_contexts()):
                src = task.ctx
                cands = [c.index
                         for d in self.live_devices() if d != src[0]
                         for c in self.workers[d].live_contexts()
                         if self.workers[d].admits(c.index, task, now)]
                if cands:
                    k = min(cands,
                            key=lambda c: self.migration_eta(c, now, src))
                    self._move_task(task, k)
                    home = self.workers[k[0]]
        return home.on_release(task, now)

    def on_stage_finish(self, inst: StageInstance, now: float,
                        et_ms: float) -> Optional[Job]:
        """Delegate to the worker of the device that EXECUTED the stage
        (its speed factor normalizes the MRET observation); job/queue
        bookkeeping runs on the shared tables either way."""
        dev = inst.lane[0][0] if inst.lane is not None else inst.job.ctx[0]
        done = self.workers[dev].on_stage_finish(inst, now, et_ms)
        if done is not None:
            self._state_dev.pop(done.job_id, None)
        else:
            # state location commits at COMPLETION, not dispatch: a
            # transfer-charged stage that is straggler-killed or
            # cancelled never finished moving the state, so its replay
            # must pay the charge again
            self._state_dev[inst.job.job_id] = dev
        return done

    def find_job(self, task_index: int, release_ms: float):
        # the active-job table is shared, so the single-GPU scan applies
        return DarisScheduler.find_job(self, task_index, release_ms)

    def cancel_job(self, task_index: int, release_ms: float, now: float):
        """Cancellation across the fleet: resolve against the shared job
        table, then let the worker that HOMES the job run the single-GPU
        retirement (its coalescer holds any open batch head). A queued
        whole-job cancel never reaches ``on_stage_finish``, so the
        inter-stage state pointer is released here."""
        job, member = DarisScheduler.find_job(self, task_index, release_ms)
        if job is None:
            return "absent", None
        outcome, job = self.workers[job.ctx[0]]._cancel_found(job, member, now)
        if outcome == "cancelled":
            self._state_dev.pop(job.job_id, None)
        return outcome, job

    def abort_job(self, job: Job, now: float) -> None:
        """Chaos-layer give-up on the shared tables (see the single-GPU
        version); the fleet additionally releases the job's inter-stage
        state pointer — an aborted job never finishes a stage again."""
        DarisScheduler.abort_job(self, job, now)
        self._state_dev.pop(job.job_id, None)

    def next_for_lane(self, ctx_key: CtxKey, now: float
                      ) -> Optional[StageInstance]:
        """Dispatch for one lane's context, stamping the inter-GPU
        transfer cost whenever the job's inter-stage state lives on a
        different device (the zero-delay migration made physical: state
        moves between stage programs, charged to the receiving stage)."""
        inst = self.workers[ctx_key[0]].next_for_lane(ctx_key, now)
        if inst is None:
            return None
        dev = ctx_key[0]
        # src = device holding the last COMPLETED stage's output (absent
        # for stage 0: the input materializes wherever it first runs)
        src = self._state_dev.get(inst.job.job_id)
        if src is None or src == dev:
            inst.transfer_ms = 0.0
        else:
            inst.transfer_ms = self.transfer_ms
            self.transfers += 1     # counts charged attempts (a killed
                                    # transfer stage pays again on replay)
        return inst

    def free_lanes(self) -> List[tuple]:
        return self.lanes.free_lanes()

    # ------------------------------------------------------ fault / elastic
    def fault_cancel_keys(self, key: CtxKey) -> List[CtxKey]:
        """Mirrors ``fail_context``'s escalation: when the fault will
        take the device's last live context, the whole-device failure
        requeues in-flight stages from every context (retired ones may
        still be draining), so the engine must cancel all of them."""
        dev = self.fault_escalates_to(key)
        if dev is None:
            return [key]
        return self.device_ctx_keys(dev)

    def fault_escalates_to(self, key: CtxKey) -> Optional[int]:
        """Device a context fault would escalate to (it targets the
        device's last LIVE context), or None. The engine consults this
        to skip a planned fault that would kill the fleet's last
        survivor — mirroring its FAIL_DEV handling."""
        dev = key[0]
        if dev in self._dead_devs:
            return None
        w = self.workers[dev]
        ctx = w.contexts.get(key)
        if (ctx is None or not ctx.alive
                or len(w.live_contexts()) != 1):
            return None             # incl. retired keys: no escalation
        return dev

    def fail_context(self, key: CtxKey, now: float):
        """Single-partition loss inside one device: the worker re-places
        intra-device. Losing the device's LAST live context escalates to
        a whole-device failure (surviving devices inherit)."""
        dev = key[0]
        if dev in self._dead_devs:
            return []                     # nothing left to fail
        if key not in self.queues:
            # reconfigure creates contexts at fresh indices, so bad keys
            # can only be caught here — with a diagnosable error, not
            # the KeyError the worker's table would throw mid-replace
            raise ValueError(
                f"unknown context key {key!r}; device {dev} has contexts "
                f"{[c.index for c in self.workers[dev].contexts]}")
        w = self.workers[dev]
        live = w.live_contexts()
        if not live:
            return []
        # escalation is for losing the device's LAST live context; a
        # fault on an already-retired (draining) key must not take the
        # healthy survivor down with it
        if w.contexts[key].alive and len(live) == 1:
            return self.fail_device(dev, now)
        return w.fail_context(key, now)

    def fail_device(self, dev: int, now: float) -> List[StageInstance]:
        """Whole-GPU loss: every task homed there re-places HP-first onto
        the least-loaded surviving devices (each move is a cross-GPU
        migration); in-flight stages replay from their last boundary on
        the new home — with the transfer charge, since their inter-stage
        state must be refetched (the dead device can't ship it)."""
        if dev in self._dead_devs:
            raise ValueError(f"device {dev} already dead")
        if self.live_devices() == [dev]:
            # checked BEFORE any mutation: callers get a clean error,
            # not a half-retired fleet (the engine skips this case)
            raise RuntimeError(f"cannot fail device {dev}: it is the "
                               f"last live device")
        w = self.workers[dev]
        orphans = self._retire_device(dev)
        # beyond graceful retirement: busy lanes die on EVERY context of
        # the device — stages still draining on contexts an earlier
        # reconfigure retired are just as gone as the live ones
        for c in w.contexts:
            for lane, inst in self.lanes.busy_in_ctx(c.index):
                orphans.append(inst)
                self.lanes[lane] = None
                inst.work_done = 0.0      # replay from stage start
        live = self.live_devices()   # non-empty: prechecked above
        moved, w.tasks = w.tasks, []
        ordered = hp_first(moved, now)
        # survivors keep their current load: seed the accumulators with
        # what is already placed/active there, then place incrementally
        loads = {d: self.device_load(d, now) for d in live}
        utils = {d: {c.index: (self.workers[d].util_hp_total(c.index, now)
                               + self.workers[d].util_lp_active(c.index, now))
                     for c in self.workers[d].live_contexts()}
                 for d in live}
        self._migrations += self._place_ordered(ordered, now, loads, utils)
        self._rehome_orphans(orphans)
        return orphans

    def _rehome_orphans(self, orphans: List[StageInstance]) -> None:
        """Requeue orphaned stage instances at their task's (possibly
        new) home, moving the active-job registration along."""
        for inst in orphans:
            job = inst.job
            old = job.ctx
            tgt = job.task.ctx
            jobs = self.active_jobs.get(old)
            if jobs is not None and job in jobs:
                del jobs[job]
                self.active_jobs[tgt][job] = None
            job.ctx = tgt
            inst.lane = None
            self.queues[tgt].push(inst)

    def _retire_device(self, d: int) -> List[StageInstance]:
        """Graceful (zero-delay) device retirement: queued work drains
        out for re-homing, in-flight stages FINISH on their lanes and
        migrate at the next boundary — nothing replays (contrast
        ``fail_device``)."""
        w = self.workers[d]
        self._dead_devs.add(d)
        orphans: List[StageInstance] = []
        for c in list(w.live_contexts()):
            c.alive = False
            self.lanes.retire_ctx(c.index)
            orphans.extend(self.queues[c.index].drain())
        w._invalidate_live()
        return orphans

    def _global_replace(self, now: float,
                        extra_orphans: List[StageInstance]) -> int:
        """Algorithm 1 re-run across the whole fleet (HP first), used by
        whole-GPU elasticity: every task lands on the least-loaded live
        device's least-utilized context; queued stages re-home, in-flight
        stages finish where they run and migrate at the next stage
        boundary (zero-delay). Returns the number of cross-device moves
        (each counted into ``migrations``)."""
        orphans = list(extra_orphans)
        live = self.live_devices()
        for d in live:
            for c in self.workers[d].live_contexts():
                orphans.extend(self.queues[c.index].drain())
        all_tasks: List[Task] = []
        for w in self.workers.values():
            all_tasks.extend(w.tasks)
            w.tasks = []
        loads = {d: 0.0 for d in live}
        utils = {d: {c.index: 0.0 for c in self.workers[d].live_contexts()}
                 for d in live}
        migrated = self._place_ordered(hp_first(all_tasks, now), now,
                                       loads, utils)
        # re-home live jobs to their task's new context; their running
        # stage (if any) finishes on its current lane
        for key in list(self.active_jobs):
            jobs = self.active_jobs[key]
            for job in list(jobs):
                tgt = job.task.ctx
                if tgt != key:
                    del jobs[job]
                    self.active_jobs[tgt][job] = None
                    job.ctx = tgt
        self._rehome_orphans(orphans)
        self._migrations += migrated
        return migrated

    def add_context(self, now: float) -> Context:
        """Scale-out by one context, on the least-loaded live device."""
        live = self.live_devices()
        d = min(live, key=lambda dd: self.device_load(dd, now))
        return self.workers[d].add_context(now)

    def reconfigure(self, now: float, n_gpus: Optional[int] = None,
                    n_contexts: Optional[int] = None,
                    n_streams: Optional[int] = None,
                    oversubscription: Optional[float] = None) -> dict:
        """Online cluster reshape. Per-device shape kwargs forward to
        every live worker's own Eq. 9 reconfigure; ``n_gpus`` scales by
        whole devices — growing appends fresh workers (device models
        cycle through ``device_models``), shrinking retires the
        highest-numbered live devices gracefully — followed by a global
        Algorithm 1 re-place with zero-delay migration."""
        info = {"retired": [], "created": [], "rehomed": 0, "inflight": 0,
                "migrated": 0, "devices_added": [], "devices_retired": []}
        shape = {k: v for k, v in (("n_contexts", n_contexts),
                                   ("n_streams", n_streams),
                                   ("oversubscription", oversubscription))
                 if v is not None}
        if shape and n_gpus is not None:
            # the per-device reshape and the whole-fleet resize each run
            # their own full re-place; combined they'd shuffle every
            # task twice and double-count migrations — demand two events
            raise ValueError(
                "reshape contexts/streams/oversubscription and n_gpus in "
                "separate reconfigure events (each runs one re-place)")
        if shape:
            for d in self.live_devices():
                sub = self.workers[d].reconfigure(now, **shape)
                for key in ("retired", "created"):
                    info[key] += sub[key]
                for key in ("rehomed", "inflight", "migrated"):
                    info[key] += sub[key]
        if n_gpus is not None:
            if n_gpus < 1:
                raise ValueError(f"reconfigure needs n_gpus >= 1, got "
                                 f"{n_gpus}")
            live = self.live_devices()
            orphans: Optional[List[StageInstance]] = None
            if n_gpus > len(live):
                orphans = []
                for _ in range(n_gpus - len(live)):
                    info["devices_added"].append(self._add_device())
            elif n_gpus < len(live):
                orphans = []
                for d in live[n_gpus - len(live):]:
                    orphans.extend(self._retire_device(d))
                    info["devices_retired"].append(d)
            if orphans is not None:
                info["migrated"] += self._global_replace(now, orphans)
                info["rehomed"] += len(orphans)
        return info
