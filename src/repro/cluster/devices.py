"""Device-model presets for heterogeneous clusters.

``speed`` is the scalar factor versus the reference device every
``StageProfile`` was calibrated on (the paper's RTX 2080 Ti, Table I):
an ``a100``-class device at 2.1 executes a profiled stage in 1/2.1 of
its reference time. ``n_units`` follows each part's SM count, so Eq. 9
partition geometry reflects the real device width. The numbers are
deliberately coarse (public spec-sheet ratios, not microbenchmarks) —
they exist so heterogeneous scheduling decisions have something honest
to chew on, not to re-profile every DNN per device.
"""
from __future__ import annotations

from typing import List, Union

from ..runtime.contention import DeviceModel

DEVICE_PRESETS = {
    # the calibration device itself — same issue-gap waste as
    # serving.profiles.device(), so the speed=1.0 slot of a mixed fleet
    # behaves exactly like the reference device in every other figure
    "rtx2080ti": DeviceModel(n_units=68.0, bubble=0.12, name="rtx2080ti"),
    # V100: 80 SMs, roughly 1.3x the 2080 Ti on fp16 DNN inference
    "v100": DeviceModel(n_units=80.0, bubble=0.16, l2_pressure=0.08,
                        name="v100", speed=1.3),
    # A100: 108 SMs, ~2.1x; bigger L2 eases co-tenant thrash
    "a100": DeviceModel(n_units=108.0, bubble=0.14, l2_pressure=0.06,
                        name="a100", speed=2.1),
    # L4-class edge part: narrow and slower than the reference
    "l4": DeviceModel(n_units=58.0, bubble=0.20, l2_pressure=0.10,
                      name="l4", speed=0.8),
}


def resolve_device(spec: Union[str, DeviceModel]) -> DeviceModel:
    """Accept a preset name or a ready ``DeviceModel``."""
    if isinstance(spec, DeviceModel):
        return spec
    try:
        return DEVICE_PRESETS[spec]
    except KeyError:
        raise ValueError(f"unknown device preset {spec!r}; known: "
                         f"{sorted(DEVICE_PRESETS)}") from None


def resolve_devices(specs) -> List[DeviceModel]:
    return [resolve_device(s) for s in specs]
