"""Multi-GPU DARIS: global admission, cross-GPU zero-delay migration,
heterogeneous device models. See ``cluster.scheduler`` for the design.

    from repro.api import ServerConfig
    server = (ServerConfig.cluster(4, device_models=["a100", "v100"])
              .tasks(specs).contexts(4).oversubscribe(4.0)
              .horizon_ms(6000).build())
"""
from .devices import DEVICE_PRESETS, resolve_device, resolve_devices
from .scheduler import ClusterScheduler

__all__ = ["ClusterScheduler", "DEVICE_PRESETS", "resolve_device",
           "resolve_devices"]
