"""Seeded transient-fault injection and graceful degradation (PR 8).

The chaos layer is a *plan* — a frozen, declarative description of the
hazards a run must survive — plus a tiny runtime (``ChaosState``)
holding the plan's RNG streams and the degradation-mode state machine.
Everything here is pure data + numpy; the engine/backends consume it.

Twin-path discipline (same contract as the PR 3 fast path and the PR 7
sanitizer): with no ``ChaosPlan`` installed the engine takes bit-for-bit
the same decisions as before — every chaos hook is gated on an
``is not None`` check and the simulation RNG stream is never touched.
Chaos draws come from two *independent* generators:

* ``rng`` (seed)      — one uniform draw per configured hazard per
  dispatched stage, in launch order. The stream advance is a pure
  function of the dispatch sequence, so the same seed + plan + workload
  reproduces the same faults bit-identically.
* ``io_rng`` (seed+1) — journal/checkpoint I/O errors. The serve daemon
  journals from its pump loop while the engine dispatches; a shared
  stream would let wall-clock-timed I/O perturb stage faults.

Hazard menu:

* ``stage_fault_rate`` — transient stage-execution failures (the kernel
  "ran" but the result is garbage: full execution time is paid, then the
  stage must be retried or the job aborted).
* ``stall_rate``/``stall_ms`` — temporary lane stalls (driver hiccup,
  ECC scrub): the stage completes but late.
* ``brownouts`` — timed per-device slowdowns (thermal throttle, power
  cap): every lane on the device runs ``slow_factor``x slower for the
  window.
* ``io_error_rate`` — transient ``OSError`` on journal appends and
  checkpoint writes, retried up to ``io_max_retries`` times.

Recovery knobs:

* ``RetryPolicy`` — bounded attempts with exponential backoff charged on
  the *virtual* clock; ``deadline_aware`` gives up early when even an
  immediate retry could not finish by the job's absolute deadline
  (the abort unwinds the Eq. 12 charge — see
  ``DarisScheduler.abort_job``).
* ``watchdog_kappa`` — per-stage watchdog timeout as a multiple of the
  predicted MRET; expiry kills the lane entry and re-dispatches at the
  stage boundary via the existing zero-delay migration path.
* ``DegradationPolicy`` — NORMAL / BROWNOUT / EMERGENCY controller with
  hysteresis; BROWNOUT sheds LP admissions and widens batching waits,
  EMERGENCY additionally cancels queued LP work through the PR 6
  cancellation path.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Set, Tuple

import numpy as np

# degradation modes (journaled by the serve daemon — keep them stable)
NORMAL = "normal"
BROWNOUT = "brownout"
EMERGENCY = "emergency"


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff on the virtual clock."""

    max_attempts: int = 3          # total tries, the first one included
    backoff_ms: float = 1.0        # delay after the first failure
    backoff_mult: float = 2.0
    backoff_cap_ms: float = 50.0
    deadline_aware: bool = True    # abort when a retry cannot make it

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("RetryPolicy.max_attempts must be >= 1")
        if self.backoff_ms < 0 or self.backoff_cap_ms < 0:
            raise ValueError("RetryPolicy backoff delays must be >= 0")
        if self.backoff_mult < 1.0:
            raise ValueError("RetryPolicy.backoff_mult must be >= 1")

    def delay_ms(self, attempt: int) -> float:
        """Backoff charged before re-dispatch, after the ``attempt``-th
        failure (1-based)."""
        return min(self.backoff_ms
                   * self.backoff_mult ** max(attempt - 1, 0),
                   self.backoff_cap_ms)


@dataclasses.dataclass(frozen=True)
class DegradationPolicy:
    """NORMAL -> BROWNOUT -> EMERGENCY hysteresis controller over the
    same utilization signal the autoscaler reads (mean of Eq. 11/12
    utilization over live contexts)."""

    check_every_ms: float = 100.0
    brownout_enter: float = 0.90   # signal >= this: NORMAL -> BROWNOUT
    brownout_exit: float = 0.70    # signal <  this: BROWNOUT -> NORMAL
    emergency_enter: float = 0.98  # signal >= this: -> EMERGENCY
    emergency_exit: float = 0.85   # signal <  this: EMERGENCY -> BROWNOUT
    batch_widen: float = 2.0       # max_wait_ms multiplier while degraded

    def __post_init__(self):
        if self.check_every_ms <= 0:
            raise ValueError("DegradationPolicy.check_every_ms must be > 0")
        if not (self.brownout_exit < self.brownout_enter):
            raise ValueError("brownout_exit must be < brownout_enter")
        if not (self.emergency_exit < self.emergency_enter):
            raise ValueError("emergency_exit must be < emergency_enter")
        if self.brownout_enter > self.emergency_enter:
            raise ValueError("brownout_enter must be <= emergency_enter")
        if self.batch_widen < 1.0:
            raise ValueError("DegradationPolicy.batch_widen must be >= 1")


@dataclasses.dataclass(frozen=True)
class Brownout:
    """Timed per-device slowdown window: every lane on ``device`` runs
    ``slow_factor``x slower for ``[t0_ms, t1_ms)``."""

    t0_ms: float
    t1_ms: float
    device: int = 0
    slow_factor: float = 2.0

    def __post_init__(self):
        if not (self.t1_ms > self.t0_ms >= 0):
            raise ValueError("Brownout window needs t1_ms > t0_ms >= 0")
        if self.slow_factor < 1.0:
            raise ValueError("Brownout.slow_factor must be >= 1")


@dataclasses.dataclass(frozen=True)
class ChaosPlan:
    """The full hazard + recovery description for one run."""

    seed: int = 0
    stage_fault_rate: float = 0.0
    stall_rate: float = 0.0
    stall_ms: float = 5.0
    brownouts: Tuple[Brownout, ...] = ()
    io_error_rate: float = 0.0
    io_max_retries: int = 3
    retry: RetryPolicy = RetryPolicy()
    degradation: Optional[DegradationPolicy] = None
    watchdog_kappa: float = 0.0    # 0 disables the stage watchdog

    def __post_init__(self):
        for name in ("stage_fault_rate", "stall_rate", "io_error_rate"):
            v = getattr(self, name)
            if not (0.0 <= v <= 1.0):
                raise ValueError(f"ChaosPlan.{name} must be in [0, 1]")
        if self.stall_ms < 0:
            raise ValueError("ChaosPlan.stall_ms must be >= 0")
        if self.io_max_retries < 0:
            raise ValueError("ChaosPlan.io_max_retries must be >= 0")
        if self.watchdog_kappa < 0:
            raise ValueError("ChaosPlan.watchdog_kappa must be >= 0")
        if not isinstance(self.brownouts, tuple):
            object.__setattr__(self, "brownouts", tuple(self.brownouts))


def plan_from_dict(d) -> ChaosPlan:
    """JSON-friendly coercion for serving configs: nested dicts become
    the matching dataclasses (``{"chaos": {...}}`` in serve/config)."""
    d = dict(d)
    r = d.get("retry")
    if isinstance(r, dict):
        d["retry"] = RetryPolicy(**r)
    g = d.get("degradation")
    if isinstance(g, dict):
        d["degradation"] = DegradationPolicy(**g)
    bs = d.get("brownouts")
    if bs is not None:
        d["brownouts"] = tuple(Brownout(**b) if isinstance(b, dict) else b
                               for b in bs)
    return ChaosPlan(**d)


class ChaosState:
    """Mutable per-run chaos machinery: RNG streams + degradation mode.

    ``draw_launch`` makes exactly one uniform draw per *configured*
    hazard, in a fixed order, so the stream position is a pure function
    of the static plan and the number of launches so far — adding a
    hazard to the plan changes the draws (expected), but engine-side
    control flow never does.
    """

    def __init__(self, plan: ChaosPlan):
        self.plan = plan
        self.rng = np.random.default_rng(plan.seed)
        self.io_rng = np.random.default_rng(plan.seed + 1)
        self.mode = NORMAL
        # (t_ms, from_mode, to_mode), appended in virtual-time order; the
        # serve daemon drains this with a cursor and journals each one
        self.transitions: List[Tuple[float, str, str]] = []
        self.io_injected = 0       # transient I/O errors injected

    # ------------------------------------------------------------ draws
    def draw_launch(self) -> Tuple[bool, float]:
        """(failed, stall_ms) for the next dispatched stage."""
        p = self.plan
        failed = bool(p.stage_fault_rate
                      and self.rng.random() < p.stage_fault_rate)
        stall = 0.0
        if p.stall_rate and self.rng.random() < p.stall_rate:
            stall = p.stall_ms
        return failed, stall

    def io_fails(self) -> bool:
        p = self.plan
        if p.io_error_rate and self.io_rng.random() < p.io_error_rate:
            self.io_injected += 1
            return True
        return False

    # -------------------------------------------------------- brownouts
    def brownout_factor(self, device: int, now_ms: float) -> float:
        f = 1.0
        for b in self.plan.brownouts:
            if b.device == device and b.t0_ms <= now_ms < b.t1_ms:
                f = max(f, b.slow_factor)
        return f

    def brownout_edges(self) -> List[float]:
        """Window boundaries — the engine schedules a re-rate event at
        each so in-flight work picks the factor change up mid-stage."""
        edges: Set[float] = set()
        for b in self.plan.brownouts:
            edges.add(b.t0_ms)
            edges.add(b.t1_ms)
        return sorted(edges)

    # ------------------------------------------------------ degradation
    def set_mode(self, now_ms: float, mode: str) -> bool:
        """Record a mode transition; returns True when it changed."""
        if mode == self.mode:
            return False
        self.transitions.append((now_ms, self.mode, mode))
        self.mode = mode
        return True
