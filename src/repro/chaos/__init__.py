"""Chaos layer: seeded fault injection + recovery policies (PR 8)."""
from .plan import (BROWNOUT, EMERGENCY, NORMAL, Brownout, ChaosPlan,
                   ChaosState, DegradationPolicy, RetryPolicy,
                   plan_from_dict)

__all__ = [
    "Brownout", "ChaosPlan", "ChaosState", "DegradationPolicy",
    "RetryPolicy", "plan_from_dict", "NORMAL", "BROWNOUT", "EMERGENCY",
]
