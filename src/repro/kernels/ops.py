"""Jit'd dispatch wrappers: Pallas kernel on TPU (or interpret-mode
validation), pure-jnp reference everywhere else.

``mode``: "auto" (kernel on TPU, ref otherwise), "kernel" (force kernel —
interpret-mode on CPU), "ref".
"""
from __future__ import annotations

import jax

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import rmsnorm as _rms
from . import ref as _ref
from . import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_kernel(mode: str) -> bool:
    if mode == "kernel":
        return True
    if mode == "ref":
        return False
    return _on_tpu()


def rmsnorm(x, weight, *, eps: float = 1e-6, plus_one: bool = False,
            mode: str = "auto"):
    if _use_kernel(mode):
        return _rms.rmsnorm(x, weight, eps=eps, plus_one=plus_one,
                            interpret=not _on_tpu())
    return _ref.rmsnorm_ref(x, weight, eps, plus_one)


def rmsnorm_residual(x, residual, weight, *, eps: float = 1e-6,
                     plus_one: bool = False, mode: str = "auto"):
    if _use_kernel(mode):
        return _rms.rmsnorm_residual(x, residual, weight, eps=eps,
                                     plus_one=plus_one,
                                     interpret=not _on_tpu())
    return _ref.rmsnorm_residual_ref(x, residual, weight, eps, plus_one)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale=None, block_q: int = 128,
                    block_k: int = 128, mode: str = "auto"):
    if _use_kernel(mode):
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   block_q=block_q, block_k=block_k,
                                   interpret=not _on_tpu())
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale)


def decode_attention(q, k, v, kv_pos, q_pos, *, window: int = 0,
                     softcap: float = 0.0, scale=None, block_k: int = 512,
                     mode: str = "auto"):
    if _use_kernel(mode):
        return _dec.decode_attention(q, k, v, kv_pos, q_pos, window=window,
                                     softcap=softcap, scale=scale,
                                     block_k=block_k,
                                     interpret=not _on_tpu())
    return _ref.decode_attention_ref(q, k, v, kv_pos, q_pos, window=window,
                                     softcap=softcap, scale=scale)


def ssd(x, dt, a_log, b, c, chunk: int = 256, init_state=None,
        mode: str = "auto"):
    if _use_kernel(mode):
        return _ssd.ssd(x, dt, a_log, b, c, chunk, init_state,
                        interpret=not _on_tpu())
    return _ref.ssd_ref(x, dt, a_log, b, c, chunk, init_state)
