"""Fused (residual-add +) RMSNorm Pallas TPU kernel.

Tokens are flattened to [M, D]; the grid tiles M into ``bm``-row blocks that
stream HBM->VMEM; the full feature dim D stays resident per block (D <= a
few k for every assigned arch, well inside VMEM). f32 accumulation in VREGs
regardless of IO dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, plus_one: bool):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    o_ref[...] = (y * w[None]).astype(o_ref.dtype)


def _rmsnorm_res_kernel(x_ref, r_ref, w_ref, o_ref, res_ref, *, eps: float,
                        plus_one: bool):
    s = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    res_ref[...] = s.astype(res_ref.dtype)
    var = jnp.mean(jnp.square(s), axis=-1, keepdims=True)
    y = s * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    o_ref[...] = (y * w[None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "plus_one", "block_m",
                                             "interpret"))
def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
            plus_one: bool = False, block_m: int = 256,
            interpret: bool = True) -> jax.Array:
    """x: [..., D] -> normalized [..., D]."""
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    m = xf.shape[0]
    bm = min(block_m, m)
    pad = (-m) % bm
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    grid = (xf.shape[0] // bm,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, plus_one=plus_one),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, weight)
    if pad:
        out = out[:m]
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("eps", "plus_one", "block_m",
                                             "interpret"))
def rmsnorm_residual(x: jax.Array, residual: jax.Array, weight: jax.Array, *,
                     eps: float = 1e-6, plus_one: bool = False,
                     block_m: int = 256, interpret: bool = True):
    """Fused y = rmsnorm(x + residual); returns (y, x + residual)."""
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    rf = residual.reshape(-1, d)
    m = xf.shape[0]
    bm = min(block_m, m)
    pad = (-m) % bm
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        rf = jnp.pad(rf, ((0, pad), (0, 0)))
    grid = (xf.shape[0] // bm,)
    out, res = pl.pallas_call(
        functools.partial(_rmsnorm_res_kernel, eps=eps, plus_one=plus_one),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                  pl.BlockSpec((bm, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                   pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(xf.shape, x.dtype),
                   jax.ShapeDtypeStruct(xf.shape, x.dtype)],
        interpret=interpret,
    )(xf, rf, weight)
    if pad:
        out, res = out[:m], res[:m]
    return out.reshape(shape), res.reshape(shape)
