"""Fused contention + ETA kernel for fleet-scale lane sweeps.

Evaluates the processor-sharing contention model (runtime/contention.py:
Eq. 9 share-capping, unit-budget shrink, L2-thrash bandwidth congestion)
and the finish-time prediction ``eta = now + rem / rate`` over thousands
of lanes in one jitted pass. Two implementations:

* ``rates`` / ``fused`` — jitted jnp in **float64** (scoped
  ``jax.experimental.enable_x64``), with the three reductions (share
  total, unit usage, bandwidth phi) evaluated as *sequential*
  left-to-right ``lax.fori_loop`` accumulations over the live prefix.
  This reproduces ``ContentionModel.rates_seq`` bit-for-bit — it is the
  path the epoch engine (runtime/epoch.py) dispatches to above
  ``EpochSimBackend.KERNEL_MIN`` lanes per rate-group, so engine results
  stay on the golden-fixture bits no matter which side of the threshold
  a sweep lands on (tests/test_epoch_engine.py locks this).

* ``fused_pallas`` — a Pallas kernel (TPU; interpret-mode elsewhere)
  following the kernels/ops.py dispatch idiom. TPU vector units have no
  float64, so this variant runs in float32: it serves analytic
  fleet-capacity sweeps where raw lane throughput matters and bit-parity
  with the CPU reference does not. It is NOT used by the sim engines.

Inputs are padded to a power-of-two panel so jit retraces O(log m)
times, never per lane count; padding lanes are masked out of every
reduction (they contribute exact ``+0.0``) and sliced off the result.
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import numpy as np

try:
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    _HAVE_JAX = True
except Exception:                                    # pragma: no cover
    _HAVE_JAX = False


def available() -> bool:
    """True when the jitted float64 kernel can run in this process."""
    return _HAVE_JAX


def _panel(m: int) -> int:
    """Smallest power-of-two >= m (floor 8): the static pad size."""
    return 1 << max(m - 1, 7).bit_length()


if _HAVE_JAX:

    @jax.jit
    def _kernel_f64(u, ns, mf, rem, m, now, one, n_units, bubble, l2p):
        """One fused pass: speeds (pre-clamp), clamped rates, ETAs.

        Every elementwise step is the same IEEE-754 op sequence as
        ``ContentionModel.rates_arrays``; the reductions accumulate
        left-to-right over the live prefix (padding adds +0.0, which is
        exact for these non-negative terms), matching ``_seq_sum``.

        Two XLA:CPU rewrites silently change bits, so the kernel routes
        around both:

        * ``add(mul(a, b), c)`` contracts into a single-rounding FMA —
          one ulp off the two-rounding scalar reference — and neither
          ``optimization_barrier`` nor a bitcast round-trip survives the
          simplifier. ``nofma(t) = t * one`` does (``one`` is a
          runtime-supplied 1.0): a multiply by a runtime parameter cannot
          be folded, and even if the *outer* multiply contracts,
          ``fma(t, 1.0, c)`` rounds identically to ``t + c``.

        * division by a compile-time constant is rewritten to a multiply
          by its (inexactly rounded) reciprocal. Hence the device
          parameters (``n_units``, ``bubble``, ``l2p``) arrive as traced
          runtime scalars, never jit-time constants, so every divisor in
          the graph stays a true divide.
        """
        P = u.shape[0]
        live = jnp.arange(P) < m
        mf64 = m.astype(u.dtype)

        def nofma(x):
            return x * one

        def seq_sum(x):
            # the product array is materialized (dynamically indexed in
            # the loop), so the loop add cannot contract with it
            x = jnp.where(live, x, 0.0)
            return lax.fori_loop(0, P, lambda j, acc: acc + x[j], 0.0)

        total = seq_sum(u)
        u = jnp.where(total > n_units, u * (n_units / total), u)
        gain = (1.0 - bubble / mf64) / (1.0 - bubble)
        speeds = jnp.minimum(1.0, jnp.minimum(u, ns) / ns * gain)
        used = seq_sum(speeds * ns)
        budget = n_units * (1.0 + nofma(bubble * (1.0 - 1.0 / mf64)))
        speeds = jnp.where(used > budget, speeds * (budget / used), speeds)
        thrash = 1.0 + nofma(l2p * jnp.maximum(mf64 - 1.0, 0.0))
        phi = seq_sum(mf * speeds) * thrash
        speeds = jnp.where(phi > 1.0,
                           speeds / ((1.0 - mf) + nofma(mf * phi)), speeds)
        rates = jnp.where(speeds > 1e-6, speeds, 1e-6)
        eta = now + rem / rates
        return speeds, rates, eta

    def _call(device, u, ns, mf, rem, now):
        m = len(u)
        P = _panel(m)
        with enable_x64():
            bu = np.ones(P)         # neutral pads: ns=1 avoids 0/0
            bns = np.ones(P)
            bmf = np.zeros(P)
            brem = np.zeros(P)
            bu[:m] = u
            bns[:m] = ns
            bmf[:m] = mf
            if rem is not None:
                brem[:m] = rem
            bu[m:] = 0.0
            return _kernel_f64(
                jnp.asarray(bu), jnp.asarray(bns), jnp.asarray(bmf),
                jnp.asarray(brem), jnp.asarray(m), jnp.asarray(float(now)),
                jnp.asarray(1.0), jnp.asarray(float(device.n_units)),
                jnp.asarray(float(device.bubble)),
                jnp.asarray(float(device.l2_pressure)))


def rates(device, u: Sequence[float], ns: Sequence[float],
          mf: Sequence[float]) -> List[float]:
    """Bit-exact drop-in for ``ContentionModel.rates_seq`` (pre-clamp
    speed fractions) through the jitted float64 kernel."""
    m = len(u)
    if m == 0:
        return []
    speeds, _, _ = _call(device, u, ns, mf, None, 0.0)
    return np.asarray(speeds)[:m].tolist()


def fused(device, now: float, u: Sequence[float], ns: Sequence[float],
          mf: Sequence[float], rem: Sequence[float]
          ) -> Tuple[np.ndarray, np.ndarray]:
    """Fused contention + ETA: returns ``(rates, etas)`` as float64
    arrays of length ``len(u)``, where rates carry the engine's 1e-6
    clamp and ``eta = now + rem / rate`` — the epoch engine's whole
    prediction pass for one rate-group in a single jitted call."""
    m = len(u)
    if m == 0:
        z = np.empty(0)
        return z, z
    _, r, eta = _call(device, u, ns, mf, rem, now)
    return np.asarray(r)[:m], np.asarray(eta)[:m]


# --------------------------------------------------------------- Pallas
def _on_tpu() -> bool:                               # pragma: no cover
    return _HAVE_JAX and jax.default_backend() == "tpu"


def fused_pallas(device, now: float, u, ns, mf, rem, *,
                 interpret: bool = None):
    """Float32 Pallas variant of ``fused`` for analytic fleet sweeps
    (see module docstring — NOT the engines' bit-exact path). Single
    VMEM-resident panel; reductions run as sequential ``fori_loop``
    accumulations inside the kernel, mirroring the f64 path's order."""
    if not _HAVE_JAX:
        raise RuntimeError("contention_eta.fused_pallas requires jax")
    from jax.experimental import pallas as pl

    if interpret is None:
        interpret = not _on_tpu()
    m = len(u)
    P = max(128, _panel(m))
    f32 = np.float32

    def pad(x, fill):
        out = np.full(P, fill, dtype=f32)
        out[:m] = np.asarray(x, dtype=f32)[:m]
        return out

    bu, bns = pad(u, 0.0), pad(ns, 1.0)
    bmf, brem = pad(mf, 0.0), pad(rem, 0.0)
    n_units = f32(device.n_units)
    bubble = f32(device.bubble)
    l2p = f32(device.l2_pressure)
    mf32 = f32(m)
    now32 = f32(now)

    def kernel(u_ref, ns_ref, mf_ref, rem_ref, rate_ref, eta_ref):
        live = (lax.broadcasted_iota(jnp.int32, (1, P), 1)
                < m).astype(jnp.float32)
        u = u_ref[...] * live
        ns_ = ns_ref[...]
        mfr = mf_ref[...] * live

        def seq_sum(x):
            return lax.fori_loop(
                0, P, lambda j, acc: acc + x[0, j], jnp.float32(0.0))

        total = seq_sum(u)
        u = jnp.where(total > n_units, u * (n_units / total), u)
        gain = (1.0 - bubble / mf32) / (1.0 - bubble)
        speeds = jnp.minimum(1.0, jnp.minimum(u, ns_) / ns_ * gain)
        used = seq_sum(speeds * ns_)
        budget = n_units * (1.0 + bubble * (1.0 - 1.0 / mf32))
        speeds = jnp.where(used > budget, speeds * (budget / used), speeds)
        thrash = 1.0 + l2p * jnp.maximum(mf32 - 1.0, 0.0)
        phi = seq_sum(mfr * speeds) * thrash
        speeds = jnp.where(phi > 1.0,
                           speeds / ((1.0 - mfr) + mfr * phi), speeds)
        rate = jnp.where(speeds > 1e-6, speeds, jnp.float32(1e-6))
        rate_ref[...] = rate
        eta_ref[...] = now32 + rem_ref[...] / rate

    out_shape = [jax.ShapeDtypeStruct((1, P), f32)] * 2
    rate, eta = pl.pallas_call(kernel, out_shape=out_shape,
                               interpret=interpret)(
        bu.reshape(1, P), bns.reshape(1, P),
        bmf.reshape(1, P), brem.reshape(1, P))
    return np.asarray(rate)[0, :m], np.asarray(eta)[0, :m]
