"""Mamba2 SSD chunk-scan Pallas TPU kernel.

One kernel does the whole SSD: grid = (B, H, n_chunks) with the chunk axis
innermost-sequential; the running state [P, N] lives in f32 VMEM scratch and
carries across chunks (the inter-chunk recurrence), while each grid step
computes the intra-chunk quadratic term with MXU dots:

    y_intra = (tril(C B^T * segsum-decay) * dt) X
    y_inter = (C . state_prev) * decay_from_start
    state   = state_prev * total_decay + (B * decay_to_end * dt)^T X

Per-block working set (Q=256, P=64, N<=128) is a few hundred KB — well
inside VMEM. Groups are pre-broadcast to heads outside the kernel (G is 1
for every assigned arch, so this costs nothing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref,
                y_ref, sf_ref, state_scr, *, q: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0].astype(jnp.float32)            # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)          # [Q]
    a = a_ref[0]                                      # scalar A_log (this head)
    bm = b_ref[0, :, 0].astype(jnp.float32)           # [Q, N]
    cm = c_ref[0, :, 0].astype(jnp.float32)           # [Q, N]

    neg_a = -jnp.exp(a.astype(jnp.float32))           # scalar, negative
    da = dt * neg_a                                   # [Q]
    cum = jnp.cumsum(da)                              # [Q]
    # segsum decay: exp(cum_i - cum_j) masked to j <= i
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(jj <= ii, jnp.exp(seg), 0.0)    # [Q, Q]

    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)  # [Q, Q]
    w = cb * decay * dt[None, :]
    y_intra = jnp.dot(w, x, preferred_element_type=jnp.float32)     # [Q, P]

    prev = state_scr[...]                              # [P, N]
    decay_from_start = jnp.exp(cum)                    # [Q]
    y_inter = jnp.dot(cm, prev.T,
                      preferred_element_type=jnp.float32) * decay_from_start[:, None]
    y_ref[0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    decay_to_end = jnp.exp(cum[-1] - cum)              # [Q]
    bw = bm * (decay_to_end * dt)[:, None]             # [Q, N]
    new_state = prev * jnp.exp(cum[-1]) + jnp.dot(
        x.T, bw, preferred_element_type=jnp.float32)   # [P, N]
    state_scr[...] = new_state

    @pl.when(ci == nc - 1)
    def _finish():
        sf_ref[0, 0] = new_state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, a_log: jax.Array, b: jax.Array,
        c: jax.Array, chunk: int = 256, init_state: jax.Array | None = None,
        *, interpret: bool = True):
    """x [B,L,H,P]; dt [B,L,H] (post-softplus); a_log [H]; b/c [B,L,G,N].

    Returns (y [B,L,H,P], final_state [B,H,P,N] f32)."""
    bs, ln, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    assert ln % chunk == 0
    nc = ln // chunk
    bh = jnp.repeat(b, rep, axis=2) if rep > 1 else b   # [B,L,H,N]
    ch = jnp.repeat(c, rep, axis=2) if rep > 1 else c
    if init_state is None:
        init_state = jnp.zeros((bs, h, p, n), jnp.float32)
    kernel = functools.partial(_ssd_kernel, q=chunk, nc=nc)
    y, sf = pl.pallas_call(
        kernel,
        grid=(bs, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, ci: (b_, ci, h_)),
            pl.BlockSpec((1,), lambda b_, h_, ci: (h_,)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, ci: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, ci: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((bs, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log, bh, ch, init_state)
    return y, sf
