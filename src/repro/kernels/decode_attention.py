"""Flash-decode Pallas TPU kernel: one new token against a long KV cache.

q [B, H, Dh] (q_len folded to 1), k/v [B, KV, S, Dh]. Grid = (B, H, S/bk)
with the KV axis innermost-sequential; f32 VMEM scratch carries the online
softmax, exactly like the prefill kernel but with a 1-row query tile padded
to the 8-sublane minimum (the row dim of the q tile is replicated 8x and
row 0 is written out). Positions arrive as a per-slot vector so ring
buffers / partially-filled caches mask correctly (slot_pos < 0 = invalid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30
QROWS = 8    # sublane padding for the single query row


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, qpos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, window: int,
                   softcap: float, bk: int, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # [QROWS, dh]
    k = k_ref[0, 0].astype(jnp.float32)               # [bk, dh]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    kp = pos_ref[0]                                   # [bk] absolute slot pos
    qp = qpos_ref[0]                                  # [1] query position
    ok = (kp >= 0) & (kp <= qp)
    if window > 0:
        ok = ok & (qp - kp < window)
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "scale", "block_k", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_pos: jax.Array, q_pos: jax.Array, *,
                     window: int = 0, softcap: float = 0.0,
                     scale: float | None = None, block_k: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q: [B, H, Dh]; k/v: [B, KV, S, Dh]; kv_pos: [S] (−1 invalid);
    q_pos: [B] -> out [B, H, Dh]."""
    b, h, dh = q.shape
    kvh, s = k.shape[1], k.shape[2]
    g = h // kvh
    if scale is None:
        scale = dh ** -0.5
    bk = min(block_k, s)
    assert s % bk == 0
    nk = s // bk
    qr = jnp.broadcast_to(q[:, :, None, :], (b, h, QROWS, dh))
    kv_pos2 = jnp.broadcast_to(kv_pos[None], (b, s))
    qp2 = q_pos.reshape(b, 1).astype(jnp.int32)
    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               softcap=softcap, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1, QROWS, dh), lambda b_, h_, ki: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h_, ki: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h_, ki: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, bk), lambda b_, h_, ki: (b_, ki)),
            pl.BlockSpec((1, 1), lambda b_, h_, ki: (b_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, QROWS, dh),
                               lambda b_, h_, ki: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, QROWS, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((QROWS, 1), jnp.float32),
            pltpu.VMEM((QROWS, 1), jnp.float32),
            pltpu.VMEM((QROWS, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qr, k, v, kv_pos2, qp2)
    return out[:, :, 0, :]
