"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, weight: jax.Array, eps: float = 1e-6,
                plus_one: bool = False) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    return (y * w).astype(x.dtype)


def rmsnorm_residual_ref(x, residual, weight, eps: float = 1e-6,
                         plus_one: bool = False):
    s = (x.astype(jnp.float32) + residual.astype(jnp.float32)).astype(x.dtype)
    return rmsnorm_ref(s, weight, eps, plus_one), s


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0, softcap: float = 0.0,
                  scale: Optional[float] = None) -> jax.Array:
    """q [B,H,S,Dh], k/v [B,KV,S,Dh] -> [B,H,S,Dh]; f32 softmax."""
    b, h, s, dh = q.shape
    kvh = k.shape[1]
    g = h // kvh
    if scale is None:
        scale = dh ** -0.5
    qg = q.reshape(b, kvh, g, s, dh)
    logits = jnp.einsum("bkgqd,bktd->bkgqt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok = ok & (kpos <= qpos)
    if window > 0:
        ok = ok & (qpos - kpos < window)
    logits = jnp.where(ok[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqt,bktd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, s, dh).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_pos: jax.Array, q_pos: jax.Array, *,
                         window: int = 0, softcap: float = 0.0,
                         scale: Optional[float] = None) -> jax.Array:
    """q [B,H,Dh], k/v [B,KV,S,Dh], kv_pos [S], q_pos [B] -> [B,H,Dh]."""
    b, h, dh = q.shape
    kvh, s = k.shape[1], k.shape[2]
    g = h // kvh
    if scale is None:
        scale = dh ** -0.5
    qg = q.reshape(b, kvh, g, dh)
    logits = jnp.einsum("bkgd,bktd->bkgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    ok = (kv_pos[None] >= 0) & (kv_pos[None] <= q_pos[:, None])
    if window > 0:
        ok = ok & (q_pos[:, None] - kv_pos[None] < window)
    logits = jnp.where(ok[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, dh).astype(q.dtype)


def ssd_ref(x, dt, a_log, b, c, chunk: int = 256, init_state=None):
    """Chunked SSD oracle (the model's reference implementation)."""
    from ..models.mamba2 import ssd_reference
    return ssd_reference(x, dt, a_log, b, c, chunk, init_state)
