"""Flash attention (prefill) Pallas TPU kernel.

Layout: q [B, H, S, Dh], k/v [B, KV, S, Dh] (GQA: the kv-head index map is
h // group so grouped q heads stream the same K/V block — no materialized
head expansion). Grid = (B, H, S/bq, S/bk) with the KV axis innermost:
Pallas TPU executes the grid sequentially, so f32 VMEM scratch (m, l, acc)
carries the online softmax across KV blocks and the output is written at
the last KV block. Causal masking, sliding window and gemma-style logit
softcap are fused in. Tiles are MXU-aligned (bq x bk x Dh multiples of 128
for production; interpret mode accepts any shape).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  bq: int, bk: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # [bq, dh]
    k = k_ref[0, 0].astype(jnp.float32)              # [bk, dh]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), bool)
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window > 0:
        ok = ok & (q_pos - k_pos < window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: [B, H, S, Dh]; k/v: [B, KV, S, Dh] -> [B, H, S, Dh]."""
    b, h, s, dh = q.shape
    kvh = k.shape[1]
    g = h // kvh
    if scale is None:
        scale = dh ** -0.5
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running max
            pltpu.VMEM((bq, 1), jnp.float32),      # running denom
            pltpu.VMEM((bq, dh), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
