"""Durable request journal: append-only JSONL, one record per line.

The daemon writes a ``submit`` record — tenant id, priority, virtual
release time — BEFORE acknowledging a submission, so an acknowledged
request is always recoverable. Terminal outcomes append ``done`` records;
cancels append ``cancel`` records; a restart appends ``resubmitted``
records for journaled-but-unfinished requests it re-injects. The file is
therefore both the durability log and a complete traffic capture:
``to_trace_arrivals`` turns it into per-task ``TraceArrival`` processes,
so a recorded outage replays as a deterministic chaos scenario.

Record kinds (``rec`` field):

    meta         {"version", "created_unix", "config_sha"?}   (file open)
    submit       {"seq", "task", "tenant", "prio", "at_ms"}
    cancel       {"seq", "at_ms"}
    done         {"seq", "status", "response_ms"}             (terminal)
    resubmitted  {"seq", "at_ms"}          (restart re-injection, same seq)
    checkpoint   {"path", "at_ms"}         (SIGTERM / shutdown)
    final        {"summary"}               (graceful drain only)

``audit_zero_lost`` is the durability contract: every journaled ``seq``
must reach a terminal ``done``/``cancel`` record, possibly across
restarts (``resubmitted`` chains keep the same seq).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

JOURNAL_VERSION = 1

# submissions in these states are finished business; anything else found
# in a journal at restart must be re-injected
TERMINAL_STATUSES = ("completed", "missed", "rejected", "cancelled",
                     "aborted")


class Journal:
    """Append-only JSONL writer. ``append`` flushes every record (the
    ack-after-journal contract); ``fsync=True`` additionally fsyncs,
    trading throughput for power-loss durability. ``chaos`` (a
    ``ChaosState``) injects transient flush failures: ``append`` retries
    up to ``plan.io_max_retries`` times, then re-raises — the daemon's
    ack-after-journal contract turns an exhausted retry into a refused
    submission rather than a silently lost one."""

    def __init__(self, path: str, fsync: bool = False, chaos=None):
        self.path = str(path)
        self.fsync = fsync
        self.chaos = chaos
        fresh = not os.path.exists(self.path) \
            or os.path.getsize(self.path) == 0
        self._f = open(self.path, "a", encoding="utf-8")
        if fresh:
            self.append({"rec": "meta", "version": JOURNAL_VERSION})

    def append(self, record: Dict) -> None:
        line = json.dumps(record, sort_keys=True) + "\n"
        ch = self.chaos
        attempts = 1 + (ch.plan.io_max_retries if ch is not None else 0)
        for i in range(attempts):
            try:
                if ch is not None and ch.io_fails():
                    raise OSError("chaos: injected journal write failure")
                self._f.write(line)
                self._f.flush()
                if self.fsync:
                    os.fsync(self._f.fileno())
                return
            except OSError:
                if i + 1 >= attempts:
                    raise

    def close(self) -> None:
        self._f.close()


def read_journal(path: str) -> List[Dict]:
    """All records, in append order. A torn final line (crash mid-write)
    is dropped — it was never acknowledged, so losing it is correct."""
    out: List[Dict] = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                break     # torn tail: everything after it is unreadable
    return out


def fsck_journal(path: str) -> Dict:
    """Classify journal damage without modifying the file.

    Returns ``{"ok", "kind", "records", "bad_line", "valid_bytes"}``:

    * ``kind="clean"`` — every line parses.
    * ``kind="torn-tail"`` — exactly one undecodable line and it is the
      LAST line: the classic crash-mid-append artifact. ``read_journal``
      already tolerates this (the torn record was never acknowledged).
    * ``kind="mid-file"`` — an undecodable line with valid JSON records
      AFTER it. That is not a torn write; it is corruption (bit rot,
      concurrent writer, manual editing) and acknowledged records after
      the damage would be silently dropped by a tolerant reader. The
      daemon refuses to start on such a journal; ``repair_journal``
      truncates to ``valid_bytes`` (the last valid prefix) after the
      operator confirms losing everything beyond it.

    ``valid_bytes`` is the byte offset of the end of the last good line
    BEFORE the first bad one — the truncation point a repair uses.
    """
    records: List[Dict] = []
    bad_line = None            # 1-based line number of first bad line
    valid_bytes = 0
    after_bad = False          # any valid JSON after the first bad line?
    offset = 0
    with open(path, "rb") as f:
        for lineno, raw in enumerate(f, 1):
            end = offset + len(raw)
            text = raw.decode("utf-8", errors="replace").strip()
            if not text:
                if bad_line is None:
                    valid_bytes = end
                offset = end
                continue
            try:
                rec = json.loads(text)
            except json.JSONDecodeError:
                if bad_line is None:
                    bad_line = lineno
                offset = end
                continue
            if bad_line is None:
                records.append(rec)
                valid_bytes = end
            else:
                after_bad = True
            offset = end
    if bad_line is None:
        kind = "clean"
    elif after_bad:
        kind = "mid-file"
    else:
        kind = "torn-tail"
    return {"ok": kind in ("clean", "torn-tail"), "kind": kind,
            "records": records, "bad_line": bad_line,
            "valid_bytes": valid_bytes}


def repair_journal(path: str) -> Dict:
    """Truncate ``path`` to its last valid prefix (``fsck_journal``'s
    ``valid_bytes``). Destructive — every record at or beyond the first
    undecodable line is lost; callers must get explicit operator
    confirmation first (``python -m repro.serve fsck --yes``)."""
    report = fsck_journal(path)
    if report["kind"] == "clean":
        return report
    with open(path, "r+b") as f:
        f.truncate(report["valid_bytes"])
    report["repaired"] = True
    return report


def submit_records(records: List[Dict]) -> List[Dict]:
    return [r for r in records if r.get("rec") == "submit"]


def unfinished_submits(records: List[Dict]) -> List[Dict]:
    """Journaled submissions with no terminal record — the restart
    re-injection set. A ``resubmitted`` record does NOT finish a seq; it
    only marks that a later run took responsibility for it again."""
    terminal = {r["seq"] for r in records if r.get("rec") == "done"}
    return [r for r in submit_records(records) if r["seq"] not in terminal]


def audit_zero_lost(records: List[Dict]) -> List[int]:
    """Seqs that were acknowledged but never reached a terminal state —
    the list a healthy drain leaves empty."""
    return sorted(r["seq"] for r in unfinished_submits(records))


def to_trace_arrivals(records: List[Dict],
                      until_ms: Optional[float] = None):
    """Per-task ``TraceArrival`` processes reproducing the journaled
    traffic: ``{task_name: TraceArrival([...])}``. Submission stamps are
    strictly monotonic per daemon run, so replay order equals the order
    the live engine processed the releases in.

    Bit-exactness caveat: the lazy-dispatch batching hold
    (``DarisScheduler._should_hold``) keys off the engine's next known
    wake-up. A trace replay knows every future arrival; the live daemon
    cannot (clients have not sent them yet), so a replay of a
    batching-enabled config may coalesce MORE than the live run did.
    Replay is bit-identical whenever no hold triggers — batching off, or
    traffic sparse enough that heads never grow."""
    from ..runtime.arrivals import TraceArrival
    times: Dict[str, List[float]] = {}
    for r in submit_records(records):
        if until_ms is not None and r["at_ms"] > until_ms:
            continue
        times.setdefault(r["task"], []).append(float(r["at_ms"]))
    return {name: TraceArrival(ts) for name, ts in times.items()}


def replay_plan(records: List[Dict]):
    """(submits, cancels) for a handle-accurate replay: submits in stamp
    order, cancels as ``(seq, at_ms)`` referencing them. Used when the
    replay must also reproduce cancellations (TraceArrival replays the
    load shape only)."""
    subs = submit_records(records)
    cancels = [(r["seq"], float(r["at_ms"]))
               for r in records if r.get("rec") == "cancel"]
    return subs, cancels
