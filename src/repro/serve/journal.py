"""Durable request journal: append-only JSONL, one record per line.

The daemon writes a ``submit`` record — tenant id, priority, virtual
release time — BEFORE acknowledging a submission, so an acknowledged
request is always recoverable. Terminal outcomes append ``done`` records;
cancels append ``cancel`` records; a restart appends ``resubmitted``
records for journaled-but-unfinished requests it re-injects. The file is
therefore both the durability log and a complete traffic capture:
``to_trace_arrivals`` turns it into per-task ``TraceArrival`` processes,
so a recorded outage replays as a deterministic chaos scenario.

Record kinds (``rec`` field):

    meta         {"version", "created_unix", "config_sha"?}   (file open)
    submit       {"seq", "task", "tenant", "prio", "at_ms"}
    cancel       {"seq", "at_ms"}
    done         {"seq", "status", "response_ms"}             (terminal)
    resubmitted  {"seq", "at_ms"}          (restart re-injection, same seq)
    checkpoint   {"path", "at_ms"}         (SIGTERM / shutdown)
    final        {"summary"}               (graceful drain only)

``audit_zero_lost`` is the durability contract: every journaled ``seq``
must reach a terminal ``done``/``cancel`` record, possibly across
restarts (``resubmitted`` chains keep the same seq).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

JOURNAL_VERSION = 1

# submissions in these states are finished business; anything else found
# in a journal at restart must be re-injected
TERMINAL_STATUSES = ("completed", "missed", "rejected", "cancelled")


class Journal:
    """Append-only JSONL writer. ``append`` flushes every record (the
    ack-after-journal contract); ``fsync=True`` additionally fsyncs,
    trading throughput for power-loss durability."""

    def __init__(self, path: str, fsync: bool = False):
        self.path = str(path)
        self.fsync = fsync
        fresh = not os.path.exists(self.path) \
            or os.path.getsize(self.path) == 0
        self._f = open(self.path, "a", encoding="utf-8")
        if fresh:
            self.append({"rec": "meta", "version": JOURNAL_VERSION})

    def append(self, record: Dict) -> None:
        self._f.write(json.dumps(record, sort_keys=True) + "\n")
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        self._f.close()


def read_journal(path: str) -> List[Dict]:
    """All records, in append order. A torn final line (crash mid-write)
    is dropped — it was never acknowledged, so losing it is correct."""
    out: List[Dict] = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                break     # torn tail: everything after it is unreadable
    return out


def submit_records(records: List[Dict]) -> List[Dict]:
    return [r for r in records if r.get("rec") == "submit"]


def unfinished_submits(records: List[Dict]) -> List[Dict]:
    """Journaled submissions with no terminal record — the restart
    re-injection set. A ``resubmitted`` record does NOT finish a seq; it
    only marks that a later run took responsibility for it again."""
    terminal = {r["seq"] for r in records if r.get("rec") == "done"}
    return [r for r in submit_records(records) if r["seq"] not in terminal]


def audit_zero_lost(records: List[Dict]) -> List[int]:
    """Seqs that were acknowledged but never reached a terminal state —
    the list a healthy drain leaves empty."""
    return sorted(r["seq"] for r in unfinished_submits(records))


def to_trace_arrivals(records: List[Dict],
                      until_ms: Optional[float] = None):
    """Per-task ``TraceArrival`` processes reproducing the journaled
    traffic: ``{task_name: TraceArrival([...])}``. Submission stamps are
    strictly monotonic per daemon run, so replay order equals the order
    the live engine processed the releases in.

    Bit-exactness caveat: the lazy-dispatch batching hold
    (``DarisScheduler._should_hold``) keys off the engine's next known
    wake-up. A trace replay knows every future arrival; the live daemon
    cannot (clients have not sent them yet), so a replay of a
    batching-enabled config may coalesce MORE than the live run did.
    Replay is bit-identical whenever no hold triggers — batching off, or
    traffic sparse enough that heads never grow."""
    from ..runtime.arrivals import TraceArrival
    times: Dict[str, List[float]] = {}
    for r in submit_records(records):
        if until_ms is not None and r["at_ms"] > until_ms:
            continue
        times.setdefault(r["task"], []).append(float(r["at_ms"]))
    return {name: TraceArrival(ts) for name, ts in times.items()}


def replay_plan(records: List[Dict]):
    """(submits, cancels) for a handle-accurate replay: submits in stamp
    order, cancels as ``(seq, at_ms)`` referencing them. Used when the
    replay must also reproduce cancellations (TraceArrival replays the
    load shape only)."""
    subs = submit_records(records)
    cancels = [(r["seq"], float(r["at_ms"]))
               for r in records if r.get("rec") == "cancel"]
    return subs, cancels
