"""CLI for the serving front-end: ``python -m repro.serve <verb> ...``.

    # run the ops daemon (blocks; SIGTERM checkpoints and exits)
    python -m repro.serve daemon --config serve.json \\
        --socket /tmp/daris.sock --journal /tmp/daris.jsonl \\
        --checkpoint /tmp/daris.ckpt

    # client verbs against a running daemon
    python -m repro.serve submit --socket /tmp/daris.sock \\
        --task resnet18-hp0 --tenant teamA
    python -m repro.serve status --socket /tmp/daris.sock --seq 3
    python -m repro.serve cancel --socket /tmp/daris.sock --seq 3
    python -m repro.serve stats  --socket /tmp/daris.sock
    python -m repro.serve drain  --socket /tmp/daris.sock

    # offline: deterministic journal replay / durability audit / repair
    python -m repro.serve replay --config serve.json \\
        --journal /tmp/daris.jsonl
    python -m repro.serve audit  --journal /tmp/daris.jsonl
    python -m repro.serve fsck   --journal /tmp/daris.jsonl [--yes]
"""
from __future__ import annotations

import argparse
import json
import sys

from .client import DarisClient
from .config import build_server, load_config
from .daemon import ServeDaemon
from .journal import (audit_zero_lost, fsck_journal, read_journal,
                      repair_journal, to_trace_arrivals)


def _cmd_daemon(a) -> int:
    d = ServeDaemon(load_config(a.config), socket_path=a.socket,
                    journal_path=a.journal, checkpoint_path=a.checkpoint,
                    time_scale=a.time_scale, fsync=a.fsync)
    print(f"daris daemon: socket={a.socket} journal={a.journal}",
          flush=True)
    d.run()
    return 0


def _client_verb(a) -> int:
    c = DarisClient(a.socket)
    if a.verb == "submit":
        out = c.submit(a.task, tenant=a.tenant)
    elif a.verb == "status":
        out = c.status(a.seq)
    elif a.verb == "result":
        out = c.result(a.seq, timeout_s=a.timeout_s)
    elif a.verb == "cancel":
        out = c.cancel(a.seq)
    elif a.verb == "stats":
        out = c.stats()
    elif a.verb == "drain":
        out = c.drain()
    else:
        out = c.shutdown()
    print(json.dumps(out, indent=2, sort_keys=True))
    return 0


def _cmd_replay(a) -> int:
    """Deterministic replay: journaled traffic becomes TraceArrival input
    to a freshly built engine (same config, same seed). Recorded outages
    replay as plain load — chaos scenarios become regression scenarios."""
    records = read_journal(a.journal)
    arrivals = to_trace_arrivals(records, until_ms=a.until_ms)
    server = build_server(load_config(a.config), arrivals=arrivals)
    m = server.drain()
    print(json.dumps(m.summary(), indent=2, sort_keys=True))
    return 0


def _cmd_audit(a) -> int:
    lost = audit_zero_lost(read_journal(a.journal))
    if lost:
        print(f"LOST: {len(lost)} acknowledged submission(s) never "
              f"reached a terminal state: {lost}")
        return 1
    print("ok: every acknowledged submission reached a terminal state")
    return 0


def _cmd_fsck(a) -> int:
    """Classify journal damage; with ``--yes``, truncate mid-file
    corruption to the last valid prefix (destructive, hence the explicit
    confirmation — everything past the first bad line is lost)."""
    report = fsck_journal(a.journal)
    n = len(report["records"])
    if report["kind"] == "clean":
        print(f"ok: journal is clean ({n} records)")
        return 0
    if report["kind"] == "torn-tail":
        print(f"ok: torn tail at line {report['bad_line']} ({n} valid "
              f"records before it) — a normal crash artifact; readers "
              f"drop it, no repair needed")
        return 0
    print(f"CORRUPT: undecodable line {report['bad_line']} with valid "
          f"records after it; last valid prefix is "
          f"{report['valid_bytes']} bytes ({n} records)")
    if not a.yes:
        print("re-run with --yes to truncate to the last valid prefix "
              "(records at and beyond the damage are LOST)")
        return 1
    repair_journal(a.journal)
    print(f"repaired: truncated to {report['valid_bytes']} bytes "
          f"({n} records)")
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="repro.serve", description=__doc__)
    sub = p.add_subparsers(dest="verb", required=True)

    d = sub.add_parser("daemon", help="run the ops daemon (blocks)")
    d.add_argument("--config", required=True)
    d.add_argument("--socket", required=True)
    d.add_argument("--journal", required=True)
    d.add_argument("--checkpoint", default=None)
    d.add_argument("--time-scale", type=float, default=1.0,
                   help="virtual ms per wall ms (sim pacing)")
    d.add_argument("--fsync", action="store_true",
                   help="fsync the journal on every record")

    for verb in ("submit", "status", "result", "cancel", "stats",
                 "drain", "shutdown"):
        c = sub.add_parser(verb)
        c.add_argument("--socket", required=True)
        if verb == "submit":
            c.add_argument("--task", required=True)
            c.add_argument("--tenant", default=None)
        if verb in ("status", "result", "cancel"):
            c.add_argument("--seq", type=int, required=True)
        if verb == "result":
            c.add_argument("--timeout-s", type=float, default=30.0)

    r = sub.add_parser("replay", help="deterministic journal replay")
    r.add_argument("--config", required=True)
    r.add_argument("--journal", required=True)
    r.add_argument("--until-ms", type=float, default=None)

    au = sub.add_parser("audit", help="zero-lost durability audit")
    au.add_argument("--journal", required=True)

    fs = sub.add_parser("fsck", help="journal damage triage / repair")
    fs.add_argument("--journal", required=True)
    fs.add_argument("--yes", action="store_true",
                    help="truncate mid-file corruption to the last "
                         "valid prefix (destructive)")

    a = p.parse_args(argv)
    if a.verb == "daemon":
        return _cmd_daemon(a)
    if a.verb == "replay":
        return _cmd_replay(a)
    if a.verb == "audit":
        return _cmd_audit(a)
    if a.verb == "fsck":
        return _cmd_fsck(a)
    return _client_verb(a)


if __name__ == "__main__":
    sys.exit(main())
